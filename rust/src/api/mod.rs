//! The embeddable front door: build a [`Session`] fluently, run it, get a
//! typed [`RunResult`].
//!
//! ```ignore
//! use evosample::prelude::*;
//!
//! let report = SessionBuilder::new(
//!         "mlp_cifar10",
//!         DatasetConfig::SynthCifar { n: 2048, classes: 10, label_noise: 0.05, hard_frac: 0.2 },
//!     )
//!     .epochs(10)
//!     .batch_sizes(128, 32)
//!     .sampler(SamplerConfig::es_default())
//!     .sink(Box::new(ProgressSink::new()))
//!     .build()?
//!     .run()?;
//! println!("acc {:.2}%", report.accuracy_pct());
//! ```
//!
//! Ownership (DESIGN.md §6): the builder assembles a `RunConfig`, a data
//! split, a model runtime, and an [`EventBus`] of sinks; the `Session`
//! owns all four (the runtime optionally borrowed from the caller for
//! artifact reuse across sessions) and lends them to a fresh
//! `coordinator::engine::Engine` per `run()`. Sampler state is rebuilt
//! from config each run — through the open [`sampler::registry`], so
//! externally-registered policies work everywhere built-ins do — keeping
//! every run an independent trial.

pub mod events;
pub mod prelude;

pub use events::{Event, EventBus, EventSink, ProgressSink};

use crate::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use crate::coordinator::engine::Engine;
pub use crate::coordinator::engine::{EngineResume, EpochHook, RunSnapshot};
use crate::coordinator::TrainResult;
use crate::data::{self, SplitDataset};
use crate::runtime::{make_runtime, ModelRuntime};
use crate::sampler::{self, registry};

/// What one `Session::run` produces — the same typed report the
/// historical `coordinator::train` returned (accuracy, curves, cost
/// accounting, phase timers).
pub type RunResult = TrainResult;

/// The model runtime a session drives: built by the session, handed over
/// (`runtime`), or borrowed from the embedding application
/// (`runtime_mut`) so expensive artifact loads amortize across sessions.
enum RtSlot<'rt> {
    Owned(Box<dyn ModelRuntime>),
    Borrowed(&'rt mut (dyn ModelRuntime + 'rt)),
}

impl<'rt> RtSlot<'rt> {
    fn get(&mut self) -> &mut (dyn ModelRuntime + 'rt) {
        match self {
            RtSlot::Owned(b) => b.as_mut(),
            RtSlot::Borrowed(r) => &mut **r,
        }
    }
}

/// Fluent constructor for a [`Session`]: dataset → runtime → sampler →
/// engine mode → event sinks. Every knob defaults to the `RunConfig`
/// defaults; `build()` validates the assembled config.
pub struct SessionBuilder<'rt> {
    cfg: RunConfig,
    /// A registry-named sampler choice, resolved at `build()`.
    pending_sampler: Option<(String, registry::ParamBag)>,
    rt: Option<RtSlot<'rt>>,
    split: Option<SplitDataset>,
    bus: EventBus,
}

impl<'rt> SessionBuilder<'rt> {
    /// Start from a model name and dataset description.
    pub fn new(model: &str, dataset: DatasetConfig) -> SessionBuilder<'rt> {
        SessionBuilder::from_config(RunConfig::new("session", model, dataset))
    }

    /// Start from a fully-specified config (TOML, presets).
    pub fn from_config(cfg: RunConfig) -> SessionBuilder<'rt> {
        SessionBuilder {
            cfg,
            pending_sampler: None,
            rt: None,
            split: None,
            bus: EventBus::new(),
        }
    }

    /// Run name (lands in `RunResult::name` and metrics records).
    pub fn named(mut self, name: &str) -> Self {
        self.cfg.name = name.to_string();
        self
    }

    /// Selection policy by typed config.
    pub fn sampler(mut self, s: SamplerConfig) -> Self {
        self.pending_sampler = None;
        self.cfg.sampler = s;
        self
    }

    /// Selection policy by registry name — the route for externally
    /// registered policies. Unknown names/params error at `build()`.
    pub fn sampler_named(mut self, name: &str, params: &[(&str, f64)]) -> Self {
        self.pending_sampler = Some((name.to_string(), registry::bag(params)));
        self
    }

    pub fn epochs(mut self, epochs: usize) -> Self {
        self.cfg.epochs = epochs;
        self
    }

    /// Meta-batch B (drawn uniformly each step) and mini-batch b (kept
    /// for BP). `b == B` disables batch-level selection.
    pub fn batch_sizes(mut self, meta: usize, mini: usize) -> Self {
        self.cfg.meta_batch = meta;
        self.cfg.mini_batch = mini;
        self
    }

    /// Gradient-accumulation micro-batch (0 = off).
    pub fn micro_batch(mut self, micro: usize) -> Self {
        self.cfg.micro_batch = micro;
        self
    }

    /// Scoring cadence k (frequency tuning, DESIGN.md §8): run the
    /// scoring FP every k-th eligible step and select from cached weight
    /// tables in between. 1 (default) = the historical per-step scoring.
    pub fn score_every(mut self, k: usize) -> Self {
        self.cfg.score_every = k;
        self
    }

    /// Scoring-FP precision (DESIGN.md §9): `Exact` (default,
    /// bit-for-bit) or `Bf16` (rank from a bf16 weight shadow; stacks
    /// multiplicatively with `score_every`). The BP batch and eval are
    /// never affected.
    pub fn scoring_precision(mut self, p: crate::config::ScoringPrecision) -> Self {
        self.cfg.scoring_precision = p;
        self
    }

    /// Telemetry level (`off` default / `counters` / `trace`). Raised
    /// process-wide when the session runs — purely observational
    /// (DESIGN.md §11); numerics and event streams are identical at
    /// every level.
    pub fn telemetry(mut self, level: crate::config::TelemetryLevel) -> Self {
        self.cfg.telemetry = level;
        self
    }

    pub fn lr(mut self, schedule: LrSchedule) -> Self {
        self.cfg.lr = schedule;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Evaluate every `k` epochs (0 = only at the end).
    pub fn eval_every(mut self, k: usize) -> Self {
        self.cfg.eval_every = k;
        self
    }

    pub fn test_n(mut self, n: usize) -> Self {
        self.cfg.test_n = n;
        self
    }

    /// Engine mode: sequential data-parallel simulation with `n` workers.
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self.cfg.threaded_workers = false;
        self.cfg.sync_every = 0;
        self
    }

    /// Engine mode: `n` real threaded worker replicas, parameters
    /// averaged every `sync_every` local steps (0 = epoch boundaries
    /// only). Requires a runtime with `spawn_replica`.
    pub fn threaded(mut self, n: usize, sync_every: usize) -> Self {
        self.cfg.workers = n;
        self.cfg.threaded_workers = true;
        self.cfg.sync_every = sync_every;
        self
    }

    /// Arbitrary config access for knobs without a dedicated method.
    pub fn configure(mut self, f: impl FnOnce(&mut RunConfig)) -> Self {
        f(&mut self.cfg);
        self
    }

    /// Use this runtime instead of auto-detecting (XLA artifacts if
    /// present, else the native fallback).
    pub fn runtime(mut self, rt: Box<dyn ModelRuntime>) -> Self {
        self.rt = Some(RtSlot::Owned(rt));
        self
    }

    /// Borrow the caller's runtime (artifact reuse across sessions).
    pub fn runtime_mut(mut self, rt: &'rt mut (dyn ModelRuntime + 'rt)) -> Self {
        self.rt = Some(RtSlot::Borrowed(rt));
        self
    }

    /// Use this data split instead of generating one from the dataset
    /// config (seed `cfg.seed ^ 0xda7a_5eed`).
    pub fn split(mut self, split: SplitDataset) -> Self {
        self.split = Some(split);
        self
    }

    /// Subscribe an event sink (repeatable; invoked in subscription order).
    pub fn sink(mut self, sink: Box<dyn EventSink>) -> Self {
        self.bus.add(sink);
        self
    }

    /// Subscribe a closure sink.
    pub fn on_event(self, f: impl FnMut(&Event) + Send + 'static) -> Self {
        self.sink(Box::new(f))
    }

    /// Validate and assemble the session.
    pub fn build(self) -> anyhow::Result<Session<'rt>> {
        let mut cfg = self.cfg;
        if let Some((name, bag)) = &self.pending_sampler {
            cfg.sampler = registry::parse(name, bag).map_err(|e| anyhow::anyhow!("sampler: {e}"))?;
        }
        cfg.validate().map_err(|e| anyhow::anyhow!("config: {e}"))?;
        let split = match self.split {
            Some(s) => s,
            None => data::build(&cfg.dataset, cfg.test_n, cfg.seed ^ 0xda7a_5eed),
        };
        anyhow::ensure!(
            split.train.n == cfg.dataset.n(),
            "provided split has {} train samples but the config describes {}",
            split.train.n,
            cfg.dataset.n()
        );
        let rt = match self.rt {
            Some(slot) => slot,
            None => RtSlot::Owned(make_runtime(&cfg)?),
        };
        Ok(Session { cfg, rt, split, bus: self.bus })
    }
}

/// A configured, runnable training session. Each `run()` is an
/// independent trial: fresh sampler state from config, runtime
/// re-initialized from the seed.
pub struct Session<'rt> {
    cfg: RunConfig,
    rt: RtSlot<'rt>,
    split: SplitDataset,
    bus: EventBus,
}

impl<'rt> Session<'rt> {
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn data(&self) -> &SplitDataset {
        &self.split
    }

    /// Swap the selection policy for subsequent runs (method-comparison
    /// loops over one shared runtime + split).
    pub fn set_sampler(&mut self, s: SamplerConfig) {
        self.cfg.sampler = s;
    }

    /// Rename subsequent runs' reports.
    pub fn set_name(&mut self, name: &str) {
        self.cfg.name = name.to_string();
    }

    /// Subscribe another event sink.
    pub fn add_sink(&mut self, sink: Box<dyn EventSink>) {
        self.bus.add(sink);
    }

    /// Execute one full training run and return its typed report.
    pub fn run(&mut self) -> anyhow::Result<RunResult> {
        self.run_resumable(None, None)
    }

    /// [`Session::run`] with the engine's checkpoint/resume surface
    /// exposed: continue from an [`EngineResume`] instead of starting
    /// fresh, and/or observe every epoch boundary through an
    /// [`EpochHook`] (the serve scheduler's checkpoint writer and
    /// cancellation point). Sequential engine modes only — threaded
    /// workers reject both.
    pub fn run_resumable(
        &mut self,
        resume: Option<EngineResume>,
        hook: Option<Box<dyn EpochHook>>,
    ) -> anyhow::Result<RunResult> {
        self.cfg.validate().map_err(|e| anyhow::anyhow!("config: {e}"))?;
        // Sessions raise the process telemetry level, never lower it —
        // one `telemetry = "off"` job can't blind a server that scrapes.
        crate::obs::raise_level(self.cfg.telemetry.as_obs_level());
        let sampler = sampler::build(&self.cfg.sampler, self.split.train.n, self.cfg.epochs)?;
        let mut engine = Engine::new(&self.cfg, self.rt.get(), &self.split, sampler)
            .with_event_bus(&mut self.bus);
        if let Some(r) = resume {
            engine = engine.resume_from(r);
        }
        if let Some(h) = hook {
            engine = engine.with_epoch_hook(h);
        }
        engine.run()
    }

    /// Run `trials` independent seeds (seed, seed+1000, ...) on this
    /// session's split and runtime; restores the base seed afterwards.
    pub fn run_trials(&mut self, trials: usize) -> anyhow::Result<Vec<RunResult>> {
        let base = self.cfg.seed;
        let mut out = Vec::with_capacity(trials);
        for t in 0..trials {
            self.cfg.seed = base + 1000 * t as u64;
            let r = self.run();
            self.cfg.seed = base;
            out.push(r?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeRuntime;
    use std::sync::{Arc, Mutex};

    fn tiny_dataset() -> DatasetConfig {
        DatasetConfig::SynthCifar { n: 128, classes: 4, label_noise: 0.0, hard_frac: 0.2 }
    }

    fn tiny_builder<'rt>() -> SessionBuilder<'rt> {
        SessionBuilder::new("native", tiny_dataset())
            .epochs(2)
            .batch_sizes(32, 8)
            .test_n(64)
            .runtime(Box::new(NativeRuntime::new(3072, 8, 4)))
    }

    #[test]
    fn builder_runs_and_reports() {
        let r = tiny_builder()
            .named("unit")
            .sampler(SamplerConfig::es_default())
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r.name, "unit");
        assert_eq!(r.sampler, "es");
        assert_eq!(r.epochs, 2);
        assert!(r.final_eval.accuracy.is_finite());
    }

    #[test]
    fn builder_rejects_invalid_config() {
        let err = tiny_builder().batch_sizes(16, 32).build().unwrap_err().to_string();
        assert!(err.contains("mini_batch"), "{err}");
    }

    #[test]
    fn builder_rejects_unknown_named_sampler() {
        let err = tiny_builder().sampler_named("nope", &[]).build().unwrap_err().to_string();
        assert!(err.contains("unknown sampler"), "{err}");
    }

    #[test]
    fn events_flow_to_sinks() {
        let seen: Arc<Mutex<Vec<Event>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = seen.clone();
        let r = tiny_builder()
            // 4 epochs so the 5% annealing window leaves active epochs
            // and the scoring-FP stage (and its event) actually runs.
            .epochs(4)
            .sampler(SamplerConfig::es_default())
            .eval_every(1)
            .on_event(move |ev: &Event| sink.lock().unwrap().push(ev.clone()))
            .build()
            .unwrap()
            .run()
            .unwrap();
        let seen = seen.lock().unwrap();
        assert!(matches!(seen.first(), Some(Event::RunStart { .. })));
        assert!(matches!(seen.last(), Some(Event::RunEnd { .. })));
        let epoch_starts =
            seen.iter().filter(|e| matches!(e, Event::EpochStart { .. })).count();
        assert_eq!(epoch_starts, 4);
        let evals = seen.iter().filter(|e| matches!(e, Event::EvalDone { .. })).count();
        assert_eq!(evals, 4, "eval_every=1 over 4 epochs");
        // Batch-level ES in active epochs emits per-step selection events.
        assert!(seen.iter().any(|e| matches!(e, Event::SelectionMade { .. })));
        assert!(seen.iter().any(|e| matches!(e, Event::ScoringFp { .. })));
        // The report matches the event stream's final eval.
        if let Some(Event::RunEnd { accuracy, .. }) = seen.last() {
            assert_eq!(*accuracy, r.final_eval.accuracy);
        }
    }

    #[test]
    fn score_every_strides_scoring_and_tags_events() {
        let seen: Arc<Mutex<Vec<Event>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = seen.clone();
        let r = tiny_builder()
            .epochs(2)
            // anneal_frac 0 => every step is scoring-eligible.
            .sampler(SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.0 })
            .score_every(2)
            .on_event(move |ev: &Event| sink.lock().unwrap().push(ev.clone()))
            .build()
            .unwrap()
            .run()
            .unwrap();
        // 128/32 = 4 steps/epoch × 2 epochs = 8 steps; k=2 => 4 scoring FPs.
        assert_eq!(r.steps, 8);
        assert_eq!(r.cost.fp_passes, 4);
        assert_eq!(r.cost.fp_samples, 4 * 32);
        let seen = seen.lock().unwrap();
        let fp_events = seen.iter().filter(|e| matches!(e, Event::ScoringFp { .. })).count();
        assert_eq!(fp_events, 4);
        let flags: Vec<bool> = seen
            .iter()
            .filter_map(|e| match e {
                Event::SelectionMade { scored, .. } => Some(*scored),
                _ => None,
            })
            .collect();
        assert_eq!(flags, vec![true, false, true, false, true, false, true, false]);
    }

    #[test]
    fn run_trials_varies_seed_and_restores() {
        let mut session = tiny_builder().build().unwrap();
        let base_seed = session.config().seed;
        let rs = session.run_trials(2).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].seed, base_seed);
        assert_eq!(rs[1].seed, base_seed + 1000);
        assert_eq!(session.config().seed, base_seed);
    }

    #[test]
    fn split_mismatch_is_rejected() {
        let other = data::build(
            &DatasetConfig::SynthCifar { n: 64, classes: 4, label_noise: 0.0, hard_frac: 0.2 },
            16,
            0,
        );
        let err = tiny_builder().split(other).build().unwrap_err().to_string();
        assert!(err.contains("64"), "{err}");
    }
}
