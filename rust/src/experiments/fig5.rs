//! Fig. 5: the performance/speed trade-offs.
//! Left: b/B sweep for ES — lossless down to b/B=1/16; degradation below.
//! Right: pruning-ratio sweep for ESWP — a knee around r ≈ 0.2–0.3.

use crate::config::presets::{fig5_bb_sweep, fig5_prune_sweep, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;

use super::{make_runtime, mean_acc, run_config, total_cost, trials};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let rec = Recorder::new("fig5_tradeoffs")?;
    let n_trials = trials(scale);

    // Left panel: b/B sweep.
    let runs = fig5_bb_sweep(scale);
    table_header("Fig. 5 (left) — ES b/B sweep", &["run", "b/B", "acc%", "time saved"]);
    let mut rt = make_runtime(&runs[0])?;
    let mut base_cost = None;
    for cfg in &runs {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        let acc = mean_acc(&rs);
        let cost = total_cost(&rs);
        let ratio = format!("{}/{}", cfg.mini_batch, cfg.meta_batch);
        let saved = match &base_cost {
            None => "—".into(),
            Some(b) => super::fmt_saved(b, &cost),
        };
        println!("{:<22} | {ratio:>7} | {acc:5.1} | {saved}", cfg.name);
        if cfg.sampler.name() == "baseline" {
            base_cost = Some(cost);
        }
    }

    // Right panel: pruning-ratio sweep.
    let runs = fig5_prune_sweep(scale);
    table_header("Fig. 5 (right) — ESWP pruning-ratio sweep", &["run", "r", "acc%", "time saved"]);
    let mut rt = make_runtime(&runs[0])?;
    let mut es_cost = None;
    for cfg in &runs {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        let acc = mean_acc(&rs);
        let cost = total_cost(&rs);
        let r_tag = cfg.name.split('r').next_back().unwrap_or("?").to_string();
        let saved = match &es_cost {
            None => "—".into(),
            Some(b) => super::fmt_saved(b, &cost),
        };
        println!("{:<22} | {r_tag:>5} | {acc:5.1} | {saved}", cfg.name);
        if es_cost.is_none() {
            es_cost = Some(cost); // r=0 (plain ES) anchors the sweep
        }
    }
    Ok(())
}
