//! Registry-coherence catalogs, parsed out of the crate's own sources.
//!
//! The three `registry/*` rules check string literals at instrumentation
//! sites against authoritative name lists that already live in the code:
//!
//! * failpoint sites — the `pub const NAME: &str = "…";` items in
//!   `fault/mod.rs` (the same constants `fault::sites::ALL` collects),
//! * metric names — the constants in `obs/catalog.rs`,
//! * event names — the `api::events::Event` variants (snake-cased, the
//!   exact form `metrics::event_to_json` emits) plus the serve job
//!   lifecycle names in `serve/protocol.rs::LIFECYCLE_EVENTS`.
//!
//! Parsing the catalogs from source (rather than importing the consts)
//! keeps the linter honest about what is *written*, not what this build
//! happened to link — and keeps fixture tests able to supply synthetic
//! catalogs. An empty catalog is a hard error: a refactor that moved a
//! name list must break the lint run loudly, never make every check
//! vacuously pass.

use std::collections::BTreeSet;

use super::lexer::{lex, Tok, Token};

/// Source files the catalogs are extracted from (paths relative to
/// `rust/src`).
pub const FAULT_SITES_FILE: &str = "fault/mod.rs";
pub const METRIC_CATALOG_FILE: &str = "obs/catalog.rs";
pub const EVENT_ENUM_FILE: &str = "api/events.rs";
pub const LIFECYCLE_FILE: &str = "serve/protocol.rs";

/// The three name lists the `registry/*` rules check against.
#[derive(Clone, Debug)]
pub struct Catalogs {
    pub fault_sites: BTreeSet<String>,
    pub metric_names: BTreeSet<String>,
    pub event_names: BTreeSet<String>,
}

impl Catalogs {
    /// Build the catalogs by lexing the four source files, fetched
    /// through `read` (rel path → contents). Missing files or empty
    /// extraction results are errors.
    pub fn from_sources(
        read: impl Fn(&str) -> Option<String>,
    ) -> Result<Catalogs, String> {
        let src_of = |rel: &str| {
            read(rel).ok_or_else(|| format!("catalog source {rel} not found under the lint root"))
        };
        let fault_sites = const_str_values(&lex(&src_of(FAULT_SITES_FILE)?).tokens);
        let metric_names = const_str_values(&lex(&src_of(METRIC_CATALOG_FILE)?).tokens);
        let mut event_names: BTreeSet<String> =
            enum_variants(&lex(&src_of(EVENT_ENUM_FILE)?).tokens, "Event")
                .iter()
                .map(|v| snake_case(v))
                .collect();
        event_names
            .extend(array_str_values(&lex(&src_of(LIFECYCLE_FILE)?).tokens, "LIFECYCLE_EVENTS"));
        for (what, set, file) in [
            ("failpoint-site", &fault_sites, FAULT_SITES_FILE),
            ("metric-name", &metric_names, METRIC_CATALOG_FILE),
            ("event-name", &event_names, EVENT_ENUM_FILE),
        ] {
            if set.is_empty() {
                return Err(format!(
                    "{what} catalog extracted from {file} is empty — \
                     the registry rules would pass vacuously"
                ));
            }
        }
        Ok(Catalogs { fault_sites, metric_names, event_names })
    }
}

/// Collect the values of `const NAME: … str … = "value";` items — one
/// string literal between `const` and the terminating `;`, with `str`
/// somewhere in the type. Array consts like `ALL: &[&str] = &[A, B]`
/// reference the named constants (no literals), so they are skipped.
pub fn const_str_values(tokens: &[Token]) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if matches!(&tokens[i].tok, Tok::Ident(s) if s == "const") {
            let mut saw_str_type = false;
            let mut lits: Vec<&str> = Vec::new();
            let mut j = i + 1;
            while j < tokens.len() && tokens[j].tok != Tok::Punct(';') {
                match &tokens[j].tok {
                    Tok::Ident(s) if s == "str" => saw_str_type = true,
                    Tok::Str(s) => lits.push(s),
                    _ => {}
                }
                j += 1;
            }
            if saw_str_type && lits.len() == 1 {
                out.insert(lits[0].to_string());
            }
            i = j;
        }
        i += 1;
    }
    out
}

/// Collect the string literals in `NAME: … = &["a", "b", …];`.
pub fn array_str_values(tokens: &[Token], name: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let Some(start) = tokens
        .iter()
        .position(|t| matches!(&t.tok, Tok::Ident(s) if s == name))
    else {
        return out;
    };
    for t in &tokens[start..] {
        match &t.tok {
            Tok::Str(s) => {
                out.insert(s.clone());
            }
            Tok::Punct(';') => break,
            _ => {}
        }
    }
    out
}

/// Collect the variant names of `enum <enum_name> { … }`: identifiers at
/// brace depth 1, in variant-name position (fields and attribute
/// contents are deeper or skipped).
pub fn enum_variants(tokens: &[Token], enum_name: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 0usize;
    // Find `enum <enum_name>` then its opening brace.
    let mut found = false;
    while i + 1 < tokens.len() {
        if matches!(&tokens[i].tok, Tok::Ident(s) if s == "enum")
            && matches!(&tokens[i + 1].tok, Tok::Ident(s) if s == enum_name)
        {
            found = true;
            break;
        }
        i += 1;
    }
    if !found {
        return out;
    }
    while i < tokens.len() && tokens[i].tok != Tok::Punct('{') {
        i += 1;
    }
    if i >= tokens.len() {
        return out;
    }
    let mut depth = 1i32;
    let mut expect_variant = true;
    i += 1;
    while i < tokens.len() && depth > 0 {
        match &tokens[i].tok {
            Tok::Punct('{') | Tok::Punct('(') | Tok::Punct('[') => {
                depth += 1;
            }
            Tok::Punct('}') | Tok::Punct(')') | Tok::Punct(']') => {
                depth -= 1;
            }
            Tok::Punct(',') if depth == 1 => expect_variant = true,
            Tok::Punct('#') if depth == 1 => {
                // Variant attribute: skip its balanced `[…]` group so
                // attribute arguments never look like variant names.
                i += 1;
                continue;
            }
            Tok::Ident(name) if depth == 1 && expect_variant => {
                out.push(name.clone());
                expect_variant = false;
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// `RunStart` → `run_start` (the `metrics::event_to_json` convention).
pub fn snake_case(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 4);
    for (i, ch) in name.chars().enumerate() {
        if ch.is_ascii_uppercase() {
            if i > 0 {
                out.push('_');
            }
            out.push(ch.to_ascii_lowercase());
        } else {
            out.push(ch);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_str_extraction_skips_arrays_and_non_str() {
        let src = r#"
pub mod sites {
    /// Doc.
    pub const A: &str = "a.site";
    pub const B: &str = "b.site";
    pub const N: usize = 3;
    pub const ALL: &[&str] = &[A, B];
}
"#;
        let got = const_str_values(&lex(src).tokens);
        let want: BTreeSet<String> = ["a.site", "b.site"].iter().map(|s| s.to_string()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn array_values_extract_until_semicolon() {
        let src = r#"
pub const LIFECYCLE_EVENTS: &[&str] = &["queued", "admitted"];
pub const OTHER: &str = "not.collected";
"#;
        let got = array_str_values(&lex(src).tokens, "LIFECYCLE_EVENTS");
        let want: BTreeSet<String> = ["queued", "admitted"].iter().map(|s| s.to_string()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn enum_variants_skip_fields_and_attrs() {
        let src = r#"
pub enum Event {
    /// Doc comment.
    RunStart { name: String, epochs: usize },
    #[deprecated(note = "NotAVariant")]
    ScoringFp { elapsed: Duration },
    RunEnd { steps: u64 },
}
pub enum Other { X, Y }
"#;
        let got = enum_variants(&lex(src).tokens, "Event");
        assert_eq!(got, vec!["RunStart", "ScoringFp", "RunEnd"]);
    }

    #[test]
    fn snake_case_matches_event_to_json_convention() {
        assert_eq!(snake_case("RunStart"), "run_start");
        assert_eq!(snake_case("ScoringFp"), "scoring_fp");
        assert_eq!(snake_case("EvalDone"), "eval_done");
        assert_eq!(snake_case("tick"), "tick");
    }

    #[test]
    fn real_crate_catalogs_extract_nonempty() {
        let root = crate::analysis::default_src_root();
        let read = |rel: &str| std::fs::read_to_string(root.join(rel)).ok();
        let cats = Catalogs::from_sources(read).expect("catalogs from the real tree");
        assert!(cats.fault_sites.contains("checkpoint.save"));
        assert!(cats.metric_names.contains("engine.steps"));
        assert!(cats.event_names.contains("run_start"), "{:?}", cats.event_names);
        assert!(cats.event_names.contains("queued"), "{:?}", cats.event_names);
    }
}
