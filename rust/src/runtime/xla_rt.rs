//! XlaRuntime: loads AOT HLO-text artifacts and executes them via PJRT.
//!
//! This is the production request path: `HloModuleProto::from_text_file`
//! → `PjRtClient::compile` → `execute`. HLO *text* is the interchange
//! format because the image's xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit-instruction-id protos; the text parser reassigns ids (see
//! aot.py / /opt/xla-example/README.md).
//!
//! Parameters and optimizer state stay **literal-resident** across steps:
//! the train_step output tuple is decomposed without copy
//! (`literal_decompose_tuple`) and its params/m/v elements are fed straight
//! back as the next step's inputs. This avoids the Literal↔Vec<f32> round
//! trip per step that otherwise dominates small-model training on the CPU
//! backend (≈3×param_count copied each way) — the headline L3 optimization
//! in EXPERIMENTS.md §Perf. Host vectors are materialized only on demand
//! (`get_params`/`set_params`, checkpointing, distributed sync).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::manifest::{Manifest, ModelEntry, XDtype};
use super::{BatchX, ModelRuntime, StepOutput};

/// Configure XLA's CPU backend for the available parallelism, once,
/// before the first client is created. On low-core boxes XLA's
/// multi-threaded Eigen contractions busy-wait and collapse throughput
/// (measured 14x on batch-128 train steps at nproc=1 — EXPERIMENTS.md
/// §Perf); respect a user-provided XLA_FLAGS if already set.
fn configure_xla_flags() {
    static ONCE: std::sync::OnceLock<()> = std::sync::OnceLock::new();
    ONCE.get_or_init(|| {
        if std::env::var_os("XLA_FLAGS").is_none() {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            if cores <= 2 {
                std::env::set_var("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false");
            }
        }
    });
}

/// Compile one HLO text artifact on a PJRT client.
fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

/// Execute and unpack the (return_tuple=True) single tuple output.
fn run_tuple<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    args: &[L],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<L>(args).map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape f32{dims:?}: {e:?}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape i32{dims:?}: {e:?}"))
}

pub struct XlaRuntime {
    entry: ModelEntry,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    init_exe: xla::PjRtLoadedExecutable,
    train_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    fwd_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    eval_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    params: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    step: f32,
}

fn zeros_lit(n: usize) -> xla::Literal {
    xla::Literal::vec1(&vec![0.0f32; n])
}

impl XlaRuntime {
    /// Load every artifact of `model` from the manifest and compile.
    pub fn load(manifest: &Manifest, model: &str) -> Result<XlaRuntime> {
        let entry = manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest (re-run `make artifacts`)"))?
            .clone();
        configure_xla_flags();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let init_exe = compile(&client, &entry.init).context("init artifact")?;
        let mut train_exes = BTreeMap::new();
        for (&n, path) in &entry.train_step {
            train_exes.insert(n, compile(&client, path).context("train_step artifact")?);
        }
        let mut fwd_exes = BTreeMap::new();
        for (&n, path) in &entry.loss_fwd {
            fwd_exes.insert(n, compile(&client, path).context("loss_fwd artifact")?);
        }
        let mut eval_exes = BTreeMap::new();
        for (&n, path) in &entry.eval_step {
            eval_exes.insert(n, compile(&client, path).context("eval artifact")?);
        }
        let pc = entry.param_count;
        Ok(XlaRuntime {
            entry,
            client,
            init_exe,
            train_exes,
            fwd_exes,
            eval_exes,
            params: zeros_lit(pc),
            m: zeros_lit(pc),
            v: zeros_lit(pc),
            step: 0.0,
        })
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn x_literal(&self, x: BatchX<'_>, n: usize) -> Result<xla::Literal> {
        let mut dims: Vec<i64> = vec![n as i64];
        dims.extend(self.entry.x_shape.iter().map(|&d| d as i64));
        let per = self.entry.x_len();
        match (x, &self.entry.x_dtype) {
            (BatchX::F32(v), XDtype::F32) => {
                ensure!(v.len() == n * per, "x len {} != {}", v.len(), n * per);
                lit_f32(v, &dims)
            }
            (BatchX::I32(v), XDtype::I32) => {
                ensure!(v.len() == n * per, "x len {} != {}", v.len(), n * per);
                lit_i32(v, &dims)
            }
            _ => bail!("batch modality does not match model {}", self.entry.name),
        }
    }

    fn y_literal(&self, y: &[i32], n: usize) -> Result<xla::Literal> {
        let per = self.entry.y_len();
        ensure!(y.len() == n * per, "y len {} != {}", y.len(), n * per);
        let dims: Vec<i64> = if self.entry.y_shape.is_empty() {
            vec![n as i64]
        } else {
            let mut d: Vec<i64> = vec![n as i64];
            d.extend(self.entry.y_shape.iter().map(|&s| s as i64));
            d
        };
        lit_i32(y, &dims)
    }
}

impl ModelRuntime for XlaRuntime {
    fn param_count(&self) -> usize {
        self.entry.param_count
    }

    fn init(&mut self, seed: i32) -> Result<()> {
        let mut out = run_tuple(&self.init_exe, &[xla::Literal::scalar(seed)])?;
        ensure!(!out.is_empty(), "init output");
        let params = out.remove(0);
        ensure!(params.element_count() == self.entry.param_count, "init param count");
        self.params = params;
        self.m = zeros_lit(self.entry.param_count);
        self.v = zeros_lit(self.entry.param_count);
        self.step = 0.0;
        Ok(())
    }

    fn loss_fwd_into(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        n: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let exe = self
            .fwd_exes
            .get(&n)
            .ok_or_else(|| anyhow!("{}: no loss_fwd artifact for n={n}", self.entry.name))?;
        let xl = self.x_literal(x, n)?;
        let yl = self.y_literal(y, n)?;
        let args: [&xla::Literal; 3] = [&self.params, &xl, &yl];
        let res = run_tuple(exe, &args)?;
        // The device→host literal readback allocates regardless; append
        // it so callers keep the shared-buffer contract.
        let losses = res[0].to_vec::<f32>().map_err(|e| anyhow!("losses: {e:?}"))?;
        out.extend_from_slice(&losses);
        Ok(())
    }

    fn train_step(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
    ) -> Result<StepOutput> {
        let exe = self
            .train_exes
            .get(&n)
            .ok_or_else(|| anyhow!("{}: no train_step artifact for n={n}", self.entry.name))?;
        ensure!(weights.len() == n, "weights len");
        let xl = self.x_literal(x, n)?;
        let yl = self.y_literal(y, n)?;
        let wl = xla::Literal::vec1(weights);
        let lrl = xla::Literal::scalar(lr);
        let stepl = xla::Literal::scalar(self.step);
        let args: [&xla::Literal; 8] =
            [&self.params, &self.m, &self.v, &xl, &yl, &wl, &lrl, &stepl];
        let mut out = run_tuple(exe, &args)?;
        ensure!(out.len() == 5, "train_step arity {}", out.len());
        // Keep the state literal-resident: no host round-trip.
        let mean_loss = out[4]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("mean loss: {e:?}"))?;
        let losses = out[3].to_vec::<f32>().map_err(|e| anyhow!("losses: {e:?}"))?;
        self.v = out.swap_remove(2);
        self.m = out.swap_remove(1);
        self.params = out.swap_remove(0);
        self.step += 1.0;
        Ok(StepOutput { losses, mean_loss })
    }

    fn eval(&mut self, x: BatchX<'_>, y: &[i32], n: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self
            .eval_exes
            .get(&n)
            .ok_or_else(|| anyhow!("{}: no eval artifact for n={n}", self.entry.name))?;
        let xl = self.x_literal(x, n)?;
        let yl = self.y_literal(y, n)?;
        let args: [&xla::Literal; 3] = [&self.params, &xl, &yl];
        let out = run_tuple(exe, &args)?;
        ensure!(out.len() == 2, "eval arity");
        let losses = out[0].to_vec::<f32>().map_err(|e| anyhow!("losses: {e:?}"))?;
        let correct = out[1].to_vec::<f32>().map_err(|e| anyhow!("correct: {e:?}"))?;
        Ok((losses, correct))
    }

    fn train_sizes(&self) -> Vec<usize> {
        self.train_exes.keys().copied().collect()
    }

    fn fwd_size(&self) -> usize {
        self.fwd_exes.keys().next_back().copied().unwrap_or(0)
    }

    fn eval_size(&self) -> usize {
        self.eval_exes.keys().next_back().copied().unwrap_or(0)
    }

    fn get_params(&mut self) -> Result<Vec<f32>> {
        self.params.to_vec::<f32>().map_err(|e| anyhow!("params: {e:?}"))
    }

    fn set_params(&mut self, params: &[f32]) -> Result<()> {
        ensure!(params.len() == self.entry.param_count, "param count");
        self.params = xla::Literal::vec1(params);
        Ok(())
    }

    fn flops_per_sample_fwd(&self) -> u64 {
        self.entry.flops_per_sample_fwd
    }

    fn spawn_replica(&self) -> Result<Box<dyn ModelRuntime + Send>> {
        // PJRT executables and device-resident literals are bound to the
        // client that compiled them; duplicating them per thread would
        // need one client (and one artifact re-compile) per replica.
        bail!(
            "XlaRuntime does not support threaded replicas: PJRT state is \
             client-bound ({}); use the sequential data-parallel simulation \
             (threaded_workers = false) or the NativeRuntime",
            self.entry.name
        )
    }
}

/// The standalone L1 dual-EMA table-refresh kernel (`es_update_n{N}`),
/// used for dense score-table refreshes at epoch boundaries. The rust
/// scalar loop in `sampler::evolved` handles scattered per-step updates;
/// this kernel demonstrates (and benches) the fused path for web-scale
/// tables, chunked through the artifact's fixed block size.
pub struct EsUpdateKernel {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    block: usize,
}

impl EsUpdateKernel {
    pub fn load(manifest: &Manifest) -> Result<EsUpdateKernel> {
        let sizes = manifest
            .kernels
            .get("es_update")
            .ok_or_else(|| anyhow!("manifest has no es_update kernel"))?;
        let (&block, path) =
            sizes.iter().next_back().ok_or_else(|| anyhow!("empty es_update entry"))?;
        configure_xla_flags();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
        let exe = compile(&client, path)?;
        Ok(EsUpdateKernel { client, exe, block })
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Fused (s, w) refresh over a full table; `mask[i] = 1.0` applies the
    /// update to entry i. Tables of any size are processed in `block`-sized
    /// chunks with a zero-padded tail.
    pub fn refresh(
        &self,
        s: &mut [f32],
        w: &mut [f32],
        losses: &[f32],
        mask: &[f32],
        beta1: f32,
        beta2: f32,
    ) -> Result<()> {
        let n = s.len();
        ensure!(w.len() == n && losses.len() == n && mask.len() == n, "table lengths");
        let betas = xla::Literal::vec1(&[beta1, beta2]);
        let b = self.block;
        let mut buf_s = vec![0.0f32; b];
        let mut buf_w = vec![0.0f32; b];
        let mut buf_l = vec![0.0f32; b];
        let mut buf_m = vec![0.0f32; b];
        let mut off = 0;
        while off < n {
            let len = b.min(n - off);
            buf_s[..len].copy_from_slice(&s[off..off + len]);
            buf_w[..len].copy_from_slice(&w[off..off + len]);
            buf_l[..len].copy_from_slice(&losses[off..off + len]);
            buf_m[..len].copy_from_slice(&mask[off..off + len]);
            buf_m[len..].iter_mut().for_each(|x| *x = 0.0); // pad: no-op
            // Arg order matches aot.py's `fn(s, w, l, mask, betas)`.
            let args = vec![
                xla::Literal::vec1(&buf_s),
                xla::Literal::vec1(&buf_w),
                xla::Literal::vec1(&buf_l),
                xla::Literal::vec1(&buf_m),
                betas.clone(),
            ];
            let out = run_tuple(&self.exe, &args)?;
            let s2 = out[0].to_vec::<f32>().map_err(|e| anyhow!("s': {e:?}"))?;
            let w2 = out[1].to_vec::<f32>().map_err(|e| anyhow!("w': {e:?}"))?;
            s[off..off + len].copy_from_slice(&s2[..len]);
            w[off..off + len].copy_from_slice(&w2[..len]);
            off += len;
        }
        Ok(())
    }
}

// NOTE ON Clone FOR LITERAL: xla::Literal implements Clone via C-side copy.
// The betas literal is tiny; cloning per chunk is negligible.

#[cfg(test)]
mod tests {
    //! Unit tests here only cover argument validation; real end-to-end
    //! XLA execution is exercised by tests/xla_integration.rs (gated on
    //! artifacts/ being built).

    use super::*;

    #[test]
    fn manifest_missing_model_is_clear_error() {
        let m = Manifest {
            dir: std::path::PathBuf::from("."),
            models: Default::default(),
            kernels: Default::default(),
        };
        let err = match XlaRuntime::load(&m, "nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("load should fail"),
        };
        assert!(err.contains("not in manifest"), "{err}");
    }
}
