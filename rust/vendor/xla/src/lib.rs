//! API-compatible stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The container image for this repo does not ship the XLA extension, so
//! this crate keeps `runtime::xla_rt` compiling unchanged while every
//! operation that would need a real PJRT device errors out with a clear
//! message. `Literal` is implemented for real (host-side tensors) because
//! construction must be infallible; clients, compilation and execution
//! fail at `PjRtClient::cpu()`, the first call on every load path.
//!
//! To run against real artifacts, replace this path dependency with the
//! actual `xla` crate (see DESIGN.md §5); no source changes are needed.

const UNAVAILABLE: &str =
    "XLA/PJRT backend unavailable: this build links the in-tree stub `xla` crate. \
     Replace rust/vendor/xla with the real xla-rs bindings (DESIGN.md §5) to execute \
     HLO artifacts; the NativeRuntime path is fully functional without them.";

/// Stub error type; formatted with `{:?}` at call sites.
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side tensor storage for the element types the runtime moves.
#[derive(Clone, Debug, PartialEq)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host literal: flat data + dims. Fully functional (the runtime builds
/// literals before execution, which must not fail).
#[derive(Clone, Debug)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

/// Element types a `Literal` can hold / yield.
pub trait NativeType: Copy + Sized {
    fn store(v: &[Self]) -> Storage;
    fn load(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn store(v: &[Self]) -> Storage {
        Storage::F32(v.to_vec())
    }
    fn load(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.storage {
            Storage::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn store(v: &[Self]) -> Storage {
        Storage::I32(v.to_vec())
    }
    fn load(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.storage {
            Storage::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], storage: T::store(v) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), storage: T::store(&[v]) }
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} incompatible with {} elements",
                dims,
                self.element_count()
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(self)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::load(self)?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error("stub literal is never a tuple".into()))
    }

    pub fn shape_dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module handle. Parsing requires the XLA extension.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. `cpu()` is the first call on every load path and
/// is where the stub reports itself.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape_dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7i32).get_first_element::<i32>().unwrap(), 7);
        assert!(Literal::scalar(7i32).to_vec::<f32>().is_err());
    }

    #[test]
    fn device_paths_error_clearly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
