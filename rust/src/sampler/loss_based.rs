//! "Loss" baseline (Katharopoulos & Fleuret 2017) — paper Eq. 2.3:
//! batch-level sampling with probability proportional to the *current*
//! loss, no history. Equivalent to ES with β1 = β2 = 0 (Prop. 3.1), kept
//! as an independent implementation so the equivalence is testable.

use super::{json_to_table, table_to_json, weights, Sampler, Selection};
use crate::util::json::{obj, Json};
use crate::util::Pcg64;

pub struct LossSampler {
    /// Most recent loss per sample (init 1/n like ES for a fair cold start).
    last: Vec<f32>,
    scratch: Vec<f32>,
}

impl LossSampler {
    pub fn new(n: usize) -> Self {
        LossSampler { last: vec![1.0 / n as f32; n], scratch: Vec::new() }
    }
}

impl Sampler for LossSampler {
    fn name(&self) -> &'static str {
        "loss"
    }

    fn n(&self) -> usize {
        self.last.len()
    }

    fn needs_meta_losses(&self, _epoch: usize) -> bool {
        true
    }

    fn observe_meta(&mut self, indices: &[u32], losses: &[f32], _epoch: usize) {
        for (&i, &l) in indices.iter().zip(losses) {
            self.last[i as usize] = l;
        }
    }

    fn select(&mut self, meta: &[u32], mini: usize, _epoch: usize, rng: &mut Pcg64) -> Selection {
        if mini >= meta.len() {
            return Selection::unweighted(meta.to_vec());
        }
        self.scratch.clear();
        self.scratch.extend(meta.iter().map(|&i| self.last[i as usize]));
        let picked = weights::sample_without_replacement(&self.scratch, mini, rng);
        Selection::unweighted(picked.into_iter().map(|p| meta[p as usize]).collect())
    }

    // Batch-level only: selection state is per-shard-local by construction
    // (a worker only selects within its own shard), so no §D.5 sync.

    fn state_json(&self) -> Option<Json> {
        Some(obj(vec![("last", table_to_json(&self.last))]))
    }

    fn restore_state(&mut self, state: &Json) -> anyhow::Result<()> {
        let n = self.n();
        self.last = json_to_table(
            state.get("last").ok_or_else(|| anyhow::anyhow!("loss state: missing last"))?,
            n,
        )?;
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::evolved::Evolved;

    #[test]
    fn tracks_only_current_loss() {
        let mut s = LossSampler::new(4);
        s.observe_meta(&[2], &[3.0], 0);
        s.observe_meta(&[2], &[0.5], 0);
        assert_eq!(s.last[2], 0.5, "no history: overwritten");
    }

    #[test]
    fn equivalent_to_es_with_zero_betas() {
        // After identical observations, the sampling weights must match.
        let mut loss = LossSampler::new(8);
        let mut es0 = Evolved::new(8, 10, 0.0, 0.0, 0.0, 0.0);
        let idx: Vec<u32> = (0..8).collect();
        let rng = Pcg64::new(9);
        for t in 0..5 {
            let ls: Vec<f32> = (0..8).map(|i| ((i + t) % 8) as f32 + 0.1).collect();
            loss.observe_meta(&idx, &ls, 1);
            es0.observe_meta(&idx, &ls, 1);
        }
        assert_eq!(loss.last, es0.weights_table());
        // And identical RNG draws give identical selections.
        let a = loss.select(&idx, 3, 1, &mut rng.clone());
        let b = es0.select(&idx, 3, 1, &mut rng.clone());
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn prefers_high_loss() {
        let mut s = LossSampler::new(10);
        let idx: Vec<u32> = (0..10).collect();
        let losses: Vec<f32> = (0..10).map(|i| if i == 7 { 50.0 } else { 0.1 }).collect();
        s.observe_meta(&idx, &losses, 0);
        let mut rng = Pcg64::new(1);
        let hits = (0..300).filter(|_| s.select(&idx, 1, 0, &mut rng).indices[0] == 7).count();
        assert!(hits > 270, "hits={hits}");
    }
}
