"""L2 correctness: model zoo shapes, gradients, and kernel-vs-ref parity.

Each model must (a) produce finite per-sample losses of the right shape,
(b) produce identical losses whether routed through the Pallas kernels or
the pure-jnp refs, (c) train (loss decreases on a tiny overfit task), and
(d) keep the uniform train_step contract that the rust runtime assumes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ALL_MODELS = list(M.DEFAULT_OPTS)
FAST_MODELS = ["mlp_cifar10", "cnn_small_c10", "txf_nlu", "txf_lm", "mae_mlp"]


def _batch(model, n, seed=0):
    spec = model.spec
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if spec.x_dtype == "f32":
        x = jax.random.normal(k1, spec.x_batch_shape(n))
    else:
        x = jax.random.randint(k1, spec.x_batch_shape(n), 0, model.vocab)
    hi = max(spec.classes, 2)
    y = jax.random.randint(k2, spec.y_batch_shape(n), 0, hi)
    return x, y


@pytest.mark.parametrize("name", ALL_MODELS)
def test_loss_shape_and_finite(name):
    model = M.make_model(name)
    params = model.init_params(jax.random.PRNGKey(0))
    x, y = _batch(model, 8)
    losses = model.per_sample_loss(params, x, y)
    assert losses.shape == (8,)
    assert np.all(np.isfinite(np.asarray(losses)))
    assert np.all(np.asarray(losses) >= -1e-5)


@pytest.mark.parametrize("name", FAST_MODELS)
def test_kernel_vs_ref_model_parity(name):
    """The same model lowered with kernels and with refs must agree."""
    mk = M.make_model(name, use_kernels=True)
    mr = M.make_model(name, use_kernels=False)
    params = mk.init_params(jax.random.PRNGKey(1))
    x, y = _batch(mk, 8, seed=1)
    lk = mk.per_sample_loss(params, x, y)
    lr = mr.per_sample_loss(params, x, y)
    np.testing.assert_allclose(lk, lr, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ALL_MODELS)
def test_metrics_contract(name):
    model = M.make_model(name)
    params = model.init_params(jax.random.PRNGKey(0))
    x, y = _batch(model, 8)
    losses, correct = model.metrics(params, x, y)
    assert losses.shape == (8,) and correct.shape == (8,)
    c = np.asarray(correct)
    assert np.all((c >= 0) & (c <= 1))


@pytest.mark.parametrize("name", FAST_MODELS)
def test_train_step_decreases_loss(name):
    """A few steps on one fixed batch must overfit it."""
    model = M.make_model(name)
    fns = M.build_fns(model, M.DEFAULT_OPTS[name])
    x, y = _batch(model, 8, seed=2)
    flat = fns["flat0"]
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    w = jnp.ones((8,))
    step_fn = jax.jit(fns["train_step"])
    first = None
    lr = 1e-2 if M.DEFAULT_OPTS[name].kind == "sgdm" else 1e-3
    # MAE's per-step mask is derived from `step`; hold it fixed so the
    # objective is deterministic and the overfit check is meaningful.
    for i in range(12):
        step_val = 0 if name == "mae_mlp" else i
        flat, m, v, losses, mean = step_fn(
            flat, m, v, x, y, w, jnp.float32(lr), jnp.float32(step_val)
        )
        if first is None:
            first = float(mean)
    assert float(mean) < first, f"{name}: {first} -> {float(mean)}"


@pytest.mark.parametrize("name", FAST_MODELS)
def test_train_step_losses_are_per_sample(name):
    """train_step's aux losses equal loss_fwd on the same inputs."""
    model = M.make_model(name)
    fns = M.build_fns(model, M.DEFAULT_OPTS[name])
    x, y = _batch(model, 8, seed=3)
    flat = fns["flat0"]
    z = jnp.zeros_like(flat)
    _, _, _, losses, _ = fns["train_step"](
        flat, z, z, x, y, jnp.ones((8,)), jnp.float32(0.0), jnp.float32(0.0)
    )
    (fwd,) = fns["loss_fwd"](flat, x, y)
    np.testing.assert_allclose(losses, fwd, rtol=1e-5, atol=1e-6)


def test_weighted_step_ignores_zero_weight_samples():
    """With weight 0, a sample must not influence the gradient."""
    model = M.make_model("mlp_cifar10")
    fns = M.build_fns(model, M.DEFAULT_OPTS["mlp_cifar10"])
    x, y = _batch(model, 8, seed=4)
    flat = fns["flat0"]
    z = jnp.zeros_like(flat)
    w = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    out_w = fns["train_step"](flat, z, z, x, y, w, jnp.float32(0.1), jnp.float32(0))[0]
    # Same step with the zero-weight samples replaced by garbage.
    x2 = x.at[4:].set(jax.random.normal(jax.random.PRNGKey(9), x[4:].shape) * 50)
    out_g = fns["train_step"](flat, z, z, x2, y, w, jnp.float32(0.1), jnp.float32(0))[0]
    np.testing.assert_allclose(out_w, out_g, rtol=1e-5, atol=1e-6)


def test_init_is_seed_deterministic_and_varies():
    model = M.make_model("mlp_cifar10")
    fns = M.build_fns(model, M.DEFAULT_OPTS["mlp_cifar10"])
    (a,) = fns["init"](jnp.int32(7))
    (b,) = fns["init"](jnp.int32(7))
    (c,) = fns["init"](jnp.int32(8))
    np.testing.assert_array_equal(a, b)
    assert not np.allclose(a, c)


def test_sgdm_vs_adamw_distinct():
    """Sanity: the two optimizers produce different updates."""
    flat = jnp.ones((16,))
    g = jnp.full((16,), 0.5)
    m = jnp.zeros((16,))
    v = jnp.zeros((16,))
    sg = M.apply_optimizer(M.OptSpec("sgdm"), flat, m, v, g, 0.1, 0.0)[0]
    ad = M.apply_optimizer(M.OptSpec("adamw"), flat, m, v, g, 0.1, 0.0)[0]
    assert not np.allclose(sg, ad)


def test_adamw_bias_correction_first_step():
    """First AdamW step ≈ lr * sign(g) for small eps."""
    flat = jnp.zeros((8,))
    g = jnp.array([1.0, -1, 2, -2, 0.5, -0.5, 3, -3])
    m = jnp.zeros((8,))
    v = jnp.zeros((8,))
    out = M.apply_optimizer(M.OptSpec("adamw", eps=1e-12), flat, m, v, g, 0.1, 0.0)[0]
    np.testing.assert_allclose(out, -0.1 * np.sign(g), rtol=1e-5, atol=1e-6)


def test_mae_mask_determinism_per_step():
    model = M.make_model("mae_mlp")
    params = model.init_params(jax.random.PRNGKey(0))
    x, y = _batch(model, 4)
    a = model.per_sample_loss(params, x, y, step=jnp.int32(5))
    b = model.per_sample_loss(params, x, y, step=jnp.int32(5))
    c = model.per_sample_loss(params, x, y, step=jnp.int32(6))
    np.testing.assert_array_equal(a, b)
    assert not np.allclose(a, c)


def test_transformer_causal_mask_respected():
    """Perturbing future tokens must not change earlier LM logits."""
    model = M.make_model("txf_lm")
    params = model.init_params(jax.random.PRNGKey(0))
    x, _ = _batch(model, 2, seed=5)
    logits_a = model.lm_logits(params, x)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % model.vocab)
    logits_b = model.lm_logits(params, x2)
    np.testing.assert_allclose(
        logits_a[:, : model.seq - 1], logits_b[:, : model.seq - 1], rtol=2e-4, atol=2e-4
    )
