//! Fig. 6 + 7: the (β1, β2) landscape for ES. Coarse grid (Fig. 6) and a
//! dense grid around the default (0.2, 0.9) (Fig. 7) — the paper's claim
//! is local optimality of the defaults and graceful degradation elsewhere
//! (corners reduce to Loss (0,0) and Baseline (1,1)).

use crate::config::presets::{fig6_beta_grid, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;

use super::{make_runtime, mean_acc, run_config, trials};

pub fn run(scale: Scale, dense: bool) -> anyhow::Result<()> {
    let grid = fig6_beta_grid(scale, dense);
    let rec = Recorder::new(if dense { "fig7_betas_dense" } else { "fig6_betas" })?;
    let n_trials = trials(scale);
    table_header(
        if dense { "Fig. 7 — dense beta grid" } else { "Fig. 6 — beta grid" },
        &["beta1", "beta2", "acc%"],
    );
    let mut rt = make_runtime(&grid[0].2)?;
    let mut best = (0.0f32, 0.0f32, f64::MIN);
    for (b1, b2, cfg) in &grid {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        let acc = mean_acc(&rs);
        println!("{b1:5.2} | {b2:5.2} | {acc:5.1}");
        if acc > best.2 {
            best = (*b1, *b2, acc);
        }
    }
    println!("best: (beta1, beta2) = ({}, {}) at {:.1}%", best.0, best.1, best.2);
    Ok(())
}
