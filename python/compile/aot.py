"""AOT lowering: JAX (L2+L1) → HLO text artifacts + manifest.json.

Run once at build time (``make artifacts``); the rust coordinator then loads
``artifacts/*.hlo.txt`` through PJRT and python never appears on the
training path again.

Interchange is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every model emits the uniform artifact family from model.build_fns, at the
batch sizes each experiment needs (meta-batch B for baseline/scoring,
mini-batch b for selected BP, sweep sizes for Fig. 5):

  {model}_init.hlo.txt
  {model}_loss_fwd_n{B}.hlo.txt
  {model}_train_step_n{b}.hlo.txt
  {model}_eval_n{E}.hlo.txt

plus the standalone L1 table-refresh kernel ``es_update_n{N}.hlo.txt``.

``manifest.json`` records shapes/dtypes/param counts/FLOP estimates so the
rust runtime stays model-agnostic.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.es_update import es_update


# ---------------------------------------------------------------------------
# Batch-size plan per model (see DESIGN.md §4 for the experiment mapping)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArtifactPlan:
    model: str
    train_steps: tuple[int, ...]  # mini/meta batch sizes for train_step
    loss_fwds: tuple[int, ...]  # meta-batch sizes for scoring FP
    evals: tuple[int, ...]  # eval chunk sizes


PLANS: dict[str, ArtifactPlan] = {
    # Fig. 5 sweeps b/B on the cheap model => many train_step sizes.
    "mlp_cifar10": ArtifactPlan("mlp_cifar10", (4, 8, 16, 32, 64, 128), (128,), (256,)),
    "cnn_small_c10": ArtifactPlan("cnn_small_c10", (32, 128), (128,), (256,)),
    "cnn_small_c100": ArtifactPlan("cnn_small_c100", (32, 128), (128,), (256,)),
    "cnn_deep_c100": ArtifactPlan("cnn_deep_c100", (64, 128), (128,), (256,)),
    "txf_cls": ArtifactPlan("txf_cls", (16, 64), (64,), (128,)),
    "txf_nlu": ArtifactPlan("txf_nlu", (16, 64), (64,), (128,)),
    "txf_lm": ArtifactPlan("txf_lm", (8, 32), (32,), (32,)),
    "txf_lm_large": ArtifactPlan("txf_lm_large", (4, 16), (16,), (16,)),
    "mae_mlp": ArtifactPlan("mae_mlp", (64, 256), (256,), (256,)),
}

# A fast subset for `make artifacts QUICK=1` / CI-style smoke runs.
QUICK_MODELS = ("mlp_cifar10",)

ES_UPDATE_BLOCK = 4096  # rust chunks score tables through this size


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(fn, *arg_specs) -> str:
    """jit → lower → stablehlo → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype(name):
    return {"f32": jnp.float32, "i32": jnp.int32}[name]


def _write(out_dir: str, name: str, text: str, verbose: bool) -> str:
    fname = f"{name}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    if verbose:
        print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB)")
    return fname


def emit_model(model_name: str, out_dir: str, verbose: bool = True) -> dict:
    """Emit the artifact family for one model; returns its manifest entry."""
    t0 = time.time()
    model = M.make_model(model_name)
    opt = M.DEFAULT_OPTS[model_name]
    fns = M.build_fns(model, opt)
    spec = model.spec
    plan = PLANS[model_name]
    pc = fns["param_count"]

    pf = _spec((pc,), jnp.float32)
    seed = _spec((), jnp.int32)
    scalar = _spec((), jnp.float32)
    xd = _dtype(spec.x_dtype)

    def xb(n):
        return _spec(spec.x_batch_shape(n), xd)

    def yb(n):
        return _spec(spec.y_batch_shape(n), jnp.int32)

    entry = {
        "kind": spec.kind,
        "param_count": pc,
        "classes": spec.classes,
        "x_shape": list(spec.x_shape),
        "x_dtype": spec.x_dtype,
        "y_shape": list(spec.y_shape),
        "flops_per_sample_fwd": spec.flops_per_sample_fwd,
        "optimizer": opt.kind,
        "artifacts": {"train_step": {}, "loss_fwd": {}, "eval_step": {}},
    }

    entry["artifacts"]["init"] = _write(
        out_dir, f"{model_name}_init", to_hlo_text(fns["init"], seed), verbose
    )
    for b in plan.train_steps:
        wspec = _spec((b,), jnp.float32)
        text = to_hlo_text(fns["train_step"], pf, pf, pf, xb(b), yb(b), wspec, scalar, scalar)
        entry["artifacts"]["train_step"][str(b)] = _write(
            out_dir, f"{model_name}_train_step_n{b}", text, verbose
        )
    for n in plan.loss_fwds:
        text = to_hlo_text(fns["loss_fwd"], pf, xb(n), yb(n))
        entry["artifacts"]["loss_fwd"][str(n)] = _write(
            out_dir, f"{model_name}_loss_fwd_n{n}", text, verbose
        )
    for n in plan.evals:
        text = to_hlo_text(fns["eval_step"], pf, xb(n), yb(n))
        entry["artifacts"]["eval_step"][str(n)] = _write(
            out_dir, f"{model_name}_eval_n{n}", text, verbose
        )
    if verbose:
        print(f"  [{model_name}] {pc} params, {time.time() - t0:.1f}s")
    return entry


def emit_es_update(out_dir: str, n: int, verbose: bool = True) -> str:
    """Emit the standalone L1 dual-EMA table-refresh kernel."""
    v = _spec((n,), jnp.float32)
    betas = _spec((2,), jnp.float32)

    def fn(s, w, l, mask, b):
        return es_update(s, w, l, mask, b)

    return _write(out_dir, f"es_update_n{n}", to_hlo_text(fn, v, v, v, v, betas), verbose)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="all",
        help="comma-separated model names, 'all', or 'quick'",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    if args.models == "all":
        names = list(PLANS)
    elif args.models == "quick":
        names = list(QUICK_MODELS)
    else:
        names = [m.strip() for m in args.models.split(",") if m.strip()]
        unknown = [m for m in names if m not in PLANS]
        if unknown:
            sys.exit(f"unknown models: {unknown}; known: {sorted(PLANS)}")

    os.makedirs(args.out_dir, exist_ok=True)
    verbose = not args.quiet
    t0 = time.time()

    manifest = {"version": 1, "models": {}, "kernels": {}}
    for name in names:
        if verbose:
            print(f"[aot] lowering {name} ...")
        manifest["models"][name] = emit_model(name, args.out_dir, verbose)

    manifest["kernels"]["es_update"] = {
        str(ES_UPDATE_BLOCK): emit_es_update(args.out_dir, ES_UPDATE_BLOCK, verbose)
    }

    # Merge with any pre-existing manifest so partial emissions (e.g.
    # `--models quick` after a full build) never drop entries.
    mpath = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        for k, v in old.get("models", {}).items():
            manifest["models"].setdefault(k, v)
        for k, v in old.get("kernels", {}).items():
            manifest["kernels"].setdefault(k, v)

    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"[aot] manifest: {len(manifest['models'])} models, {time.time() - t0:.1f}s total")


if __name__ == "__main__":
    main()
