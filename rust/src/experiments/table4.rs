//! Tab. 4 + Fig. 3: MAE pre-training under 4-worker data-parallel
//! simulation. Rows: Baseline / InfoBatch / ESWP r=0.3 / ESWP r=0.5.
//! Paper shape: ESWP r=0.3 lossless with more savings than InfoBatch;
//! r=0.5 saves ~45% with a small loss. Also emits the Fig. 3
//! reconstruction-loss curves (per-epoch) to results/.

use crate::config::presets::{table4, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;
use crate::util::json::{num, obj, s, Json};

use super::{fmt_saved, make_runtime, mean_loss, run_config, total_cost, trials};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let runs = table4(scale);
    let rec = Recorder::new("table4_mae_pretrain")?;
    let n_trials = trials(scale);
    table_header(
        "Table 4 / Fig. 3 — MAE pre-training (4 simulated workers)",
        &["method", "final recon loss", "time saved (flops-pred)"],
    );
    let mut rt = make_runtime(&runs[0])?;
    let mut base_cost = None;
    for cfg in &runs {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        let tag = cfg.name.split('/').next_back().unwrap_or("?");
        // Fig. 3 curves: per-epoch reconstruction loss.
        for r in &rs {
            rec.record_result(r)?;
            rec.record(&obj(vec![
                ("fig", s("fig3_curve")),
                ("method", s(tag)),
                ("curve", Json::Arr(r.loss_curve.iter().map(|&l| num(l)).collect())),
            ]))?;
        }
        let loss = mean_loss(&rs);
        let cost = total_cost(&rs);
        if tag == "baseline" {
            base_cost = Some(cost);
            println!("{tag:<12} | {loss:8.4}         | —");
        } else {
            println!(
                "{tag:<12} | {loss:8.4}         | {}",
                fmt_saved(base_cost.as_ref().unwrap(), &cost)
            );
        }
    }
    println!("(fig3 loss curves in results/table4_mae_pretrain.jsonl)");
    Ok(())
}
