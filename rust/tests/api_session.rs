//! Public session API integration tests: the deprecated `train()` shim is
//! pinned bit-for-bit against `Session::run()`, and an out-of-crate
//! sampler registered through `sampler::registry` trains end-to-end —
//! including on the threaded engine with a custom `EventSink` watching.

// This file pins the deprecated `coordinator::train` shim on purpose.
#![allow(deprecated)]

use std::sync::{Arc, Mutex};

use evosample::prelude::*;
use evosample::config::Doc;
use evosample::runtime::native::NativeRuntime;
use evosample::sampler::registry::SamplerEntry;

fn small_cfg(sampler: SamplerConfig) -> RunConfig {
    let mut cfg = RunConfig::new(
        "api_session",
        "native",
        DatasetConfig::SynthCifar { n: 256, classes: 4, label_noise: 0.05, hard_frac: 0.2 },
    );
    cfg.epochs = 4;
    cfg.meta_batch = 32;
    cfg.mini_batch = 8;
    cfg.lr = LrSchedule::Const { lr: 0.02 };
    cfg.test_n = 64;
    cfg.eval_every = 1;
    cfg.seed = 5;
    cfg.sampler = sampler;
    cfg
}

fn native_rt(split: &SplitDataset) -> NativeRuntime {
    NativeRuntime::new(split.train.x_len(), 16, 4)
}

// ---- the deprecated shim is bit-for-bit the session API ----------------

#[test]
fn train_shim_equals_session_run_bit_for_bit() {
    for sampler in [SamplerConfig::Uniform, SamplerConfig::es_default(), SamplerConfig::eswp_default()]
    {
        let cfg = small_cfg(sampler);
        // The exact split the builder would generate on its own.
        let split = data::build(&cfg.dataset, cfg.test_n, cfg.seed ^ 0xda7a_5eed);

        let mut rt = native_rt(&split);
        let shim = evosample::coordinator::train(&cfg, &mut rt, &split).unwrap();

        let run = SessionBuilder::from_config(cfg.clone())
            .runtime(Box::new(native_rt(&split)))
            .build()
            .unwrap()
            .run()
            .unwrap();

        // Bit-for-bit: every number the report carries, and the same
        // phase ledger shape (wall-clock durations themselves are not
        // comparable across runs).
        assert_eq!(shim.loss_curve, run.loss_curve, "{}", cfg.sampler.name());
        assert_eq!(shim.eval_curve, run.eval_curve, "{}", cfg.sampler.name());
        assert_eq!(shim.final_eval.accuracy, run.final_eval.accuracy);
        assert_eq!(shim.final_eval.loss, run.final_eval.loss);
        assert_eq!(shim.steps, run.steps);
        assert_eq!(shim.cost.fp_samples, run.cost.fp_samples);
        assert_eq!(shim.cost.bp_samples, run.cost.bp_samples);
        assert_eq!(shim.cost.bp_passes, run.cost.bp_passes);
        assert_eq!(shim.class_bp_counts, run.class_bp_counts);
        assert_eq!(shim.bp_at_eval, run.bp_at_eval);
        assert_eq!(shim.sampler, run.sampler);
        let phases = |r: &RunResult| -> Vec<String> {
            r.timers.phases().map(|(k, _)| k.to_string()).collect()
        };
        assert_eq!(phases(&shim), phases(&run), "{}", cfg.sampler.name());
    }
}

// ---- an out-of-crate sampler, registered not forked --------------------

/// A minimal external policy: keep a deterministic evenly-strided subset
/// of every meta-batch. No scoring FP, no state — the point is that the
/// *registration machinery* carries it everywhere built-ins go.
struct StridedSelect {
    n: usize,
    stride_bias: usize,
}

impl Sampler for StridedSelect {
    fn name(&self) -> &'static str {
        "strided"
    }

    fn select(
        &mut self,
        meta: &[u32],
        mini: usize,
        _epoch: usize,
        _rng: &mut Pcg64,
    ) -> Selection {
        let take = mini.min(meta.len()).max(1);
        let mut idx = Vec::with_capacity(take);
        for k in 0..take {
            idx.push(meta[(k * meta.len() / take + self.stride_bias) % meta.len()]);
        }
        Selection::unweighted(idx)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[test]
fn external_sampler_trains_threaded_with_events_observed() {
    evosample::sampler::registry::register(
        SamplerEntry::new("strided", SamplerKind::BatchLevel, |p, n, _| {
            Ok(Box::new(StridedSelect { n, stride_bias: p.get("stride_bias") as usize }))
        })
        .param("stride_bias", 0.0, "rotation applied to the strided picks"),
    )
    .unwrap();

    let seen: Arc<Mutex<Vec<Event>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = seen.clone();
    let r = SessionBuilder::new(
        "native",
        DatasetConfig::SynthCifar { n: 128, classes: 4, label_noise: 0.0, hard_frac: 0.2 },
    )
    .named("external_threaded")
    .epochs(3)
    .batch_sizes(32, 8)
    .test_n(64)
    .seed(9)
    .sampler_named("strided", &[("stride_bias", 1.0)])
    .threaded(2, 2)
    .runtime(Box::new(NativeRuntime::new(3072, 8, 4)))
    .on_event(move |ev: &Event| sink.lock().unwrap().push(ev.clone()))
    .build()
    .unwrap()
    .run()
    .unwrap();

    // The run completed under the external policy on real threads.
    assert_eq!(r.sampler, "strided");
    assert_eq!(r.epochs, 3);
    assert!(r.steps > 0);
    assert!(r.final_eval.accuracy.is_finite());

    // The custom sink observed the typed stream per the ordering contract.
    let seen = seen.lock().unwrap();
    assert!(matches!(seen.first(), Some(Event::RunStart { .. })));
    assert!(matches!(seen.last(), Some(Event::RunEnd { .. })));
    let count = |f: fn(&Event) -> bool| seen.iter().filter(|e| f(*e)).count();
    assert_eq!(count(|e| matches!(e, Event::EpochStart { .. })), 3);
    assert_eq!(count(|e| matches!(e, Event::EpochEnd { .. })), 3);
    // One §D.5 sync round per epoch boundary, with both workers in.
    assert_eq!(count(|e| matches!(e, Event::SyncRound { workers: 2, .. })), 3);
    assert_eq!(count(|e| matches!(e, Event::EvalDone { .. })), 1);
    if let Some(Event::RunEnd { accuracy, .. }) = seen.last() {
        assert_eq!(*accuracy, r.final_eval.accuracy);
    }
}

#[test]
fn external_sampler_round_trips_through_toml_and_builder() {
    let taus: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let record = taus.clone();
    evosample::sampler::registry::register(
        SamplerEntry::new("ext_toml", SamplerKind::BatchLevel, move |p, n, _| {
            record.lock().unwrap().push(p.get("tau"));
            Ok(Box::new(StridedSelect { n, stride_bias: 0 }))
        })
        .param("tau", 0.5, "recorded by the factory"),
    )
    .unwrap();

    // TOML `sampler.kind` resolves the external entry and carries params.
    let src = "
[run]
model = \"native\"
epochs = 2
meta_batch = 32
mini_batch = 8
test_n = 64

[dataset]
kind = \"synth_cifar\"
n = 128
classes = 4

[sampler]
kind = \"ext_toml\"
tau = 0.25
";
    let cfg = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
    assert_eq!(
        cfg.sampler,
        SamplerConfig::Custom { name: "ext_toml".into(), params: vec![("tau".into(), 0.25)] }
    );
    assert!(cfg.sampler.is_batch_level() && !cfg.sampler.is_set_level());

    let split = data::build(&cfg.dataset, cfg.test_n, cfg.seed ^ 0xda7a_5eed);
    let r = SessionBuilder::from_config(cfg)
        .runtime(Box::new(native_rt(&split)))
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(r.sampler, "strided", "report carries the Sampler::name()");
    assert_eq!(taus.lock().unwrap().as_slice(), &[0.25], "factory saw the TOML param");
}

#[test]
fn builder_surfaces_registry_errors() {
    // Unknown names list what IS available.
    let err = SessionBuilder::new(
        "native",
        DatasetConfig::SynthCifar { n: 128, classes: 4, label_noise: 0.0, hard_frac: 0.2 },
    )
    .sampler_named("not_a_policy", &[])
    .build()
    .unwrap_err()
    .to_string();
    assert!(err.contains("unknown sampler"), "{err}");
    assert!(err.contains("baseline") && err.contains("eswp"), "{err}");

    // Duplicate registration is rejected, first registration wins.
    let entry = || {
        SamplerEntry::new("ext_dup", SamplerKind::Baseline, |_, n, _| {
            Ok(Box::new(StridedSelect { n, stride_bias: 0 }))
        })
    };
    evosample::sampler::registry::register(entry()).unwrap();
    let err = evosample::sampler::registry::register(entry()).unwrap_err();
    assert!(err.contains("already registered"), "{err}");
}
