//! L3 perf microbenches: the sampler hot paths (score update, weighted
//! selection, pruning) + the XLA es_update kernel vs the rust scalar loop.
//! These back the EXPERIMENTS.md §Perf L3 numbers.

use evosample::runtime::manifest::Manifest;
use evosample::runtime::xla_rt::EsUpdateKernel;
use evosample::sampler::evolved::Evolved;
use evosample::sampler::weights::sample_without_replacement;
use evosample::sampler::Sampler;
use evosample::util::bench::Bencher;
use evosample::util::Pcg64;

fn main() {
    let b = Bencher::default();
    let mut rng = Pcg64::new(1);

    // --- per-step ES observe+select at meta-batch scale ----------------
    for &(n, bb, mini) in &[(50_000usize, 128usize, 32usize), (1_000_000, 1024, 256)] {
        let mut es = Evolved::new(n, 10, 0.2, 0.9, 0.0, 0.0);
        let meta: Vec<u32> = (0..bb as u32).map(|i| i * (n as u32 / bb as u32)).collect();
        let losses: Vec<f32> = (0..bb).map(|_| rng.f32() * 3.0).collect();
        b.run(&format!("es observe_meta        n={n} B={bb}"), || {
            es.observe_meta(&meta, &losses, 1);
        });
        b.run(&format!("es select              n={n} B={bb} b={mini}"), || {
            es.select(&meta, mini, 1, &mut rng)
        });
    }

    // --- weighted sampling without replacement --------------------------
    for &(n, k) in &[(128usize, 32usize), (4096, 1024), (1_000_000, 200_000)] {
        let w: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-3).collect();
        b.run(&format!("swor (gumbel top-k)    n={n} k={k}"), || {
            sample_without_replacement(&w, k, &mut rng)
        });
    }

    // --- epoch-level pruning --------------------------------------------
    for &n in &[50_000usize, 1_000_000] {
        let mut es = Evolved::new(n, 10, 0.2, 0.8, 0.0, 0.3);
        b.run(&format!("eswp epoch prune       n={n} r=0.3"), || {
            es.on_epoch_start(1, &mut rng)
        });
    }

    // --- dense table refresh: rust loop vs L1 kernel ---------------------
    let n = 65_536usize;
    let s0: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let losses: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let mask = vec![1.0f32; n];
    {
        let mut s = s0.clone();
        let mut w = s0.clone();
        b.run(&format!("table refresh (rust)   n={n}"), || {
            for i in 0..n {
                let so = s[i];
                w[i] = 0.2 * so + 0.8 * losses[i];
                s[i] = 0.9 * so + 0.1 * losses[i];
            }
        });
    }
    if let Ok(m) = Manifest::load_default() {
        if let Ok(kernel) = EsUpdateKernel::load(&m) {
            let mut s = s0.clone();
            let mut w = s0;
            b.run(&format!("table refresh (xla L1) n={n}"), || {
                kernel.refresh(&mut s, &mut w, &losses, &mask, 0.2, 0.9).unwrap();
            });
        }
    } else {
        println!("(artifacts missing: skipping xla kernel bench)");
    }
}
