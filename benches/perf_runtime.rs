//! Runtime perf: the native backend's kernel layer vs the pre-kernel
//! scalar reference (CIFAR-scale MLP dims), plus XLA step costs when
//! artifacts are present. Quantifies the paper's §3.3 claim that BP
//! dominates and ES's scoring FP is cheap — with kernels fast enough
//! that the measured FP/BP ratio reflects algorithmic cost, not cache
//! misses.
//!
//! Emits machine-readable `BENCH_native.json` (ns per FP/BP sample,
//! samples/sec at 1/2/4 kernel threads, speedups vs scalar) so the perf
//! trajectory is tracked across PRs. Smoke mode (the default) uses
//! short measurement budgets; `EVOSAMPLE_BENCH_FULL=1` for longer runs.

use std::collections::BTreeMap;

use evosample::runtime::kernel::reference::ScalarMlp;
use evosample::runtime::manifest::Manifest;
use evosample::runtime::native::NativeRuntime;
use evosample::runtime::xla_rt::XlaRuntime;
use evosample::runtime::{BatchX, ModelRuntime};
use evosample::util::bench::{smoke_mode, BenchResult, Bencher};
use evosample::util::json::{num, obj, s, Json};
use evosample::util::Pcg64;

/// CIFAR-scale MLP dims (what `make_runtime`'s native fallback builds).
const D: usize = 3072;
const H: usize = 64;
const C: usize = 10;
/// BP mini-batch and scoring meta-batch sizes.
const TRAIN_N: usize = 64;
const FWD_N: usize = 256;

fn ns_per_sample(r: &BenchResult, n: usize) -> f64 {
    r.median.as_nanos() as f64 / n as f64
}

fn samples_per_s(r: &BenchResult, n: usize) -> f64 {
    n as f64 / r.median.as_secs_f64().max(1e-12)
}

fn result_obj(fwd: &BenchResult, train: &BenchResult) -> Json {
    obj(vec![
        ("fwd_ns_per_sample", num(ns_per_sample(fwd, FWD_N))),
        ("fwd_samples_per_s", num(samples_per_s(fwd, FWD_N))),
        ("train_ns_per_sample", num(ns_per_sample(train, TRAIN_N))),
        ("train_samples_per_s", num(samples_per_s(train, TRAIN_N))),
    ])
}

fn main() {
    let smoke = smoke_mode();
    let bench = if smoke { Bencher::quick() } else { Bencher::default() };
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("== native runtime kernels (d={D}, h={H}, c={C}, {cores} cores) ==");

    let mut rng = Pcg64::new(3);
    let x_train: Vec<f32> = (0..TRAIN_N * D).map(|_| rng.normal()).collect();
    let y_train: Vec<i32> = (0..TRAIN_N).map(|_| rng.int_in(0, C as i64) as i32).collect();
    let w_train = vec![1.0f32; TRAIN_N];
    let x_fwd: Vec<f32> = (0..FWD_N * D).map(|_| rng.normal()).collect();
    let y_fwd: Vec<i32> = (0..FWD_N).map(|_| rng.int_in(0, C as i64) as i32).collect();

    // Shared deterministic init so every variant times identical math.
    // lr = 0 keeps parameters fixed across timed iterations (the full
    // optimizer update still runs, so the cost is representative).
    let mut seed_rt = NativeRuntime::new(D, H, C);
    seed_rt.init(0).unwrap();
    let params0 = seed_rt.get_params().unwrap();

    // ---- scalar reference: the pre-kernel NativeRuntime math -----------
    let mut scalar = ScalarMlp::new(D, H, C);
    scalar.set_params(&params0);
    let scalar_fwd = bench
        .run(&format!("scalar     loss_fwd   n={FWD_N}"), || scalar.loss_fwd(&x_fwd, &y_fwd, FWD_N));
    let scalar_train = bench.run(&format!("scalar     train_step n={TRAIN_N}"), || {
        scalar.train_step(&x_train, &y_train, &w_train, 0.0, TRAIN_N)
    });

    // ---- kernel layer (default dispatch) at 1 / 2 / 4 threads -----------
    let mut per_thread: Vec<(usize, BenchResult, BenchResult)> = Vec::new();
    for &t in &[1usize, 2, 4] {
        let mut rt = NativeRuntime::new(D, H, C).with_kernel_threads(t);
        rt.set_params(&params0).unwrap();
        let rf = bench.run(&format!("kernel t={t} loss_fwd   n={FWD_N}"), || {
            rt.loss_fwd(BatchX::F32(&x_fwd), &y_fwd, FWD_N).unwrap()
        });
        let rt_res = bench.run(&format!("kernel t={t} train_step n={TRAIN_N}"), || {
            rt.train_step(BatchX::F32(&x_train), &y_train, &w_train, 0.0, TRAIN_N).unwrap()
        });
        per_thread.push((t, rf, rt_res));
    }

    let t1_train = per_thread[0].2.median.as_secs_f64();
    let t4_train = per_thread[2].2.median.as_secs_f64();
    let t1_fwd = per_thread[0].1.median.as_secs_f64();
    let train_vs_scalar = scalar_train.median.as_secs_f64() / t1_train.max(1e-12);
    let fwd_vs_scalar = scalar_fwd.median.as_secs_f64() / t1_fwd.max(1e-12);
    let t4_vs_t1 = t1_train / t4_train.max(1e-12);
    println!(
        "\ntrain_step: kernel(t=1) {train_vs_scalar:.2}x vs scalar (target >= 4x), \
         t=4 {t4_vs_t1:.2}x vs t=1 (target >= 2.5x on a 4-core box; this box: {cores})"
    );

    let mut threads_map: BTreeMap<String, Json> = BTreeMap::new();
    for (t, rf, rtr) in &per_thread {
        threads_map.insert(format!("t{t}"), result_obj(rf, rtr));
    }
    let out = obj(vec![
        ("bench", s("perf_runtime")),
        ("backend", s("native")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("cores", num(cores as f64)),
        (
            "dims",
            obj(vec![
                ("d", num(D as f64)),
                ("h", num(H as f64)),
                ("c", num(C as f64)),
                ("train_batch", num(TRAIN_N as f64)),
                ("fwd_batch", num(FWD_N as f64)),
            ]),
        ),
        ("scalar", result_obj(&scalar_fwd, &scalar_train)),
        ("threads", Json::Obj(threads_map)),
        (
            "speedup",
            obj(vec![
                ("train_t1_vs_scalar", num(train_vs_scalar)),
                ("fwd_t1_vs_scalar", num(fwd_vs_scalar)),
                ("train_t4_vs_t1", num(t4_vs_t1)),
            ]),
        ),
    ]);
    let payload = out.to_string_compact() + "\n";
    std::fs::write("BENCH_native.json", payload).expect("write BENCH_native.json");
    println!("wrote BENCH_native.json");

    scoring_section(&bench, smoke, cores, &params0, &x_fwd, &y_fwd);
    xla_section(&bench, smoke);
}

/// Dispatch × precision sweep over the scoring forward (DESIGN.md §9):
/// blocked-scalar vs SIMD `loss_fwd`, and exact vs bf16 ranked scoring,
/// at 1 and 4 kernel threads on the CIFAR-scale shape. Emits
/// `BENCH_scoring.json` and enforces the two claims the fast path
/// exists for — SIMD beats blocked-scalar and bf16 beats exact — so the
/// CI smoke run fails on a regression instead of silently keeping a
/// slower default.
fn scoring_section(
    bench: &Bencher,
    smoke: bool,
    cores: usize,
    params0: &[f32],
    x_fwd: &[f32],
    y_fwd: &[i32],
) {
    use evosample::runtime::kernel::KernelDispatch;
    println!("\n== scoring path: dispatch x precision (d={D}, h={H}, c={C}, n={FWD_N}) ==");

    let mut rows: BTreeMap<String, Json> = BTreeMap::new();
    let mut medians: BTreeMap<String, f64> = BTreeMap::new();
    for &t in &[1usize, 4] {
        for dispatch in [KernelDispatch::Scalar, KernelDispatch::Simd] {
            let mut rt =
                NativeRuntime::new(D, H, C).with_kernel_threads(t).with_dispatch(dispatch);
            rt.set_params(params0).unwrap();
            let r = bench.run(
                &format!("{:<7} t={t} loss_fwd    n={FWD_N}", dispatch.as_str()),
                || rt.loss_fwd(BatchX::F32(x_fwd), y_fwd, FWD_N).unwrap(),
            );
            let tag = format!("{}_t{t}", dispatch.as_str());
            medians.insert(tag.clone(), r.median.as_secs_f64());
            rows.insert(
                tag,
                obj(vec![
                    ("fwd_ns_per_sample", num(ns_per_sample(&r, FWD_N))),
                    ("fwd_samples_per_s", num(samples_per_s(&r, FWD_N))),
                ]),
            );
        }
        // bf16 ranked scoring (always the simd kernels; the bf16 shadow
        // is refreshed once outside the timed loop, as in training).
        let mut rt = NativeRuntime::new(D, H, C).with_kernel_threads(t);
        rt.set_params(params0).unwrap();
        let mut out: Vec<f32> = Vec::with_capacity(FWD_N);
        let r = bench.run(&format!("bf16    t={t} loss_ranked n={FWD_N}"), || {
            out.clear();
            rt.loss_fwd_ranked(BatchX::F32(x_fwd), y_fwd, FWD_N, &mut out).unwrap()
        });
        let tag = format!("bf16_t{t}");
        medians.insert(tag.clone(), r.median.as_secs_f64());
        rows.insert(
            tag,
            obj(vec![
                ("fwd_ns_per_sample", num(ns_per_sample(&r, FWD_N))),
                ("fwd_samples_per_s", num(samples_per_s(&r, FWD_N))),
            ]),
        );
    }

    let simd_vs_blocked = medians["scalar_t1"] / medians["simd_t1"].max(1e-12);
    let bf16_vs_exact = medians["simd_t1"] / medians["bf16_t1"].max(1e-12);
    println!(
        "\nscoring fwd: simd {simd_vs_blocked:.2}x vs blocked-scalar, \
         bf16 {bf16_vs_exact:.2}x vs exact-simd (t=1; both must be > 1x)"
    );

    let out = obj(vec![
        ("bench", s("perf_scoring")),
        ("backend", s("native")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("cores", num(cores as f64)),
        (
            "dims",
            obj(vec![
                ("d", num(D as f64)),
                ("h", num(H as f64)),
                ("c", num(C as f64)),
                ("fwd_batch", num(FWD_N as f64)),
            ]),
        ),
        ("rows", Json::Obj(rows)),
        (
            "speedup",
            obj(vec![
                ("fwd_simd_t1_vs_blocked_t1", num(simd_vs_blocked)),
                ("fwd_bf16_t1_vs_exact_simd_t1", num(bf16_vs_exact)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_scoring.json", out.to_string_compact() + "\n")
        .expect("write BENCH_scoring.json");
    println!("wrote BENCH_scoring.json");

    if simd_vs_blocked <= 1.0 {
        eprintln!(
            "FAIL: simd loss_fwd ({:.3} ms) is not faster than blocked-scalar \
             ({:.3} ms) at t=1 — the default dispatch would be a slowdown",
            medians["simd_t1"] * 1e3,
            medians["scalar_t1"] * 1e3,
        );
        std::process::exit(1);
    }
    if bf16_vs_exact <= 1.0 {
        eprintln!(
            "FAIL: bf16 ranked scoring ({:.3} ms) is not faster than the exact \
             simd forward ({:.3} ms) at t=1 — the precision ladder buys nothing",
            medians["bf16_t1"] * 1e3,
            medians["simd_t1"] * 1e3,
        );
        std::process::exit(1);
    }
}

/// XLA step costs per model/batch (FP vs BP) — unchanged from the
/// historical bench; runs only when artifacts exist.
fn xla_section(bench: &Bencher, smoke: bool) {
    let Ok(m) = Manifest::load_default() else {
        println!("xla: artifacts missing (run `make artifacts`) — skipping");
        return;
    };
    let mut rng = Pcg64::new(3);
    let models: Vec<&str> = if smoke {
        vec!["mlp_cifar10", "cnn_small_c100", "txf_lm"]
    } else {
        m.models.keys().map(|s| s.as_str()).collect()
    };

    for name in models {
        let Some(entry) = m.models.get(name) else { continue };
        let mut rt = XlaRuntime::load(&m, name).expect(name);
        rt.init(0).unwrap();
        let xd = entry.x_len();
        let yd = entry.y_len();
        let hi = entry.classes.max(2) as i64;

        let fwd_n = rt.fwd_size();
        let make_x_f32 = |n: usize, rng: &mut Pcg64| -> Vec<f32> {
            (0..n * xd).map(|_| rng.normal()).collect()
        };
        let make_x_i32 = |n: usize, rng: &mut Pcg64| -> Vec<i32> {
            (0..n * xd).map(|_| rng.int_in(0, hi) as i32).collect()
        };
        let make_y = |n: usize, rng: &mut Pcg64| -> Vec<i32> {
            (0..n * yd).map(|_| rng.int_in(0, hi) as i32).collect()
        };

        // Scoring FP at meta-batch size.
        let y = make_y(fwd_n, &mut rng);
        match entry.x_dtype {
            evosample::runtime::manifest::XDtype::F32 => {
                let x = make_x_f32(fwd_n, &mut rng);
                bench.run(&format!("{name:<16} loss_fwd  n={fwd_n}"), || {
                    rt.loss_fwd(BatchX::F32(&x), &y, fwd_n).unwrap()
                });
            }
            evosample::runtime::manifest::XDtype::I32 => {
                let x = make_x_i32(fwd_n, &mut rng);
                bench.run(&format!("{name:<16} loss_fwd  n={fwd_n}"), || {
                    rt.loss_fwd(BatchX::I32(&x), &y, fwd_n).unwrap()
                });
            }
        }
        // Train step at each emitted size.
        for n in rt.train_sizes() {
            let y = make_y(n, &mut rng);
            let w = vec![1.0f32; n];
            match entry.x_dtype {
                evosample::runtime::manifest::XDtype::F32 => {
                    let x = make_x_f32(n, &mut rng);
                    bench.run(&format!("{name:<16} train_step n={n}"), || {
                        rt.train_step(BatchX::F32(&x), &y, &w, 1e-3, n).unwrap()
                    });
                }
                evosample::runtime::manifest::XDtype::I32 => {
                    let x = make_x_i32(n, &mut rng);
                    bench.run(&format!("{name:<16} train_step n={n}"), || {
                        rt.train_step(BatchX::I32(&x), &y, &w, 1e-3, n).unwrap()
                    });
                }
            }
        }
    }
}
