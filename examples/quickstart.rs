//! Quickstart: train a classifier twice — standard sampling vs Evolved
//! Sampling — and compare accuracy, BP samples, and wall-clock.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Uses the AOT XLA path when `artifacts/` exists, else the pure-rust
//! native runtime (same coordinator, no python either way).

use evosample::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use evosample::coordinator::{saved_time_pct, train};
use evosample::data;
use evosample::experiments::make_runtime;

fn main() -> anyhow::Result<()> {
    // 1. Describe the run: model, data, batching, schedule.
    let dataset = DatasetConfig::SynthCifar {
        n: 2048,
        classes: 10,
        label_noise: 0.05,
        hard_frac: 0.2,
    };
    let mut cfg = RunConfig::new("quickstart", "mlp_cifar10", dataset);
    cfg.epochs = 10;
    cfg.meta_batch = 128; // B: drawn uniformly each step
    cfg.mini_batch = 32; //  b: selected for BP (b/B = 25%)
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
    cfg.test_n = 512;

    // 2. Data + runtime (XLA artifacts or native fallback).
    let split = data::build(&cfg.dataset, cfg.test_n, 42);
    let mut rt = make_runtime(&cfg)?;

    // 3. Baseline: no data selection.
    cfg.sampler = SamplerConfig::Uniform;
    let base = train(&cfg, rt.as_mut(), &split)?;

    // 4. Evolved Sampling (paper defaults β1=0.2, β2=0.9, 5% annealing).
    cfg.sampler = SamplerConfig::es_default();
    let es = train(&cfg, rt.as_mut(), &split)?;

    // 5. ESWP: + set-level pruning (r=0.2).
    cfg.sampler = SamplerConfig::eswp_default();
    let eswp = train(&cfg, rt.as_mut(), &split)?;

    println!("\n{:<10} {:>7} {:>12} {:>12} {:>10}", "method", "acc%", "bp samples", "fp samples", "wall s");
    for r in [&base, &es, &eswp] {
        println!(
            "{:<10} {:>7.2} {:>12} {:>12} {:>10.2}",
            r.sampler,
            r.accuracy_pct(),
            r.cost.bp_samples,
            r.cost.fp_samples,
            r.cost.train_wall_s()
        );
    }
    println!(
        "\nES saved {:.1}% wall-clock, ESWP {:.1}% (vs baseline), with accuracies within noise.",
        saved_time_pct(&base.cost, &es.cost),
        saved_time_pct(&base.cost, &eswp.cost),
    );
    Ok(())
}
