//! Small numeric helpers shared across the coordinator.

/// Numerically-stable softmax over a slice (in place not required).
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    if xs.is_empty() {
        return Vec::new();
    }
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z).collect()
}

/// Normalize non-negative weights into a probability vector. All-zero or
/// non-finite inputs degrade to uniform — the sampler must never stall on
/// a degenerate score table.
pub fn normalize_probs(ws: &[f32]) -> Vec<f32> {
    let n = ws.len();
    if n == 0 {
        return Vec::new();
    }
    let mut clean: Vec<f32> = ws.iter().map(|&w| if w.is_finite() && w > 0.0 { w } else { 0.0 }).collect();
    let z: f64 = clean.iter().map(|&w| w as f64).sum();
    if z <= 0.0 {
        return vec![1.0 / n as f32; n];
    }
    for w in &mut clean {
        *w = (*w as f64 / z) as f32;
    }
    clean
}

pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mu = mean(xs);
    xs.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// p-th percentile (0..=100) by sorting a copy; p interpolated linearly.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = p / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = (pos - lo as f64) as f32;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median via `percentile(50)`.
pub fn median(xs: &[f32]) -> f32 {
    percentile(xs, 50.0)
}

/// Indices of the k largest values (descending). Deterministic: ties break
/// toward the lower index, which keeps runs reproducible across platforms.
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(xs.len());
    let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        xs[b as usize]
            .total_cmp(&xs[a as usize])
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// argsort ascending, stable on ties.
pub fn argsort(xs: &[f32]) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
    idx.sort_by(|&a, &b| xs[a as usize].total_cmp(&xs[b as usize]).then(a.cmp(&b)));
    idx
}

/// Exponential moving average update: `ema = beta*ema + (1-beta)*x`.
#[inline]
pub fn ema(prev: f32, x: f32, beta: f32) -> f32 {
    beta * prev + (1.0 - beta) * x
}

/// Linear interpolation.
#[inline]
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_stable_on_large_inputs() {
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn normalize_handles_zeros_and_nans() {
        let p = normalize_probs(&[0.0, 0.0]);
        assert_eq!(p, vec![0.5, 0.5]);
        let p = normalize_probs(&[f32::NAN, 1.0]);
        assert!((p[1] - 1.0).abs() < 1e-6 && p[0] == 0.0);
        let p = normalize_probs(&[2.0, 2.0, 4.0]);
        assert!((p[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn percentile_median() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn top_k_descending_and_tie_stable() {
        let xs = [1.0, 5.0, 5.0, 0.0];
        assert_eq!(top_k_indices(&xs, 2), vec![1, 2]);
        assert_eq!(top_k_indices(&xs, 10).len(), 4);
    }

    #[test]
    fn argsort_ascending() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argsort(&xs), vec![1, 2, 0]);
    }

    #[test]
    fn variance_of_constant_is_zero() {
        assert_eq!(variance(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    fn mean_and_var_simple() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-9);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-6);
    }
}
