//! Experiment drivers: one module per paper table/figure (DESIGN.md §4).
//!
//! Each module exposes `run(scale) -> anyhow::Result<()>` which trains the
//! preset configs, prints a paper-style table to stdout, and records JSONL
//! under `results/`. The `benches/*.rs` targets are thin wrappers so
//! `cargo bench` regenerates every table and figure; `EVOSAMPLE_BENCH_FULL=1`
//! switches from smoke to paper-faithful scale.

pub mod ablations;
pub mod fig1;
pub mod fig10;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig9;
pub mod frequency;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod theory;

use crate::api::SessionBuilder;
use crate::config::RunConfig;
use crate::coordinator::TrainResult;
use crate::runtime::ModelRuntime;

// Historical home of the runtime chooser; it now lives beside the
// runtimes themselves.
pub use crate::runtime::make_runtime;

/// Number of independent trials per config (paper: 3-4; smoke: 1).
pub fn trials(scale: crate::config::presets::Scale) -> usize {
    match scale {
        crate::config::presets::Scale::Smoke => 1,
        crate::config::presets::Scale::Full => 3,
    }
}

/// Train `trials` seeds of one config on a (cached) runtime, through the
/// public session API (the split is generated once from the base seed;
/// trial seeds offset by 1000 as always).
pub fn run_config(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    n_trials: usize,
) -> anyhow::Result<Vec<TrainResult>> {
    SessionBuilder::from_config(cfg.clone()).runtime_mut(rt).build()?.run_trials(n_trials)
}

/// Mean accuracy% across trials.
pub fn mean_acc(rs: &[TrainResult]) -> f64 {
    rs.iter().map(|r| r.accuracy_pct()).sum::<f64>() / rs.len() as f64
}

/// Mean eval loss across trials.
pub fn mean_loss(rs: &[TrainResult]) -> f64 {
    rs.iter().map(|r| r.final_eval.loss).sum::<f64>() / rs.len() as f64
}

/// Sum the cost across trials.
pub fn total_cost(rs: &[TrainResult]) -> crate::coordinator::CostSummary {
    let mut total = crate::coordinator::CostSummary::default();
    for r in rs {
        total.accumulate(&r.cost);
    }
    total
}

/// Format the paper's accuracy delta annotation, e.g. "84.7 (+0.3)".
pub fn fmt_acc(acc: f64, baseline: f64) -> String {
    let d = acc - baseline;
    format!("{acc:5.1} ({}{d:.1})", if d >= 0.0 { "+" } else { "" })
}

/// Format measured + FLOPs-predicted saved time.
pub fn fmt_saved(base: &crate::coordinator::CostSummary, c: &crate::coordinator::CostSummary) -> String {
    let meas = crate::coordinator::saved_time_pct(base, c);
    let pred = crate::coordinator::predicted_saved_time_pct(base, c);
    format!("{meas:5.1}% ({pred:5.1}%)")
}
