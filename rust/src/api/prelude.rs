//! One-stop imports for embedding applications, examples, and tests:
//! `use evosample::prelude::*;` brings in the session API, the event
//! stream, config types, the sampler registry, and the result/metrics
//! helpers.

pub use super::events::{Event, EventBus, EventSink, ProgressSink};
pub use super::{RunResult, Session, SessionBuilder};

pub use crate::config::presets::{all_samplers, Scale};
pub use crate::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig, ScoringPrecision};
pub use crate::coordinator::{
    predicted_saved_time_pct, saved_time_pct, CostSummary, EvalStats, TrainResult,
};
pub use crate::data::{self, SplitDataset};
pub use crate::metrics::{EventLog, Recorder};
pub use crate::runtime::{make_runtime, ModelRuntime};
pub use crate::sampler::{analysis, registry, Sampler, SamplerKind, Selection};
pub use crate::util::Pcg64;
