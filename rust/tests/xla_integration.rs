//! Integration tests over the REAL request path: AOT HLO artifacts loaded
//! through PJRT and driven by the coordinator. Skipped (cleanly, with a
//! message) when `artifacts/` has not been built — run `make artifacts`.

// Exercises the deprecated `coordinator::train` shim on purpose.
#![allow(deprecated)]

use evosample::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use evosample::coordinator::train;
use evosample::runtime::manifest::Manifest;
use evosample::runtime::xla_rt::{EsUpdateKernel, XlaRuntime};
use evosample::runtime::{BatchX, ModelRuntime};
use evosample::util::Pcg64;

fn manifest() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).expect("manifest parses"))
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn mlp_artifact_roundtrip_and_determinism() {
    let Some(m) = manifest() else { return };
    let mut rt = XlaRuntime::load(&m, "mlp_cifar10").unwrap();
    assert_eq!(rt.param_count(), m.models["mlp_cifar10"].param_count);

    rt.init(7).unwrap();
    let p1 = rt.get_params().unwrap();
    rt.init(7).unwrap();
    let p2 = rt.get_params().unwrap();
    assert_eq!(p1, p2, "init deterministic in seed");
    rt.init(8).unwrap();
    assert_ne!(rt.get_params().unwrap(), p1);
}

#[test]
fn xla_train_step_decreases_loss_and_matches_fwd() {
    let Some(m) = manifest() else { return };
    let mut rt = XlaRuntime::load(&m, "mlp_cifar10").unwrap();
    rt.init(0).unwrap();

    // One fixed mini-batch of size 32 (an emitted train_step size).
    let n = 32usize;
    let mut rng = Pcg64::new(1);
    let x: Vec<f32> = (0..n * 3072).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..n).map(|i| (i % 10) as i32).collect();
    let w = vec![1.0f32; n];

    let fwd = rt.loss_fwd_any(&x, &y, n, &m);
    let first = rt.train_step(BatchX::F32(&x), &y, &w, 0.05, n).unwrap();
    // train_step losses are computed at pre-update params == loss_fwd...
    // loss_fwd artifact is only emitted at the meta size (128), so compare
    // against the step's own aux losses over repeated steps instead.
    let mut last = first.mean_loss;
    for _ in 0..15 {
        last = rt.train_step(BatchX::F32(&x), &y, &w, 0.05, n).unwrap().mean_loss;
    }
    assert!(
        last < 0.5 * first.mean_loss,
        "overfit failed: {} -> {last}",
        first.mean_loss
    );
    drop(fwd);
}

// Helper: loss_fwd at the emitted meta size with padding.
trait FwdAny {
    fn loss_fwd_any(&mut self, x: &[f32], y: &[i32], n: usize, m: &Manifest) -> Vec<f32>;
}

impl FwdAny for XlaRuntime {
    fn loss_fwd_any(&mut self, x: &[f32], y: &[i32], n: usize, _m: &Manifest) -> Vec<f32> {
        let fb = self.fwd_size();
        if n == fb {
            return self.loss_fwd(BatchX::F32(x), y, n).unwrap();
        }
        let d = x.len() / n;
        let mut xp = x.to_vec();
        let mut yp = y.to_vec();
        while yp.len() < fb {
            xp.extend_from_slice(&x[..d]);
            yp.push(y[0]);
        }
        let mut out = self.loss_fwd(BatchX::F32(&xp), &yp, fb).unwrap();
        out.truncate(n);
        out
    }
}

#[test]
fn full_training_run_on_xla_runtime_with_es() {
    let Some(m) = manifest() else { return };
    let mut rt = XlaRuntime::load(&m, "mlp_cifar10").unwrap();

    let ds_cfg = DatasetConfig::SynthCifar {
        n: 512,
        classes: 10,
        label_noise: 0.05,
        hard_frac: 0.2,
    };
    let split = evosample::data::build(&ds_cfg, 256, 11);
    let mut cfg = RunConfig::new("xla_es", "mlp_cifar10", ds_cfg);
    cfg.epochs = 4;
    cfg.meta_batch = 128;
    cfg.mini_batch = 32;
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.05, warmup_frac: 0.3 };
    cfg.test_n = 256;
    cfg.sampler = SamplerConfig::es_default();

    let r = train(&cfg, &mut rt, &split).unwrap();
    assert!(r.final_eval.accuracy > 0.2, "acc {}", r.final_eval.accuracy);
    assert!(r.loss_curve.first().unwrap() > r.loss_curve.last().unwrap());
    assert!(r.cost.fp_samples > 0, "ES must run scoring FPs");
    assert!(r.cost.bp_samples < 4 * 512, "BP reduced vs baseline");
}

#[test]
fn token_model_runs_on_xla_runtime() {
    let Some(m) = manifest() else { return };
    let mut rt = XlaRuntime::load(&m, "txf_nlu").unwrap();
    rt.init(3).unwrap();

    let ds_cfg = DatasetConfig::Nlu {
        task: "sst2".into(),
        n: 128,
        vocab: 512,
        seq: 48,
        classes: 2,
    };
    let split = evosample::data::build(&ds_cfg, 128, 5);
    let mut cfg = RunConfig::new("xla_nlu", "txf_nlu", ds_cfg);
    cfg.epochs = 2;
    cfg.meta_batch = 64;
    cfg.mini_batch = 16;
    cfg.lr = LrSchedule::Const { lr: 5e-4 };
    cfg.test_n = 128;
    cfg.sampler = SamplerConfig::es_default();
    let r = train(&cfg, &mut rt, &split).unwrap();
    assert!(r.final_eval.loss.is_finite());
}

#[test]
fn es_update_kernel_matches_rust_reference() {
    let Some(m) = manifest() else { return };
    let kernel = EsUpdateKernel::load(&m).unwrap();

    let n = kernel.block() + 137; // force a padded tail chunk
    let mut rng = Pcg64::new(9);
    let s0: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let w0: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let losses: Vec<f32> = (0..n).map(|_| rng.f32() * 4.0).collect();
    let mask: Vec<f32> = (0..n).map(|_| if rng.f32() > 0.5 { 1.0 } else { 0.0 }).collect();
    let (b1, b2) = (0.2f32, 0.9f32);

    let mut s = s0.clone();
    let mut w = w0.clone();
    kernel.refresh(&mut s, &mut w, &losses, &mask, b1, b2).unwrap();

    for i in 0..n {
        let (es, ew) = if mask[i] > 0.5 {
            (
                b2 * s0[i] + (1.0 - b2) * losses[i],
                b1 * s0[i] + (1.0 - b1) * losses[i],
            )
        } else {
            (s0[i], w0[i])
        };
        assert!((s[i] - es).abs() < 1e-5, "s[{i}]: {} vs {es}", s[i]);
        assert!((w[i] - ew).abs() < 1e-5, "w[{i}]: {} vs {ew}", w[i]);
    }
}

#[test]
fn native_and_xla_agree_on_training_dynamics_shape() {
    // Cross-implementation check: both backends, same workload family,
    // must show the same qualitative result (loss decreasing, ES cheaper
    // than baseline in BP samples by the same ratio).
    let Some(m) = manifest() else { return };
    let ds_cfg = DatasetConfig::SynthCifar {
        n: 256,
        classes: 10,
        label_noise: 0.0,
        hard_frac: 0.2,
    };
    let split = evosample::data::build(&ds_cfg, 128, 21);
    let mut cfg = RunConfig::new("xcheck", "mlp_cifar10", ds_cfg);
    cfg.epochs = 3;
    cfg.meta_batch = 128;
    cfg.mini_batch = 32;
    cfg.test_n = 128;
    cfg.sampler = SamplerConfig::es_default();

    let mut xla = XlaRuntime::load(&m, "mlp_cifar10").unwrap();
    let rx = train(&cfg, &mut xla, &split).unwrap();

    let mut native = evosample::runtime::native::NativeRuntime::new(3072, 64, 10);
    let rn = train(&cfg, &mut native, &split).unwrap();

    assert_eq!(rx.cost.bp_samples, rn.cost.bp_samples, "identical selection schedule");
    assert_eq!(rx.cost.fp_samples, rn.cost.fp_samples);
    assert!(rx.loss_curve.last().unwrap() < rx.loss_curve.first().unwrap());
    assert!(rn.loss_curve.last().unwrap() < rn.loss_curve.first().unwrap());
}
