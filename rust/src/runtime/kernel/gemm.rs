//! Unit-stride micro-kernels for the one-hidden-layer MLP.
//!
//! Everything here operates on the packed layout (see [`super::pack`]):
//! `W1` transposed to `[hidden][in_dim]`, `W2` canonical
//! `[hidden][classes]`. With those orientations *every* inner loop below
//! is unit-stride on both operands, which is what lets LLVM vectorize
//! them:
//!
//! * forward hidden:  `h[j] = relu(b1[j] + dot(x, W1ᵀ[j]))` — a length-d
//!   dot with both slices contiguous;
//! * forward logits:  `logits += h[k] · W2[k]` — an axpy over `classes`,
//!   skipping relu-dead `h[k] == 0` rows;
//! * backward:        `dh[k] = dot(dl, W2[k])`, `gW2[k] += h[k]·dl`,
//!   `gW1ᵀ[k] += dh[k]·x` — dots and axpys, all contiguous, with the
//!   relu gate skipping dead hidden units entirely;
//! * fused softmax-CE: one max/exp sweep produces the per-sample loss
//!   *and* the scaled `dlogits` row, instead of the historical
//!   recompute-in-backward pattern.
//!
//! Per-row op sequences are fixed, so the same row always produces the
//! same bits no matter which pool lane computes it. [`dot`] uses eight
//! independent accumulator lanes folded in a fixed tree — that breaks
//! the FP dependency chain for SIMD without making the result depend on
//! anything but the input slices.

/// Unit-stride dot product with 8 accumulator lanes (fixed reduction
/// order — deterministic for a given input, friendly to SLP
/// vectorization).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        for ((acc, &x), &y) in lanes.iter_mut().zip(ca).zip(cb) {
            *acc += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    (((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
        + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7])))
        + tail
}

/// `y[i] += alpha * x[i]` (unit-stride, no reduction — auto-vectorizes).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Hidden-layer forward for consecutive samples: `x` is `rows·d`,
/// `h_out` is `rows·h`; `w1t` is the packed `[h][d]` transposed weight,
/// `b1` the bias.
pub fn hidden_fwd(x: &[f32], w1t: &[f32], b1: &[f32], d: usize, h: usize, h_out: &mut [f32]) {
    debug_assert_eq!(x.len() % d.max(1), 0);
    debug_assert_eq!(w1t.len(), d * h);
    for (xi, hrow) in x.chunks_exact(d).zip(h_out.chunks_exact_mut(h)) {
        for (j, hj) in hrow.iter_mut().enumerate() {
            let acc = b1[j] + dot(xi, &w1t[j * d..(j + 1) * d]);
            *hj = acc.max(0.0); // relu
        }
    }
}

/// Output-layer forward for consecutive samples: `hrows` is `rows·h`,
/// `out` is `rows·c`; `w2` is the packed `[h][c]` weight, `b2` the bias.
/// Relu-dead hidden units (`h[k] == 0`) contribute nothing and are
/// skipped.
pub fn logits_fwd(hrows: &[f32], w2: &[f32], b2: &[f32], h: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(w2.len(), h * c);
    for (hi, li) in hrows.chunks_exact(h).zip(out.chunks_exact_mut(c)) {
        li.copy_from_slice(b2);
        for (k, &hk) in hi.iter().enumerate() {
            if hk != 0.0 {
                axpy(hk, &w2[k * c..(k + 1) * c], li);
            }
        }
    }
}

/// Per-sample CE loss from one logits row (max-subtracted log-sum-exp).
#[inline]
pub fn ce_loss_row(li: &[f32], y: usize) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for &v in li {
        m = m.max(v);
    }
    let mut z = 0.0f32;
    for &v in li {
        z += (v - m).exp();
    }
    z.ln() + m - li[y]
}

/// Fused softmax-CE: one max/exp sweep fills `dl` with the scaled
/// gradient `scale · (softmax(li) - onehot(y))` and returns the
/// (unscaled) CE loss. The loss bits are identical to [`ce_loss_row`]
/// (same max fold, same summation order).
#[inline]
pub fn ce_loss_grad_row(li: &[f32], y: usize, scale: f32, dl: &mut [f32]) -> f32 {
    debug_assert_eq!(li.len(), dl.len());
    let mut m = f32::NEG_INFINITY;
    for &v in li {
        m = m.max(v);
    }
    let mut z = 0.0f32;
    for (dj, &v) in dl.iter_mut().zip(li) {
        let e = (v - m).exp();
        z += e;
        *dj = e;
    }
    let loss = z.ln() + m - li[y];
    let inv = scale / z;
    for dj in dl.iter_mut() {
        *dj *= inv;
    }
    dl[y] -= scale;
    loss
}

/// Accumulate one sample's gradient contribution into a shard buffer.
///
/// Inputs: `xi` (`d`), `hi` (`h`, post-relu), `dl` (`c`, the scaled
/// `dlogits` row from [`ce_loss_grad_row`]), and the packed `w2`.
/// Outputs accumulate into the shard's packed gradient segments; `dh`
/// is caller-provided `h`-length scratch (fully overwritten).
#[allow(clippy::too_many_arguments)]
pub fn backward_row(
    xi: &[f32],
    hi: &[f32],
    dl: &[f32],
    w2: &[f32],
    d: usize,
    c: usize,
    gw1t: &mut [f32],
    gb1: &mut [f32],
    gw2: &mut [f32],
    gb2: &mut [f32],
    dh: &mut [f32],
) {
    axpy(1.0, dl, gb2);
    for (k, &hk) in hi.iter().enumerate() {
        if hk > 0.0 {
            // Relu active: the unit propagates gradient both ways.
            dh[k] = dot(dl, &w2[k * c..(k + 1) * c]);
            axpy(hk, dl, &mut gw2[k * c..(k + 1) * c]);
        } else {
            dh[k] = 0.0;
        }
    }
    for (k, &g) in dh.iter().enumerate() {
        if g != 0.0 {
            gb1[k] += g;
            axpy(g, xi, &mut gw1t[k * d..(k + 1) * d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive_on_ragged_lengths() {
        for len in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 100] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
            let b: Vec<f32> = (0..len).map(|i| (i as f32 * 0.3).cos()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot(&a, &b);
            assert!((naive - fast).abs() <= 1e-4 * (1.0 + naive.abs()), "len={len}");
        }
    }

    #[test]
    fn axpy_accumulates() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0, 31.5]);
    }

    #[test]
    fn ce_loss_grad_matches_loss_and_sums_to_zero_at_unit_scale() {
        let li = [0.2f32, -1.0, 3.0, 0.5];
        let y = 2usize;
        let mut dl = [0.0f32; 4];
        let loss = ce_loss_grad_row(&li, y, 1.0, &mut dl);
        assert_eq!(loss, ce_loss_row(&li, y), "fused loss must be bit-identical");
        // softmax - onehot sums to zero.
        let s: f32 = dl.iter().sum();
        assert!(s.abs() < 1e-6, "grad sum {s}");
        assert!(dl[y] < 0.0, "true-class grad must be negative");
    }

    #[test]
    fn ce_loss_is_shift_invariant() {
        let li = [1.0f32, 2.0, 3.0];
        let shifted = [101.0f32, 102.0, 103.0];
        let a = ce_loss_row(&li, 1);
        let b = ce_loss_row(&shifted, 1);
        assert!((a - b).abs() < 1e-5);
    }

    #[test]
    fn hidden_fwd_applies_relu_and_bias() {
        // d=2, h=2: W1T rows [1,0] and [-1,0]; b1 = [0.5, -10].
        let w1t = [1.0f32, 0.0, -1.0, 0.0];
        let b1 = [0.5f32, -10.0];
        let x = [2.0f32, 7.0];
        let mut h = [0.0f32; 2];
        hidden_fwd(&x, &w1t, &b1, 2, 2, &mut h);
        assert_eq!(h, [2.5, 0.0]);
    }

    #[test]
    fn logits_fwd_skips_dead_units() {
        // h=2, c=2: W2 rows [1,2] (live) and [100,100] (dead input).
        let w2 = [1.0f32, 2.0, 100.0, 100.0];
        let b2 = [0.1f32, 0.2];
        let hrow = [3.0f32, 0.0];
        let mut out = [0.0f32; 2];
        logits_fwd(&hrow, &w2, &b2, 2, 2, &mut out);
        assert!((out[0] - 3.1).abs() < 1e-6);
        assert!((out[1] - 6.2).abs() < 1e-6);
    }
}
