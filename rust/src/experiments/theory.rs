//! Theory checks — Prop. 2.1/B.1 (loss-weighted GD converges faster than
//! vanilla GD on realizable convex problems), Prop. 3.1 (recursion ≡
//! explicit expansion, error = O(β2^t)) and Thm. 3.2 (transfer-function
//! table). These are exact numerical verifications of the paper's math,
//! independent of any neural workload.

use crate::sampler::analysis::{explicit_weight, scalar_step, transfer_magnitude};
use crate::util::bench::table_header;
use crate::util::Pcg64;

/// Realizable least-squares: ℓ_i(θ) = 0.5 (a_iᵀθ − b_i)², b = Aθ*.
struct LeastSquares {
    a: Vec<Vec<f32>>,
    b: Vec<f32>,
    dim: usize,
}

impl LeastSquares {
    fn new(n: usize, dim: usize, rng: &mut Pcg64) -> Self {
        let theta_star: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        let a: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        let b: Vec<f32> = a
            .iter()
            .map(|ai| ai.iter().zip(&theta_star).map(|(x, t)| x * t).sum())
            .collect();
        LeastSquares { a, b, dim }
    }

    fn losses(&self, theta: &[f32]) -> Vec<f32> {
        self.a
            .iter()
            .zip(&self.b)
            .map(|(ai, &bi)| {
                let r: f32 = ai.iter().zip(theta).map(|(x, t)| x * t).sum::<f32>() - bi;
                0.5 * r * r
            })
            .collect()
    }

    fn mean_loss(&self, theta: &[f32]) -> f64 {
        let l = self.losses(theta);
        l.iter().map(|&x| x as f64).sum::<f64>() / l.len() as f64
    }

    /// One step of (optionally loss-weighted) GD.
    fn gd_step(&self, theta: &mut [f32], lr: f32, loss_weighted: bool) {
        let losses = self.losses(theta);
        let z: f32 = if loss_weighted {
            losses.iter().sum::<f32>().max(1e-12)
        } else {
            losses.len() as f32
        };
        let mut grad = vec![0.0f32; self.dim];
        for (i, ai) in self.a.iter().enumerate() {
            let r: f32 = ai.iter().zip(theta.iter()).map(|(x, t)| x * t).sum::<f32>() - self.b[i];
            let w = if loss_weighted { losses[i] / z } else { 1.0 / z };
            for (g, &x) in grad.iter_mut().zip(ai) {
                *g += w * r * x;
            }
        }
        for (t, g) in theta.iter_mut().zip(&grad) {
            *t -= lr * g;
        }
    }
}

/// Prop. 2.1: iterations to reach a loss threshold, loss-weighted vs plain.
pub fn run_prop21() -> anyhow::Result<()> {
    table_header(
        "Prop. 2.1 — loss-weighted GD vs GD (realizable least squares)",
        &["trial", "iters (GD)", "iters (loss-weighted)", "speedup"],
    );
    let mut total_speedup = 0.0;
    let trials = 5;
    for trial in 0..trials {
        let mut rng = Pcg64::new(100 + trial);
        let ls = LeastSquares::new(64, 16, &mut rng);
        let theta0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let threshold = ls.mean_loss(&theta0) * 1e-4;
        let run = |loss_weighted: bool| -> usize {
            let mut theta = theta0.clone();
            for it in 0..200_000 {
                if ls.mean_loss(&theta) < threshold {
                    return it;
                }
                ls.gd_step(&mut theta, 0.01, loss_weighted);
            }
            200_000
        };
        let plain = run(false);
        let weighted = run(true);
        let speedup = plain as f64 / weighted as f64;
        total_speedup += speedup;
        println!("{trial:>5} | {plain:>10} | {weighted:>21} | {speedup:5.2}x");
    }
    let avg = total_speedup / trials as f64;
    println!("average speedup {avg:.2}x (paper: loss-weighted flow converges more than sub-linearly)");
    anyhow::ensure!(avg > 1.0, "loss-weighted GD should dominate on realizable convex problems");
    Ok(())
}

/// Prop. 3.1: |recursion − explicit Eq. 3.2| shrinks like β2^t.
pub fn run_prop31() -> anyhow::Result<()> {
    table_header("Prop. 3.1 — recursion vs explicit expansion", &["T", "max err", "bound 5·β2^T"]);
    let (b1, b2) = (0.2f32, 0.9f32);
    let mut rng = Pcg64::new(7);
    for t_max in [5usize, 10, 20, 40, 80] {
        let mut max_err = 0.0f32;
        for _ in 0..50 {
            let losses: Vec<f32> = (0..t_max).map(|_| rng.f32() * 4.0).collect();
            let s0 = 1.0 / 8.0;
            let (mut s, mut w) = (s0, s0);
            for &l in &losses {
                let (w2, s2) = scalar_step(s, l, b1, b2);
                w = w2;
                s = s2;
            }
            // Truncated Eq. 3.2 (drop the boundary terms == the O(β2^t)
            // remainder the paper hides in big-O).
            let truncated = {
                let full = explicit_weight(&losses, b1, b2, s0);
                let boundary = explicit_weight(&losses, b1, b2, 0.0);
                // full - (terms ∝ s0) isolates the kept sums; compare the
                // recursion against the s0-free truncation:
                let _ = full;
                boundary
            };
            max_err = max_err.max((w - truncated).abs());
        }
        let bound = 5.0 * (b2 as f32).powi(t_max as i32);
        println!("{t_max:>3} | {max_err:9.2e} | {bound:9.2e}");
        anyhow::ensure!(max_err <= bound + 1e-5, "T={t_max}: err {max_err} > bound {bound}");
    }
    Ok(())
}

/// Thm. 3.2: transfer-magnitude table over frequencies.
pub fn run_thm32() -> anyhow::Result<()> {
    table_header(
        "Thm. 3.2 — |H(iω)| (β1=0.2, β2=0.9)",
        &["omega", "|H|", "", "high-freq limit |β2-β1| = 0.7"],
    );
    for &omega in &[1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0, 1e4] {
        let h = transfer_magnitude(0.2, 0.9, omega);
        anyhow::ensure!(h <= 1.0 + 1e-12);
        println!("{omega:8.0e} | {h:6.4} |  |");
    }
    Ok(())
}

pub fn run_all() -> anyhow::Result<()> {
    run_prop21()?;
    run_prop31()?;
    run_thm32()
}
