//! KAKURENBO (Thao Nguyen et al. 2023): adaptively *hide* the easiest
//! samples each epoch, with a "moving-back" correction.
//!
//! Original method combines loss ranking with prediction confidence and
//! accuracy. Our scoring FP exposes losses only, so the reproduction uses
//! the loss-rank hiding rule plus move-back — a sample scheduled for
//! hiding is moved back into the epoch if its loss *increased* since the
//! last time it was seen (the paper's signal that the model started
//! forgetting it). The confidence threshold τ maps onto a loss threshold:
//! samples with loss below −ln(τ) are confidently fit and eligible for
//! hiding regardless of rank. Documented as a substitution in DESIGN.md §3.

use super::{Sampler, Selection, ShardLog, ShardObservations};
use crate::util::math;
use crate::util::Pcg64;

pub struct Kakurenbo {
    hide_ratio: f64,
    /// Loss threshold derived from the confidence threshold τ.
    loss_threshold: f32,
    /// Last observed loss (NaN = unseen).
    last: Vec<f32>,
    /// Loss at the previous epoch (for the move-back rule).
    prev_epoch: Vec<f32>,
    /// Applied-observation buffer for worker-replica mode (§D.5 sync).
    shard_log: ShardLog,
}

impl Kakurenbo {
    pub fn new(n: usize, hide_ratio: f64, conf_threshold: f32) -> Self {
        assert!((0.0..1.0).contains(&hide_ratio));
        assert!((0.0..1.0).contains(&conf_threshold));
        Kakurenbo {
            hide_ratio,
            loss_threshold: -(conf_threshold.ln()),
            last: vec![f32::NAN; n],
            prev_epoch: vec![f32::NAN; n],
            shard_log: ShardLog::default(),
        }
    }
}

impl Sampler for Kakurenbo {
    fn name(&self) -> &'static str {
        "ka"
    }

    fn n(&self) -> usize {
        self.last.len()
    }

    fn on_epoch_start(&mut self, epoch: usize, _rng: &mut Pcg64) -> Vec<u32> {
        let n = self.n();
        if epoch == 0 {
            return (0..n as u32).collect();
        }
        // Rank by current loss ascending; the lowest `hide_ratio` fraction
        // that is also confidently fit is a candidate for hiding.
        let scores: Vec<f32> =
            self.last.iter().map(|&l| if l.is_finite() { l } else { f32::INFINITY }).collect();
        let order = math::argsort(&scores);
        let max_hidden = (self.hide_ratio * n as f64).floor() as usize;
        let mut hidden = vec![false; n];
        let mut count = 0usize;
        for &i in order.iter() {
            if count >= max_hidden {
                break;
            }
            let i = i as usize;
            let l = self.last[i];
            if !l.is_finite() || l > self.loss_threshold {
                break; // remaining samples are not confidently fit
            }
            // Moving-back: if the loss increased since last epoch, the
            // model is forgetting this sample — keep it in.
            let moving_back = self.prev_epoch[i].is_finite() && l > self.prev_epoch[i] + 1e-6;
            if !moving_back {
                hidden[i] = true;
                count += 1;
            }
        }
        // Snapshot losses for next epoch's move-back comparison.
        self.prev_epoch.copy_from_slice(&self.last);
        let kept: Vec<u32> = (0..n as u32).filter(|&i| !hidden[i as usize]).collect();
        if kept.is_empty() {
            (0..n as u32).collect()
        } else {
            kept
        }
    }

    fn observe_train(&mut self, indices: &[u32], losses: &[f32], _epoch: usize) {
        self.shard_log.record(indices, losses);
        for (&i, &l) in indices.iter().zip(losses) {
            self.last[i as usize] = l;
        }
    }

    fn select(&mut self, meta: &[u32], _mini: usize, _epoch: usize, _rng: &mut Pcg64) -> Selection {
        Selection::unweighted(meta.to_vec())
    }

    fn begin_shard(&mut self, _shard: &[u32]) {
        self.shard_log.begin();
    }

    fn export_observations(&mut self) -> ShardObservations {
        self.shard_log.export()
    }

    fn merge_observations(&mut self, obs: &[(Vec<u32>, Vec<f32>)], _epoch: usize) {
        // Apply directly (not via observe_train) so merged peer state is
        // not re-exported from the local shard log.
        for (indices, losses) in obs {
            for (&i, &l) in indices.iter().zip(losses) {
                self.last[i as usize] = l;
            }
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn observed(n: usize, losses: &[f32]) -> Kakurenbo {
        let mut ka = Kakurenbo::new(n, 0.3, 0.7);
        let idx: Vec<u32> = (0..n as u32).collect();
        ka.observe_train(&idx, losses, 0);
        ka
    }

    #[test]
    fn hides_lowest_loss_confident_samples() {
        // τ=0.7 => threshold ≈ 0.357. Samples 0..3 are confidently fit.
        let losses = [0.01, 0.02, 0.03, 0.04, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0];
        let mut ka = observed(10, &losses);
        let kept = ka.on_epoch_start(1, &mut Pcg64::new(0));
        // hide_ratio 0.3 => up to 3 hidden; the 3 lowest-loss hidden.
        assert_eq!(kept.len(), 7);
        for h in [0u32, 1, 2] {
            assert!(!kept.contains(&h), "{h} should be hidden");
        }
        assert!(kept.contains(&3));
    }

    #[test]
    fn unconfident_samples_never_hidden() {
        let losses = [1.0f32; 10]; // all above -ln(0.7)
        let mut ka = observed(10, &losses);
        let kept = ka.on_epoch_start(1, &mut Pcg64::new(0));
        assert_eq!(kept.len(), 10);
    }

    #[test]
    fn moving_back_rescues_forgotten_samples() {
        let mut ka = Kakurenbo::new(6, 0.5, 0.7);
        let idx: Vec<u32> = (0..6).collect();
        ka.observe_train(&idx, &[0.01, 0.02, 0.03, 1.0, 1.0, 1.0], 0);
        let _ = ka.on_epoch_start(1, &mut Pcg64::new(0)); // snapshots prev
        // Sample 0's loss increased since the snapshot => moved back.
        ka.observe_train(&idx, &[0.2, 0.02, 0.03, 1.0, 1.0, 1.0], 1);
        let kept = ka.on_epoch_start(2, &mut Pcg64::new(0));
        assert!(kept.contains(&0), "increased-loss sample moved back");
        assert!(!kept.contains(&1), "still-easy sample hidden");
    }

    #[test]
    fn epoch_zero_keeps_everything() {
        let mut ka = Kakurenbo::new(5, 0.3, 0.7);
        assert_eq!(ka.on_epoch_start(0, &mut Pcg64::new(0)).len(), 5);
    }

    #[test]
    fn is_set_level_only() {
        let ka = Kakurenbo::new(5, 0.3, 0.7);
        assert!(!ka.needs_meta_losses(1));
    }
}
