//! CIFAR-style scenario: the Table-2 workload on one dataset, comparing
//! the full method zoo (baseline + 3 set-level + 3 batch-level + ESWP)
//! through one shared [`Session`] — swap the sampler, rerun.
//!
//!     make artifacts && cargo run --release --example cifar_selection
//!
//! Flags via env: EVOSAMPLE_BENCH_FULL=1 for paper-scale sizes.

use evosample::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let (n, epochs) = match scale {
        Scale::Smoke => (2048, 10),
        Scale::Full => (16384, 60),
    };
    let dataset = DatasetConfig::SynthCifar { n, classes: 100, label_noise: 0.05, hard_frac: 0.2 };
    let mut session = SessionBuilder::new("cnn_small_c100", dataset)
        .named("cifar_selection")
        .epochs(epochs)
        .batch_sizes(128, 32)
        .lr(LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 })
        .test_n(512)
        .seed(7)
        .build()?;

    println!("{:<14} {:>7} {:>9} {:>16}", "method", "acc%", "wall s", "saved (pred)");
    let mut base_cost = None;
    for sampler in all_samplers() {
        session.set_sampler(sampler);
        let r = session.run()?;
        match &base_cost {
            None => {
                println!(
                    "{:<14} {:>7.2} {:>9.2} {:>16}",
                    r.sampler, r.accuracy_pct(), r.cost.train_wall_s(), "—"
                );
                base_cost = Some(r.cost.clone());
            }
            Some(b) => println!(
                "{:<14} {:>7.2} {:>9.2} {:>7.1}% ({:>5.1}%)",
                r.sampler,
                r.accuracy_pct(),
                r.cost.train_wall_s(),
                saved_time_pct(b, &r.cost),
                predicted_saved_time_pct(b, &r.cost)
            ),
        }
    }
    Ok(())
}
