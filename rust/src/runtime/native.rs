//! NativeRuntime: a pure-rust one-hidden-layer MLP classifier with
//! hand-written forward/backward and SGD-momentum.
//!
//! Purpose (DESIGN.md §3): (a) lets the entire coordinator stack be tested
//! and benchmarked without AOT artifacts, (b) provides an independent
//! second implementation of weighted-batch training to cross-check the XLA
//! path, and (c) isolates L3 overhead in the perf benches (selection cost
//! vs BP cost with a known-cost backend).
//!
//! Model: x[in_dim] → relu(W1 x + b1)[hidden] → W2 h + b2 → softmax CE.
//! Per-sample losses, weighted gradient (Σ w_i ∇ℓ_i / Σ w_i) — the same
//! objective the L2 train_step lowers.

use super::{BatchX, ModelRuntime, StepOutput};
use crate::util::Pcg64;

#[derive(Clone)]
pub struct NativeRuntime {
    in_dim: usize,
    hidden: usize,
    classes: usize,
    momentum: f32,
    weight_decay: f32,
    /// [W1 (in*h) | b1 (h) | W2 (h*c) | b2 (c)]
    params: Vec<f32>,
    velocity: Vec<f32>,
    grads: Vec<f32>,
    /// Supported batch sizes are unconstrained for the native path, but we
    /// report the configured ones so the trainer's validation still runs.
    fwd_size: usize,
    eval_size: usize,
    // scratch
    h_buf: Vec<f32>,
    logits_buf: Vec<f32>,
}

impl NativeRuntime {
    pub fn new(in_dim: usize, hidden: usize, classes: usize) -> Self {
        let pc = in_dim * hidden + hidden + hidden * classes + classes;
        NativeRuntime {
            in_dim,
            hidden,
            classes,
            momentum: 0.9,
            weight_decay: 0.0,
            params: vec![0.0; pc],
            velocity: vec![0.0; pc],
            grads: vec![0.0; pc],
            fwd_size: 0,
            eval_size: 0,
            h_buf: Vec::new(),
            logits_buf: Vec::new(),
        }
    }

    fn layout(&self) -> (usize, usize, usize, usize) {
        let w1 = 0;
        let b1 = self.in_dim * self.hidden;
        let w2 = b1 + self.hidden;
        let b2 = w2 + self.hidden * self.classes;
        (w1, b1, w2, b2)
    }

    /// Forward one batch; fills h_buf [n*hidden] and logits_buf [n*classes].
    fn forward(&mut self, x: &[f32], n: usize) {
        let (w1, b1, w2, b2) = self.layout();
        let (d, h, c) = (self.in_dim, self.hidden, self.classes);
        self.h_buf.resize(n * h, 0.0);
        self.logits_buf.resize(n * c, 0.0);
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            let hi = &mut self.h_buf[i * h..(i + 1) * h];
            for j in 0..h {
                // W1 stored row-major [d][h]: column j dotted with x.
                let mut acc = self.params[b1 + j];
                for k in 0..d {
                    acc += self.params[w1 + k * h + j] * xi[k];
                }
                hi[j] = acc.max(0.0); // relu
            }
            let li = &mut self.logits_buf[i * c..(i + 1) * c];
            for j in 0..c {
                let mut acc = self.params[b2 + j];
                for k in 0..h {
                    acc += self.params[w2 + k * c + j] * self.h_buf[i * h + k];
                }
                li[j] = acc;
            }
        }
    }

    /// Per-sample CE losses from logits_buf.
    fn ce_losses(&self, y: &[i32], n: usize) -> Vec<f32> {
        let c = self.classes;
        (0..n)
            .map(|i| {
                let li = &self.logits_buf[i * c..(i + 1) * c];
                let m = li.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let lse = li.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
                lse - li[y[i] as usize]
            })
            .collect()
    }

    fn expect_f32<'a>(x: BatchX<'a>) -> anyhow::Result<&'a [f32]> {
        match x {
            BatchX::F32(v) => Ok(v),
            BatchX::I32(_) => anyhow::bail!("NativeRuntime supports float features only"),
        }
    }
}

impl ModelRuntime for NativeRuntime {
    fn param_count(&self) -> usize {
        self.params.len()
    }

    fn init(&mut self, seed: i32) -> anyhow::Result<()> {
        let mut rng = Pcg64::new(seed as u64 ^ 0xab5e1);
        let (_, b1, w2, b2) = self.layout();
        let std1 = (2.0 / self.in_dim as f32).sqrt();
        let std2 = (2.0 / self.hidden as f32).sqrt();
        for i in 0..self.params.len() {
            self.params[i] = if i < b1 {
                std1 * rng.normal()
            } else if i < w2 {
                0.0
            } else if i < b2 {
                std2 * rng.normal()
            } else {
                0.0
            };
        }
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
        Ok(())
    }

    fn loss_fwd(&mut self, x: BatchX<'_>, y: &[i32], n: usize) -> anyhow::Result<Vec<f32>> {
        let x = Self::expect_f32(x)?;
        anyhow::ensure!(x.len() == n * self.in_dim && y.len() == n, "batch shape mismatch");
        self.forward(x, n);
        Ok(self.ce_losses(y, n))
    }

    fn train_step(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
    ) -> anyhow::Result<StepOutput> {
        let x = Self::expect_f32(x)?;
        anyhow::ensure!(x.len() == n * self.in_dim, "x shape");
        anyhow::ensure!(y.len() == n && weights.len() == n, "y/weights shape");
        self.forward(x, n);
        let losses = self.ce_losses(y, n);
        let wsum: f32 = weights.iter().sum::<f32>().max(1e-12);
        let mean_loss =
            losses.iter().zip(weights).map(|(&l, &w)| l * w).sum::<f32>() / wsum;

        // Backward: dlogits = w_i/Σw * (softmax - onehot).
        let (w1o, b1o, w2o, b2o) = self.layout();
        let (d, h, c) = (self.in_dim, self.hidden, self.classes);
        self.grads.iter_mut().for_each(|g| *g = 0.0);
        let mut dh = vec![0.0f32; h];
        for i in 0..n {
            let scale = weights[i] / wsum;
            if scale == 0.0 {
                continue;
            }
            let li = &self.logits_buf[i * c..(i + 1) * c];
            let m = li.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = li.iter().map(|&v| (v - m).exp()).sum();
            let hi = &self.h_buf[i * h..(i + 1) * h];
            let xi = &x[i * d..(i + 1) * d];
            dh.iter_mut().for_each(|v| *v = 0.0);
            for j in 0..c {
                let p = (li[j] - m).exp() / z;
                let dl = scale * (p - if y[i] as usize == j { 1.0 } else { 0.0 });
                self.grads[b2o + j] += dl;
                for k in 0..h {
                    self.grads[w2o + k * c + j] += dl * hi[k];
                    dh[k] += dl * self.params[w2o + k * c + j];
                }
            }
            for k in 0..h {
                if hi[k] <= 0.0 {
                    continue; // relu gate
                }
                self.grads[b1o + k] += dh[k];
                let g = dh[k];
                for q in 0..d {
                    self.grads[w1o + q * h + k] += g * xi[q];
                }
            }
        }
        // SGD momentum + weight decay.
        for i in 0..self.params.len() {
            let g = self.grads[i] + self.weight_decay * self.params[i];
            self.velocity[i] = self.momentum * self.velocity[i] + g;
            self.params[i] -= lr * self.velocity[i];
        }
        Ok(StepOutput { losses, mean_loss })
    }

    fn eval(&mut self, x: BatchX<'_>, y: &[i32], n: usize) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let xs = Self::expect_f32(x)?;
        self.forward(xs, n);
        let losses = self.ce_losses(y, n);
        let c = self.classes;
        let correct = (0..n)
            .map(|i| {
                let li = &self.logits_buf[i * c..(i + 1) * c];
                let argmax = li
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                (argmax == y[i] as usize) as u8 as f32
            })
            .collect();
        Ok((losses, correct))
    }

    fn train_sizes(&self) -> Vec<usize> {
        Vec::new() // native path accepts any batch size
    }

    fn fwd_size(&self) -> usize {
        self.fwd_size
    }

    fn eval_size(&self) -> usize {
        self.eval_size
    }

    fn get_params(&mut self) -> anyhow::Result<Vec<f32>> {
        Ok(self.params.clone())
    }

    fn set_params(&mut self, params: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(params.len() == self.params.len(), "param count mismatch");
        self.params.copy_from_slice(params);
        Ok(())
    }

    fn flops_per_sample_fwd(&self) -> u64 {
        (2 * self.in_dim * self.hidden + 2 * self.hidden * self.classes) as u64
    }

    fn spawn_replica(&self) -> anyhow::Result<Box<dyn ModelRuntime + Send>> {
        // Pure host state: a replica is a deep copy (params, velocity,
        // scratch) sharing nothing with the parent.
        Ok(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(n: usize, d: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        // Linearly separable blobs: class c centered at unit vector e_c.
        let mut rng = Pcg64::new(seed);
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0i32; n];
        for i in 0..n {
            let c = i % classes;
            y[i] = c as i32;
            for j in 0..d {
                x[i * d + j] = if j == c { 2.0 } else { 0.0 } + 0.3 * rng.normal();
            }
        }
        (x, y)
    }

    #[test]
    fn overfits_separable_blobs() {
        let mut rt = NativeRuntime::new(8, 16, 4);
        rt.init(0).unwrap();
        let (x, y) = toy_batch(32, 8, 4, 1);
        let w = vec![1.0; 32];
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.1, 32).unwrap();
            first.get_or_insert(out.mean_loss);
            last = out.mean_loss;
        }
        assert!(last < 0.2 * first.unwrap(), "{} -> {last}", first.unwrap());
        let (_, correct) = rt.eval(BatchX::F32(&x), &y, 32).unwrap();
        let acc: f32 = correct.iter().sum::<f32>() / 32.0;
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn losses_match_loss_fwd() {
        let mut rt = NativeRuntime::new(8, 16, 4);
        rt.init(3).unwrap();
        let (x, y) = toy_batch(16, 8, 4, 2);
        let fwd = rt.loss_fwd(BatchX::F32(&x), &y, 16).unwrap();
        let w = vec![1.0; 16];
        // train_step computes losses at the SAME params before updating.
        let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.01, 16).unwrap();
        for (a, b) in fwd.iter().zip(&out.losses) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_weight_samples_do_not_affect_update() {
        let (x, y) = toy_batch(8, 8, 4, 3);
        let mut rt1 = NativeRuntime::new(8, 8, 4);
        rt1.init(7).unwrap();
        let mut rt2 = NativeRuntime::new(8, 8, 4);
        rt2.init(7).unwrap();
        let mut w = vec![1.0f32; 8];
        w[4..].iter_mut().for_each(|v| *v = 0.0);
        // rt2 sees garbage in the zero-weighted rows.
        let mut x2 = x.clone();
        for v in &mut x2[4 * 8..] {
            *v = 99.0;
        }
        rt1.train_step(BatchX::F32(&x), &y, &w, 0.1, 8).unwrap();
        rt2.train_step(BatchX::F32(&x2), &y, &w, 0.1, 8).unwrap();
        let p1 = rt1.get_params().unwrap();
        let p2 = rt2.get_params().unwrap();
        for (a, b) in p1.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gradcheck_against_finite_differences() {
        // Weighted-CE gradient vs central differences on a tiny model.
        let mut rt = NativeRuntime::new(3, 4, 3);
        rt.init(11).unwrap();
        let (x, y) = toy_batch(4, 3, 3, 5);
        let w = vec![0.7f32, 1.3, 0.0, 2.0];

        let loss_at = |rt: &mut NativeRuntime, params: &[f32]| -> f32 {
            rt.set_params(params).unwrap();
            let l = rt.loss_fwd(BatchX::F32(&x), &y, 4).unwrap();
            let ws: f32 = w.iter().sum();
            l.iter().zip(&w).map(|(&l, &wi)| l * wi).sum::<f32>() / ws
        };

        let p0 = rt.get_params().unwrap();
        // Analytic grads: run one step with lr so small the params barely
        // move, but read rt.grads directly instead.
        rt.set_params(&p0).unwrap();
        rt.train_step(BatchX::F32(&x), &y, &w, 0.0, 4).unwrap();
        let analytic = rt.grads.clone();

        let eps = 1e-3f32;
        let mut checked = 0;
        for idx in (0..p0.len()).step_by(p0.len() / 13 + 1) {
            let mut pp = p0.clone();
            pp[idx] += eps;
            let lp = loss_at(&mut rt, &pp);
            pp[idx] -= 2.0 * eps;
            let lm = loss_at(&mut rt, &pp);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - analytic[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {idx}: fd={fd} analytic={}",
                analytic[idx]
            );
            checked += 1;
        }
        assert!(checked >= 10);
    }

    #[test]
    fn init_resets_state_deterministically() {
        let mut rt = NativeRuntime::new(4, 4, 2);
        rt.init(5).unwrap();
        let a = rt.get_params().unwrap();
        let (x, y) = toy_batch(4, 4, 2, 6);
        rt.train_step(BatchX::F32(&x), &y, &[1.0; 4], 0.1, 4).unwrap();
        rt.init(5).unwrap();
        assert_eq!(rt.get_params().unwrap(), a);
    }

    #[test]
    fn rejects_token_batches() {
        let mut rt = NativeRuntime::new(4, 4, 2);
        rt.init(0).unwrap();
        assert!(rt.loss_fwd(BatchX::I32(&[1, 2]), &[0], 1).is_err());
    }

    #[test]
    fn replica_starts_equal_then_diverges_independently() {
        let mut rt = NativeRuntime::new(8, 8, 4);
        rt.init(2).unwrap();
        let mut replica = rt.spawn_replica().unwrap();
        assert_eq!(rt.get_params().unwrap(), replica.get_params().unwrap());

        let (x, y) = toy_batch(8, 8, 4, 4);
        replica.train_step(BatchX::F32(&x), &y, &[1.0; 8], 0.1, 8).unwrap();
        assert_ne!(
            rt.get_params().unwrap(),
            replica.get_params().unwrap(),
            "replica steps must not touch the parent"
        );

        // Param-averaging round brings them back together.
        let p = replica.get_params().unwrap();
        rt.set_params(&p).unwrap();
        assert_eq!(rt.get_params().unwrap(), p);
    }
}
