//! Typed experiment configuration + validation.
//!
//! A `RunConfig` fully determines one training run (model, dataset,
//! sampler, schedule, batching, trials, seeds). Configs come from three
//! places: TOML files (`evosample train --config run.toml`), CLI overrides,
//! and the built-in experiment presets (`config::presets`) that regenerate
//! the paper's tables.

use super::toml::Doc;

/// Which dynamic-sampling method drives data selection (paper Tab. 1).
#[derive(Clone, Debug, PartialEq)]
pub enum SamplerConfig {
    /// Standard batched sampling — the paper's "Baseline".
    Uniform,
    /// Loss-proportional batch selection (Katharopoulos & Fleuret 2017).
    Loss,
    /// Ordered SGD: top-q losses per meta-batch (Kawaguchi & Lu 2020).
    Ordered,
    /// Evolved Sampling (paper Eq. 3.1), batch level.
    Es { beta1: f32, beta2: f32, anneal_frac: f64 },
    /// ES With Pruning: ES + set-level epoch pruning.
    Eswp { beta1: f32, beta2: f32, anneal_frac: f64, prune_ratio: f64 },
    /// InfoBatch (Qin et al. 2024): prune below-mean losses, rescale kept.
    InfoBatch { prune_ratio: f64, anneal_frac: f64 },
    /// KAKURENBO (Thao Nguyen et al. 2023): hide easiest samples w/ move-back.
    Kakurenbo { prune_ratio: f64, conf_threshold: f32 },
    /// UCB dynamic pruning (Raju et al. 2021).
    Ucb { prune_ratio: f64, decay: f32, c: f32 },
    /// Purely random set-level pruning (ablation Tab. 7).
    RandomPrune { prune_ratio: f64 },
    /// An externally-registered policy (`sampler::registry::register`),
    /// addressed by registry name with resolved numeric params.
    Custom { name: String, params: Vec<(String, f64)> },
}

impl SamplerConfig {
    /// Paper defaults: ES (0.2, 0.9); ESWP (0.2, 0.8, r=0.2); 5% annealing.
    pub fn es_default() -> Self {
        SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.05 }
    }

    pub fn eswp_default() -> Self {
        SamplerConfig::Eswp { beta1: 0.2, beta2: 0.8, anneal_frac: 0.05, prune_ratio: 0.2 }
    }

    pub fn infobatch_default() -> Self {
        // InfoBatch defaults from the original paper: r=0.5, anneal δ=0.875.
        SamplerConfig::InfoBatch { prune_ratio: 0.5, anneal_frac: 0.125 }
    }

    pub fn kakurenbo_default() -> Self {
        SamplerConfig::Kakurenbo { prune_ratio: 0.3, conf_threshold: 0.7 }
    }

    pub fn ucb_default() -> Self {
        SamplerConfig::Ucb { prune_ratio: 0.3, decay: 0.8, c: 1.0 }
    }

    pub fn name(&self) -> &str {
        match self {
            SamplerConfig::Uniform => "baseline",
            SamplerConfig::Loss => "loss",
            SamplerConfig::Ordered => "order",
            SamplerConfig::Es { .. } => "es",
            SamplerConfig::Eswp { .. } => "eswp",
            SamplerConfig::InfoBatch { .. } => "infobatch",
            SamplerConfig::Kakurenbo { .. } => "ka",
            SamplerConfig::Ucb { .. } => "ucb",
            SamplerConfig::RandomPrune { .. } => "random_prune",
            SamplerConfig::Custom { name, .. } => name,
        }
    }

    /// Registry spec: (canonical name, explicit param bag). Construction
    /// and taxonomy queries route through `sampler::registry` with this.
    pub fn to_spec(&self) -> (String, crate::sampler::registry::ParamBag) {
        use crate::sampler::registry::bag;
        let params = match self {
            SamplerConfig::Uniform
            | SamplerConfig::Loss
            | SamplerConfig::Ordered => Default::default(),
            SamplerConfig::Es { beta1, beta2, anneal_frac } => bag(&[
                ("beta1", *beta1 as f64),
                ("beta2", *beta2 as f64),
                ("anneal_frac", *anneal_frac),
            ]),
            SamplerConfig::Eswp { beta1, beta2, anneal_frac, prune_ratio } => bag(&[
                ("beta1", *beta1 as f64),
                ("beta2", *beta2 as f64),
                ("anneal_frac", *anneal_frac),
                ("prune_ratio", *prune_ratio),
            ]),
            SamplerConfig::InfoBatch { prune_ratio, anneal_frac } => {
                bag(&[("prune_ratio", *prune_ratio), ("anneal_frac", *anneal_frac)])
            }
            SamplerConfig::Kakurenbo { prune_ratio, conf_threshold } => bag(&[
                ("prune_ratio", *prune_ratio),
                ("conf_threshold", *conf_threshold as f64),
            ]),
            SamplerConfig::Ucb { prune_ratio, decay, c } => bag(&[
                ("prune_ratio", *prune_ratio),
                ("decay", *decay as f64),
                ("c", *c as f64),
            ]),
            SamplerConfig::RandomPrune { prune_ratio } => {
                bag(&[("prune_ratio", *prune_ratio)])
            }
            SamplerConfig::Custom { params, .. } => {
                params.iter().map(|(k, v)| (k.clone(), *v)).collect()
            }
        };
        (self.name().to_string(), params)
    }

    /// Batch-level methods need per-step scoring FPs over the meta-batch.
    pub fn is_batch_level(&self) -> bool {
        use crate::sampler::SamplerKind;
        match self {
            SamplerConfig::Custom { name, .. } => matches!(
                crate::sampler::registry::kind_of(name),
                Some(SamplerKind::BatchLevel) | Some(SamplerKind::Both)
            ),
            _ => matches!(
                self,
                SamplerConfig::Loss
                    | SamplerConfig::Ordered
                    | SamplerConfig::Es { .. }
                    | SamplerConfig::Eswp { .. }
            ),
        }
    }

    /// Set-level methods prune the dataset at epoch boundaries.
    pub fn is_set_level(&self) -> bool {
        use crate::sampler::SamplerKind;
        match self {
            SamplerConfig::Custom { name, .. } => matches!(
                crate::sampler::registry::kind_of(name),
                Some(SamplerKind::SetLevel) | Some(SamplerKind::Both)
            ),
            _ => matches!(
                self,
                SamplerConfig::Eswp { .. }
                    | SamplerConfig::InfoBatch { .. }
                    | SamplerConfig::Kakurenbo { .. }
                    | SamplerConfig::Ucb { .. }
                    | SamplerConfig::RandomPrune { .. }
            ),
        }
    }
}

/// Learning-rate schedules (computed in rust, passed as a scalar input to
/// every train_step artifact — so schedules never require re-lowering).
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Const { lr: f64 },
    /// OneCycle w/ cosine anneal (Smith & Topin 2019) — the CIFAR recipe.
    OneCycle { max_lr: f64, warmup_frac: f64 },
    /// Linear warmup then cosine decay — the transformer recipe.
    WarmupCosine { base_lr: f64, warmup_frac: f64, min_lr: f64 },
    /// Polynomial decay with warmup — the ALBERT/GLUE recipe.
    Poly { base_lr: f64, power: f64, warmup_frac: f64 },
}

impl LrSchedule {
    /// lr at `step` of `total` steps.
    pub fn lr_at(&self, step: usize, total: usize) -> f64 {
        let total = total.max(1);
        let t = (step as f64 / total as f64).clamp(0.0, 1.0);
        match *self {
            LrSchedule::Const { lr } => lr,
            LrSchedule::OneCycle { max_lr, warmup_frac } => {
                if t < warmup_frac {
                    max_lr * (t / warmup_frac.max(1e-9))
                } else {
                    let u = (t - warmup_frac) / (1.0 - warmup_frac).max(1e-9);
                    max_lr * 0.5 * (1.0 + (std::f64::consts::PI * u).cos())
                }
            }
            LrSchedule::WarmupCosine { base_lr, warmup_frac, min_lr } => {
                if t < warmup_frac {
                    base_lr * (t / warmup_frac.max(1e-9))
                } else {
                    let u = (t - warmup_frac) / (1.0 - warmup_frac).max(1e-9);
                    min_lr + (base_lr - min_lr) * 0.5 * (1.0 + (std::f64::consts::PI * u).cos())
                }
            }
            LrSchedule::Poly { base_lr, power, warmup_frac } => {
                if t < warmup_frac {
                    base_lr * (t / warmup_frac.max(1e-9))
                } else {
                    let u = (t - warmup_frac) / (1.0 - warmup_frac).max(1e-9);
                    base_lr * (1.0 - u).max(0.0).powf(power)
                }
            }
        }
    }
}

/// Synthetic dataset descriptor (generators live in `crate::data`).
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetConfig {
    /// CIFAR-like class-prototype images: flat f32[3072].
    SynthCifar { n: usize, classes: usize, label_noise: f64, hard_frac: f64 },
    /// Zipf-grammar token corpus for LM training: i32[seq] x/y pairs.
    LmCorpus { n: usize, vocab: usize, seq: usize },
    /// GLUE-like NLU classification task (one of 8 synthetic tasks).
    Nlu { task: String, n: usize, vocab: usize, seq: usize, classes: usize },
    /// Unlabeled images for MAE pre-training.
    MaeImages { n: usize, dim: usize },
}

impl DatasetConfig {
    pub fn n(&self) -> usize {
        match self {
            DatasetConfig::SynthCifar { n, .. }
            | DatasetConfig::LmCorpus { n, .. }
            | DatasetConfig::Nlu { n, .. }
            | DatasetConfig::MaeImages { n, .. } => *n,
        }
    }
}

/// Numeric precision of the sampler's scoring forward pass (the
/// ScoringFp stage). Selection only needs a *ranking*, so the scoring
/// FP can run on reduced-precision weights without touching what the
/// optimizer sees — the BP batch and eval always run exact (DESIGN.md
/// §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScoringPrecision {
    /// Exact f32 scoring (`loss_fwd_into`) — bit-for-bit the historical
    /// behavior. The default.
    #[default]
    Exact,
    /// bf16-weight scoring (`loss_fwd_ranked`): runtimes that support it
    /// score from a bf16 shadow of the weights; others transparently
    /// fall back to exact.
    Bf16,
}

impl ScoringPrecision {
    pub fn parse(s: &str) -> Result<ScoringPrecision, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "exact" | "f32" | "fp32" => Ok(ScoringPrecision::Exact),
            "bf16" => Ok(ScoringPrecision::Bf16),
            other => Err(format!(
                "unknown scoring_precision {other:?} (expected \"exact\" or \"bf16\")"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ScoringPrecision::Exact => "exact",
            ScoringPrecision::Bf16 => "bf16",
        }
    }
}

/// Telemetry level for the run (`run.telemetry`, DESIGN.md §11):
/// `off` (default, near-zero overhead), `counters` (metrics registry
/// accumulates), or `trace` (counters + ring-buffered spans exportable
/// as Chrome-trace JSON via `--trace-out`). Telemetry observes the run
/// without perturbing it — determinism holds at every level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TelemetryLevel {
    #[default]
    Off,
    Counters,
    Trace,
}

impl TelemetryLevel {
    pub fn parse(s: &str) -> Result<TelemetryLevel, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "0" => Ok(TelemetryLevel::Off),
            "counters" | "metrics" => Ok(TelemetryLevel::Counters),
            "trace" | "full" => Ok(TelemetryLevel::Trace),
            other => Err(format!(
                "unknown telemetry {other:?} (expected \"off\", \"counters\", or \"trace\")"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TelemetryLevel::Off => "off",
            TelemetryLevel::Counters => "counters",
            TelemetryLevel::Trace => "trace",
        }
    }

    /// The `crate::obs` level constant this config level maps to.
    pub fn as_obs_level(&self) -> u8 {
        match self {
            TelemetryLevel::Off => crate::obs::OFF,
            TelemetryLevel::Counters => crate::obs::COUNTERS,
            TelemetryLevel::Trace => crate::obs::TRACE,
        }
    }
}

/// One fully-specified training run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub name: String,
    /// Manifest model name (e.g. "cnn_small_c100").
    pub model: String,
    pub dataset: DatasetConfig,
    pub sampler: SamplerConfig,
    pub epochs: usize,
    /// Meta-batch size B (uniformly drawn each step).
    pub meta_batch: usize,
    /// Mini-batch size b selected for BP (== meta_batch ⇒ no batch selection).
    pub mini_batch: usize,
    /// Scoring cadence k (≥ 1): run the scoring forward pass on every k-th
    /// scoring-eligible step; in between, the sampler selects from its
    /// *cached* weight tables (`Sampler::select_cached`). The paper's
    /// "flexible frequency tuning" — the extra FP of §3.3 amortizes to
    /// ~1/k of its cost. `1` (default) is the historical per-step scoring,
    /// bit-for-bit. See DESIGN.md §8.
    pub score_every: usize,
    /// Precision of the scoring FP: `Exact` (default, bit-for-bit) or
    /// `Bf16` (rank from a bf16 weight shadow — stacks multiplicatively
    /// with `score_every`). Never affects the BP batch or eval. See
    /// DESIGN.md §9.
    pub scoring_precision: ScoringPrecision,
    pub lr: LrSchedule,
    pub seed: u64,
    /// Evaluate on the held-out set every k epochs (0 = only at end).
    pub eval_every: usize,
    /// Held-out test set size.
    pub test_n: usize,
    /// Gradient-accumulation micro-batch (0 = off). Fig. 4 low-resource mode.
    pub micro_batch: usize,
    /// Data-parallel workers (1 = off). Table 4 pre-training mode.
    pub workers: usize,
    /// Run `workers` as real `std::thread` replicas instead of the
    /// sequential simulation. Requires a runtime with `spawn_replica`
    /// (NativeRuntime); see DESIGN.md §2.
    pub threaded_workers: bool,
    /// Threaded mode: average replica parameters every `sync_every` local
    /// steps (0 = only at epoch boundaries, the §D.5 default).
    pub sync_every: usize,
    /// Kernel worker threads for the native runtime's blocked kernels
    /// (0 = auto: the `EVOSAMPLE_KERNEL_THREADS` env var, else
    /// `available_parallelism`, clamped to the fixed gradient-shard
    /// count). Thread count never changes numerics (DESIGN.md §7).
    /// NOTE: applies to the main runtime only — in threaded
    /// data-parallel mode (`threaded_workers`) each worker replica is
    /// pinned to 1 kernel lane by `spawn_replica` so W replicas don't
    /// oversubscribe the box; parallelism there comes from the workers.
    pub kernel_threads: usize,
    /// Telemetry level applied (raised, process-wide — see
    /// `crate::obs`) when the session starts: `off` | `counters` |
    /// `trace`. Purely observational; never changes numerics or event
    /// ordering (DESIGN.md §11).
    pub telemetry: TelemetryLevel,
}

impl RunConfig {
    /// Sensible small defaults; presets/TOML override.
    pub fn new(name: &str, model: &str, dataset: DatasetConfig) -> Self {
        RunConfig {
            name: name.to_string(),
            model: model.to_string(),
            dataset,
            sampler: SamplerConfig::Uniform,
            epochs: 10,
            meta_batch: 128,
            mini_batch: 32,
            score_every: 1,
            scoring_precision: ScoringPrecision::Exact,
            lr: LrSchedule::Const { lr: 1e-3 },
            seed: 0,
            eval_every: 0,
            test_n: 512,
            micro_batch: 0,
            workers: 1,
            threaded_workers: false,
            sync_every: 0,
            kernel_threads: 0,
            telemetry: TelemetryLevel::Off,
        }
    }

    pub fn with_sampler(mut self, s: SamplerConfig) -> Self {
        self.sampler = s;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.epochs == 0 {
            return Err("epochs must be >= 1".into());
        }
        if self.mini_batch == 0 || self.meta_batch == 0 {
            return Err("batch sizes must be >= 1".into());
        }
        if self.mini_batch > self.meta_batch {
            return Err(format!(
                "mini_batch ({}) must be <= meta_batch ({})",
                self.mini_batch, self.meta_batch
            ));
        }
        if self.dataset.n() < self.meta_batch {
            return Err(format!(
                "dataset n ({}) must be >= meta_batch ({})",
                self.dataset.n(),
                self.meta_batch
            ));
        }
        if self.micro_batch > self.mini_batch {
            return Err("micro_batch must be <= mini_batch".into());
        }
        if self.score_every == 0 {
            return Err("score_every must be >= 1 (1 = score every step)".into());
        }
        // Catches negative TOML values too (they wrap huge via `as usize`).
        if self.score_every > 1 << 20 {
            return Err("score_every out of range".into());
        }
        if self.workers == 0 {
            return Err("workers must be >= 1".into());
        }
        if self.threaded_workers && self.workers < 2 {
            return Err("threaded_workers requires workers >= 2".into());
        }
        if self.sync_every > 0 && !self.threaded_workers {
            return Err("sync_every requires threaded_workers".into());
        }
        // Catches negative TOML values too (they wrap huge via `as usize`).
        if self.kernel_threads > 1024 {
            return Err("kernel_threads out of range (0 = auto)".into());
        }
        if let SamplerConfig::Custom { name, params } = &self.sampler {
            // Delegate to the registry: the name must be registered and
            // every param declared by its entry.
            let bag: crate::sampler::registry::ParamBag =
                params.iter().map(|(k, v)| (k.clone(), *v)).collect();
            crate::sampler::registry::parse(name, &bag)?;
        }
        let ratios: &[f64] = match &self.sampler {
            SamplerConfig::Eswp { prune_ratio, anneal_frac, .. } => &[*prune_ratio, *anneal_frac],
            SamplerConfig::InfoBatch { prune_ratio, anneal_frac } => &[*prune_ratio, *anneal_frac],
            SamplerConfig::Kakurenbo { prune_ratio, .. } => &[*prune_ratio],
            SamplerConfig::Ucb { prune_ratio, .. } => &[*prune_ratio],
            SamplerConfig::RandomPrune { prune_ratio } => &[*prune_ratio],
            SamplerConfig::Es { anneal_frac, .. } => &[*anneal_frac],
            _ => &[],
        };
        for r in ratios {
            if !(0.0..1.0).contains(r) {
                return Err(format!("ratio {r} out of [0,1)"));
            }
        }
        if let SamplerConfig::Es { beta1, beta2, .. }
        | SamplerConfig::Eswp { beta1, beta2, .. } = self.sampler
        {
            if !(0.0..=1.0).contains(&beta1) || !(0.0..=1.0).contains(&beta2) {
                return Err(format!("betas ({beta1}, {beta2}) out of [0,1]"));
            }
        }
        Ok(())
    }

    /// Parse from a TOML document (all keys optional except model/dataset).
    pub fn from_doc(doc: &Doc) -> Result<RunConfig, String> {
        let model = doc.require("run.model")?.as_str().ok_or("run.model must be a string")?.to_string();
        let ds_kind = doc.str_or("dataset.kind", "synth_cifar");
        let n = doc.i64_or("dataset.n", 4096) as usize;
        let dataset = match ds_kind.as_str() {
            "synth_cifar" => DatasetConfig::SynthCifar {
                n,
                classes: doc.i64_or("dataset.classes", 10) as usize,
                label_noise: doc.f64_or("dataset.label_noise", 0.05),
                hard_frac: doc.f64_or("dataset.hard_frac", 0.2),
            },
            "lm_corpus" => DatasetConfig::LmCorpus {
                n,
                vocab: doc.i64_or("dataset.vocab", 1024) as usize,
                seq: doc.i64_or("dataset.seq", 64) as usize,
            },
            "nlu" => DatasetConfig::Nlu {
                task: doc.str_or("dataset.task", "sst2"),
                n,
                vocab: doc.i64_or("dataset.vocab", 512) as usize,
                seq: doc.i64_or("dataset.seq", 48) as usize,
                classes: doc.i64_or("dataset.classes", 2) as usize,
            },
            "mae_images" => DatasetConfig::MaeImages {
                n,
                dim: doc.i64_or("dataset.dim", 3072) as usize,
            },
            other => return Err(format!("unknown dataset.kind {other:?}")),
        };
        // Sampler parsing delegates to the open registry: `sampler.kind`
        // names any registered entry (built-in or external), and every
        // other `[sampler]` key lands in its param bag — so unknown
        // methods and typo'd params both fail loudly with the declared
        // alternatives.
        let sampler_kind = doc.str_or("sampler.kind", "baseline");
        let mut sampler_bag = crate::sampler::registry::ParamBag::new();
        for key in doc.keys_under("sampler.") {
            if key == "kind" {
                continue;
            }
            let full = format!("sampler.{key}");
            let v = doc
                .get(&full)
                .and_then(super::toml::Value::as_f64)
                .ok_or_else(|| format!("{full} must be a number"))?;
            sampler_bag.insert(key.to_string(), v);
        }
        let sampler = crate::sampler::registry::parse(&sampler_kind, &sampler_bag)
            .map_err(|e| format!("sampler: {e}"))?;
        let lr = match doc.str_or("lr.schedule", "const").as_str() {
            "const" => LrSchedule::Const { lr: doc.f64_or("lr.lr", 1e-3) },
            "onecycle" => LrSchedule::OneCycle {
                max_lr: doc.f64_or("lr.max_lr", 0.05),
                warmup_frac: doc.f64_or("lr.warmup_frac", 0.3),
            },
            "warmup_cosine" => LrSchedule::WarmupCosine {
                base_lr: doc.f64_or("lr.base_lr", 1e-3),
                warmup_frac: doc.f64_or("lr.warmup_frac", 0.1),
                min_lr: doc.f64_or("lr.min_lr", 0.0),
            },
            "poly" => LrSchedule::Poly {
                base_lr: doc.f64_or("lr.base_lr", 1e-3),
                power: doc.f64_or("lr.power", 1.0),
                warmup_frac: doc.f64_or("lr.warmup_frac", 0.1),
            },
            other => return Err(format!("unknown lr.schedule {other:?}")),
        };
        let cfg = RunConfig {
            name: doc.str_or("run.name", "run"),
            model,
            dataset,
            sampler,
            epochs: doc.i64_or("run.epochs", 10) as usize,
            meta_batch: doc.i64_or("run.meta_batch", 128) as usize,
            mini_batch: doc.i64_or("run.mini_batch", 32) as usize,
            score_every: doc.i64_or("run.score_every", 1) as usize,
            scoring_precision: ScoringPrecision::parse(
                &doc.str_or("run.scoring_precision", "exact"),
            )?,
            lr,
            seed: doc.i64_or("run.seed", 0) as u64,
            eval_every: doc.i64_or("run.eval_every", 0) as usize,
            test_n: doc.i64_or("run.test_n", 512) as usize,
            micro_batch: doc.i64_or("run.micro_batch", 0) as usize,
            workers: doc.i64_or("run.workers", 1) as usize,
            threaded_workers: doc.bool_or("run.threaded_workers", false),
            sync_every: doc.i64_or("run.sync_every", 0) as usize,
            kernel_threads: doc.i64_or("run.kernel_threads", 0) as usize,
            telemetry: TelemetryLevel::parse(&doc.str_or("run.telemetry", "off"))?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Steps per epoch (meta-batches drawn from the possibly-pruned set).
    pub fn steps_per_epoch(&self, kept_n: usize) -> usize {
        kept_n.div_ceil(self.meta_batch)
    }
}

/// The `[serve]` table: knobs for the multi-tenant selection service
/// (`crate::serve`). Parsed from the same TOML documents as `RunConfig`
/// but independent of it — a serve config describes the *server*, each
/// submitted job carries its own run config.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// TCP port on 127.0.0.1 (0 = OS-assigned ephemeral port; the server
    /// prints the bound address on startup).
    pub port: u16,
    /// Jobs allowed to run at once; the rest wait in the queue.
    pub max_concurrent: usize,
    /// Queue depth past the running set. A submit that would exceed it is
    /// shed with an explicit `rejected{reason: "queue_full"}`.
    pub max_queue: usize,
    /// Aggregate cap on *spawned* kernel lanes across all running jobs
    /// (each job's lane 0 is its own worker thread and is never counted).
    /// 0 = auto: `available_parallelism - 1`, floor 1. Budget exhaustion
    /// degrades lane counts, never numerics (DESIGN.md §7).
    pub kernel_budget: usize,
    /// Directory for job records, checkpoints, and results. Jobs found
    /// here in a non-terminal state on startup are resumed.
    pub state_dir: String,
    /// Checkpoint a running job every k completed epochs (0 = never; a
    /// killed server then restarts the job from scratch).
    pub checkpoint_every: usize,
    /// Per-connection read timeout in milliseconds (0 = none). A client
    /// that goes silent mid-request gets a clean
    /// `rejected{reason: "read_timeout"}` instead of pinning a
    /// connection thread forever.
    pub read_timeout_ms: u64,
    /// Transient-failure retry budget per job (0 = fail on first error).
    /// Only errors the fault layer classifies as transient are retried;
    /// cancels and shutdowns are never retried (DESIGN.md §12).
    pub retry_max: usize,
    /// Base backoff before retry attempt k, doubled each attempt:
    /// `retry_backoff_ms * 2^(k-1)` milliseconds.
    pub retry_backoff_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            port: 0,
            max_concurrent: 2,
            max_queue: 16,
            kernel_budget: 0,
            state_dir: "serve_state".to_string(),
            checkpoint_every: 1,
            read_timeout_ms: 30_000,
            retry_max: 2,
            retry_backoff_ms: 50,
        }
    }
}

impl ServeConfig {
    /// Spawned-lane budget with the auto default resolved.
    pub fn effective_kernel_budget(&self) -> usize {
        if self.kernel_budget > 0 {
            self.kernel_budget
        } else {
            let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
            cores.saturating_sub(1).max(1)
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.max_concurrent == 0 {
            return Err("serve.max_concurrent must be >= 1".into());
        }
        // Catch negative TOML values wrapped huge via `as usize`.
        if self.max_concurrent > 1024 {
            return Err("serve.max_concurrent out of range".into());
        }
        if self.max_queue > 1 << 20 {
            return Err("serve.max_queue out of range".into());
        }
        if self.kernel_budget > 4096 {
            return Err("serve.kernel_budget out of range (0 = auto)".into());
        }
        if self.checkpoint_every > 1 << 20 {
            return Err("serve.checkpoint_every out of range (0 = never)".into());
        }
        if self.state_dir.is_empty() {
            return Err("serve.state_dir must not be empty".into());
        }
        if self.read_timeout_ms > 3_600_000 {
            return Err("serve.read_timeout_ms out of range (0 = none)".into());
        }
        if self.retry_max > 16 {
            return Err("serve.retry_max out of range".into());
        }
        if self.retry_backoff_ms > 60_000 {
            return Err("serve.retry_backoff_ms out of range".into());
        }
        Ok(())
    }

    /// Parse the `[serve]` table (every key optional; missing table =
    /// all defaults).
    pub fn from_doc(doc: &Doc) -> Result<ServeConfig, String> {
        let d = ServeConfig::default();
        let port = doc.i64_or("serve.port", d.port as i64);
        if !(0..=u16::MAX as i64).contains(&port) {
            return Err(format!("serve.port {port} out of range"));
        }
        let cfg = ServeConfig {
            port: port as u16,
            max_concurrent: doc.i64_or("serve.max_concurrent", d.max_concurrent as i64) as usize,
            max_queue: doc.i64_or("serve.max_queue", d.max_queue as i64) as usize,
            kernel_budget: doc.i64_or("serve.kernel_budget", d.kernel_budget as i64) as usize,
            state_dir: doc.str_or("serve.state_dir", &d.state_dir),
            checkpoint_every: doc.i64_or("serve.checkpoint_every", d.checkpoint_every as i64)
                as usize,
            read_timeout_ms: doc.i64_or("serve.read_timeout_ms", d.read_timeout_ms as i64)
                as u64,
            retry_max: doc.i64_or("serve.retry_max", d.retry_max as i64) as usize,
            retry_backoff_ms: doc.i64_or("serve.retry_backoff_ms", d.retry_backoff_ms as i64)
                as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunConfig {
        RunConfig::new(
            "t",
            "mlp_cifar10",
            DatasetConfig::SynthCifar { n: 1024, classes: 10, label_noise: 0.0, hard_frac: 0.2 },
        )
    }

    #[test]
    fn default_validates() {
        base().validate().unwrap();
    }

    #[test]
    fn rejects_bad_batches() {
        let mut c = base();
        c.mini_batch = 256;
        c.meta_batch = 128;
        assert!(c.validate().is_err());
        let mut c = base();
        c.meta_batch = 4096;
        assert!(c.validate().is_err(), "meta_batch > n must fail");
    }

    #[test]
    fn rejects_bad_betas_and_ratios() {
        let mut c = base();
        c.sampler = SamplerConfig::Es { beta1: 1.5, beta2: 0.9, anneal_frac: 0.05 };
        assert!(c.validate().is_err());
        c.sampler = SamplerConfig::Eswp {
            beta1: 0.2,
            beta2: 0.8,
            anneal_frac: 0.05,
            prune_ratio: 1.0,
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_toml_roundtrip() {
        let src = r#"
[run]
name = "demo"
model = "cnn_small_c100"
epochs = 20
meta_batch = 128
mini_batch = 32
seed = 7

[dataset]
kind = "synth_cifar"
n = 2048
classes = 100

[sampler]
kind = "eswp"
beta1 = 0.2
beta2 = 0.8
prune_ratio = 0.3

[lr]
schedule = "onecycle"
max_lr = 0.05
"#;
        let doc = Doc::parse(src).unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.name, "demo");
        assert_eq!(cfg.epochs, 20);
        assert_eq!(cfg.sampler.name(), "eswp");
        assert!(matches!(cfg.lr, LrSchedule::OneCycle { .. }));
        assert!(matches!(cfg.dataset, DatasetConfig::SynthCifar { classes: 100, .. }));
    }

    #[test]
    fn threaded_knobs_validate() {
        let mut c = base();
        c.threaded_workers = true;
        assert!(c.validate().is_err(), "threaded with workers=1 must fail");
        c.workers = 4;
        c.validate().unwrap();
        c.sync_every = 8;
        c.validate().unwrap();
        c.threaded_workers = false;
        assert!(c.validate().is_err(), "sync_every without threaded must fail");
        let mut c = base();
        c.kernel_threads = 4;
        c.validate().unwrap();
        c.kernel_threads = (-2i64) as usize; // wrapped negative TOML value
        assert!(c.validate().is_err(), "wrapped negative kernel_threads must fail");
    }

    #[test]
    fn score_every_validates() {
        let mut c = base();
        c.score_every = 4;
        c.validate().unwrap();
        c.score_every = 0;
        assert!(c.validate().is_err(), "score_every = 0 must fail");
        c.score_every = (-3i64) as usize; // wrapped negative TOML value
        assert!(c.validate().is_err(), "wrapped negative score_every must fail");
    }

    #[test]
    fn score_every_parses_from_toml_and_defaults_to_1() {
        let src = "[run]\nmodel = \"mlp_cifar10\"\nscore_every = 4\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let cfg = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.score_every, 4);
        let src = "[run]\nmodel = \"mlp_cifar10\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let cfg = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.score_every, 1, "default cadence is per-step scoring");
    }

    #[test]
    fn scoring_precision_parses_from_toml_and_defaults_to_exact() {
        let src = "[run]\nmodel = \"mlp_cifar10\"\nscoring_precision = \"bf16\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let cfg = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.scoring_precision, ScoringPrecision::Bf16);
        let src = "[run]\nmodel = \"mlp_cifar10\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let cfg = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.scoring_precision, ScoringPrecision::Exact, "default is exact");
        let src = "[run]\nmodel = \"mlp_cifar10\"\nscoring_precision = \"fp8\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let err = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap_err();
        assert!(err.contains("scoring_precision"), "{err}");
    }

    #[test]
    fn scoring_precision_parse_accepts_aliases() {
        assert_eq!(ScoringPrecision::parse("exact"), Ok(ScoringPrecision::Exact));
        assert_eq!(ScoringPrecision::parse("f32"), Ok(ScoringPrecision::Exact));
        assert_eq!(ScoringPrecision::parse(" BF16 "), Ok(ScoringPrecision::Bf16));
        assert!(ScoringPrecision::parse("int8").is_err());
        for p in [ScoringPrecision::Exact, ScoringPrecision::Bf16] {
            assert_eq!(ScoringPrecision::parse(p.as_str()), Ok(p));
        }
    }

    #[test]
    fn telemetry_parses_from_toml_and_defaults_to_off() {
        let src = "[run]\nmodel = \"mlp_cifar10\"\ntelemetry = \"trace\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let cfg = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.telemetry, TelemetryLevel::Trace);
        let src = "[run]\nmodel = \"mlp_cifar10\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let cfg = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.telemetry, TelemetryLevel::Off, "default is off");
        let src = "[run]\nmodel = \"mlp_cifar10\"\ntelemetry = \"loud\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n";
        let err = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap_err();
        assert!(err.contains("telemetry"), "{err}");
    }

    #[test]
    fn telemetry_parse_accepts_aliases_and_maps_to_obs_levels() {
        assert_eq!(TelemetryLevel::parse("off"), Ok(TelemetryLevel::Off));
        assert_eq!(TelemetryLevel::parse("none"), Ok(TelemetryLevel::Off));
        assert_eq!(TelemetryLevel::parse(" Counters "), Ok(TelemetryLevel::Counters));
        assert_eq!(TelemetryLevel::parse("metrics"), Ok(TelemetryLevel::Counters));
        assert_eq!(TelemetryLevel::parse("TRACE"), Ok(TelemetryLevel::Trace));
        assert!(TelemetryLevel::parse("verbose").is_err());
        for t in [TelemetryLevel::Off, TelemetryLevel::Counters, TelemetryLevel::Trace] {
            assert_eq!(TelemetryLevel::parse(t.as_str()), Ok(t));
        }
        assert_eq!(TelemetryLevel::Off.as_obs_level(), crate::obs::OFF);
        assert_eq!(TelemetryLevel::Counters.as_obs_level(), crate::obs::COUNTERS);
        assert_eq!(TelemetryLevel::Trace.as_obs_level(), crate::obs::TRACE);
    }

    #[test]
    fn threaded_knobs_parse_from_toml() {
        let src = r#"
[run]
model = "mlp_cifar10"
workers = 4
threaded_workers = true
sync_every = 16
kernel_threads = 2

[dataset]
kind = "synth_cifar"
n = 1024
"#;
        let doc = Doc::parse(src).unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert!(cfg.threaded_workers);
        assert_eq!(cfg.sync_every, 16);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.kernel_threads, 2);
    }

    #[test]
    fn from_doc_requires_model() {
        let doc = Doc::parse("[run]\nepochs = 3\n").unwrap();
        assert!(RunConfig::from_doc(&doc).unwrap_err().contains("run.model"));
    }

    #[test]
    fn from_doc_unknown_sampler_lists_available() {
        let src = "[run]\nmodel = \"mlp_cifar10\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n[sampler]\nkind = \"bogus\"\n";
        let err = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap_err();
        assert!(err.contains("unknown sampler"), "{err}");
        assert!(err.contains("eswp") && err.contains("baseline"), "{err}");
    }

    #[test]
    fn from_doc_rejects_typod_sampler_param() {
        let src = "[run]\nmodel = \"mlp_cifar10\"\n[dataset]\nkind = \"synth_cifar\"\nn = 1024\n[sampler]\nkind = \"es\"\nbeta3 = 0.1\n";
        let err = RunConfig::from_doc(&Doc::parse(src).unwrap()).unwrap_err();
        assert!(err.contains("beta3"), "{err}");
    }

    #[test]
    fn custom_sampler_validates_through_registry() {
        let mut c = base();
        c.sampler = SamplerConfig::Custom { name: "never_registered".into(), params: vec![] };
        let err = c.validate().unwrap_err();
        assert!(err.contains("unknown sampler"), "{err}");
    }

    #[test]
    fn sampler_level_taxonomy_matches_table1() {
        // Paper Tab. 1: set/batch membership per method.
        assert!(!SamplerConfig::Uniform.is_batch_level());
        assert!(SamplerConfig::Loss.is_batch_level() && !SamplerConfig::Loss.is_set_level());
        assert!(SamplerConfig::es_default().is_batch_level());
        assert!(!SamplerConfig::es_default().is_set_level());
        let eswp = SamplerConfig::eswp_default();
        assert!(eswp.is_batch_level() && eswp.is_set_level());
        assert!(SamplerConfig::infobatch_default().is_set_level());
        assert!(!SamplerConfig::infobatch_default().is_batch_level());
        assert!(SamplerConfig::ucb_default().is_set_level());
        assert!(SamplerConfig::kakurenbo_default().is_set_level());
    }

    #[test]
    fn serve_table_parses_with_defaults_and_validates() {
        let src = "[serve]\nport = 4717\nmax_concurrent = 3\nkernel_budget = 6\n";
        let sc = ServeConfig::from_doc(&Doc::parse(src).unwrap()).unwrap();
        assert_eq!(sc.port, 4717);
        assert_eq!(sc.max_concurrent, 3);
        assert_eq!(sc.kernel_budget, 6);
        assert_eq!(sc.max_queue, 16, "unset keys fall back to defaults");
        assert_eq!(sc.state_dir, "serve_state");
        assert_eq!(sc.checkpoint_every, 1);

        // A document without a [serve] table is all defaults.
        let sc = ServeConfig::from_doc(&Doc::parse("[run]\nepochs = 1\n").unwrap()).unwrap();
        assert_eq!(sc, ServeConfig::default());
        assert!(sc.effective_kernel_budget() >= 1);

        let err =
            ServeConfig::from_doc(&Doc::parse("[serve]\nmax_concurrent = 0\n").unwrap())
                .unwrap_err();
        assert!(err.contains("max_concurrent"), "{err}");
        let err =
            ServeConfig::from_doc(&Doc::parse("[serve]\nport = 70000\n").unwrap()).unwrap_err();
        assert!(err.contains("port"), "{err}");
        let err = ServeConfig::from_doc(&Doc::parse("[serve]\nmax_queue = -1\n").unwrap())
            .unwrap_err();
        assert!(err.contains("max_queue"), "{err}");
    }

    #[test]
    fn lr_schedules_shape() {
        let oc = LrSchedule::OneCycle { max_lr: 1.0, warmup_frac: 0.5 };
        assert!(oc.lr_at(0, 100) < 0.05);
        assert!((oc.lr_at(50, 100) - 1.0).abs() < 0.05);
        assert!(oc.lr_at(99, 100) < 0.01);

        let wc = LrSchedule::WarmupCosine { base_lr: 1.0, warmup_frac: 0.1, min_lr: 0.1 };
        assert!(wc.lr_at(5, 100) < 1.0);
        assert!((wc.lr_at(10, 100) - 1.0).abs() < 0.01);
        assert!((wc.lr_at(100, 100) - 0.1).abs() < 0.01);

        let p = LrSchedule::Poly { base_lr: 1.0, power: 1.0, warmup_frac: 0.0 };
        assert!((p.lr_at(50, 100) - 0.5).abs() < 0.02);
    }

    #[test]
    fn steps_per_epoch_ceil() {
        let c = base();
        assert_eq!(c.steps_per_epoch(1024), 8);
        assert_eq!(c.steps_per_epoch(1000), 8);
        assert_eq!(c.steps_per_epoch(128), 1);
    }
}
