//! `evosample` CLI — train with any sampler, inspect artifacts and
//! registered samplers, run the paper experiments.
//!
//! Subcommands:
//!   train          --config <run.toml> [--trials N] [--workers W]
//!                  [--threaded-workers] [--sync-every K] [--score-every K]
//!                  [--scoring-precision exact|bf16]
//!                  [--telemetry off|counters|trace] [--trace-out FILE]
//!   list-models                       (artifact inventory)
//!   list-samplers                     (registry inventory: name/kind/params)
//!   experiment     --id <table2|table3|table4|table5|fig4|fig5|fig6|fig7|
//!                       fig1|fig9|fig10|tab6|tab7|tab8|freq|theory> [--full]
//!   illustrate                        (fig1 weight-signal traces)
//!   serve          [--config <serve.toml>] [--port P] [--max-concurrent N]
//!                  [--max-queue N] [--kernel-budget N]
//!                  [--checkpoint-every K] [--dir STATE_DIR]
//!   submit         --addr <host:port> (--config <run.toml> [--sampler S]
//!                  [--name N] [--job-id ID] [--follow] | --status [--job ID]
//!                  | --metrics [--job ID] | --cancel ID
//!                  | --shutdown drain|abort)
//!   top            --addr <host:port> [--interval-ms MS] [--count N]
//!   lint           [--format text|json] [--root DIR]
//!   help
//!
//! Unknown subcommands are an error (exit 1); `help` is the only usage
//! path.

use evosample::cli::Args;
use evosample::config;
use evosample::config::presets::Scale;
use evosample::experiments;
use evosample::metrics::{EventLog, Recorder};
use evosample::prelude::{ProgressSink, SessionBuilder};
use evosample::runtime::manifest::Manifest;
use evosample::sampler::registry;

const USAGE: &str = "\
evosample — Data-Efficient Training by Evolved Sampling (ES/ESWP)

USAGE:
  evosample train --config <run.toml> [--trials N] [--workers W]
                  [--threaded-workers] [--sync-every K] [--score-every K]
                  [--scoring-precision exact|bf16]
                  [--telemetry off|counters|trace] [--trace-out FILE]
                  (--score-every K re-scores the meta-batch every K-th
                   step and selects from cached weights in between;
                   --scoring-precision bf16 ranks the meta-batch from a
                   bf16 weight shadow — BP and eval stay exact;
                   --telemetry counters prints a metrics snapshot after
                   the run, --trace-out writes a Chrome-trace/Perfetto
                   JSON of the per-stage spans and implies trace level)
  evosample list-models
  evosample list-samplers
  evosample experiment --id <table2|table3|table4|table5|fig1|fig4|fig5|
                             fig6|fig7|fig9|fig10|tab6|tab7|tab8|freq|
                             theory>
                       [--full]
  evosample illustrate
  evosample serve    [--config <serve.toml>] [--port P] [--max-concurrent N]
                     [--max-queue N] [--kernel-budget N]
                     [--checkpoint-every K] [--dir STATE_DIR]
                     [--read-timeout-ms MS] [--retry-max N]
                     [--retry-backoff-ms MS] [--faults SPEC]
                     (multi-tenant selection service: queued jobs behind a
                      JSONL-over-TCP protocol on localhost; see DESIGN.md §10.
                      --faults / the EVOSAMPLE_FAULTS env var arm the
                      deterministic fault-injection layer, e.g.
                      \"seed=7;checkpoint.save=err,times=1\"; DESIGN.md §12)
  evosample submit   --addr <host:port>
                     (--config <run.toml> [--sampler S] [--name N]
                      [--job-id ID] [--follow]
                      | --status [--job ID] | --metrics [--job ID]
                      | --cancel ID | --shutdown drain|abort)
  evosample top      --addr <host:port> [--interval-ms MS] [--count N]
                     (live telemetry view over the serve protocol's
                      metrics verb: queue depth, kernel-lane occupancy,
                      per-job selection health; --count 0 polls forever)
  evosample lint     [--format text|json] [--root DIR]
                     (evolint: self-hosted static analysis of the crate's
                      determinism/durability/panic-safety contracts,
                      DESIGN.md §13; exits 1 when violations are found)
  evosample help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(argv, &["full", "threaded-workers", "follow", "status", "metrics"])
        .map_err(|e| anyhow::anyhow!("{e}\n{USAGE}"))?;
    // Deterministic fault injection (DESIGN.md §12): armed process-wide
    // from EVOSAMPLE_FAULTS before any subcommand touches disk or
    // sockets; a malformed spec is a hard startup error, never a
    // silently-unarmed chaos run.
    let armed = evosample::fault::arm_from_env()
        .map_err(|e| anyhow::anyhow!("EVOSAMPLE_FAULTS: {e}"))?;
    if armed > 0 {
        eprintln!("fault: {armed} injection rule(s) armed from EVOSAMPLE_FAULTS");
    }
    match args.subcommand.as_str() {
        "train" => {
            let path = args
                .flag("config")
                .ok_or_else(|| anyhow::anyhow!("train needs --config <run.toml>"))?;
            let mut cfg = config::load(path).map_err(|e| anyhow::anyhow!("{e}"))?;
            let trials = args.usize_flag("trials").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap_or(1);
            // Engine knobs: CLI overrides on top of the TOML config.
            if let Some(w) = args.usize_flag("workers").map_err(|e| anyhow::anyhow!("{e}"))? {
                cfg.workers = w;
            }
            if args.has("threaded-workers") {
                cfg.threaded_workers = true;
            }
            if let Some(k) = args.usize_flag("sync-every").map_err(|e| anyhow::anyhow!("{e}"))? {
                cfg.sync_every = k;
            }
            if let Some(k) = args.usize_flag("score-every").map_err(|e| anyhow::anyhow!("{e}"))? {
                cfg.score_every = k;
            }
            if let Some(p) = args.flag("scoring-precision") {
                cfg.scoring_precision =
                    config::ScoringPrecision::parse(p).map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            if let Some(t) = args.flag("telemetry") {
                cfg.telemetry =
                    config::TelemetryLevel::parse(t).map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            let trace_out = args.flag("trace-out").map(str::to_string);
            if trace_out.is_some() && cfg.telemetry != config::TelemetryLevel::Trace {
                // A trace file without trace-level spans would be empty.
                cfg.telemetry = config::TelemetryLevel::Trace;
            }
            cfg.validate().map_err(|e| anyhow::anyhow!("config: {e}"))?;
            if cfg.score_every > 1 {
                println!(
                    "scoring: every {} steps (stale-weight selection in between)",
                    cfg.score_every
                );
            }
            if cfg.scoring_precision != config::ScoringPrecision::Exact {
                println!("scoring: {} forward pass (BP and eval stay exact)", cfg.scoring_precision.as_str());
            }
            if cfg.threaded_workers {
                println!(
                    "engine: {} threaded workers (param sync every {})",
                    cfg.workers,
                    if cfg.sync_every > 0 {
                        format!("{} steps", cfg.sync_every)
                    } else {
                        "epoch".to_string()
                    }
                );
            }
            // One runtime serves every trial; each trial is an
            // independent session (own split from its trial seed) with
            // progress + event-log sinks on the typed event stream.
            let mut rt = experiments::make_runtime(&cfg)?;
            let rec = Recorder::new("cli_train")?;
            for t in 0..trials {
                let mut c = cfg.clone();
                c.seed = cfg.seed + 1000 * t as u64;
                let mut session = SessionBuilder::from_config(c)
                    .runtime_mut(rt.as_mut())
                    .sink(Box::new(ProgressSink::new()))
                    .sink(Box::new(EventLog::new("cli_train_events")?))
                    .build()?;
                let r = session.run()?;
                rec.record_result(&r)?;
                println!(
                    "trial {t}: acc {:.2}%  eval loss {:.4}  wall {:.2}s  bp_samples {}  ({})",
                    r.accuracy_pct(),
                    r.final_eval.loss,
                    r.cost.train_wall_s(),
                    r.cost.bp_samples,
                    r.timers.summary(),
                );
            }
            if cfg.telemetry != config::TelemetryLevel::Off {
                println!(
                    "telemetry: {}",
                    evosample::metrics::obs_snapshot_json().to_string_compact()
                );
            }
            if let Some(path) = trace_out {
                let spans = evosample::obs::span_count();
                // A durable artifact goes through the atomic commit path
                // (tmp + fsync + rename) like every other one.
                evosample::fault::write_atomic(
                    std::path::Path::new(&path),
                    evosample::obs::chrome_trace_json().to_string_compact().as_bytes(),
                )
                .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
                println!("telemetry: wrote {spans} span(s) to {path} (open in Perfetto/chrome://tracing)");
            }
            Ok(())
        }
        "list-models" => {
            let m = Manifest::load_default()?;
            println!("{:<16} {:>10} {:>8} {:>14} train_steps", "model", "params", "classes", "fwd GFLOP/sample");
            for (name, e) in &m.models {
                println!(
                    "{name:<16} {:>10} {:>8} {:>14.4} {:?}",
                    e.param_count,
                    e.classes,
                    e.flops_per_sample_fwd as f64 / 1e9,
                    e.train_step.keys().collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        "list-samplers" => {
            println!(
                "{:<14} {:<10} {:<8} {:<18} params",
                "name", "kind", "scoring", "aliases"
            );
            for e in registry::entries() {
                let params: Vec<String> = e
                    .params()
                    .iter()
                    .map(|p| format!("{}={} ({})", p.name, p.default, p.doc))
                    .collect();
                println!(
                    "{:<14} {:<10} {:<8} {:<18} {}",
                    e.name(),
                    e.kind(),
                    // "strided" = the per-step scoring FP honors
                    // run.score_every; "-" = the method never scores.
                    if e.frequency_tunable() { "strided" } else { "-" },
                    e.aliases().join(","),
                    if params.is_empty() { "-".to_string() } else { params.join("; ") },
                );
            }
            Ok(())
        }
        "experiment" => {
            let id = args
                .flag("id")
                .ok_or_else(|| anyhow::anyhow!("experiment needs --id <...>"))?;
            let scale = if args.has("full") { Scale::Full } else { Scale::from_env() };
            match id {
                "table2" => experiments::table2::run(scale),
                "table3" => experiments::table3::run(scale),
                "table4" => experiments::table4::run(scale),
                "table5" => experiments::table5::run(scale),
                "fig1" => experiments::fig1::run(400),
                "fig4" => experiments::fig4::run(scale),
                "fig5" => experiments::fig5::run(scale),
                "fig6" => experiments::fig6::run(scale, false),
                "fig7" => experiments::fig6::run(scale, true),
                "fig9" => experiments::fig9::run(scale),
                "fig10" => experiments::fig10::run(scale),
                "tab6" => experiments::ablations::run_tab6(scale),
                "tab7" => experiments::ablations::run_tab7(scale),
                "tab8" => experiments::ablations::run_tab8(scale),
                "freq" => experiments::frequency::run(scale),
                "theory" => experiments::theory::run_all(),
                other => anyhow::bail!("unknown experiment {other:?}\n{USAGE}"),
            }
        }
        "illustrate" => experiments::fig1::run(400),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "top" => cmd_top(&args),
        "lint" => cmd_lint(&args),
        "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

/// evolint (DESIGN.md §13): lint the crate's own sources against the
/// determinism/durability/panic-safety contracts. Exit 0 when clean;
/// violations print (text or JSON) and exit 1 — the CI gate and the
/// `tests/lint_clean.rs` self-check share this code path.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let default_root = evosample::analysis::default_src_root();
    let root = match args.flag("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => default_root,
    };
    let report = evosample::analysis::lint_crate(&root)?;
    match args.flag_or("format", "text").as_str() {
        "json" => println!("{}", report.to_json().to_string_compact()),
        "text" => print!("{}", report.to_text()),
        other => anyhow::bail!("--format expects text|json, got {other:?}"),
    }
    anyhow::ensure!(
        report.is_clean(),
        "lint found {} violation(s)",
        report.findings.len()
    );
    Ok(())
}

/// Boot the multi-tenant selection service (blocks until a client sends
/// `shutdown`). Flags override the `[serve]` table from `--config`.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut sc = match args.flag("config") {
        Some(path) => {
            let src = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
            let doc = config::Doc::parse(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
            // The same document may carry a `[fault]` table (chaos runs).
            let armed = evosample::fault::arm_from_doc(&doc)
                .map_err(|e| anyhow::anyhow!("[fault]: {e}"))?;
            if armed > 0 {
                eprintln!("fault: {armed} injection rule(s) armed from {path}");
            }
            config::ServeConfig::from_doc(&doc).map_err(|e| anyhow::anyhow!("{e}"))?
        }
        None => config::ServeConfig::default(),
    };
    if let Some(spec) = args.flag("faults") {
        let armed =
            evosample::fault::arm_spec(spec).map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
        eprintln!("fault: {armed} injection rule(s) armed from --faults");
    }
    if let Some(p) = args.usize_flag("port").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.port = u16::try_from(p).map_err(|_| anyhow::anyhow!("--port out of range"))?;
    }
    if let Some(n) = args.usize_flag("max-concurrent").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.max_concurrent = n;
    }
    if let Some(n) = args.usize_flag("max-queue").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.max_queue = n;
    }
    if let Some(n) = args.usize_flag("kernel-budget").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.kernel_budget = n;
    }
    if let Some(k) = args.usize_flag("checkpoint-every").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.checkpoint_every = k;
    }
    if let Some(ms) = args.usize_flag("read-timeout-ms").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.read_timeout_ms = ms as u64;
    }
    if let Some(n) = args.usize_flag("retry-max").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.retry_max = n;
    }
    if let Some(ms) = args.usize_flag("retry-backoff-ms").map_err(|e| anyhow::anyhow!("{e}"))? {
        sc.retry_backoff_ms = ms as u64;
    }
    if let Some(dir) = args.flag("dir") {
        sc.state_dir = dir.to_string();
    }
    let handle = evosample::serve::Server::start(sc)?;
    handle.wait();
    Ok(())
}

/// Thin line-protocol client for the serve service.
fn cmd_submit(args: &Args) -> anyhow::Result<()> {
    use evosample::util::json::{obj, s, Json};
    use std::io::{BufRead, BufReader, Write};

    let addr = args
        .flag("addr")
        .ok_or_else(|| anyhow::anyhow!("submit needs --addr <host:port>"))?;
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    fn send(stream: &mut std::net::TcpStream, j: &Json) -> anyhow::Result<()> {
        stream.write_all(j.to_string_compact().as_bytes())?;
        stream.write_all(b"\n")?;
        Ok(())
    }
    fn read_line(reader: &mut BufReader<std::net::TcpStream>) -> anyhow::Result<String> {
        let mut line = String::new();
        let n = reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Ok(line.trim().to_string())
    }

    if args.has("status") {
        let mut fields = vec![("cmd", s("status"))];
        if let Some(id) = args.flag("job") {
            fields.push(("job", s(id)));
        }
        send(&mut stream, &obj(fields))?;
        println!("{}", read_line(&mut reader)?);
        return Ok(());
    }
    if args.has("metrics") {
        let mut fields = vec![("cmd", s("metrics"))];
        if let Some(id) = args.flag("job") {
            fields.push(("job", s(id)));
        }
        send(&mut stream, &obj(fields))?;
        println!("{}", read_line(&mut reader)?);
        return Ok(());
    }
    if let Some(id) = args.flag("cancel") {
        send(&mut stream, &obj(vec![("cmd", s("cancel")), ("job", s(id))]))?;
        println!("{}", read_line(&mut reader)?);
        return Ok(());
    }
    if let Some(mode) = args.flag("shutdown") {
        send(&mut stream, &obj(vec![("cmd", s("shutdown")), ("mode", s(mode))]))?;
        println!("{}", read_line(&mut reader)?);
        return Ok(());
    }

    let path = args.flag("config").ok_or_else(|| {
        anyhow::anyhow!("submit needs --config <run.toml> (or --status/--cancel/--shutdown)")
    })?;
    let toml_src =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
    let mut fields = vec![("cmd", s("submit")), ("config", s(toml_src))];
    if let Some(n) = args.flag("name") {
        fields.push(("name", s(n)));
    }
    if let Some(sm) = args.flag("sampler") {
        fields.push(("sampler", s(sm)));
    }
    if let Some(id) = args.flag("job-id") {
        fields.push(("job_id", s(id)));
    }
    send(&mut stream, &obj(fields))?;
    let resp_line = read_line(&mut reader)?;
    println!("{resp_line}");
    if !args.has("follow") {
        return Ok(());
    }
    let resp = Json::parse(&resp_line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    anyhow::ensure!(
        resp.get("ok") == Some(&Json::Bool(true)),
        "submission not accepted; nothing to follow"
    );
    let job = resp
        .get("job")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("response carries no job id"))?
        .to_string();
    send(&mut stream, &obj(vec![("cmd", s("events")), ("job", s(job))]))?;
    loop {
        let line = read_line(&mut reader)?;
        println!("{line}");
        // The stream ends with one ok/err line after the final event.
        if Json::parse(&line).is_ok_and(|j| j.get("ok").is_some()) {
            return Ok(());
        }
    }
}

/// Live telemetry view: poll the serve protocol's `metrics` verb over
/// one connection and render a compact dashboard — queue depth, kernel
/// lane occupancy, and one line per job with its selection health.
fn cmd_top(args: &Args) -> anyhow::Result<()> {
    use evosample::util::json::{obj, s, Json};
    use std::io::{BufRead, BufReader, IsTerminal, Write};

    let addr = args
        .flag("addr")
        .ok_or_else(|| anyhow::anyhow!("top needs --addr <host:port>"))?;
    let interval =
        args.usize_flag("interval-ms").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap_or(1000);
    let count = args.usize_flag("count").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap_or(0);
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    // Only repaint in-place when a human is watching; piped output gets
    // plain appended frames.
    let repaint = std::io::stdout().is_terminal();
    let mut polls = 0usize;
    loop {
        stream.write_all(obj(vec![("cmd", s("metrics"))]).to_string_compact().as_bytes())?;
        stream.write_all(b"\n")?;
        let mut line = String::new();
        anyhow::ensure!(reader.read_line(&mut line)? > 0, "server closed the connection");
        let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if j.get("ok") != Some(&Json::Bool(true)) {
            anyhow::bail!("server error: {}", j.to_string_compact());
        }
        if repaint {
            print!("\x1b[2J\x1b[H");
        }
        render_top(addr, &j);
        polls += 1;
        if count > 0 && polls >= count {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(interval as u64));
    }
}

fn render_top(addr: &str, j: &evosample::util::json::Json) {
    use evosample::util::json::Json;
    let f = |j: Option<&Json>| j.and_then(Json::as_f64).unwrap_or(0.0);
    let global = j.get("global");
    let queue = global.and_then(|g| g.get("queue"));
    let kernel = global.and_then(|g| g.get("kernel"));
    let shutting = queue.and_then(|q| q.get("shutting_down")) == Some(&Json::Bool(true));
    println!(
        "evosample top — {addr}  pending {}  running {}  kernel {}/{} lanes{}",
        f(queue.and_then(|q| q.get("pending"))),
        f(queue.and_then(|q| q.get("running"))),
        f(kernel.and_then(|k| k.get("in_use"))),
        f(kernel.and_then(|k| k.get("budget"))),
        if shutting { "  [shutting down]" } else { "" },
    );
    let jobs = j.get("jobs").and_then(Json::as_arr);
    let Some(jobs) = jobs else { return };
    if jobs.is_empty() {
        println!("(no jobs)");
        return;
    }
    println!(
        "{:<24} {:<10} {:>9} {:>7} {:>10} {:>11} {:>8}",
        "job", "state", "epochs", "keep%", "fp_passes", "bp_samples", "wall_s"
    );
    for job in jobs {
        let sg = |k: &str| job.get(k).and_then(Json::as_str).unwrap_or("?");
        let keep = job
            .get("keep_rate_pct")
            .and_then(Json::as_f64)
            .map(|k| format!("{k:.1}"))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<24} {:<10} {:>4}/{:<4} {:>7} {:>10} {:>11} {:>8.1}",
            sg("job"),
            sg("state"),
            f(job.get("epochs_done")),
            f(job.get("epochs_total")),
            keep,
            f(job.get("fp_passes")),
            f(job.get("bp_samples")),
            f(job.get("wall_s")),
        );
    }
}
