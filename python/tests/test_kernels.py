"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes/dtypes/value ranges; each kernel must match its
reference to float32 tolerance on every draw. These tests are the core
correctness signal for everything the rust runtime executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import flash_attention, flash_attention_vjp
from compile.kernels.ce_loss import cross_entropy, cross_entropy_vjp
from compile.kernels.es_update import es_update

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(deadline=None, max_examples=25, derandomize=True)


def _key(seed):
    return jax.random.PRNGKey(seed)


# ---------------------------------------------------------------------------
# cross_entropy
# ---------------------------------------------------------------------------


class TestCrossEntropy:
    @hypothesis.given(
        batch=st.sampled_from([1, 3, 8, 16, 40, 64]),
        classes=st.sampled_from([2, 10, 100, 257, 1024]),
        seed=st.integers(0, 2**16),
        scale=st.sampled_from([0.1, 1.0, 10.0]),
    )
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, batch, classes, seed, scale):
        k1, k2 = jax.random.split(_key(seed))
        logits = jax.random.normal(k1, (batch, classes)) * scale
        labels = jax.random.randint(k2, (batch,), 0, classes)
        got = cross_entropy(logits, labels)
        want = ref.cross_entropy_ref(logits, labels)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_extreme_logits_stable(self):
        """Large logits must not overflow (log-sum-exp stabilization)."""
        logits = jnp.array([[1000.0, 0.0], [-1000.0, 0.0], [0.0, 0.0]])
        labels = jnp.array([0, 1, 0])
        got = cross_entropy(logits, labels)
        assert np.all(np.isfinite(got))
        want = ref.cross_entropy_ref(logits, labels)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_correct_class_low_loss(self):
        logits = jnp.eye(4) * 20.0
        labels = jnp.arange(4)
        got = cross_entropy(logits, labels)
        assert np.all(np.asarray(got) < 1e-3)

    def test_loss_nonnegative(self):
        k = _key(3)
        logits = jax.random.normal(k, (32, 10)) * 3
        labels = jax.random.randint(k, (32,), 0, 10)
        assert np.all(np.asarray(cross_entropy(logits, labels)) >= -1e-6)

    @hypothesis.given(seed=st.integers(0, 2**16))
    @hypothesis.settings(**SETTINGS)
    def test_vjp_matches_autodiff_of_ref(self, seed):
        """Hand-written backward == autodiff of the reference."""
        k1, k2 = jax.random.split(_key(seed))
        logits = jax.random.normal(k1, (8, 16))
        labels = jax.random.randint(k2, (8,), 0, 16)

        g_kernel = jax.grad(lambda l: cross_entropy_vjp(l, labels).sum())(logits)
        g_ref = jax.grad(lambda l: ref.cross_entropy_ref(l, labels).sum())(logits)
        np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-4, atol=1e-5)

    def test_ragged_batch_fallback(self):
        """Non-multiple-of-8 batches take the single-tile fallback."""
        logits = jax.random.normal(_key(0), (13, 7))
        labels = jax.random.randint(_key(1), (13,), 0, 7)
        np.testing.assert_allclose(
            cross_entropy(logits, labels),
            ref.cross_entropy_ref(logits, labels),
            rtol=1e-5,
            atol=1e-5,
        )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @hypothesis.given(
        seq=st.sampled_from([8, 32, 64, 128]),
        dim=st.sampled_from([8, 16, 32, 64]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, seq, dim, causal, seed):
        ks = jax.random.split(_key(seed), 3)
        q, k, v = (jax.random.normal(kk, (seq, dim)) for kk in ks)
        got = flash_attention(q, k, v, causal=causal)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_ragged_seq_fallback(self):
        ks = jax.random.split(_key(7), 3)
        q, k, v = (jax.random.normal(kk, (24, 16)) for kk in ks)
        got = flash_attention(q, k, v, causal=True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_first_token_attends_to_itself(self):
        """Causal row 0 can only see k[0], so out[0] == v[0]."""
        ks = jax.random.split(_key(9), 3)
        q, k, v = (jax.random.normal(kk, (32, 8)) for kk in ks)
        got = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got[0], v[0], rtol=1e-5, atol=1e-5)

    def test_uniform_values_passthrough(self):
        """If all v rows are equal, attention output equals that row."""
        ks = jax.random.split(_key(11), 2)
        q, k = (jax.random.normal(kk, (16, 8)) for kk in ks)
        v = jnp.ones((16, 8)) * 3.5
        got = flash_attention(q, k, v, causal=False)
        np.testing.assert_allclose(got, v, rtol=1e-5, atol=1e-5)

    @hypothesis.given(seed=st.integers(0, 2**16))
    @hypothesis.settings(**SETTINGS)
    def test_vjp_matches_autodiff_of_ref(self, seed):
        ks = jax.random.split(_key(seed), 3)
        q, k, v = (jax.random.normal(kk, (16, 8)) for kk in ks)

        def loss_kernel(q, k, v):
            return (flash_attention_vjp(q, k, v, True) ** 2).sum()

        def loss_ref(q, k, v):
            return (ref.attention_ref(q, k, v, causal=True) ** 2).sum()

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# es_update
# ---------------------------------------------------------------------------


class TestEsUpdate:
    @hypothesis.given(
        n=st.sampled_from([16, 1024, 4096, 8192, 10000]),
        beta1=st.sampled_from([0.0, 0.2, 0.5, 0.9, 1.0]),
        beta2=st.sampled_from([0.0, 0.8, 0.9, 1.0]),
        seed=st.integers(0, 2**16),
    )
    @hypothesis.settings(**SETTINGS)
    def test_matches_ref(self, n, beta1, beta2, seed):
        ks = jax.random.split(_key(seed), 4)
        s = jax.random.uniform(ks[0], (n,))
        w = jax.random.uniform(ks[1], (n,))
        l = jax.random.uniform(ks[2], (n,)) * 5
        mask = (jax.random.uniform(ks[3], (n,)) > 0.5).astype(jnp.float32)
        s2, w2 = es_update(s, w, l, mask, jnp.array([beta1, beta2]))
        sr, wr = ref.es_update_ref(s, w, l, mask, beta1, beta2)
        np.testing.assert_allclose(s2, sr, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(w2, wr, rtol=1e-6, atol=1e-6)

    def test_masked_out_entries_unchanged(self):
        n = 64
        s = jnp.arange(n, dtype=jnp.float32)
        w = jnp.arange(n, dtype=jnp.float32) * 2
        l = jnp.ones((n,)) * 100
        mask = jnp.zeros((n,))
        s2, w2 = es_update(s, w, l, mask, jnp.array([0.2, 0.9]))
        np.testing.assert_array_equal(s2, s)
        np.testing.assert_array_equal(w2, w)

    def test_beta_zero_reduces_to_loss_sampling(self):
        """β1=β2=0 ⇒ w'=s'=loss (paper Eq. 2.3 degenerate case)."""
        n = 32
        ks = jax.random.split(_key(5), 3)
        s, w = jax.random.uniform(ks[0], (n,)), jax.random.uniform(ks[1], (n,))
        l = jax.random.uniform(ks[2], (n,)) * 3
        s2, w2 = es_update(s, w, l, jnp.ones((n,)), jnp.array([0.0, 0.0]))
        np.testing.assert_allclose(s2, l, atol=1e-7)
        np.testing.assert_allclose(w2, l, atol=1e-7)

    def test_beta_one_freezes(self):
        """β1=β2=1 ⇒ w'=s'=s (standard sampling w/ frozen uniform init)."""
        n = 32
        ks = jax.random.split(_key(6), 3)
        s, w = jax.random.uniform(ks[0], (n,)), jax.random.uniform(ks[1], (n,))
        l = jax.random.uniform(ks[2], (n,)) * 3
        s2, w2 = es_update(s, w, l, jnp.ones((n,)), jnp.array([1.0, 1.0]))
        np.testing.assert_allclose(s2, s, atol=1e-7)
        np.testing.assert_allclose(w2, s, atol=1e-7)

    def test_recursion_matches_explicit_expansion(self):
        """Prop. 3.1 / Eq. 3.2: the recursion equals the explicit sum of
        discounted losses + discounted loss differences + O(β2^t)."""
        rng = np.random.default_rng(0)
        t_max, b1, b2 = 30, 0.2, 0.9
        losses = rng.uniform(0.1, 4.0, size=t_max + 1)
        s = 1.0 / 8
        s_hist = [s]
        w = None
        for t in range(1, t_max + 1):
            w = b1 * s + (1 - b1) * losses[t]
            s = b2 * s + (1 - b2) * losses[t]
            s_hist.append(s)
        # Explicit Eq. 3.2 expansion.
        term1 = (1 - b2) * sum(b2 ** (t_max - k) * losses[k] for k in range(1, t_max + 1))
        term2 = (b2 - b1) * sum(
            b2 ** (t_max - 1 - k) * (losses[k + 1] - losses[k]) for k in range(1, t_max)
        )
        # Residual O(β2^t): includes the s(0) and first-loss boundary terms.
        assert abs(w - (term1 + term2)) < 5 * b2**t_max + 1e-9


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
