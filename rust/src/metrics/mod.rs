//! Result recording: JSONL writers under `results/` + summary helpers.
//!
//! Every bench/example writes one JSON object per training run so paper
//! tables can be regenerated or re-aggregated without re-running. The
//! [`EventLog`] sink additionally streams the engine's typed events
//! (`api::Event`) to JSONL as a run progresses — the metrics layer's
//! consumer of the public event stream.

use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::api::events::{Event, EventSink};
use crate::coordinator::TrainResult;
use crate::util::json::{num, obj, s, Json};

/// Serialize a TrainResult to a flat JSON record.
pub fn result_to_json(r: &TrainResult) -> Json {
    obj(vec![
        ("name", s(r.name.clone())),
        ("sampler", s(r.sampler.clone())),
        ("seed", num(r.seed as f64)),
        ("epochs", num(r.epochs as f64)),
        ("steps", num(r.steps as f64)),
        ("accuracy_pct", num(r.accuracy_pct())),
        ("eval_loss", num(r.final_eval.loss)),
        ("train_wall_s", num(r.cost.train_wall_s())),
        ("scoring_s", num(r.cost.scoring_s)),
        ("train_s", num(r.cost.train_s)),
        ("select_s", num(r.cost.select_s)),
        ("sync_s", num(r.cost.sync_s)),
        ("fp_samples", num(r.cost.fp_samples as f64)),
        ("fp_passes", num(r.cost.fp_passes as f64)),
        ("bp_samples", num(r.cost.bp_samples as f64)),
        ("bp_passes", num(r.cost.bp_passes as f64)),
        ("total_flops", num(r.cost.total_flops() as f64)),
        (
            "loss_curve",
            Json::Arr(r.loss_curve.iter().map(|&l| num(l)).collect()),
        ),
        (
            "eval_curve",
            Json::Arr(
                r.eval_curve
                    .iter()
                    .map(|&(e, l, a)| {
                        Json::Arr(vec![num(e as f64), num(l), num(a)])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One-shot snapshot of the process telemetry registry (DESIGN.md §11):
/// the `obs::` counters/gauges/histogram summaries plus the current
/// telemetry level, rendered the way every exporter (CLI `--telemetry`
/// runs, the serve `metrics` verb) presents it.
pub fn obs_snapshot_json() -> Json {
    obj(vec![
        ("telemetry", s(crate::obs::level_str())),
        ("metrics", crate::obs::registry().snapshot_json()),
    ])
}

/// Append-only JSONL recorder.
///
/// The append handle is opened lazily on the first record and held for
/// the recorder's lifetime, so a long event stream pays one open instead
/// of an open/close syscall pair per line. Every record is flushed
/// through immediately — concurrent readers (tests, `tail -f`) see lines
/// as they land — and the `BufWriter` flushes once more on drop.
pub struct Recorder {
    path: PathBuf,
    file: Mutex<Option<BufWriter<std::fs::File>>>,
}

impl Recorder {
    /// Records under `results/<name>.jsonl` (dir created on demand).
    pub fn new(name: &str) -> std::io::Result<Recorder> {
        Recorder::in_dir(Path::new("results"), name)
    }

    pub fn in_dir(dir: &Path, name: &str) -> std::io::Result<Recorder> {
        std::fs::create_dir_all(dir)?;
        Ok(Recorder { path: dir.join(format!("{name}.jsonl")), file: Mutex::new(None) })
    }

    pub fn record(&self, j: &Json) -> std::io::Result<()> {
        let mut slot = self.file.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            let f =
                std::fs::OpenOptions::new().create(true).append(true).open(&self.path)?;
            *slot = Some(BufWriter::new(f));
        }
        let w = slot.as_mut().unwrap();
        writeln!(w, "{}", j.to_string_compact())?;
        w.flush()
    }

    pub fn record_result(&self, r: &TrainResult) -> std::io::Result<()> {
        self.record(&result_to_json(r))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Serialize one engine event to a flat, tagged JSON record.
pub fn event_to_json(ev: &Event) -> Json {
    match ev {
        Event::RunStart { name, sampler, epochs } => obj(vec![
            ("event", s("run_start")),
            ("name", s(name.clone())),
            ("sampler", s(sampler.clone())),
            ("epochs", num(*epochs as f64)),
        ]),
        Event::EpochStart { epoch, kept, dataset_n } => obj(vec![
            ("event", s("epoch_start")),
            ("epoch", num(*epoch as f64)),
            ("kept", num(*kept as f64)),
            ("dataset_n", num(*dataset_n as f64)),
        ]),
        Event::ScoringFp { epoch, step, samples, elapsed } => obj(vec![
            ("event", s("scoring_fp")),
            ("epoch", num(*epoch as f64)),
            ("step", num(*step as f64)),
            ("samples", num(*samples as f64)),
            ("elapsed_s", num(elapsed.as_secs_f64())),
        ]),
        Event::SelectionMade { epoch, step, meta, selected, scored } => obj(vec![
            ("event", s("selection_made")),
            ("epoch", num(*epoch as f64)),
            ("step", num(*step as f64)),
            ("meta", num(*meta as f64)),
            ("selected", num(*selected as f64)),
            ("scored", Json::Bool(*scored)),
        ]),
        Event::WorkerLost { epoch, worker, error } => obj(vec![
            ("event", s("worker_lost")),
            ("epoch", num(*epoch as f64)),
            ("worker", num(*worker as f64)),
            ("error", s(error.clone())),
        ]),
        Event::SyncRound { epoch, workers } => obj(vec![
            ("event", s("sync_round")),
            ("epoch", num(*epoch as f64)),
            ("workers", num(*workers as f64)),
        ]),
        Event::EvalDone { epoch, loss, accuracy, bp_samples } => obj(vec![
            ("event", s("eval_done")),
            ("epoch", num(*epoch as f64)),
            ("eval_loss", num(*loss)),
            ("accuracy", num(*accuracy)),
            ("bp_samples", num(*bp_samples as f64)),
        ]),
        Event::EpochEnd { epoch, mean_train_loss } => obj(vec![
            ("event", s("epoch_end")),
            ("epoch", num(*epoch as f64)),
            ("mean_train_loss", num(*mean_train_loss)),
        ]),
        Event::RunEnd { steps, accuracy } => obj(vec![
            ("event", s("run_end")),
            ("steps", num(*steps as f64)),
            ("accuracy", num(*accuracy)),
        ]),
    }
}

/// JSONL event sink: streams engine events through a [`Recorder`].
/// Per-step events (`ScoringFp`, `SelectionMade`) are skipped unless
/// `with_steps(true)` — epoch-level telemetry is usually what dashboards
/// want, and step events scale with the step count.
pub struct EventLog {
    rec: Recorder,
    steps: bool,
}

impl EventLog {
    /// Logs under `results/<name>.jsonl`.
    pub fn new(name: &str) -> std::io::Result<EventLog> {
        Ok(EventLog { rec: Recorder::new(name)?, steps: false })
    }

    pub fn in_dir(dir: &Path, name: &str) -> std::io::Result<EventLog> {
        Ok(EventLog { rec: Recorder::in_dir(dir, name)?, steps: false })
    }

    /// Also record per-step events.
    pub fn with_steps(mut self, steps: bool) -> EventLog {
        self.steps = steps;
        self
    }

    pub fn path(&self) -> &Path {
        self.rec.path()
    }
}

impl EventSink for EventLog {
    fn on_event(&mut self, ev: &Event) {
        if !self.steps
            && matches!(ev, Event::ScoringFp { .. } | Event::SelectionMade { .. })
        {
            return;
        }
        // Metrics are best-effort: a full disk must not kill training.
        let _ = self.rec.record(&event_to_json(ev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CostSummary, EvalStats};
    use crate::util::timer::PhaseTimers;

    fn dummy() -> TrainResult {
        TrainResult {
            name: "t".into(),
            sampler: "es".into(),
            seed: 1,
            epochs: 2,
            steps: 10,
            loss_curve: vec![1.0, 0.5],
            eval_curve: vec![(1, 0.4, 0.9)],
            final_eval: EvalStats { loss: 0.4, accuracy: 0.9 },
            timers: PhaseTimers::new(),
            cost: CostSummary::default(),
            class_bp_counts: vec![],
            bp_at_eval: vec![100],
        }
    }

    #[test]
    fn result_roundtrips_through_json() {
        let j = result_to_json(&dummy());
        let txt = j.to_string_compact();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("sampler").unwrap().as_str(), Some("es"));
        assert_eq!(back.get("accuracy_pct").unwrap().as_f64(), Some(90.0));
        assert_eq!(back.get("loss_curve").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn event_log_streams_epoch_events_skips_step_events() {
        let dir = std::env::temp_dir().join("evosample_test_evlog");
        let mut log = EventLog::in_dir(&dir, "events_unit").unwrap();
        log.on_event(&Event::RunStart { name: "t".into(), sampler: "es".into(), epochs: 2 });
        log.on_event(&Event::SelectionMade {
            epoch: 0,
            step: 0,
            meta: 32,
            selected: 8,
            scored: true,
        });
        log.on_event(&Event::EvalDone { epoch: 1, loss: 0.5, accuracy: 0.8, bp_samples: 10 });
        let text = std::fs::read_to_string(log.path()).unwrap();
        assert!(text.contains("run_start") && text.contains("eval_done"), "{text}");
        assert!(!text.contains("selection_made"), "{text}");
        let back = Json::parse(text.lines().last().unwrap()).unwrap();
        assert_eq!(back.get("event").unwrap().as_str(), Some("eval_done"));
        assert_eq!(back.get("accuracy").unwrap().as_f64(), Some(0.8));
        let _ = std::fs::remove_file(log.path());
    }

    #[test]
    fn recorder_flushes_each_line_while_open() {
        let dir = std::env::temp_dir().join("evosample_test_rec_flush");
        let rec = Recorder::in_dir(&dir, "flush_unit").unwrap();
        let _ = std::fs::remove_file(rec.path());
        // The persistent handle must not buffer lines past the record
        // call: readers see every line while the recorder stays open.
        rec.record(&Json::Null).unwrap();
        assert_eq!(std::fs::read_to_string(rec.path()).unwrap().lines().count(), 1);
        rec.record(&Json::Null).unwrap();
        assert_eq!(std::fs::read_to_string(rec.path()).unwrap().lines().count(), 2);
        let _ = std::fs::remove_file(rec.path());
    }

    #[test]
    fn recorder_appends_lines() {
        let dir = std::env::temp_dir().join("evosample_test_rec");
        let rec = Recorder::in_dir(&dir, "unit").unwrap();
        // unique content per test run; just check append semantics
        rec.record(&result_to_json(&dummy())).unwrap();
        rec.record(&result_to_json(&dummy())).unwrap();
        let text = std::fs::read_to_string(rec.path()).unwrap();
        assert!(text.lines().count() >= 2);
        let _ = std::fs::remove_file(rec.path());
    }
}
