//! Frequency-tuning ablation: the paper's "flexible frequency tuning"
//! lever — re-score the meta-batch only every k-th step so the extra
//! scoring FP of §3.3 amortizes to ~1/k of its cost, selection running on
//! cached (≤ k−1 steps stale) weight tables in between (DESIGN.md §8).
//!
//! Expected shape: fp_samples and scoring_s drop ~k-fold while accuracy
//! stays close to k=1 for small k — the amortized selection overhead is
//! what lets "lossless" hold end-to-end (InfoBatch makes the same
//! argument for set-level overhead).

use crate::config::presets::{frequency_sweep, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;

use super::{make_runtime, mean_acc, run_config, total_cost, trials};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let rows = frequency_sweep(scale);
    let rec = Recorder::new("frequency_ablation")?;
    let n_trials = trials(scale);
    table_header(
        "Frequency tuning — score every k steps (ES, CIFAR-dims MLP)",
        &["k", "acc%", "fp_samples", "fp_passes", "scoring_s", "time saved"],
    );
    let mut rt = make_runtime(&rows[0].1)?;
    let mut base: Option<crate::coordinator::CostSummary> = None;
    for (k, cfg) in &rows {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        let acc = mean_acc(&rs);
        let cost = total_cost(&rs);
        match &base {
            None => {
                println!(
                    "{k:>2} | {acc:5.1} | {:>10} | {:>9} | {:8.3} | —",
                    cost.fp_samples, cost.fp_passes, cost.scoring_s
                );
                base = Some(cost);
            }
            Some(bcost) => {
                println!(
                    "{k:>2} | {acc:5.1} | {:>10} | {:>9} | {:8.3} | {}",
                    cost.fp_samples,
                    cost.fp_passes,
                    cost.scoring_s,
                    super::fmt_saved(bcost, &cost)
                );
            }
        }
    }
    Ok(())
}
