//! Regenerates paper Table 2 (CIFAR classification, 8 sampling methods).
//! Smoke scale by default; EVOSAMPLE_BENCH_FULL=1 for paper-faithful runs.
fn main() {
    evosample::experiments::table2::run(evosample::config::presets::Scale::from_env())
        .expect("table2");
}
