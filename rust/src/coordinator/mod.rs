//! L3 coordination: the pipelined execution engine, the trainer facade,
//! cost accounting, and the trial/sweep drivers used by the experiment
//! benches.

pub mod accounting;
pub mod checkpoint;
pub mod engine;
pub mod trainer;

pub use accounting::{predicted_saved_time_pct, saved_time_pct, CostSummary};
pub use engine::{Engine, Stage, StageObserver, StepPipeline};
#[allow(deprecated)]
pub use trainer::{run_trials, train};
pub use trainer::{evaluate, train_with_sampler, EvalStats, TrainResult, TrialSummary};
