//! The training coordinator: drives runtime + sampler + data through the
//! paper's Alg. 1 loop, with full cost accounting.
//!
//! Per active step (batch-level methods):
//!   1. draw a uniform meta-batch B from the kept set           [data]
//!   2. scoring FP over B at the latest parameters              [scoring_fp]
//!   3. sampler.observe_meta — the Eq. 3.1 state update         [select]
//!   4. sampler.select — draw b ⊂ B, probability ∝ w            [select]
//!   5. train_step on b (optionally chunked into micro-batches) [train_bp]
//!   6. sampler.observe_train — free losses from the BP batch   [select]
//!
//! Set-level methods skip 2–4 (select returns the whole meta-batch with
//! per-sample gradient weights) and prune in `on_epoch_start`. Annealing
//! epochs run the standard loop.
//!
//! Gradient accumulation (`micro_batch > 0`) chunks the selected batch
//! into micro-batches executed as sequential optimizer steps — time-exact
//! for the paper's low-resource accounting (#BP passes = ceil(|b|/micro)),
//! and a standard small-scale approximation of true gradient accumulation
//! (documented in DESIGN.md §3).
//!
//! Execution lives in `coordinator::engine`: a [`StepPipeline`]
//! decomposes each step into explicit stages and an [`Engine`] runs them
//! single-threaded (`workers == 1`), as a sequential data-parallel
//! simulation (`workers > 1`), or across real `std::thread` worker
//! replicas (`threaded_workers`) with §D.5 synchronization rounds. The
//! stage contract and sync model are specified in DESIGN.md §2.
//!
//! [`StepPipeline`]: super::engine::StepPipeline
//! [`Engine`]: super::engine::Engine

use crate::config::RunConfig;
use crate::data::SplitDataset;
use crate::runtime::{BatchBuf, ModelRuntime};
use crate::sampler::{self, Sampler};
use crate::util::timer::PhaseTimers;

use super::accounting::CostSummary;
use super::engine::Engine;

#[derive(Clone, Debug, Default)]
pub struct EvalStats {
    pub loss: f64,
    pub accuracy: f64,
}

/// Everything one training run produces (one trial).
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub name: String,
    pub sampler: String,
    pub seed: u64,
    pub epochs: usize,
    pub steps: u64,
    /// Mean train loss per epoch (the Fig. 3-style curve).
    pub loss_curve: Vec<f64>,
    /// (epoch, eval loss, eval accuracy) at each eval point.
    pub eval_curve: Vec<(usize, f64, f64)>,
    pub final_eval: EvalStats,
    pub timers: PhaseTimers,
    pub cost: CostSummary,
    /// BP sample count per class (Fig. 9) — classification tasks only.
    pub class_bp_counts: Vec<u64>,
    /// Cumulative BP samples at each eval point (Fig. 10 x-axis).
    pub bp_at_eval: Vec<u64>,
}

impl TrainResult {
    pub fn accuracy_pct(&self) -> f64 {
        100.0 * self.final_eval.accuracy
    }
}

/// Train with a sampler built from the config (fresh state).
///
/// Deprecated shim over the public session API: results are bit-for-bit
/// identical to `SessionBuilder::from_config(cfg).split(...).build()?.run()?`
/// (pinned by `tests/api_session.rs`), but new code should construct a
/// [`crate::api::Session`] — it owns the data/runtime wiring and carries
/// the typed event stream.
#[deprecated(note = "use api::SessionBuilder (evosample::prelude) instead")]
pub fn train(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
) -> anyhow::Result<TrainResult> {
    cfg.validate().map_err(|e| anyhow::anyhow!("config: {e}"))?;
    let sampler = sampler::build(&cfg.sampler, data.train.n, cfg.epochs)?;
    train_with_sampler(cfg, rt, data, sampler)
}

/// Train with an externally-constructed sampler (ablations, tests).
///
/// Thin wrapper over [`Engine`]: construct one directly to install a
/// per-stage accounting hook or to inspect sampler state after the run.
pub fn train_with_sampler(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
    sampler: Box<dyn Sampler>,
) -> anyhow::Result<TrainResult> {
    Engine::new(cfg, rt, data, sampler).run()
}

/// Evaluate on the held-out set, chunked to the runtime's eval batch size
/// (tail padded by wraparound; pad rows excluded from the averages).
pub fn evaluate(rt: &mut dyn ModelRuntime, data: &SplitDataset) -> anyhow::Result<EvalStats> {
    let ds = &data.test;
    let chunk = if rt.eval_size() > 0 { rt.eval_size() } else { ds.n };
    let mut buf = BatchBuf::new();
    let mut idx = Vec::with_capacity(chunk);
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut count = 0usize;
    let mut off = 0usize;
    while off < ds.n {
        let valid = chunk.min(ds.n - off);
        idx.clear();
        for k in 0..chunk {
            idx.push(((off + k) % ds.n) as u32);
        }
        buf.fill(ds, &idx);
        let (losses, correct) = rt.eval(buf.x(ds), &buf.y, chunk)?;
        for i in 0..valid {
            loss_sum += losses[i] as f64;
            acc_sum += correct[i] as f64;
        }
        count += valid;
        off += valid;
    }
    anyhow::ensure!(count > 0, "empty test set");
    Ok(EvalStats { loss: loss_sum / count as f64, accuracy: acc_sum / count as f64 })
}

/// Run `trials` independent seeds and average the headline numbers.
pub struct TrialSummary {
    pub results: Vec<TrainResult>,
}

impl TrialSummary {
    pub fn mean_accuracy_pct(&self) -> f64 {
        self.results.iter().map(|r| r.accuracy_pct()).sum::<f64>() / self.results.len() as f64
    }

    pub fn mean_eval_loss(&self) -> f64 {
        self.results.iter().map(|r| r.final_eval.loss).sum::<f64>() / self.results.len() as f64
    }

    pub fn mean_train_wall_s(&self) -> f64 {
        self.results.iter().map(|r| r.cost.train_wall_s()).sum::<f64>()
            / self.results.len() as f64
    }

    pub fn total_cost(&self) -> CostSummary {
        // Sum counts across trials (flops ratios are scale-invariant).
        let mut total = CostSummary::default();
        for r in &self.results {
            total.accumulate(&r.cost);
        }
        total
    }
}

/// Train `trials` seeds of the same config on a fresh runtime state.
#[deprecated(note = "use api::Session::run_trials (evosample::prelude) instead")]
pub fn run_trials(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
    trials: usize,
) -> anyhow::Result<TrialSummary> {
    let mut results = Vec::with_capacity(trials);
    for t in 0..trials {
        let mut c = cfg.clone();
        c.seed = cfg.seed + 1000 * t as u64;
        #[allow(deprecated)]
        results.push(train(&c, rt, data)?);
    }
    Ok(TrialSummary { results })
}
