//! Checkpointing: persist flat parameters + run metadata so long
//! pre-training runs (Table 4) can resume and fine-tune phases (Table 3)
//! can start from a saved trunk.
//!
//! Format: `<name>.ckpt` = 16-byte header (magic, version, param count)
//! + raw little-endian f32 params; `<name>.json` = metadata sidecar.
//!
//! Both files go through [`crate::fault::write_atomic`] (tmp + fsync +
//! rename), so a crash mid-save leaves the previous checkpoint intact —
//! a reader only ever sees a complete generation (DESIGN.md §12).

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::fault::{self, sites, write_atomic};
use crate::util::json::{num, obj, s, Json};

const MAGIC: u32 = 0x45564f53; // "EVOS"
const VERSION: u32 = 1;

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub seed: u64,
    pub params: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, dir: &Path, name: &str) -> std::io::Result<PathBuf> {
        self.save_inner(dir, name, None)
    }

    /// [`Checkpoint::save`] with an additional free-form JSON document
    /// stored under an `"extra"` key in the metadata sidecar. The serve
    /// layer uses this to persist everything a mid-run resume needs
    /// beyond the flat params (RNG position, sampler tables, optimizer
    /// state, accounting counters) without changing the binary format.
    pub fn save_with_extra(&self, dir: &Path, name: &str, extra: &Json) -> std::io::Result<PathBuf> {
        self.save_inner(dir, name, Some(extra))
    }

    /// Both save forms: build each file's complete byte image in memory,
    /// then commit via [`write_atomic`] — one generation per file, no
    /// read-modify-rewrite window on the sidecar.
    fn save_inner(&self, dir: &Path, name: &str, extra: Option<&Json>) -> std::io::Result<PathBuf> {
        fault::hit_io(sites::CHECKPOINT_SAVE)?;
        std::fs::create_dir_all(dir)?;
        let bin = dir.join(format!("{name}.ckpt"));
        // Safe f32 -> bytes without unsafe: chunk through to_le_bytes.
        let mut buf = Vec::with_capacity(16 + self.params.len() * 4);
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for &p in &self.params {
            buf.extend_from_slice(&p.to_le_bytes());
        }
        write_atomic(&bin, &buf)?;
        let mut fields = vec![
            ("model", s(self.model.clone())),
            ("step", num(self.step as f64)),
            ("seed", num(self.seed as f64)),
            ("param_count", num(self.params.len() as f64)),
        ];
        if let Some(extra) = extra {
            fields.push(("extra", extra.clone()));
        }
        let meta = obj(fields);
        write_atomic(
            &dir.join(format!("{name}.json")),
            meta.to_string_compact().as_bytes(),
        )?;
        Ok(bin)
    }

    /// Read back the `"extra"` document written by
    /// [`Checkpoint::save_with_extra`]. `Json::Null` when the sidecar has
    /// no extra section (a plain [`Checkpoint::save`]).
    pub fn load_extra(dir: &Path, name: &str) -> std::io::Result<Json> {
        let src = std::fs::read_to_string(dir.join(format!("{name}.json")))?;
        let meta = Json::parse(&src)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        Ok(meta.get("extra").cloned().unwrap_or(Json::Null))
    }

    pub fn load(dir: &Path, name: &str) -> std::io::Result<Checkpoint> {
        fault::hit_io(sites::CHECKPOINT_LOAD)?;
        let invalid = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let bin = dir.join(format!("{name}.ckpt"));
        let mut f = std::fs::File::open(&bin)?;
        let file_len = f.metadata()?.len();
        if file_len < 16 {
            // A sub-header file would surface as UnexpectedEof from
            // read_exact; corruption uniformly reports InvalidData.
            return Err(invalid(format!(
                "{}: {file_len} bytes is shorter than the 16-byte header (truncated checkpoint)",
                bin.display()
            )));
        }
        let mut head = [0u8; 16];
        f.read_exact(&mut head)?;
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        let count = u64::from_le_bytes(head[8..16].try_into().unwrap());
        if magic != MAGIC {
            return Err(invalid("bad magic".into()));
        }
        if version != VERSION {
            return Err(invalid(format!("unsupported version {version}")));
        }
        // The param count is untrusted input: validate it against the
        // actual payload length BEFORE sizing any allocation, so a
        // truncated or corrupt header fails with a clean InvalidData
        // instead of a near-unbounded allocation. Exact match also
        // rejects trailing garbage.
        let payload = file_len - head.len() as u64;
        let claimed = count.checked_mul(4).ok_or_else(|| {
            invalid(format!(
                "{}: header claims {count} params, which overflows the payload size",
                bin.display()
            ))
        })?;
        if claimed != payload {
            return Err(invalid(format!(
                "{}: header claims {count} params ({claimed} payload bytes) but the file \
                 carries {payload} bytes after the header ({})",
                bin.display(),
                if claimed > payload { "truncated checkpoint" } else { "trailing garbage" },
            )));
        }
        let mut buf = vec![0u8; claimed as usize];
        f.read_exact(&mut buf)?;
        let params = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let meta_src = std::fs::read_to_string(dir.join(format!("{name}.json")))
            .unwrap_or_else(|_| "{}".to_string());
        let meta = Json::parse(&meta_src).unwrap_or(Json::Null);
        Ok(Checkpoint {
            model: meta.get("model").and_then(Json::as_str).unwrap_or("").to_string(),
            step: meta.get("step").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            seed: meta.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("evosample_ckpt_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&d);
        d
    }

    /// Per-test directory: tests run on parallel threads, so sharing one
    /// dir while some tests `remove_dir_all` it would race.
    fn fresh_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("evosample_ckpt_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_exact() {
        let d = dir();
        let ck = Checkpoint {
            model: "mlp".into(),
            step: 42,
            seed: 7,
            params: vec![1.5, -2.25, f32::MIN_POSITIVE, 0.0, 3.4e38],
        };
        ck.save(&d, "t1").unwrap();
        let back = Checkpoint::load(&d, "t1").unwrap();
        assert_eq!(ck, back);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn rejects_garbage_file() {
        let d = dir();
        std::fs::write(d.join("bad.ckpt"), b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&d, "bad").is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(Checkpoint::load(Path::new("/nonexistent"), "x").is_err());
    }

    /// A valid header + count field claiming a multi-GB payload over a
    /// tiny file must fail with InvalidData (validated BEFORE any
    /// allocation), not attempt a `count * 4` allocation.
    #[test]
    fn truncated_file_with_huge_count_is_invalid_data() {
        let d = fresh_dir("trunc");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes()); // ~4 TiB claimed
        bytes.extend_from_slice(&[0u8; 8]); // 8 bytes of actual payload
        std::fs::write(d.join("trunc.ckpt"), &bytes).unwrap();
        let err = Checkpoint::load(&d, "trunc").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("truncated"), "{msg}");
        assert!(msg.contains("1099511627776"), "message names the claimed count: {msg}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn count_overflow_is_invalid_data() {
        let d = fresh_dir("ovf");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // count * 4 overflows
        std::fs::write(d.join("ovf.ckpt"), &bytes).unwrap();
        let err = Checkpoint::load(&d, "ovf").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("overflow"), "{err}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn trailing_garbage_is_invalid_data() {
        let d = fresh_dir("tail");
        let ck = Checkpoint { model: "mlp".into(), step: 1, seed: 2, params: vec![1.0, 2.0] };
        let path = ck.save(&d, "tail").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&d, "tail").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("trailing garbage"), "{err}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn truncated_params_section_is_invalid_data() {
        let d = fresh_dir("cut");
        let ck = Checkpoint {
            model: "mlp".into(),
            step: 1,
            seed: 2,
            params: (0..64).map(|i| i as f32).collect(),
        };
        let path = ck.save(&d, "cut").unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        let err = Checkpoint::load(&d, "cut").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn extra_sidecar_roundtrips_and_keeps_core_fields() {
        let d = fresh_dir("extra");
        let ck = Checkpoint { model: "mlp".into(), step: 9, seed: 3, params: vec![0.5, 1.25] };
        let extra = obj(vec![
            ("epoch", num(4.0)),
            ("fp_passes", num(1234.0)),
            ("rng_state", s("0xdeadbeef")),
        ]);
        ck.save_with_extra(&d, "ex", &extra).unwrap();
        // The binary payload and core metadata survive unchanged...
        let back = Checkpoint::load(&d, "ex").unwrap();
        assert_eq!(ck, back);
        // ...and the extra document round-trips exactly.
        let got = Checkpoint::load_extra(&d, "ex").unwrap();
        assert_eq!(got.get("epoch").and_then(Json::as_f64), Some(4.0));
        assert_eq!(got.get("fp_passes").and_then(Json::as_f64), Some(1234.0));
        assert_eq!(got.get("rng_state").and_then(Json::as_str), Some("0xdeadbeef"));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn plain_save_has_null_extra() {
        let d = fresh_dir("noextra");
        let ck = Checkpoint { model: "mlp".into(), step: 1, seed: 2, params: vec![1.0] };
        ck.save(&d, "plain").unwrap();
        assert_eq!(Checkpoint::load_extra(&d, "plain").unwrap(), Json::Null);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn large_checkpoint_roundtrips() {
        let d = dir();
        let params: Vec<f32> = (0..100_000).map(|i| i as f32 * 0.5).collect();
        let ck = Checkpoint { model: "big".into(), step: 1, seed: 0, params };
        ck.save(&d, "big").unwrap();
        assert_eq!(Checkpoint::load(&d, "big").unwrap().params.len(), 100_000);
        let _ = std::fs::remove_dir_all(&d);
    }
}
