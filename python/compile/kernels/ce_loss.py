"""Pallas kernel: fused per-sample softmax cross-entropy (L1 hot-spot).

TPU adaptation of the usual GPU CE kernel (one warp per row + shuffle
reductions): we tile the logits as `(block_b, classes)` BlockSpecs so one
batch-tile stays resident in VMEM; the max/exp/sum reduction is a single
VPU pass over the tile, and the gold-logit gather is a masked reduction
(TPU has no cheap per-row dynamic gather, so we select with an iota mask —
this is the idiomatic Mosaic formulation).

Lowered with `interpret=True` only: the CPU PJRT plugin cannot execute the
Mosaic custom-call a real TPU lowering would produce. Correctness is pinned
to `ref.cross_entropy_ref` by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile. 8 is one VPU sublane group for f32; classes ride the lane
# dimension. VMEM footprint per tile = block_b * classes * 4 bytes
# (plus the i32 labels tile) — for CIFAR-100 shapes (classes=100) a
# 8x100 tile is ~3.2KB, tiny; for LM vocab 2048 a 8x2048 tile is 64KB,
# still far below the ~16MB VMEM budget, so the grid only runs over batch.
_BLOCK_B = 8


def _ce_kernel(logits_ref, labels_ref, out_ref):
    """One grid step: per-sample CE for a (block_b, classes) logits tile."""
    logits = logits_ref[...].astype(jnp.float32)
    labels = labels_ref[...]
    # log-sum-exp along classes (lanes), numerically stabilized.
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[:, 0]
    # Gold logit via iota mask: one-hot select instead of gather.
    classes = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[0], classes), 1)
    onehot = (iota == labels[:, None]).astype(jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    out_ref[...] = lse - gold


def cross_entropy(logits: jax.Array, labels: jax.Array, *, block_b: int = _BLOCK_B) -> jax.Array:
    """Per-sample CE; drop-in for ref.cross_entropy_ref.

    Args:
      logits: f32[batch, classes]; batch must be divisible by block_b
        (aot.py always emits batch sizes that are multiples of 8).
      labels: i32[batch]

    Returns:
      f32[batch]
    """
    batch, classes = logits.shape
    if batch % block_b != 0:
        # Fall back to a single whole-array tile for ragged batches —
        # keeps the public contract total while the tuned path stays on
        # the aligned shapes aot.py emits.
        block_b = batch
    grid = (batch // block_b,)
    return pl.pallas_call(
        _ce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, classes), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,
    )(logits, labels.astype(jnp.int32))


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def cross_entropy_vjp(logits, labels):
    """CE with a hand-written backward: softmax(logits) - onehot(labels).

    The backward recomputes softmax from the forward tile instead of
    storing it (the flash-style memory trade), mirroring how the TPU
    kernel would avoid an HBM round-trip of the [batch, classes] prob
    matrix.
    """
    return cross_entropy(logits, labels)


def _ce_fwd(logits, labels):
    return cross_entropy(logits, labels), (logits, labels)


def _ce_bwd(res, g):
    logits, labels = res
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return ((p - onehot) * g[:, None], None)


cross_entropy_vjp.defvjp(_ce_fwd, _ce_bwd)
