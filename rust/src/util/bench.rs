//! Criterion-lite benchmark harness (criterion is not available offline).
//!
//! Used by every `benches/*.rs` target (all declared `harness = false`).
//! Methodology mirrors criterion's core loop: warmup, then timed batches
//! until a time budget or iteration cap is reached; report median and MAD
//! (median absolute deviation) which are robust to scheduler noise.

use std::time::Duration;

use crate::util::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mad: Duration,
    pub total: Duration,
}

impl BenchResult {
    pub fn per_iter_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.3} ms/iter  (±{:.3} ms, {} iters)",
            self.name,
            self.median.as_secs_f64() * 1e3,
            self.mad.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(300),
            max_iters: 1_000,
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Time `f` repeatedly; `f` should perform ONE unit of work and return
    /// a value that is passed to `std::hint::black_box`.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Stopwatch::start();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed samples.
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Stopwatch::start();
        let mut iters = 0u64;
        while t0.elapsed() < self.budget && iters < self.max_iters {
            let s = Stopwatch::start();
            std::hint::black_box(f());
            samples.push(s.elapsed());
            iters += 1;
        }
        samples.sort_unstable();
        let median = samples[samples.len() / 2];
        let mut dev: Vec<i128> = samples
            .iter()
            .map(|&s| (s.as_nanos() as i128 - median.as_nanos() as i128).abs())
            .collect();
        dev.sort_unstable();
        let mad = Duration::from_nanos(dev[dev.len() / 2] as u64);
        let res = BenchResult {
            name: name.to_string(),
            iters,
            median,
            mad,
            total: t0.elapsed(),
        };
        println!("{res}");
        res
    }
}

/// True when the benches should run their scaled-down "smoke" variant
/// (default). Set `EVOSAMPLE_BENCH_FULL=1` for paper-scale runs.
pub fn smoke_mode() -> bool {
    std::env::var("EVOSAMPLE_BENCH_FULL").map(|v| v != "1").unwrap_or(true)
}

/// Print a markdown-ish table header used by the experiment benches.
pub fn table_header(title: &str, cols: &[&str]) {
    println!("\n== {title} ==");
    println!("{}", cols.join(" | "));
    println!("{}", cols.iter().map(|c| "-".repeat(c.len())).collect::<Vec<_>>().join("-|-"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let b = Bencher::quick();
        let r = b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters > 0);
        assert!(r.median.as_nanos() > 0);
        assert!(r.median < Duration::from_millis(10));
    }

    #[test]
    fn smoke_mode_defaults_true() {
        // Unless the caller exported EVOSAMPLE_BENCH_FULL=1, smoke mode is on.
        if std::env::var("EVOSAMPLE_BENCH_FULL").is_err() {
            assert!(smoke_mode());
        }
    }
}
