//! The job table + pending queue with admission control.
//!
//! One `JobQueue` sits behind the server's mutex; worker threads pop
//! ready jobs, connection threads submit/cancel/inspect. Admission is
//! explicit: a submit that would push the pending queue past
//! `max_queue` is rejected with a reason (`queue_full`), never buffered
//! unboundedly — the caller turns that into the protocol's
//! `rejected{reason}` response.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::config::RunConfig;

use super::job::{JobShared, JobState};

/// Everything the queue holds per job. The `RunConfig` is immutable
/// after submission; mutable state lives in [`JobShared`].
pub struct JobEntry {
    pub cfg: RunConfig,
    pub config_toml: String,
    pub shared: Arc<JobShared>,
    /// A checkpoint exists in the state dir — admit with resume.
    pub has_checkpoint: bool,
}

/// Handed to a worker when it claims a job.
pub struct ClaimedJob {
    pub id: String,
    pub cfg: RunConfig,
    pub config_toml: String,
    pub shared: Arc<JobShared>,
    pub has_checkpoint: bool,
}

pub struct JobQueue {
    jobs: BTreeMap<String, JobEntry>,
    pending: VecDeque<String>,
    running: usize,
    max_queue: usize,
    draining: bool,
    aborting: bool,
}

impl JobQueue {
    pub fn new(max_queue: usize) -> JobQueue {
        JobQueue {
            jobs: BTreeMap::new(),
            pending: VecDeque::new(),
            running: 0,
            max_queue,
            draining: false,
            aborting: false,
        }
    }

    /// Shed a submit: count it (total + per reason) and return the reason.
    fn shed(reason: &'static str) -> Result<usize, &'static str> {
        if crate::obs::counters_on() {
            let reg = crate::obs::registry();
            reg.counter("serve.shed").add(1);
            reg.counter(&format!("serve.shed.{reason}")).add(1);
        }
        Err(reason)
    }

    /// Queue-health gauges, refreshed at every depth transition.
    fn note_depth(&self) {
        if crate::obs::counters_on() {
            let reg = crate::obs::registry();
            reg.gauge("serve.queue_depth").set(self.pending.len() as i64);
            reg.gauge("serve.running").set(self.running as i64);
        }
    }

    /// Admit a job into the pending queue. Returns its queue position
    /// (0 = next up) or the shed reason.
    pub fn submit(&mut self, id: &str, entry: JobEntry) -> Result<usize, &'static str> {
        if self.draining || self.aborting {
            return Self::shed("shutting_down");
        }
        if self.jobs.contains_key(id) {
            return Self::shed("duplicate_id");
        }
        if self.pending.len() >= self.max_queue {
            return Self::shed("queue_full");
        }
        let position = self.pending.len();
        self.pending.push_back(id.to_string());
        self.jobs.insert(id.to_string(), entry);
        if crate::obs::counters_on() {
            crate::obs::registry().counter("serve.submitted").add(1);
        }
        self.note_depth();
        Ok(position)
    }

    /// Re-admit a rescanned job without admission control (restart
    /// recovery must never shed jobs the previous life accepted).
    pub fn requeue(&mut self, id: &str, entry: JobEntry) {
        self.pending.push_back(id.to_string());
        self.jobs.insert(id.to_string(), entry);
        self.note_depth();
    }

    /// Record a terminal job from a rescan for `status` visibility only.
    pub fn insert_terminal(&mut self, id: &str, entry: JobEntry) {
        self.jobs.insert(id.to_string(), entry);
    }

    /// Claim the next pending job (skipping any that were cancelled
    /// while queued). Increments the running count. Returns `None`
    /// outright while aborting: an abort parks the backlog for the next
    /// server life's rescan and must never start new work (drain mode,
    /// by contrast, keeps claiming until the queue empties).
    pub fn claim_next(&mut self) -> Option<ClaimedJob> {
        if self.aborting {
            return None;
        }
        while let Some(id) = self.pending.pop_front() {
            let Some(entry) = self.jobs.get(&id) else { continue };
            if entry.shared.state() != JobState::Queued {
                continue;
            }
            self.running += 1;
            self.note_depth();
            return Some(ClaimedJob {
                id,
                cfg: entry.cfg.clone(),
                config_toml: entry.config_toml.clone(),
                shared: Arc::clone(&entry.shared),
                has_checkpoint: entry.has_checkpoint,
            });
        }
        None
    }

    /// A worker finished (or parked) its claimed job.
    pub fn release(&mut self) {
        debug_assert!(self.running > 0);
        self.running = self.running.saturating_sub(1);
        self.note_depth();
    }

    pub fn get(&self, id: &str) -> Option<&JobEntry> {
        self.jobs.get(id)
    }

    pub fn jobs(&self) -> impl Iterator<Item = (&String, &JobEntry)> {
        self.jobs.iter()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn running_len(&self) -> usize {
        self.running
    }

    /// Stop admitting; `abort` additionally interrupts running jobs at
    /// their next epoch boundary.
    pub fn begin_shutdown(&mut self, abort: bool) {
        self.draining = true;
        if abort {
            self.aborting = true;
            // Flag every non-terminal job, not just those already
            // Running: a job a worker claimed but has not yet marked
            // running would otherwise miss the interrupt and run to
            // completion. Flagging still-queued jobs is harmless — the
            // aborting guard in `claim_next` keeps them unclaimed, and a
            // restart builds fresh `JobShared`s with clear flags.
            for entry in self.jobs.values() {
                if !entry.shared.state().is_terminal() {
                    entry.shared.request_interrupt(super::job::INTERRUPT_SHUTDOWN);
                }
            }
        }
    }

    pub fn shutting_down(&self) -> bool {
        self.draining
    }

    pub fn aborting(&self) -> bool {
        self.aborting
    }

    /// Workers exit when this is true and `claim_next` returns None:
    /// drain mode waits for the pending queue to empty, abort exits now.
    pub fn workers_should_exit(&self) -> bool {
        self.aborting || (self.draining && self.pending.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetConfig;

    fn entry(id: &str) -> JobEntry {
        let cfg = RunConfig::new(
            id,
            "native",
            DatasetConfig::SynthCifar { n: 128, classes: 4, label_noise: 0.0, hard_frac: 0.2 },
        );
        JobEntry {
            shared: Arc::new(JobShared::new(id, id, "baseline", cfg.epochs)),
            cfg,
            config_toml: String::new(),
            has_checkpoint: false,
        }
    }

    #[test]
    fn admission_sheds_past_max_queue() {
        let mut q = JobQueue::new(2);
        assert_eq!(q.submit("a", entry("a")), Ok(0));
        assert_eq!(q.submit("b", entry("b")), Ok(1));
        assert_eq!(q.submit("c", entry("c")), Err("queue_full"));
        assert_eq!(q.pending_len(), 2, "shed submits leave no residue");
        assert!(q.get("c").is_none());
        // Claiming frees a slot; admission recovers.
        assert!(q.claim_next().is_some());
        assert_eq!(q.submit("c", entry("c")), Ok(1));
    }

    #[test]
    fn duplicate_ids_are_rejected() {
        let mut q = JobQueue::new(4);
        q.submit("a", entry("a")).unwrap();
        assert_eq!(q.submit("a", entry("a")), Err("duplicate_id"));
    }

    #[test]
    fn claim_skips_jobs_cancelled_while_queued() {
        let mut q = JobQueue::new(4);
        q.submit("a", entry("a")).unwrap();
        q.submit("b", entry("b")).unwrap();
        q.get("a").unwrap().shared.finish(JobState::Cancelled, None, None, None);
        let claimed = q.claim_next().unwrap();
        assert_eq!(claimed.id, "b");
        assert_eq!(q.running_len(), 1);
        q.release();
        assert_eq!(q.running_len(), 0);
    }

    #[test]
    fn shutdown_stops_admission_and_flags_runners() {
        let mut q = JobQueue::new(4);
        q.submit("a", entry("a")).unwrap();
        let claimed = q.claim_next().unwrap();
        claimed.shared.mark_running();
        q.begin_shutdown(true);
        assert_eq!(q.submit("b", entry("b")), Err("shutting_down"));
        assert_eq!(claimed.shared.interrupt_kind(), crate::serve::job::INTERRUPT_SHUTDOWN);
        assert!(q.workers_should_exit());
    }

    #[test]
    fn abort_parks_pending_jobs_unclaimed() {
        let mut q = JobQueue::new(4);
        q.submit("a", entry("a")).unwrap();
        let claimed = q.claim_next().unwrap();
        claimed.shared.mark_running();
        q.submit("b", entry("b")).unwrap();
        q.begin_shutdown(true);
        // The backlog is parked for the next life's rescan, never run.
        assert!(q.claim_next().is_none(), "abort must not start queued work");
        assert_eq!(q.pending_len(), 1);
        assert!(q.workers_should_exit());
        // Even the still-queued job carries the interrupt flag, closing
        // the claimed-but-not-yet-running race.
        let flag = q.get("b").unwrap().shared.interrupt_kind();
        assert_eq!(flag, crate::serve::job::INTERRUPT_SHUTDOWN);
    }

    #[test]
    fn drain_waits_for_pending() {
        let mut q = JobQueue::new(4);
        q.submit("a", entry("a")).unwrap();
        q.begin_shutdown(false);
        assert!(!q.workers_should_exit(), "drain runs the backlog first");
        let _ = q.claim_next().unwrap();
        assert!(q.workers_should_exit());
    }
}
