//! # evosample — Data-Efficient Training by Evolved Sampling
//!
//! A three-layer reproduction of "Data-Efficient Training by Evolved
//! Sampling" (Cheng, Li, Bian; 2025):
//!
//! - **Layer 3 (this crate)**: the training coordinator — the paper's
//!   contribution. Dynamic data selection (ES / ESWP and six baselines),
//!   epoch/step orchestration, datasets, schedules, accounting, metrics.
//! - **Layer 2 (python/compile/model.py)**: JAX forward/backward passes of
//!   the workloads (MLP/CNN classifiers, transformer LM/classifier, MAE),
//!   AOT-lowered to HLO text once at build time.
//! - **Layer 1 (python/compile/kernels/)**: Pallas kernels for the compute
//!   hot-spots (fused cross-entropy, flash-style attention, evolved-score
//!   update), lowered into the same HLO.
//!
//! Python is never on the training path: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and runs
//! everything natively.
//!
//! Embedding applications enter through [`prelude`]: a fluent
//! [`SessionBuilder`] producing a runnable [`Session`], an open sampler
//! registry ([`sampler::registry`]) external crates extend with their own
//! selection policies, and a typed event stream ([`Event`]/[`EventSink`])
//! announcing engine progress.

pub mod util;
pub mod obs;
pub mod fault;
pub mod config;
pub mod data;
pub mod sampler;
pub mod runtime;
pub mod coordinator;
pub mod metrics;
pub mod api;
pub mod experiments;
pub mod serve;
pub mod cli;
pub mod analysis;

pub use api::prelude;
pub use api::{Event, EventBus, EventSink, RunResult, Session, SessionBuilder};
pub use sampler::{Sampler, SamplerKind};
