//! Numerical verification of Prop. 2.1, Prop. 3.1 and Thm. 3.2.
fn main() {
    evosample::experiments::theory::run_all().expect("theory");
}
