//! Synthetic token corpus for LM pre-training / SFT (NuminaMath substitute).
//!
//! Token sequences come from a mixture of per-topic first-order Markov
//! chains over a Zipf-weighted vocabulary. Each topic has a deterministic
//! "grammar" (a permutation-based successor function) blended with Zipf
//! noise; a per-sequence temperature controls how predictable the sequence
//! is — the LM analogue of image difficulty. Low-temperature sequences are
//! quickly learned (losses collapse), high-temperature ones stay hard,
//! giving the loss spread ES exploits.

use super::{Modality, SplitDataset, TensorDataset};
use crate::util::Pcg64;

const TOPICS: usize = 8;

struct Topic {
    /// successor[v] = preferred next token after v.
    successor: Vec<i32>,
    /// second-choice successor (bigram branching).
    successor2: Vec<i32>,
}

fn make_topics(vocab: usize, rng: &mut Pcg64) -> Vec<Topic> {
    (0..TOPICS)
        .map(|_| {
            let p1 = rng.permutation(vocab);
            let p2 = rng.permutation(vocab);
            Topic {
                successor: p1.into_iter().map(|x| x as i32).collect(),
                successor2: p2.into_iter().map(|x| x as i32).collect(),
            }
        })
        .collect()
}

fn gen_sequence(
    topic: &Topic,
    vocab: usize,
    len: usize,
    temp: f32,
    rng: &mut Pcg64,
) -> Vec<i32> {
    let mut seq = Vec::with_capacity(len);
    let mut cur = rng.below(vocab as u64) as i32;
    seq.push(cur);
    for _ in 1..len {
        let u = rng.f32();
        cur = if u < 1.0 - temp {
            topic.successor[cur as usize]
        } else if u < 1.0 - temp / 2.0 {
            topic.successor2[cur as usize]
        } else {
            // Zipf noise draw: frequent tokens dominate the noise floor.
            rng.zipf(vocab, 1.1) as i32
        };
        seq.push(cur);
    }
    seq
}

fn make_split(
    n: usize,
    vocab: usize,
    seq: usize,
    topics: &[Topic],
    rng: &mut Pcg64,
) -> TensorDataset {
    let mut x = Vec::with_capacity(n * seq);
    let mut y = Vec::with_capacity(n * seq);
    let mut difficulty = Vec::with_capacity(n);
    let mut clean = Vec::with_capacity(n);
    for _ in 0..n {
        let t = rng.below(topics.len() as u64) as usize;
        // Temperature: easy bulk (0.05–0.3) + hard tail (0.5–0.9).
        let temp = if rng.f64() < 0.2 { rng.range_f32(0.5, 0.9) } else { rng.range_f32(0.05, 0.3) };
        let toks = gen_sequence(&topics[t], vocab, seq + 1, temp, rng);
        x.extend_from_slice(&toks[..seq]);
        y.extend_from_slice(&toks[1..seq + 1]);
        difficulty.push(temp);
        clean.push(t as i32);
    }
    let ds = TensorDataset {
        modality: Modality::Tokens { seq },
        n,
        classes: 0,
        x_f32: vec![],
        x_i32: x,
        y,
        y_dim: seq,
        difficulty,
        clean_class: clean,
    };
    ds.validate().expect("corpus invariants");
    ds
}

pub fn generate(n: usize, test_n: usize, vocab: usize, seq: usize, rng: &mut Pcg64) -> SplitDataset {
    assert!(vocab >= 16, "vocab too small");
    let mut topic_rng = rng.fork(0x70_71);
    let topics = make_topics(vocab, &mut topic_rng);
    let mut tr = rng.fork(1);
    let mut te = rng.fork(2);
    SplitDataset {
        train: make_split(n, vocab, seq, &topics, &mut tr),
        test: make_split(test_n, vocab, seq, &topics, &mut te),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut rng = Pcg64::new(1);
        let split = generate(64, 16, 128, 32, &mut rng);
        assert_eq!(split.train.x_i32.len(), 64 * 32);
        assert_eq!(split.train.y.len(), 64 * 32);
        assert!(split.train.x_i32.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn y_is_next_token() {
        let mut rng = Pcg64::new(2);
        let split = generate(8, 2, 64, 16, &mut rng);
        let ds = &split.train;
        for i in 0..8 {
            for j in 0..15 {
                assert_eq!(ds.y[i * 16 + j], ds.x_i32[i * 16 + j + 1]);
            }
        }
    }

    #[test]
    fn low_temp_sequences_are_predictable() {
        // For easy sequences, next token should usually be successor(cur):
        // verify the generator actually encodes learnable structure.
        let mut rng = Pcg64::new(3);
        let vocab = 64;
        let mut topic_rng = rng.fork(0x70_71);
        let topics = make_topics(vocab, &mut topic_rng);
        let mut g = rng.fork(9);
        let toks = gen_sequence(&topics[0], vocab, 200, 0.05, &mut g);
        let hits = toks
            .windows(2)
            .filter(|w| topics[0].successor[w[0] as usize] == w[1])
            .count();
        assert!(hits as f64 / 199.0 > 0.85, "hits={hits}");
    }

    #[test]
    fn difficulty_spread_present() {
        let mut rng = Pcg64::new(4);
        let split = generate(500, 8, 64, 16, &mut rng);
        let hard = split.train.difficulty.iter().filter(|&&d| d >= 0.5).count();
        assert!(hard > 50 && hard < 200, "hard={hard}");
    }
}
