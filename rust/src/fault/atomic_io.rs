//! Crash-safe file replacement: write-tmp + fsync + rename.
//!
//! The durability contract (DESIGN.md §12): readers of a path written
//! through [`write_atomic`] observe either the previous complete
//! contents or the new complete contents — never a torn prefix. A crash
//! (or an injected [`sites::ATOMIC_COMMIT`](super::sites::ATOMIC_COMMIT)
//! fault) before the rename leaves the previous file untouched; the
//! orphaned `.tmp` sibling is simply overwritten by the next attempt.

use std::io::Write;
use std::path::Path;

/// Atomically replace `path` with `bytes`. The payload is written to a
/// sibling `<name>.tmp`, fsynced, then renamed over `path`; the
/// directory is fsynced best-effort so the rename itself is durable.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let file_name = path.file_name().ok_or_else(|| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("write_atomic: {} has no file name", path.display()),
        )
    })?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    // The commit point: everything before this line touches only the
    // tmp sibling, so a crash here (what the failpoint simulates) is
    // recoverable — the previous `path` still parses.
    super::hit_io(super::sites::ATOMIC_COMMIT)?;
    std::fs::rename(&tmp, path)?;
    // Rename durability needs a directory fsync; best-effort because
    // not every filesystem lets a directory handle sync.
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fresh_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("evosample_atomic_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_replaces() {
        let d = fresh_dir("roundtrip");
        let p = d.join("state.json");
        write_atomic(&p, b"v1").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"v1");
        write_atomic(&p, b"version-two").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"version-two");
        assert!(!d.join("state.json.tmp").exists(), "tmp consumed by the rename");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn rejects_bare_root() {
        let err = write_atomic(Path::new("/"), b"x").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
    }

    // The crash-window regression (injected atomic.commit fault leaves
    // the previous file intact) lives in tests/chaos.rs: arming that
    // site here would perturb concurrent in-crate tests that write
    // checkpoints through this helper.
}
