//! DESIGN.md §6 event-ordering grammar, enforced per engine mode:
//!
//! ```text
//! RunStart
//!   ( EpochStart ( ScoringFp? SelectionMade )* WorkerLost* SyncRound?
//!     EvalDone? EpochEnd )*
//! RunEnd
//! ```
//!
//! A state-machine validator consumes the typed stream from a custom
//! sink and rejects any out-of-order emission. All three engine modes
//! must satisfy the same grammar: single-worker, the sequential
//! data-parallel simulation (`workers > 1`), and threaded replicas
//! (which emit epoch-level events only — still grammar-conformant).

use std::sync::{Arc, Mutex};

use evosample::prelude::*;
use evosample::runtime::native::NativeRuntime;

/// Validator states, named for what the stream may legally do next.
#[derive(Clone, Copy, Debug, PartialEq)]
enum S {
    /// Nothing seen yet; only `RunStart` is legal.
    Start,
    /// Between epochs; `EpochStart` or `RunEnd`.
    BetweenEpochs,
    /// Inside an epoch, before the sync/eval tail.
    InEpoch,
    /// A `ScoringFp` was emitted; the paired `SelectionMade` must follow.
    PendingSelection,
    /// `SyncRound` seen; only `EvalDone` or `EpochEnd` remain.
    AfterSync,
    /// `EvalDone` seen; only `EpochEnd` remains.
    AfterEval,
    /// `RunEnd` seen; the stream must be over.
    Done,
}

fn check_grammar(events: &[Event]) -> Result<(), String> {
    let mut state = S::Start;
    let mut current_epoch: Option<usize> = None;
    let bad = |state: S, ev: &Event| Err(format!("{ev:?} illegal in state {state:?}"));
    for ev in events {
        // Epoch tags must match the enclosing EpochStart.
        let tag = match ev {
            Event::EpochStart { epoch, .. }
            | Event::ScoringFp { epoch, .. }
            | Event::SelectionMade { epoch, .. }
            | Event::WorkerLost { epoch, .. }
            | Event::SyncRound { epoch, .. }
            | Event::EvalDone { epoch, .. }
            | Event::EpochEnd { epoch, .. } => Some(*epoch),
            _ => None,
        };
        state = match (state, ev) {
            (S::Start, Event::RunStart { .. }) => S::BetweenEpochs,
            (S::BetweenEpochs, Event::EpochStart { epoch, .. }) => {
                if let Some(prev) = current_epoch {
                    if *epoch != prev + 1 {
                        return Err(format!("epoch {epoch} follows epoch {prev}"));
                    }
                }
                current_epoch = Some(*epoch);
                S::InEpoch
            }
            (S::BetweenEpochs, Event::RunEnd { .. }) => S::Done,
            (S::InEpoch, Event::ScoringFp { .. }) => S::PendingSelection,
            (S::InEpoch, Event::SelectionMade { .. }) => S::InEpoch,
            // Degraded mode: a quarantined worker announces before the
            // epoch's sync tail; any number may be lost in one epoch.
            (S::InEpoch, Event::WorkerLost { .. }) => S::InEpoch,
            (S::InEpoch, Event::SyncRound { .. }) => S::AfterSync,
            (S::InEpoch | S::AfterSync, Event::EvalDone { .. }) => S::AfterEval,
            (S::InEpoch | S::AfterSync | S::AfterEval, Event::EpochEnd { .. }) => S::BetweenEpochs,
            (S::PendingSelection, Event::SelectionMade { .. }) => S::InEpoch,
            (state, ev) => return bad(state, ev),
        };
        if let (Some(tag), Some(cur)) = (tag, current_epoch) {
            if tag != cur {
                return Err(format!("event tagged epoch {tag} inside epoch {cur}: {ev:?}"));
            }
        }
    }
    if state != S::Done {
        return Err(format!("stream ended in state {state:?} (no RunEnd)"));
    }
    Ok(())
}

fn run_and_collect(cfg: RunConfig) -> Vec<Event> {
    let seen: Arc<Mutex<Vec<Event>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = seen.clone();
    let split = data::build(&cfg.dataset, cfg.test_n, cfg.seed ^ 0xda7a_5eed);
    SessionBuilder::from_config(cfg)
        .runtime(Box::new(NativeRuntime::new(split.train.x_len(), 16, 4)))
        .on_event(move |ev: &Event| sink.lock().unwrap().push(ev.clone()))
        .build()
        .unwrap()
        .run()
        .unwrap();
    Arc::try_unwrap(seen).unwrap().into_inner().unwrap()
}

fn base_cfg(sampler: SamplerConfig) -> RunConfig {
    let mut cfg = RunConfig::new(
        "grammar",
        "native",
        DatasetConfig::SynthCifar { n: 192, classes: 4, label_noise: 0.05, hard_frac: 0.2 },
    );
    cfg.epochs = 3;
    cfg.meta_batch = 32;
    cfg.mini_batch = 8;
    cfg.lr = LrSchedule::Const { lr: 0.02 };
    cfg.test_n = 64;
    cfg.eval_every = 2; // EvalDone must stay optional per epoch
    cfg.seed = 11;
    cfg.sampler = sampler;
    cfg
}

#[test]
fn grammar_holds_single_worker() {
    // A scoring sampler exercises the ScoringFp→SelectionMade pairing,
    // the baseline exercises the scoring-free path.
    for sampler in [SamplerConfig::es_default(), SamplerConfig::Uniform] {
        let events = run_and_collect(base_cfg(sampler));
        assert!(events.iter().any(|e| matches!(e, Event::SelectionMade { .. })));
        check_grammar(&events).unwrap();
    }
}

#[test]
fn grammar_holds_sequential_workers() {
    let mut cfg = base_cfg(SamplerConfig::es_default());
    cfg.workers = 2;
    let events = run_and_collect(cfg);
    assert!(events.iter().any(|e| matches!(e, Event::SyncRound { workers: 2, .. })));
    check_grammar(&events).unwrap();
}

#[test]
fn grammar_holds_threaded_workers() {
    let mut cfg = base_cfg(SamplerConfig::es_default());
    cfg.workers = 2;
    cfg.threaded_workers = true;
    cfg.sync_every = 2;
    let events = run_and_collect(cfg);
    // Threaded mode emits epoch-level events only — still conformant.
    assert!(events.iter().any(|e| matches!(e, Event::SyncRound { .. })));
    check_grammar(&events).unwrap();
}

/// The DESIGN.md §11 contract: telemetry is purely observational, so
/// the exact event sequence must be identical at every `run.telemetry`
/// level. `ScoringFp` carries a measured wall-clock `elapsed`, so events
/// are compared on a fingerprint that drops only that field — every
/// numeric payload (losses, accuracies, counts) must match exactly.
#[test]
fn telemetry_levels_do_not_perturb_event_stream() {
    use evosample::config::TelemetryLevel;
    fn fingerprint(ev: &Event) -> String {
        match ev {
            Event::ScoringFp { epoch, step, samples, .. } => {
                format!("scoring_fp e{epoch} s{step} n{samples}")
            }
            other => format!("{other:?}"),
        }
    }
    let run_at = |level: TelemetryLevel| {
        // Sequential data-parallel sim: the busiest emitter (scoring,
        // selection, sync, eval all fire).
        let mut cfg = base_cfg(SamplerConfig::es_default());
        cfg.workers = 2;
        cfg.telemetry = level;
        run_and_collect(cfg).iter().map(fingerprint).collect::<Vec<_>>()
    };
    let off = run_at(TelemetryLevel::Off);
    let counters = run_at(TelemetryLevel::Counters);
    let trace = run_at(TelemetryLevel::Trace);
    assert!(off.iter().any(|f| f.starts_with("scoring_fp")), "stream exercises scoring");
    assert_eq!(off, counters, "counters level changed the event sequence");
    assert_eq!(off, trace, "trace level changed the event sequence");
}

#[test]
fn validator_rejects_malformed_streams() {
    // No RunStart.
    assert!(check_grammar(&[Event::RunEnd { steps: 1, accuracy: 0.5 }]).is_err());
    // ScoringFp without its paired SelectionMade.
    let orphan_fp = vec![
        Event::RunStart { name: "x".into(), sampler: "es".into(), epochs: 1 },
        Event::EpochStart { epoch: 0, kept: 10, dataset_n: 10 },
        Event::ScoringFp {
            epoch: 0,
            step: 0,
            samples: 8,
            elapsed: std::time::Duration::from_millis(1),
        },
        Event::EpochEnd { epoch: 0, mean_train_loss: 1.0 },
        Event::RunEnd { steps: 1, accuracy: 0.5 },
    ];
    assert!(check_grammar(&orphan_fp).unwrap_err().contains("EpochEnd"));
    // Truncated stream: no RunEnd.
    let truncated = vec![
        Event::RunStart { name: "x".into(), sampler: "es".into(), epochs: 1 },
        Event::EpochStart { epoch: 0, kept: 10, dataset_n: 10 },
    ];
    assert!(check_grammar(&truncated).unwrap_err().contains("no RunEnd"));
    // Epoch numbers must be consecutive.
    let skipped = vec![
        Event::RunStart { name: "x".into(), sampler: "es".into(), epochs: 2 },
        Event::EpochStart { epoch: 0, kept: 10, dataset_n: 10 },
        Event::EpochEnd { epoch: 0, mean_train_loss: 1.0 },
        Event::EpochStart { epoch: 2, kept: 10, dataset_n: 10 },
        Event::EpochEnd { epoch: 2, mean_train_loss: 1.0 },
        Event::RunEnd { steps: 2, accuracy: 0.5 },
    ];
    assert!(check_grammar(&skipped).unwrap_err().contains("follows"));
}
