//! Blocked, multi-threaded CPU kernel layer behind [`super::native`].
//!
//! The naive `NativeRuntime` walked `W1` with stride `hidden` in its
//! inner loops, so the FP/BP cost ratios the perf benches report were
//! dominated by cache misses rather than the algorithmic costs the
//! paper's §3.3 accounting models. This module makes the hot path fast
//! while keeping results **bit-identical across kernel thread counts**:
//!
//! * [`pack`] — the packed parameter layout. `W1` is stored transposed
//!   (`[hidden][in_dim]`) so both the forward dot products and the
//!   backward outer-product accumulation run unit-stride; `b1`, `W2`
//!   (`[hidden][classes]`) and `b2` keep their canonical orientation,
//!   which is already unit-stride for every kernel that touches them.
//!   Packing happens on `set_params`/`init`, unpacking on `get_params` —
//!   the canonical flat layout remains the only interchange format
//!   (checkpoints, §D.5 parameter averaging, the XLA cross-check).
//! * [`gemm`] — cache-blocked micro-kernels: multi-accumulator
//!   unit-stride dots, axpy updates, relu-gated backward rows, and the
//!   fused softmax-CE pass that produces per-sample loss and `dlogits`
//!   from a single max/exp sweep.
//! * [`simd`] — the explicit SIMD fast path: portable `[f32; 8]` lane
//!   blocks, multi-accumulator dots, and a register-blocked hidden
//!   forward, plus the bf16 dequantize-on-load scoring kernels. Which
//!   exact path a runtime uses is chosen once via [`KernelDispatch`]
//!   (default: simd; `EVOSAMPLE_KERNEL_DISPATCH` overrides).
//! * [`pool`] — a persistent `std::thread` worker pool, spawned once per
//!   runtime and reused for every step. Work is distributed by batch-row
//!   ranges (forward) and by fixed gradient shards (backward).
//! * [`reference`] — the pre-kernel scalar implementation, kept verbatim
//!   as an executable specification for the equivalence test-suite and
//!   as the baseline the perf benches measure speedups against.
//!
//! # Determinism contract
//!
//! Per-sample forward work is embarrassingly parallel: each row's result
//! is computed by a fixed single-row op sequence, so any row partition
//! yields identical bits. Gradients are accumulated into
//! [`GRAD_SHARDS`] *fixed* row shards — the shard boundaries depend only
//! on the batch size, never on the thread count — and reduced into the
//! final gradient in ascending shard order on one thread. A 1-thread run
//! therefore produces exactly the same bits as an 8-thread run (tested
//! in `tests/kernel_equivalence.rs`).

pub mod gemm;
pub mod pack;
pub mod pool;
pub mod reference;
pub mod simd;

/// Fixed number of gradient shards. This is the determinism anchor (the
/// reduction tree never changes shape with the thread count) and the
/// useful upper bound on backward parallelism, so auto-detected thread
/// counts are clamped to it.
pub const GRAD_SHARDS: usize = 8;

/// Selects which exact kernel implementation a runtime's hot paths run
/// on. Both variants are deterministic (bit-stable across thread
/// counts); they differ from each other only in reduction shape, so a
/// runtime applies ONE variant to every kernel call site — mixing them
/// inside a run would break the self-consistency contracts
/// (`loss_fwd` vs retained-forward losses, fused-CE vs scoring CE).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelDispatch {
    /// The blocked-scalar kernels in [`gemm`] — SLP-vectorizable but
    /// with a single accumulator chain per dot.
    Scalar,
    /// The explicit `[f32; 8]`-block kernels in [`simd`] — multi-chain
    /// dots and register-blocked hidden forward.
    Simd,
}

impl KernelDispatch {
    pub fn parse(s: &str) -> Option<KernelDispatch> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" | "blocked" => Some(KernelDispatch::Scalar),
            "simd" => Some(KernelDispatch::Simd),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KernelDispatch::Scalar => "scalar",
            KernelDispatch::Simd => "simd",
        }
    }

    #[inline]
    pub fn hidden_fwd(
        &self,
        x: &[f32],
        w1t: &[f32],
        b1: &[f32],
        d: usize,
        h: usize,
        h_out: &mut [f32],
    ) {
        match self {
            KernelDispatch::Scalar => gemm::hidden_fwd(x, w1t, b1, d, h, h_out),
            KernelDispatch::Simd => simd::hidden_fwd(x, w1t, b1, d, h, h_out),
        }
    }

    #[inline]
    pub fn logits_fwd(
        &self,
        hrows: &[f32],
        w2: &[f32],
        b2: &[f32],
        h: usize,
        c: usize,
        out: &mut [f32],
    ) {
        match self {
            KernelDispatch::Scalar => gemm::logits_fwd(hrows, w2, b2, h, c, out),
            KernelDispatch::Simd => simd::logits_fwd(hrows, w2, b2, h, c, out),
        }
    }

    #[inline]
    pub fn ce_loss_row(&self, li: &[f32], y: usize) -> f32 {
        match self {
            KernelDispatch::Scalar => gemm::ce_loss_row(li, y),
            KernelDispatch::Simd => simd::ce_loss_row(li, y),
        }
    }

    #[inline]
    pub fn ce_loss_grad_row(&self, li: &[f32], y: usize, scale: f32, dl: &mut [f32]) -> f32 {
        match self {
            KernelDispatch::Scalar => gemm::ce_loss_grad_row(li, y, scale, dl),
            KernelDispatch::Simd => simd::ce_loss_grad_row(li, y, scale, dl),
        }
    }

    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn backward_row(
        &self,
        xi: &[f32],
        hi: &[f32],
        dl: &[f32],
        w2: &[f32],
        d: usize,
        c: usize,
        gw1t: &mut [f32],
        gb1: &mut [f32],
        gw2: &mut [f32],
        gb2: &mut [f32],
        dh: &mut [f32],
    ) {
        match self {
            KernelDispatch::Scalar => {
                gemm::backward_row(xi, hi, dl, w2, d, c, gw1t, gb1, gw2, gb2, dh)
            }
            KernelDispatch::Simd => {
                simd::backward_row(xi, hi, dl, w2, d, c, gw1t, gb1, gw2, gb2, dh)
            }
        }
    }
}

/// Resolve the default kernel dispatch: the `EVOSAMPLE_KERNEL_DISPATCH`
/// env var when set to `simd` or `scalar`/`blocked`, otherwise
/// [`KernelDispatch::Simd`]. Unrecognized values warn once and fall
/// back to the default.
pub fn default_dispatch() -> KernelDispatch {
    match std::env::var("EVOSAMPLE_KERNEL_DISPATCH") {
        Ok(v) => KernelDispatch::parse(&v).unwrap_or_else(|| {
            static WARN: std::sync::Once = std::sync::Once::new();
            WARN.call_once(|| {
                eprintln!(
                    "warning: EVOSAMPLE_KERNEL_DISPATCH={v:?} is not \"simd\" or \
                     \"scalar\"; using the simd kernels"
                );
            });
            KernelDispatch::Simd
        }),
        Err(_) => KernelDispatch::Simd,
    }
}

/// Parse an `EVOSAMPLE_KERNEL_THREADS` value: a positive integer,
/// clamped to [`GRAD_SHARDS`]. `None` means the value is malformed (not
/// an integer, or zero — zero only means "auto" in `run.kernel_threads`,
/// never in the env var).
fn parse_env_threads(v: &str) -> Option<usize> {
    match v.trim().parse::<usize>() {
        Ok(t) if t >= 1 => Some(t.min(GRAD_SHARDS)),
        _ => None,
    }
}

/// Resolve the default kernel worker count: the
/// `EVOSAMPLE_KERNEL_THREADS` env var when set to a positive integer,
/// otherwise `available_parallelism`, both clamped to [`GRAD_SHARDS`].
/// A malformed env value warns once (instead of being silently
/// swallowed) and falls back to `available_parallelism`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("EVOSAMPLE_KERNEL_THREADS") {
        match parse_env_threads(&v) {
            Some(t) => return t,
            None => {
                static WARN: std::sync::Once = std::sync::Once::new();
                WARN.call_once(|| {
                    eprintln!(
                        "warning: EVOSAMPLE_KERNEL_THREADS={v:?} is not a positive \
                         integer; falling back to available_parallelism"
                    );
                });
            }
        }
    }
    std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(GRAD_SHARDS)
}

/// Contiguous even split of `n` items into `parts`: returns the
/// half-open range assigned to `part`. Ranges are disjoint, cover
/// `0..n`, and extra parts (when `parts > n`) come out empty.
pub fn split_range(n: usize, parts: usize, part: usize) -> (usize, usize) {
    debug_assert!(part < parts.max(1));
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let start = part * base + part.min(rem);
    let len = base + usize::from(part < rem);
    (start, start + len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_range_covers_and_is_disjoint() {
        for n in [0usize, 1, 3, 7, 8, 9, 64, 65] {
            for parts in 1..=9usize {
                let mut covered = 0usize;
                let mut next = 0usize;
                for p in 0..parts {
                    let (a, b) = split_range(n, parts, p);
                    assert_eq!(a, next, "n={n} parts={parts} p={p}");
                    assert!(b >= a);
                    next = b;
                    covered += b - a;
                }
                assert_eq!(next, n);
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn split_range_is_balanced() {
        let sizes: Vec<usize> =
            (0..4).map(|p| { let (a, b) = split_range(10, 4, p); b - a }).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn default_threads_is_positive_and_clamped() {
        let t = default_threads();
        assert!(t >= 1);
        assert!(t <= GRAD_SHARDS);
    }

    #[test]
    fn env_thread_values_parse_or_flag_malformed() {
        assert_eq!(parse_env_threads("4"), Some(4));
        assert_eq!(parse_env_threads(" 3 "), Some(3));
        assert_eq!(parse_env_threads("12"), Some(GRAD_SHARDS), "clamped to shard count");
        // Malformed (and zero — not a valid lane count) must be flagged
        // so default_threads can warn instead of silently ignoring.
        assert_eq!(parse_env_threads("0"), None);
        assert_eq!(parse_env_threads("abc"), None);
        assert_eq!(parse_env_threads("-2"), None);
        assert_eq!(parse_env_threads("1.5"), None);
        assert_eq!(parse_env_threads(""), None);
    }

    #[test]
    fn dispatch_parses_and_round_trips() {
        assert_eq!(KernelDispatch::parse("simd"), Some(KernelDispatch::Simd));
        assert_eq!(KernelDispatch::parse("Scalar"), Some(KernelDispatch::Scalar));
        assert_eq!(KernelDispatch::parse("blocked"), Some(KernelDispatch::Scalar));
        assert_eq!(KernelDispatch::parse("avx512"), None);
        for d in [KernelDispatch::Scalar, KernelDispatch::Simd] {
            assert_eq!(KernelDispatch::parse(d.as_str()), Some(d));
        }
    }
}
