//! The typed event stream: everything the engine announces while a
//! [`Session`](super::Session) runs.
//!
//! Events are the public promotion of the engine's internal stage
//! observer: consumers (CLI progress printing, JSONL metrics, tests,
//! embedding applications) implement [`EventSink`] and subscribe through
//! `SessionBuilder::sink`. Emission is purely additive — sinks never touch
//! the RNG schedule or arithmetic, so an instrumented run is bit-for-bit
//! the un-instrumented run.
//!
//! Ordering contract (per run; DESIGN.md §6):
//!
//! ```text
//! RunStart
//!   ( EpochStart
//!       ( ScoringFp? SelectionMade )*      sequential modes only
//!       WorkerLost*                        threaded degraded mode
//!       SyncRound?                         workers > 1
//!       EvalDone?                          at eval points
//!     EpochEnd )*
//! RunEnd
//! ```
//!
//! The threaded engine emits the epoch-level events only (worker threads
//! own their step loops; their per-step telemetry stays in the merged
//! phase ledger).

use std::time::Duration;

/// One engine announcement. Fields are plain data so sinks can serialize
/// or aggregate without touching engine internals.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A training run is starting.
    RunStart { name: String, sampler: String, epochs: usize },
    /// Epoch `epoch` begins; set-level selection kept `kept` of `dataset_n`.
    EpochStart { epoch: usize, kept: usize, dataset_n: usize },
    /// A scoring forward pass over `samples` meta-batch rows (§3.3's
    /// "extra FP") finished in `elapsed`.
    ScoringFp { epoch: usize, step: u64, samples: usize, elapsed: Duration },
    /// The sampler chose `selected` of `meta` meta-batch rows for BP.
    /// `scored` says whether this step ran a scoring forward pass (fresh
    /// weights) or reused the tables cached at the last scoring step —
    /// `false` on every `run.score_every` stride step *and* on steps that
    /// never score (set-level methods, annealing epochs). See DESIGN.md §8.
    SelectionMade { epoch: usize, step: u64, meta: usize, selected: usize, scored: bool },
    /// A threaded worker died mid-epoch (panic or step error) and was
    /// quarantined; the run continues degraded on the survivors, with the
    /// lost worker's shard redistributed at the next epoch boundary
    /// (DESIGN.md §12). Emitted before the epoch's `SyncRound`.
    WorkerLost { epoch: usize, worker: usize, error: String },
    /// A data-parallel synchronization round completed (§D.5: parameter
    /// averaging + sampler-table merge across `workers` workers).
    SyncRound { epoch: usize, workers: usize },
    /// Held-out evaluation at the end of `epoch`.
    EvalDone { epoch: usize, loss: f64, accuracy: f64, bp_samples: u64 },
    /// Epoch `epoch` finished with this mean training loss.
    EpochEnd { epoch: usize, mean_train_loss: f64 },
    /// The run finished (`steps` optimizer steps; final eval accuracy).
    RunEnd { steps: u64, accuracy: f64 },
}

/// A consumer of the event stream. Sinks are owned by the [`EventBus`]
/// and invoked synchronously, in subscription order, on the engine
/// thread.
pub trait EventSink: Send {
    fn on_event(&mut self, event: &Event);
}

/// Closures are sinks: `.on_event(|ev| ...)` in the builder.
impl<F: FnMut(&Event) + Send> EventSink for F {
    fn on_event(&mut self, event: &Event) {
        self(event)
    }
}

/// Fan-out of one engine's events to every subscribed sink.
#[derive(Default)]
pub struct EventBus {
    sinks: Vec<Box<dyn EventSink>>,
}

impl EventBus {
    pub fn new() -> EventBus {
        EventBus::default()
    }

    pub fn add(&mut self, sink: Box<dyn EventSink>) {
        self.sinks.push(sink);
    }

    pub fn is_empty(&self) -> bool {
        self.sinks.is_empty()
    }

    pub fn emit(&mut self, event: &Event) {
        for s in &mut self.sinks {
            s.on_event(event);
        }
    }
}

/// Emit into an optional bus slot — the engine's no-subscriber fast path.
pub(crate) fn emit_into(slot: &mut Option<&mut EventBus>, event: Event) {
    if let Some(bus) = slot.as_deref_mut() {
        bus.emit(&event);
    }
}

/// Stdout progress printer: one line per run start, eval point, and run
/// end. The default `--progress` style consumer for the CLI and examples.
///
/// Lines are written with explicit error handling rather than `println!`:
/// when stdout goes away mid-run (`evosample ... | head`), a broken pipe
/// silences further progress output instead of panicking the run.
#[derive(Default)]
pub struct ProgressSink;

impl ProgressSink {
    pub fn new() -> ProgressSink {
        ProgressSink
    }

    fn line(&self, args: std::fmt::Arguments<'_>) {
        use std::io::Write;
        let mut out = std::io::stdout().lock();
        let _ = out.write_fmt(args).and_then(|()| out.write_all(b"\n"));
    }
}

impl EventSink for ProgressSink {
    fn on_event(&mut self, event: &Event) {
        match event {
            Event::RunStart { name, sampler, epochs } => {
                self.line(format_args!("[{name}] sampler {sampler}, {epochs} epochs"));
            }
            Event::EpochStart { epoch, kept, dataset_n } if kept < dataset_n => {
                self.line(format_args!(
                    "  epoch {epoch}: pruned to {kept}/{dataset_n} samples"
                ));
            }
            Event::EvalDone { epoch, loss, accuracy, bp_samples } => {
                self.line(format_args!(
                    "  epoch {epoch}: eval loss {loss:.4}  acc {:.2}%  (bp samples {bp_samples})",
                    100.0 * accuracy
                ));
            }
            Event::RunEnd { steps, accuracy } => {
                self.line(format_args!(
                    "  done: {steps} steps, final acc {:.2}%",
                    100.0 * accuracy
                ));
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn bus_fans_out_in_subscription_order() {
        let log: Arc<Mutex<Vec<(usize, String)>>> = Arc::new(Mutex::new(Vec::new()));
        let mut bus = EventBus::new();
        for id in 0..2usize {
            let log = log.clone();
            bus.add(Box::new(move |ev: &Event| {
                log.lock().unwrap().push((id, format!("{ev:?}")));
            }));
        }
        bus.emit(&Event::RunEnd { steps: 3, accuracy: 0.5 });
        let log = log.lock().unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].0, 0);
        assert_eq!(log[1].0, 1);
        assert!(log[0].1.contains("RunEnd"));
    }

    #[test]
    fn emit_into_skips_empty_slot() {
        let mut none: Option<&mut EventBus> = None;
        emit_into(&mut none, Event::RunEnd { steps: 0, accuracy: 0.0 });
        let mut bus = EventBus::new();
        let seen = Arc::new(Mutex::new(0usize));
        let s2 = seen.clone();
        bus.add(Box::new(move |_: &Event| *s2.lock().unwrap() += 1));
        let mut some = Some(&mut bus);
        emit_into(&mut some, Event::RunEnd { steps: 0, accuracy: 0.0 });
        assert_eq!(*seen.lock().unwrap(), 1);
    }
}
