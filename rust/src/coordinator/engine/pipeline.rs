//! StepPipeline: one training step decomposed into explicit stages
//! (data-gather → scoring-FP → select → BP → observe) with per-stage
//! accounting hooks.
//!
//! The pipeline is the single implementation of the paper's Alg. 1 step
//! body. Every engine mode drives it: the sequential path (bit-for-bit
//! the pre-engine trainer loop), the sequential data-parallel simulation
//! (observations deferred to the epoch-end sync), and the threaded worker
//! replicas (observations applied locally and buffered by the sampler's
//! shard log). Stage wall-clock flows into the `PhaseTimers` ledger under
//! the same phase labels the accounting layer has always used, and is
//! additionally surfaced to an optional [`StageObserver`].
//!
//! The scoring-FP stage honors `run.score_every` (frequency tuning,
//! DESIGN.md §8): only every k-th scoring-eligible step per stream runs
//! the forward pass; the steps in between select from the sampler's
//! cached weight tables via [`Sampler::select_cached`]. It also honors
//! `run.scoring_precision` (DESIGN.md §9): `bf16` routes the FP through
//! [`ModelRuntime::loss_fwd_ranked`] — a ranking-grade reduced-precision
//! forward — while the BP batch and eval always stay exact.

use std::time::Duration;

use crate::api::events::{emit_into, Event, EventBus};
use crate::config::{RunConfig, ScoringPrecision};
use crate::data::TensorDataset;
use crate::runtime::{BatchBuf, BatchX, ModelRuntime};
use crate::sampler::Sampler;
use crate::util::timer::{phase, PhaseTimers, Stopwatch};
use crate::util::Pcg64;

/// The explicit stages of one training step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Assemble batch features/labels from the dataset.
    DataGather,
    /// Scoring forward pass over the meta-batch (batch-level methods).
    ScoringFp,
    /// Draw the BP mini-batch from the meta-batch.
    Select,
    /// The optimizer step(s), micro-batched under gradient accumulation.
    TrainBp,
    /// Feed fresh losses back to the sampler (or defer them to a sync).
    Observe,
}

impl Stage {
    /// Phase-ledger label. `Observe` books under `select` — sampler state
    /// maintenance has always been part of selection overhead in the
    /// paper's cost model (§3.3).
    pub fn phase_label(self) -> &'static str {
        match self {
            Stage::DataGather => phase::DATA,
            Stage::ScoringFp => phase::SCORING_FP,
            Stage::Select => phase::SELECT,
            Stage::TrainBp => phase::TRAIN_BP,
            Stage::Observe => phase::SELECT,
        }
    }

    /// Telemetry span name — unlike [`Stage::phase_label`], this keeps
    /// `Observe` distinct so traces show the full five-stage shape.
    pub fn obs_name(self) -> &'static str {
        match self {
            Stage::DataGather => "data_gather",
            Stage::ScoringFp => "scoring_fp",
            Stage::Select => "select",
            Stage::TrainBp => "train_bp",
            Stage::Observe => "observe",
        }
    }

    /// Per-stage duration histogram name (DESIGN.md §11).
    pub fn obs_metric(self) -> &'static str {
        match self {
            Stage::DataGather => "stage.data_gather",
            Stage::ScoringFp => "stage.scoring_fp",
            Stage::Select => "stage.select",
            Stage::TrainBp => "stage.train_bp",
            Stage::Observe => "stage.observe",
        }
    }
}

/// Per-stage accounting hook. Receives every stage execution with its
/// wall-clock; the timers ledger is maintained independently, so an
/// observer is purely additive (benches, tracing, regression tests).
pub trait StageObserver: Send {
    fn on_stage(&mut self, stage: Stage, elapsed: Duration);
}

/// Where a step's loss observations go.
pub enum ObservationRoute<'a> {
    /// Apply to the sampler immediately (single-worker path).
    Immediate,
    /// Sequential data-parallel simulation: apply meta losses immediately
    /// (every simulated worker shares the sampler — its "local view") and
    /// defer a copy, plus all train losses, to the epoch-end sync buffer.
    Deferred(&'a mut Vec<(Vec<u32>, Vec<f32>)>),
    /// Threaded worker replica: apply to the worker-local sampler; its
    /// shard log buffers what was applied for the §D.5 sync round.
    Replica,
}

/// Cumulative step counters, accumulated across every `run_step` call.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub fp_samples: u64,
    /// Number of scoring-FP invocations (≤ steps; ≈ steps / score_every).
    pub fp_passes: u64,
    pub bp_samples: u64,
    pub bp_passes: u64,
    pub steps: u64,
}

impl StepStats {
    pub fn accumulate(&mut self, other: &StepStats) {
        self.fp_samples += other.fp_samples;
        self.fp_passes += other.fp_passes;
        self.bp_samples += other.bp_samples;
        self.bp_passes += other.bp_passes;
        self.steps += other.steps;
    }
}

/// Per-step context that is constant within an epoch.
pub struct StepCtx<'a> {
    pub cfg: &'a RunConfig,
    pub train_ds: &'a TensorDataset,
    pub epoch: usize,
    pub lr: f32,
    /// Scoring-cadence stream this step belongs to (DESIGN.md §8): the
    /// `score_every` stride counts eligible steps *per stream*, so each
    /// data-parallel worker re-scores its own shard every k-th eligible
    /// step instead of the stride landing on whichever worker happens to
    /// align with it. Single-worker mode uses stream 0; the sequential
    /// simulation passes the worker index; threaded workers own their
    /// pipeline (stream 0, reset each epoch).
    pub stream: usize,
}

/// Reusable step executor: owns the batch buffers, loss scratch,
/// counters, and per-class BP tallies so the hot path allocates nothing
/// in steady state (losses flow through the runtime's `*_into` variants
/// into pipeline-owned buffers; only the deferred sync route — which
/// buffers by design — clones).
pub struct StepPipeline {
    meta_buf: BatchBuf,
    mini_buf: BatchBuf,
    /// Scoring-FP losses of the current step (reused across steps).
    meta_losses: Vec<f32>,
    /// BP losses of the current step, accumulated across micro-batches.
    bp_losses: Vec<f32>,
    /// Per-stream position within the current run of scoring-*eligible*
    /// steps; a step runs the scoring FP iff its stream's tick ≡ 0
    /// (mod score_every), and an ineligible step resets its stream. The
    /// reset pins the first step of EVERY eligible window (e.g. right
    /// after an annealing gap) as a scoring step, so stale-weight
    /// selection never runs on tables older than one stride — even for
    /// external samplers whose `needs_meta_losses` opens several windows.
    score_ticks: Vec<u64>,
    pub stats: StepStats,
    pub class_bp_counts: Vec<u64>,
}

/// Run a closure as one pipeline stage: book it in the phase ledger and
/// forward it to the observer hook.
fn staged<T>(
    timers: &mut PhaseTimers,
    observer: &mut Option<&mut dyn StageObserver>,
    stage: Stage,
    f: impl FnOnce() -> T,
) -> T {
    let t0 = Stopwatch::start();
    let out = f();
    let elapsed = t0.elapsed();
    timers.add(stage.phase_label(), elapsed);
    // Telemetry (DESIGN.md §11) reuses the stage timer's `Instant` reads:
    // the histogram is a few relaxed atomic adds, and the trace span is
    // back-dated from `elapsed` — neither adds clock calls or touches
    // anything the run computes with.
    if crate::obs::counters_on() {
        crate::obs::registry().histogram(stage.obs_metric()).record(elapsed.as_secs_f64());
    }
    crate::obs::record_elapsed("stage", stage.obs_name(), elapsed);
    if let Some(obs) = observer.as_deref_mut() {
        obs.on_stage(stage, elapsed);
    }
    out
}

impl StepPipeline {
    /// Per-stream scoring-cadence positions — part of a job checkpoint:
    /// in sequential modes the cadence persists across epochs, so a
    /// resumed run must continue the tick count, not restart it.
    pub fn score_ticks(&self) -> &[u64] {
        &self.score_ticks
    }

    /// Restore cadence positions captured by [`StepPipeline::score_ticks`].
    pub fn set_score_ticks(&mut self, ticks: Vec<u64>) {
        self.score_ticks = ticks;
    }

    /// `classes` sizes the Fig. 9 per-class BP tally (>= 1).
    pub fn new(classes: usize) -> StepPipeline {
        StepPipeline {
            meta_buf: BatchBuf::new(),
            mini_buf: BatchBuf::new(),
            meta_losses: Vec::new(),
            bp_losses: Vec::new(),
            score_ticks: Vec::new(),
            stats: StepStats::default(),
            class_bp_counts: vec![0u64; classes.max(1)],
        }
    }

    /// Execute one full step over `meta` and return its mean train loss.
    ///
    /// Stage-for-stage this is the pre-engine trainer loop body: identical
    /// call order, RNG usage, and arithmetic, so a single-worker run
    /// reproduces the pre-refactor loss curve bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    pub fn run_step(
        &mut self,
        ctx: &StepCtx<'_>,
        rt: &mut dyn ModelRuntime,
        sampler: &mut dyn Sampler,
        meta: &[u32],
        rng: &mut Pcg64,
        timers: &mut PhaseTimers,
        mut observer: Option<&mut dyn StageObserver>,
        route: &mut ObservationRoute<'_>,
        mut events: Option<&mut EventBus>,
    ) -> anyhow::Result<f64> {
        let cfg = ctx.cfg;
        let train_ds = ctx.train_ds;
        let step_no = self.stats.steps;

        // ---- stage 1: data-gather (meta-batch) -------------------------
        staged(timers, &mut observer, Stage::DataGather, || {
            self.meta_buf.fill(train_ds, meta)
        });

        // ---- stage 2: scoring FP (batch-level methods, active epochs) --
        // Frequency tuning (DESIGN.md §8): of the scoring-eligible steps
        // on this stream, only every `score_every`-th runs the FP; the
        // rest select from the sampler's cached tables below. k = 1 makes
        // `scoring == eligible` and the tick bookkeeping inert, so the
        // historical per-step path is reproduced bit-for-bit.
        let selecting = cfg.mini_batch < cfg.meta_batch;
        let eligible = selecting && sampler.needs_meta_losses(ctx.epoch);
        let scoring = {
            if ctx.stream >= self.score_ticks.len() {
                self.score_ticks.resize(ctx.stream + 1, 0);
            }
            let tick = &mut self.score_ticks[ctx.stream];
            if eligible {
                let fire = *tick % cfg.score_every.max(1) as u64 == 0;
                *tick += 1;
                fire
            } else {
                // Reset so the first step of the next eligible window
                // scores (see the score_ticks field docs).
                *tick = 0;
                false
            }
        };
        // Selection-health counters: scoring passes vs cadence skips is
        // the live view of the `score_every` stride actually striding.
        if crate::obs::counters_on() {
            let reg = crate::obs::registry();
            reg.counter("engine.steps").add(1);
            if scoring {
                reg.counter("select.scoring_passes").add(1);
            } else if eligible {
                reg.counter("select.cadence_skips").add(1);
            }
        }
        if scoring {
            let t0 = Stopwatch::start();
            self.meta_losses.clear();
            // The scoring FP only needs a ranking, so it may run on the
            // runtime's reduced-precision path (DESIGN.md §9). The BP
            // batch (train_step) and eval always stay exact.
            staged(timers, &mut observer, Stage::ScoringFp, || {
                if cfg.scoring_precision == ScoringPrecision::Bf16 {
                    rt.loss_fwd_ranked(
                        self.meta_buf.x(train_ds),
                        &self.meta_buf.y,
                        meta.len(),
                        &mut self.meta_losses,
                    )
                } else {
                    rt.loss_fwd_into(
                        self.meta_buf.x(train_ds),
                        &self.meta_buf.y,
                        meta.len(),
                        &mut self.meta_losses,
                    )
                }
            })?;
            self.stats.fp_samples += meta.len() as u64;
            self.stats.fp_passes += 1;
            // Score-distribution summary (mean/p50/p90 of meta losses).
            if crate::obs::counters_on() {
                let h = crate::obs::registry().histogram("select.meta_loss");
                for &l in &self.meta_losses {
                    h.record(l as f64);
                }
            }
            emit_into(
                &mut events,
                Event::ScoringFp {
                    epoch: ctx.epoch,
                    step: step_no,
                    samples: meta.len(),
                    elapsed: t0.elapsed(),
                },
            );
            match route {
                ObservationRoute::Immediate | ObservationRoute::Replica => {
                    staged(timers, &mut observer, Stage::Observe, || {
                        sampler.observe_meta(meta, &self.meta_losses, ctx.epoch)
                    });
                }
                ObservationRoute::Deferred(buf) => {
                    // Feed this worker's local view AND defer a copy to
                    // the sync round — both are selection overhead. (The
                    // deferred route buffers by design, so the clone is
                    // inherent, not hot-path waste.)
                    staged(timers, &mut observer, Stage::Observe, || {
                        sampler.observe_meta(meta, &self.meta_losses, ctx.epoch);
                        buf.push((meta.to_vec(), self.meta_losses.clone()));
                    });
                }
            }
        }

        // ---- stage 3: select -------------------------------------------
        // Non-scoring eligible steps take the cached path: selection from
        // the weight tables as of the last scoring step (stale by < k
        // steps), no fresh losses consumed.
        let sel = staged(timers, &mut observer, Stage::Select, || {
            if eligible && !scoring {
                sampler.select_cached(meta, cfg.mini_batch, ctx.epoch, rng)
            } else {
                sampler.select(meta, cfg.mini_batch, ctx.epoch, rng)
            }
        });
        debug_assert!(!sel.indices.is_empty());
        emit_into(
            &mut events,
            Event::SelectionMade {
                epoch: ctx.epoch,
                step: step_no,
                meta: meta.len(),
                selected: sel.indices.len(),
                scored: scoring,
            },
        );

        // ---- stage 4: BP (assemble + micro-batched train steps) --------
        // Reuse the meta buffer when the selection is the identity — the
        // common set-level path.
        let bsz = sel.indices.len();
        if sel.indices.as_slice() != meta {
            staged(timers, &mut observer, Stage::DataGather, || {
                self.mini_buf.fill(train_ds, &sel.indices)
            });
        }
        let (buf, y_ref): (&BatchBuf, &Vec<i32>) = if sel.indices.as_slice() == meta {
            (&self.meta_buf, &self.meta_buf.y)
        } else {
            (&self.mini_buf, &self.mini_buf.y)
        };

        // Gradient accumulation: chunk into micro-batches.
        let micro = if cfg.micro_batch > 0 && cfg.micro_batch < bsz {
            cfg.micro_batch
        } else {
            bsz
        };
        self.bp_losses.clear();
        let mut mean_acc = 0.0f64;
        let mut off = 0usize;
        let x_len = train_ds.x_len();
        let y_len = train_ds.y_dim;
        while off < bsz {
            let m = micro.min(bsz - off);
            let mean = staged(timers, &mut observer, Stage::TrainBp, || {
                let x = match buf.x(train_ds) {
                    BatchX::F32(v) => BatchX::F32(&v[off * x_len..(off + m) * x_len]),
                    BatchX::I32(v) => BatchX::I32(&v[off * x_len..(off + m) * x_len]),
                };
                rt.train_step_into(
                    x,
                    &y_ref[off * y_len..(off + m) * y_len],
                    &sel.weights[off..off + m],
                    ctx.lr,
                    m,
                    &mut self.bp_losses,
                )
            })?;
            self.stats.bp_passes += 1;
            self.stats.bp_samples += m as u64;
            mean_acc += mean as f64 * m as f64;
            off += m;
        }
        let step_mean = mean_acc / bsz as f64;

        // Per-class BP counts (Fig. 9).
        if train_ds.y_dim == 1 && train_ds.classes > 0 {
            for &i in &sel.indices {
                self.class_bp_counts[train_ds.clean_class[i as usize] as usize] += 1;
            }
        }

        // ---- stage 5: observe (free training losses) -------------------
        match route {
            ObservationRoute::Immediate | ObservationRoute::Replica => {
                staged(timers, &mut observer, Stage::Observe, || {
                    sampler.observe_train(&sel.indices, &self.bp_losses, ctx.epoch)
                });
            }
            ObservationRoute::Deferred(buf) => {
                staged(timers, &mut observer, Stage::Observe, || {
                    buf.push((sel.indices, self.bp_losses.clone()))
                });
            }
        }

        self.stats.steps += 1;
        Ok(step_mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_labels_map_to_phase_ledger() {
        assert_eq!(Stage::DataGather.phase_label(), phase::DATA);
        assert_eq!(Stage::ScoringFp.phase_label(), phase::SCORING_FP);
        assert_eq!(Stage::Select.phase_label(), phase::SELECT);
        assert_eq!(Stage::TrainBp.phase_label(), phase::TRAIN_BP);
        assert_eq!(Stage::Observe.phase_label(), phase::SELECT);
    }

    #[test]
    fn stats_accumulate() {
        let mut a =
            StepStats { fp_samples: 1, fp_passes: 5, bp_samples: 2, bp_passes: 3, steps: 4 };
        let b =
            StepStats { fp_samples: 10, fp_passes: 50, bp_samples: 20, bp_passes: 30, steps: 40 };
        a.accumulate(&b);
        assert_eq!(a.fp_samples, 11);
        assert_eq!(a.fp_passes, 55);
        assert_eq!(a.bp_samples, 22);
        assert_eq!(a.bp_passes, 33);
        assert_eq!(a.steps, 44);
    }
}
