//! Runtime perf: XLA step costs per model/batch (FP vs BP), quantifying
//! the paper's §3.3 claim that BP dominates and ES's scoring FP is cheap.
//! Backs EXPERIMENTS.md §Perf L2 numbers.

use evosample::runtime::manifest::Manifest;
use evosample::runtime::xla_rt::XlaRuntime;
use evosample::runtime::{BatchX, ModelRuntime};
use evosample::util::bench::Bencher;
use evosample::util::Pcg64;

fn main() {
    let Ok(m) = Manifest::load_default() else {
        println!("artifacts missing: run `make artifacts` first");
        return;
    };
    let bench = Bencher::default();
    let mut rng = Pcg64::new(3);
    let smoke = evosample::util::bench::smoke_mode();
    let models: Vec<&str> = if smoke {
        vec!["mlp_cifar10", "cnn_small_c100", "txf_lm"]
    } else {
        m.models.keys().map(|s| s.as_str()).collect()
    };

    for name in models {
        let Some(entry) = m.models.get(name) else { continue };
        let mut rt = XlaRuntime::load(&m, name).expect(name);
        rt.init(0).unwrap();
        let xd = entry.x_len();
        let yd = entry.y_len();
        let hi = entry.classes.max(2) as i64;

        let fwd_n = rt.fwd_size();
        let make_x_f32 = |n: usize, rng: &mut Pcg64| -> Vec<f32> {
            (0..n * xd).map(|_| rng.normal()).collect()
        };
        let make_x_i32 = |n: usize, rng: &mut Pcg64| -> Vec<i32> {
            (0..n * xd).map(|_| rng.int_in(0, hi) as i32).collect()
        };
        let make_y = |n: usize, rng: &mut Pcg64| -> Vec<i32> {
            (0..n * yd).map(|_| rng.int_in(0, hi) as i32).collect()
        };

        // Scoring FP at meta-batch size.
        let y = make_y(fwd_n, &mut rng);
        match entry.x_dtype {
            evosample::runtime::manifest::XDtype::F32 => {
                let x = make_x_f32(fwd_n, &mut rng);
                bench.run(&format!("{name:<16} loss_fwd  n={fwd_n}"), || {
                    rt.loss_fwd(BatchX::F32(&x), &y, fwd_n).unwrap()
                });
            }
            evosample::runtime::manifest::XDtype::I32 => {
                let x = make_x_i32(fwd_n, &mut rng);
                bench.run(&format!("{name:<16} loss_fwd  n={fwd_n}"), || {
                    rt.loss_fwd(BatchX::I32(&x), &y, fwd_n).unwrap()
                });
            }
        }
        // Train step at each emitted size.
        for n in rt.train_sizes() {
            let y = make_y(n, &mut rng);
            let w = vec![1.0f32; n];
            match entry.x_dtype {
                evosample::runtime::manifest::XDtype::F32 => {
                    let x = make_x_f32(n, &mut rng);
                    bench.run(&format!("{name:<16} train_step n={n}"), || {
                        rt.train_step(BatchX::F32(&x), &y, &w, 1e-3, n).unwrap()
                    });
                }
                evosample::runtime::manifest::XDtype::I32 => {
                    let x = make_x_i32(n, &mut rng);
                    bench.run(&format!("{name:<16} train_step n={n}"), || {
                        rt.train_step(BatchX::I32(&x), &y, &w, 1e-3, n).unwrap()
                    });
                }
            }
        }
    }
}
