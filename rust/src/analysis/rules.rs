//! The evolint rule registry (DESIGN.md §13).
//!
//! Every rule walks the token stream of one file, path-scoped to the
//! subsystems whose contract it protects, and skips test spans. Raw
//! findings are then filtered through the file's `lint:allow`
//! directives; a directive that suppresses nothing is itself a finding
//! (`lint/unused-allow`), so stale suppressions cannot accumulate.

use super::catalog::Catalogs;
use super::lexer::{LexFile, Tok};
use super::Finding;

/// `HashMap`/`HashSet` in determinism-scoped paths: iteration order
/// would leak into selection state or exports.
pub const UNORDERED: &str = "determinism/no-unordered-iteration";
/// Raw `Instant`/`SystemTime` outside the blessed wall-clock layers.
pub const WALLCLOCK: &str = "determinism/no-wallclock-in-pipeline";
/// `fs::write`/`File::create`/`fs::rename` outside `fault/atomic_io.rs`:
/// a durable artifact written without the tmp+fsync+rename commit.
pub const ATOMIC: &str = "durability/atomic-writes-only";
/// `.unwrap()`/`.expect()`/`panic!` in serve/fault non-test code.
pub const PANIC: &str = "robustness/no-panic-in-serve";
/// String literal handed to a failpoint helper that is not a site in
/// `fault::sites::ALL`.
pub const FAILPOINT: &str = "registry/failpoint-sites";
/// Metric-name literal at an instrumentation site missing from the
/// `obs::catalog` name list.
pub const METRIC: &str = "registry/metric-names";
/// `("event", s("…"))` name missing from the `api::events::Event`
/// variants / serve lifecycle names.
pub const EVENT: &str = "registry/event-names";
/// A `lint:allow` directive that suppresses nothing (or failed to parse).
pub const UNUSED_ALLOW: &str = "lint/unused-allow";

/// Every rule id, for `lint --list` style output and directive checks.
pub const ALL_RULES: &[&str] =
    &[UNORDERED, WALLCLOCK, ATOMIC, PANIC, FAILPOINT, METRIC, EVENT, UNUSED_ALLOW];

/// Paths (relative to `rust/src`, `/`-separated) where unordered
/// iteration can perturb determinism pins or exports.
const UNORDERED_SCOPE: &[&str] =
    &["coordinator/", "sampler/", "runtime/", "obs/", "metrics/", "data/"];

/// Layers allowed to read the wall clock: the timer abstraction itself,
/// telemetry (monotonic span anchors), the serve runtime (queue-wait
/// accounting), and fault injection (delay actions).
const WALLCLOCK_ALLOWED: &[&str] = &["obs/", "serve/", "fault/"];
const WALLCLOCK_ALLOWED_FILE: &str = "util/timer.rs";

/// The one file allowed to touch raw file-creation/rename primitives —
/// it implements the atomic commit everything else must use.
const ATOMIC_ALLOWED_FILE: &str = "fault/atomic_io.rs";

/// Paths where a panic would tear down a multi-tenant server or corrupt
/// a fault-injection run instead of failing one request.
const PANIC_SCOPE: &[&str] = &["serve/", "fault/"];

/// Functions that accept a failpoint-site string.
const FAILPOINT_FNS: &[&str] = &["hit_io", "hit_worker", "maybe_delay", "fired"];

/// Registry methods that accept a metric name.
const METRIC_FNS: &[&str] = &["counter", "gauge", "histogram"];

fn in_any(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

/// Run every rule over one lexed file and apply suppression directives.
pub fn check_file(rel: &str, lex: &LexFile, cats: &Catalogs) -> Vec<Finding> {
    let mut raw: Vec<Finding> = Vec::new();
    let toks = &lex.tokens;
    let mk = |rule: &'static str, line: u32, message: String, suggestion: &str| Finding {
        file: rel.to_string(),
        line,
        rule,
        message,
        suggestion: suggestion.to_string(),
    };

    let ident = |k: usize| match toks.get(k).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    };
    let punct = |k: usize, c: char| {
        matches!(toks.get(k).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
    };
    let str_lit = |k: usize| match toks.get(k).map(|t| &t.tok) {
        Some(Tok::Str(s)) => Some(s.as_str()),
        _ => None,
    };

    for i in 0..toks.len() {
        let line = toks[i].line;
        if lex.is_test_line(line) {
            continue;
        }

        // determinism/no-unordered-iteration
        if in_any(rel, UNORDERED_SCOPE) {
            if let Some(name @ ("HashMap" | "HashSet")) = ident(i) {
                raw.push(mk(
                    UNORDERED,
                    line,
                    format!("{name} in a determinism-scoped path"),
                    "use BTreeMap/BTreeSet (or collect and sort before iterating) so \
                     iteration order cannot leak into selection state or exports",
                ));
            }
        }

        // determinism/no-wallclock-in-pipeline
        if rel != WALLCLOCK_ALLOWED_FILE && !in_any(rel, WALLCLOCK_ALLOWED) {
            if let Some(name @ ("Instant" | "SystemTime")) = ident(i) {
                raw.push(mk(
                    WALLCLOCK,
                    line,
                    format!("raw {name} outside the blessed wall-clock layers"),
                    "time through util::timer::Stopwatch (or PhaseTimers::time) so \
                     clock reads stay confined to util/timer, obs, serve, and fault",
                ));
            }
        }

        // durability/atomic-writes-only
        if rel != ATOMIC_ALLOWED_FILE {
            let path_call = |head: &str, method: &str| {
                ident(i) == Some(head)
                    && punct(i + 1, ':')
                    && punct(i + 2, ':')
                    && ident(i + 3) == Some(method)
            };
            let hit = if path_call("fs", "write") {
                Some("fs::write")
            } else if path_call("fs", "rename") {
                Some("fs::rename")
            } else if path_call("File", "create") {
                Some("File::create")
            } else {
                None
            };
            if let Some(what) = hit {
                raw.push(mk(
                    ATOMIC,
                    line,
                    format!("{what} bypasses the atomic-commit path"),
                    "write durable artifacts via fault::write_atomic (tmp + fsync + \
                     rename); only fault/atomic_io.rs touches the raw primitives",
                ));
            }
        }

        // robustness/no-panic-in-serve
        if in_any(rel, PANIC_SCOPE) {
            if punct(i, '.') {
                if let Some(name @ ("unwrap" | "expect")) = ident(i + 1) {
                    raw.push(mk(
                        PANIC,
                        toks[i + 1].line,
                        format!(".{name}() in serve/fault non-test code"),
                        "propagate the error (log it, or answer a rejected/err \
                         response); a panic here tears down the whole server",
                    ));
                }
            }
            if ident(i) == Some("panic") && punct(i + 1, '!') {
                raw.push(mk(
                    PANIC,
                    line,
                    "panic! in serve/fault non-test code".to_string(),
                    "propagate the error (log it, or answer a rejected/err \
                     response); a panic here tears down the whole server",
                ));
            }
        }

        // registry/failpoint-sites
        if let Some(f) = ident(i) {
            if FAILPOINT_FNS.contains(&f) && punct(i + 1, '(') {
                if let Some(site) = str_lit(i + 2) {
                    if !cats.fault_sites.contains(site) {
                        raw.push(mk(
                            FAILPOINT,
                            toks[i + 2].line,
                            format!("failpoint site {site:?} is not in fault::sites::ALL"),
                            "use a fault::sites:: constant; new sites must be added \
                             to fault::sites::ALL so specs can be validated",
                        ));
                    }
                }
            }
        }

        // registry/metric-names
        if let Some(f) = ident(i) {
            if METRIC_FNS.contains(&f) && punct(i + 1, '(') {
                if let Some(name) = str_lit(i + 2) {
                    if !cats.metric_names.contains(name) {
                        raw.push(mk(
                            METRIC,
                            toks[i + 2].line,
                            format!("metric name {name:?} is not in the obs catalog"),
                            "add the name to obs::catalog (the authoritative \
                             metric-name list) or fix the typo",
                        ));
                    }
                }
            }
        }

        // registry/event-names
        if str_lit(i) == Some("event")
            && punct(i + 1, ',')
            && ident(i + 2) == Some("s")
            && punct(i + 3, '(')
        {
            if let Some(name) = str_lit(i + 4) {
                if !cats.event_names.contains(name) {
                    raw.push(mk(
                        EVENT,
                        toks[i + 4].line,
                        format!(
                            "event name {name:?} matches no api::events::Event variant \
                             or serve lifecycle event"
                        ),
                        "event-name strings must snake_case an Event variant or appear \
                         in serve::protocol::LIFECYCLE_EVENTS",
                    ));
                }
            }
        }
    }

    apply_directives(rel, lex, raw)
}

/// Filter findings through `lint:allow` directives and report unused or
/// malformed directives. A directive suppresses findings of its rule on
/// its own line or the next line (comment-above or trailing-comment
/// placement).
fn apply_directives(rel: &str, lex: &LexFile, mut raw: Vec<Finding>) -> Vec<Finding> {
    let mut used = vec![false; lex.directives.len()];
    raw.retain(|f| {
        let mut suppressed = false;
        for (k, d) in lex.directives.iter().enumerate() {
            if d.rule == f.rule && (d.line == f.line || d.line + 1 == f.line) {
                used[k] = true;
                suppressed = true;
            }
        }
        !suppressed
    });
    for (k, d) in lex.directives.iter().enumerate() {
        if used[k] || lex.is_test_line(d.line) {
            continue;
        }
        let detail = if ALL_RULES.contains(&d.rule.as_str()) {
            "it suppresses nothing on its own or the next line"
        } else {
            "its rule id matches no known rule"
        };
        raw.push(Finding {
            file: rel.to_string(),
            line: d.line,
            rule: UNUSED_ALLOW,
            message: format!("lint:allow({}) is unused — {detail}", d.rule),
            suggestion: "remove the stale directive (or fix the rule id) so \
                         suppressions always carry their justification"
                .to_string(),
        });
    }
    for &line in &lex.malformed_directives {
        if lex.is_test_line(line) {
            continue;
        }
        raw.push(Finding {
            file: rel.to_string(),
            line,
            rule: UNUSED_ALLOW,
            message: "malformed lint:allow directive".to_string(),
            suggestion: "write `// lint:allow(<rule-id>): <reason>` — the reason \
                         is mandatory"
                .to_string(),
        });
    }
    raw
}
