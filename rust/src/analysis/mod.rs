//! evolint — self-hosted static analysis for the crate's own contracts
//! (DESIGN.md §13).
//!
//! After nine PRs the repo's determinism, durability, and panic-safety
//! guarantees were enforced purely by convention: nothing stopped a new
//! `HashMap` iteration from leaking nondeterministic order into an
//! export, a raw `fs::write` from bypassing the crash-safe
//! `fault::write_atomic` commit, or a fresh `.unwrap()` from landing in
//! a serve connection path. evolint lexes the crate's sources
//! ([`lexer`]), extracts the authoritative name registries from them
//! ([`catalog`]), and machine-checks those conventions ([`rules`]).
//!
//! Three consumers share this module: the `evosample lint` CLI
//! subcommand, the `tests/lint_clean.rs` self-check (the crate must lint
//! clean, and every rule must fire on a negative fixture), and the CI
//! gate (`lint --format json`, findings uploaded as an artifact).
//!
//! Scope: `rust/src/**/*.rs` — the library and binary sources where the
//! contracts live. Benches, examples, and integration tests drive the
//! public API from outside the contract surface and are not scanned.

pub mod catalog;
pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{num, obj, s, Json};

/// One rule violation (or unused suppression), with the context a
/// reader needs to act on it.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the lint root (`rust/src`), `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
    pub suggestion: String,
}

/// The result of linting a source tree.
#[derive(Clone, Debug)]
pub struct Report {
    pub files_scanned: usize,
    /// Sorted by (file, line, rule).
    pub findings: Vec<Finding>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable rendering: one `file:line: rule: message` block
    /// per finding plus a summary line.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "rust/src/{}:{}: {}: {}\n    hint: {}\n",
                f.file, f.line, f.rule, f.message, f.suggestion
            ));
        }
        out.push_str(&format!(
            "lint: {} file(s) scanned, {} violation(s)\n",
            self.files_scanned,
            self.findings.len()
        ));
        out
    }

    /// Machine-readable rendering (the CI artifact format).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("files_scanned", num(self.files_scanned as f64)),
            ("violations", num(self.findings.len() as f64)),
            (
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            obj(vec![
                                ("file", s(format!("rust/src/{}", f.file))),
                                ("line", num(f.line as f64)),
                                ("rule", s(f.rule)),
                                ("message", s(f.message.clone())),
                                ("suggestion", s(f.suggestion.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The crate's own source root, baked in at compile time — correct for
/// the self-check and for CI, overridable via `lint --root`.
pub fn default_src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src")
}

/// Recursively collect `.rs` sources under `root` as rel-path → text.
/// BTreeMap keys give the deterministic scan order.
pub fn collect_sources(root: &Path) -> std::io::Result<BTreeMap<String, String>> {
    fn walk(
        dir: &Path,
        base: &Path,
        out: &mut BTreeMap<String, String>,
    ) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_dir() {
                walk(&path, base, out)?;
            } else if path.extension().is_some_and(|x| x == "rs") {
                let rel = path
                    .strip_prefix(base)
                    .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e))?
                    .to_string_lossy()
                    .replace('\\', "/");
                out.insert(rel, std::fs::read_to_string(&path)?);
            }
        }
        Ok(())
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out)?;
    Ok(out)
}

/// Lint one source text under its rel path, against prebuilt catalogs.
/// This is the fixture entry point: tests feed synthetic sources with
/// synthetic paths to prove each rule fires.
pub fn lint_source(rel: &str, src: &str, cats: &catalog::Catalogs) -> Vec<Finding> {
    rules::check_file(rel, &lexer::lex(src), cats)
}

/// Lint every `.rs` file under `root` (normally `rust/src`): build the
/// registry catalogs from the tree itself, run the rule registry over
/// every file, and return the sorted report.
pub fn lint_crate(root: &Path) -> anyhow::Result<Report> {
    let files = collect_sources(root)
        .map_err(|e| anyhow::anyhow!("scan {}: {e}", root.display()))?;
    anyhow::ensure!(!files.is_empty(), "no .rs sources under {}", root.display());
    let cats = catalog::Catalogs::from_sources(|rel| files.get(rel).cloned())
        .map_err(|e| anyhow::anyhow!("catalog extraction: {e}"))?;
    let mut findings: Vec<Finding> = files
        .iter()
        .flat_map(|(rel, src)| lint_source(rel, src, &cats))
        .collect();
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Report { files_scanned: files.len(), findings })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_text_and_json() {
        let r = Report {
            files_scanned: 2,
            findings: vec![Finding {
                file: "serve/x.rs".into(),
                line: 7,
                rule: rules::PANIC,
                message: "boom".into(),
                suggestion: "do not".into(),
            }],
        };
        let text = r.to_text();
        assert!(text.contains("rust/src/serve/x.rs:7"), "{text}");
        assert!(text.contains(rules::PANIC), "{text}");
        assert!(text.contains("1 violation(s)"), "{text}");
        let j = r.to_json();
        assert_eq!(j.get("violations").and_then(Json::as_f64), Some(1.0));
        let fs = j.get("findings").and_then(Json::as_arr).expect("findings array");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].get("line").and_then(Json::as_f64), Some(7.0));
        assert_eq!(
            fs[0].get("file").and_then(Json::as_str),
            Some("rust/src/serve/x.rs")
        );
    }

    #[test]
    fn collect_sources_sees_this_module() {
        let files = collect_sources(&default_src_root()).expect("scan rust/src");
        assert!(files.contains_key("analysis/mod.rs"));
        assert!(files.contains_key("lib.rs"));
        assert!(
            files.keys().all(|k| k.ends_with(".rs")),
            "only .rs files are collected"
        );
    }
}
