//! A minimal Rust lexer for evolint (DESIGN.md §13).
//!
//! Produces a flat token stream — identifiers, string literals, chars,
//! numbers, lifetimes, and single-character punctuation — plus two side
//! channels the rule engine needs:
//!
//! * **suppression directives** parsed out of plain `//` line comments
//!   (doc comments are exempt so rule documentation can quote the
//!   syntax without creating live directives), and
//! * **test spans**: the line ranges covered by `#[cfg(test)]` /
//!   `#[test]` items, so every rule can exempt test code.
//!
//! The lexer handles the hard cases that would otherwise cause false
//! positives in a grep-based checker: nested block comments, raw
//! strings (`r"…"`, `r#"…"#`, any hash depth), byte strings and byte
//! chars (`b"…"`, `b'x'`), raw identifiers (`r#type`), escapes, and
//! the char-literal vs. lifetime ambiguity (`'a'` vs. `'a`). String
//! *contents* never become identifier tokens, so a string containing
//! `"unwrap()"` cannot trip the panic-safety rule.

/// One lexical token. String contents are kept raw (escape sequences
/// unprocessed) — the rules only compare catalog names, which never
/// contain escapes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Str(String),
    Char,
    Num,
    Lifetime,
    Punct(char),
}

/// A token with the 1-based source line it starts on.
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// A parsed `// lint:allow(<rule>): <reason>` suppression directive.
#[derive(Clone, Debug)]
pub struct Directive {
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

/// The lexed view of one source file.
#[derive(Clone, Debug, Default)]
pub struct LexFile {
    pub tokens: Vec<Token>,
    pub directives: Vec<Directive>,
    /// Lines carrying a `lint:allow` marker that failed to parse
    /// (missing rule, missing `: reason`, …).
    pub malformed_directives: Vec<u32>,
    /// Inclusive line ranges covered by `#[cfg(test)]`/`#[test]` items.
    test_spans: Vec<(u32, u32)>,
}

impl LexFile {
    /// True when `line` falls inside a `#[cfg(test)]`/`#[test]` item.
    pub fn is_test_line(&self, line: u32) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= line && line <= b)
    }

    #[cfg(test)]
    pub(crate) fn test_spans(&self) -> &[(u32, u32)] {
        &self.test_spans
    }
}

/// Lex `src` into tokens, directives, and test spans.
pub fn lex(src: &str) -> LexFile {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = LexFile::default();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < n {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            _ if c.is_ascii_whitespace() => i += 1,
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let start = i + 2;
                let mut j = start;
                while j < n && b[j] != b'\n' {
                    j += 1;
                }
                let text = &src[start..j];
                // Doc comments (`///`, `//!`) document the directive
                // syntax; only plain `//` comments carry live directives.
                let doc = text.starts_with('!')
                    || (text.starts_with('/') && !text.starts_with("//"));
                if !doc {
                    parse_directive(text, line, &mut out);
                }
                i = j;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                // Block comments nest in Rust.
                let mut depth = 1u32;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == b'\n' {
                        line += 1;
                        j += 1;
                    } else if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                i = j;
            }
            b'"' => {
                let tok_line = line;
                let (content, j) = scan_plain_str(b, i + 1, &mut line);
                out.tokens.push(Token { tok: Tok::Str(content), line: tok_line });
                i = j;
            }
            b'\'' => {
                let tok_line = line;
                let (tok, j) = scan_char_or_lifetime(b, i);
                out.tokens.push(Token { tok, line: tok_line });
                i = j;
            }
            _ if c == b'_' || c.is_ascii_alphabetic() => {
                let tok_line = line;
                // Raw strings, byte strings, byte chars, raw idents.
                if c == b'r' || c == b'b' {
                    if let Some((tok, j)) = scan_prefixed_literal(b, i, &mut line) {
                        out.tokens.push(Token { tok, line: tok_line });
                        i = j;
                        continue;
                    }
                }
                let mut j = i;
                // Raw identifier: `r#type` lexes as Ident("type").
                if c == b'r' && i + 2 < n && b[i + 1] == b'#' && is_ident_start(b[i + 2]) {
                    j = i + 2;
                }
                let start = j;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
                out.tokens.push(Token {
                    tok: Tok::Ident(src[start..j].to_string()),
                    line: tok_line,
                });
                i = j;
            }
            _ if c.is_ascii_digit() => {
                let mut j = i + 1;
                while j < n {
                    let d = b[j];
                    if d.is_ascii_alphanumeric() || d == b'_' {
                        j += 1;
                    } else if d == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                        // `1.5` continues the number; `0..n` does not.
                        j += 2;
                    } else if (d == b'+' || d == b'-') && matches!(b[j - 1], b'e' | b'E') {
                        // Exponent sign: `1e-3`.
                        j += 1;
                    } else {
                        break;
                    }
                }
                out.tokens.push(Token { tok: Tok::Num, line });
                i = j;
            }
            _ => {
                out.tokens.push(Token { tok: Tok::Punct(c as char), line });
                i += 1;
            }
        }
    }

    out.test_spans = test_spans(&out.tokens);
    out
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic()
}

fn is_ident_continue(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Scan a `"…"` body starting just past the opening quote; returns the
/// raw content and the index just past the closing quote.
fn scan_plain_str(b: &[u8], mut j: usize, line: &mut u32) -> (String, usize) {
    let mut content: Vec<u8> = Vec::new();
    while j < b.len() {
        match b[j] {
            b'\\' if j + 1 < b.len() => {
                if b[j + 1] == b'\n' {
                    *line += 1;
                }
                content.push(b[j]);
                content.push(b[j + 1]);
                j += 2;
            }
            b'"' => {
                j += 1;
                break;
            }
            c => {
                if c == b'\n' {
                    *line += 1;
                }
                content.push(c);
                j += 1;
            }
        }
    }
    (String::from_utf8_lossy(&content).into_owned(), j)
}

/// Scan `'x'` / `'\n'` / `'a` starting at the opening quote; returns the
/// token and the index just past it.
fn scan_char_or_lifetime(b: &[u8], i: usize) -> (Tok, usize) {
    let n = b.len();
    if i + 1 >= n {
        return (Tok::Punct('\''), i + 1);
    }
    let mut j = i + 1;
    if b[j] == b'\\' {
        // Escaped char literal: `'\n'`, `'\u{1F600}'`.
        j += 1;
        if j < n && b[j] == b'u' && j + 1 < n && b[j + 1] == b'{' {
            j += 2;
            while j < n && b[j] != b'}' {
                j += 1;
            }
            j += 1;
        } else {
            j += 1;
        }
        if j < n && b[j] == b'\'' {
            return (Tok::Char, j + 1);
        }
        return (Tok::Punct('\''), i + 1);
    }
    // One (possibly multibyte) char then a closing quote → char literal.
    let mut k = j + 1;
    if b[j] >= 0x80 {
        while k < n && (b[k] & 0xC0) == 0x80 {
            k += 1;
        }
    }
    if k < n && b[k] == b'\'' && b[j] != b'\'' {
        // `'a'`, `'.'`, `'é'` — one char then a closing quote.
        return (Tok::Char, k + 1);
    }
    // Lifetime: consume identifier chars after the quote.
    while j < n && is_ident_continue(b[j]) {
        j += 1;
    }
    (Tok::Lifetime, j)
}

/// Try to scan `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, or `b'x'` at `i`.
/// Returns None when `i` starts a plain identifier instead.
fn scan_prefixed_literal(b: &[u8], i: usize, line: &mut u32) -> Option<(Tok, usize)> {
    let n = b.len();
    let c = b[i];
    if c == b'r' {
        // r"…" or r#…#"…"#…# (raw ident `r#word` is handled by caller).
        let mut hashes = 0usize;
        let mut j = i + 1;
        while j < n && b[j] == b'#' {
            hashes += 1;
            j += 1;
        }
        if j < n && b[j] == b'"' && (hashes > 0 || b[i + 1] == b'"') {
            return Some(scan_raw_str(b, j + 1, hashes, line));
        }
        return None;
    }
    // c == b'b'
    if i + 1 < n && b[i + 1] == b'"' {
        let (content, j) = scan_plain_str(b, i + 2, line);
        return Some((Tok::Str(content), j));
    }
    if i + 1 < n && b[i + 1] == b'\'' {
        let (tok, j) = scan_char_or_lifetime(b, i + 1);
        return Some((tok, j));
    }
    if i + 1 < n && b[i + 1] == b'r' {
        let mut hashes = 0usize;
        let mut j = i + 2;
        while j < n && b[j] == b'#' {
            hashes += 1;
            j += 1;
        }
        if j < n && b[j] == b'"' {
            return Some(scan_raw_str(b, j + 1, hashes, line));
        }
    }
    None
}

/// Scan a raw-string body starting just past the opening quote; the
/// terminator is `"` followed by `hashes` `#`s.
fn scan_raw_str(b: &[u8], mut j: usize, hashes: usize, line: &mut u32) -> (Tok, usize) {
    let n = b.len();
    let start = j;
    while j < n {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if b[j] == b'"' && n - (j + 1) >= hashes && b[j + 1..j + 1 + hashes].iter().all(|&h| h == b'#')
        {
            let content = String::from_utf8_lossy(&b[start..j]).into_owned();
            return (Tok::Str(content), j + 1 + hashes);
        }
        j += 1;
    }
    (Tok::Str(String::from_utf8_lossy(&b[start..]).into_owned()), n)
}

/// Parse a `lint:allow(rule): reason` directive out of one line-comment
/// body. Parse failures are recorded so a typo cannot silently disable
/// nothing (they surface as `lint/unused-allow` findings).
fn parse_directive(text: &str, line: u32, out: &mut LexFile) {
    const MARKER: &str = "lint:allow";
    let Some(pos) = text.find(MARKER) else { return };
    let rest = &text[pos + MARKER.len()..];
    let parsed = rest.strip_prefix('(').and_then(|r| r.split_once(')')).and_then(
        |(rule, tail)| {
            let reason = tail.strip_prefix(':')?.trim();
            let rule = rule.trim();
            (!rule.is_empty() && !reason.is_empty())
                .then(|| (rule.to_string(), reason.to_string()))
        },
    );
    match parsed {
        Some((rule, reason)) => out.directives.push(Directive { line, rule, reason }),
        None => out.malformed_directives.push(line),
    }
}

/// True when `tokens[i..]` opens an attribute (`#[…]` or `#![…]`).
fn is_attr_start(tokens: &[Token], i: usize) -> bool {
    matches!(tokens.get(i).map(|t| &t.tok), Some(Tok::Punct('#')))
        && (matches!(tokens.get(i + 1).map(|t| &t.tok), Some(Tok::Punct('[')))
            || (matches!(tokens.get(i + 1).map(|t| &t.tok), Some(Tok::Punct('!')))
                && matches!(tokens.get(i + 2).map(|t| &t.tok), Some(Tok::Punct('[')))))
}

/// Index just past the attribute opening at `i` (balanced brackets).
fn skip_attr(tokens: &[Token], i: usize) -> usize {
    let mut j = i;
    while j < tokens.len() && tokens[j].tok != Tok::Punct('[') {
        j += 1;
    }
    let mut depth = 0i32;
    while j < tokens.len() {
        match tokens[j].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    j
}

/// True when the attribute at `i` is exactly `#[test]` or `#[cfg(test)]`.
/// The exact-token match means `#[cfg(not(test))]` and friends do NOT
/// create exemption spans.
fn is_test_attr(tokens: &[Token], i: usize) -> bool {
    let ident = |k: usize, name: &str| {
        matches!(tokens.get(k).map(|t| &t.tok), Some(Tok::Ident(s)) if s == name)
    };
    let punct = |k: usize, c: char| {
        matches!(tokens.get(k).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
    };
    if !(punct(i, '#') && punct(i + 1, '[')) {
        return false;
    }
    (ident(i + 2, "test") && punct(i + 3, ']'))
        || (ident(i + 2, "cfg")
            && punct(i + 3, '(')
            && ident(i + 4, "test")
            && punct(i + 5, ')')
            && punct(i + 6, ']'))
}

/// Compute the line spans of `#[cfg(test)]`/`#[test]` items: from the
/// attribute through the item's closing brace (or terminating `;`).
fn test_spans(tokens: &[Token]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if !is_test_attr(tokens, i) {
            i += 1;
            continue;
        }
        let attr_line = tokens[i].line;
        // Skip this attribute and any further attributes on the item.
        let mut j = i;
        while is_attr_start(tokens, j) {
            j = skip_attr(tokens, j);
        }
        // Find the item body: first `{` (then match braces) or `;` at
        // paren/bracket depth 0.
        let mut depth = 0i32;
        let mut end_line = attr_line;
        while j < tokens.len() {
            match tokens[j].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct(';') if depth == 0 => {
                    end_line = tokens[j].line;
                    j += 1;
                    break;
                }
                Tok::Punct('{') if depth == 0 => {
                    let mut braces = 1i32;
                    j += 1;
                    while j < tokens.len() && braces > 0 {
                        match tokens[j].tok {
                            Tok::Punct('{') => braces += 1,
                            Tok::Punct('}') => braces -= 1,
                            _ => {}
                        }
                        end_line = tokens[j].line;
                        j += 1;
                    }
                    break;
                }
                _ => {}
            }
            end_line = tokens[j].line;
            j += 1;
        }
        spans.push((attr_line, end_line));
        i = j;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }

    fn strs(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn nested_block_comments_are_skipped() {
        let src = "fn a() {}\n/* outer /* inner */ still comment */ fn b() {}\n";
        let ids = idents(src);
        assert_eq!(ids, vec!["fn", "a", "fn", "b"], "comment text never tokenizes");
        // Line numbers survive the newline inside the comment.
        let lexed = lex("/* one\n * two\n */ fn tail() {}\n");
        let f = lexed.tokens.first().expect("token after comment");
        assert_eq!(f.line, 3);
    }

    #[test]
    fn raw_strings_round_trip_without_escaping() {
        let src = r####"let a = r"plain"; let b = r#"has "quotes" inside"#;"####;
        let got = strs(src);
        assert_eq!(got, vec!["plain".to_string(), "has \"quotes\" inside".to_string()]);
        // Multi-hash terminator: `"#` inside a `##`-delimited raw string
        // does not terminate it.
        let src2 = "let c = r##\"one \"# two\"##;";
        assert_eq!(strs(src2), vec!["one \"# two".to_string()]);
        // No identifier ever leaks out of raw-string content.
        assert_eq!(idents(src), vec!["let", "a", "let", "b"]);
    }

    #[test]
    fn string_containing_unwrap_is_not_an_ident() {
        let src = "let msg = \"please call .unwrap() later\";\n";
        let ids = idents(src);
        assert!(
            !ids.iter().any(|s| s == "unwrap"),
            "string content must not produce identifier tokens: {ids:?}"
        );
        assert_eq!(strs(src), vec!["please call .unwrap() later".to_string()]);
    }

    #[test]
    fn escaped_quotes_stay_inside_the_string() {
        let src = r#"let a = "say \"hi\" now"; let b = 1;"#;
        assert_eq!(strs(src), vec![r#"say \"hi\" now"#.to_string()]);
        assert_eq!(idents(src), vec!["let", "a", "let", "b"]);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let src = "let a = b\"bytes\"; let c = b'{'; let d = b'\\n';";
        assert_eq!(strs(src), vec!["bytes".to_string()]);
        let chars = lex(src).tokens.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!(chars, 2, "byte chars lex as char literals");
    }

    #[test]
    fn char_vs_lifetime_disambiguation() {
        let lexed = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes = lexed.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count();
        let chars = lexed.tokens.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn raw_identifiers_lex_as_plain_idents() {
        assert_eq!(idents("let r#type = 1;"), vec!["let", "type"]);
    }

    #[test]
    fn cfg_test_module_span_covers_body_only() {
        let src = "\
fn live() { x.unwrap(); }

#[cfg(test)]
mod tests {
    #[test]
    fn t() { y.unwrap(); }
}

fn also_live() {}
";
        let lexed = lex(src);
        assert_eq!(lexed.test_spans(), &[(3, 7)], "span is attr line..closing brace");
        assert!(!lexed.is_test_line(1), "code before the module is live");
        assert!(lexed.is_test_line(6), "test fn body is exempt");
        assert!(!lexed.is_test_line(9), "code after the module is live");
    }

    #[test]
    fn cfg_not_test_is_not_a_test_span() {
        let src = "#[cfg(not(test))]\nfn live() { x.unwrap(); }\n";
        let lexed = lex(src);
        assert!(lexed.test_spans().is_empty(), "cfg(not(test)) must stay live");
    }

    #[test]
    fn test_attr_with_following_attrs_and_semicolon_items() {
        let src = "#[test]\n#[should_panic]\nfn t() { boom(); }\n";
        let lexed = lex(src);
        assert_eq!(lexed.test_spans(), &[(1, 3)]);
        // `#[cfg(test)] use x;` — semicolon-terminated item.
        let lexed = lex("#[cfg(test)]\nuse std::collections::HashMap;\nfn f() {}\n");
        assert_eq!(lexed.test_spans(), &[(1, 2)]);
    }

    #[test]
    fn directives_parse_from_plain_comments_only() {
        let src = "\
// lint:allow(robustness/no-panic-in-serve): fixture reason
/// lint:allow(robustness/no-panic-in-serve): doc text, not a directive
//! lint:allow(robustness/no-panic-in-serve): module doc, not a directive
// lint:allow(broken
";
        let lexed = lex(src);
        assert_eq!(lexed.directives.len(), 1);
        assert_eq!(lexed.directives[0].line, 1);
        assert_eq!(lexed.directives[0].rule, "robustness/no-panic-in-serve");
        assert_eq!(lexed.directives[0].reason, "fixture reason");
        assert_eq!(lexed.malformed_directives, vec![4]);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let src = "for i in 0..10 { let x = 1.5e-3; let y = t.0; }";
        let lexed = lex(src);
        let nums = lexed.tokens.iter().filter(|t| t.tok == Tok::Num).count();
        // 0, 10, 1.5e-3, 0 (tuple index)
        assert_eq!(nums, 4);
        let dots = lexed
            .tokens
            .iter()
            .filter(|t| t.tok == Tok::Punct('.'))
            .count();
        assert_eq!(dots, 3, "`..` is two dots, `t.0` one");
    }
}
