//! Fig. 10: test accuracy versus cumulative BP samples — the "learning
//! efficiency" view. Paper shape: ES/ESWP reach each accuracy level with
//! far fewer BP samples than Baseline.

use crate::config::presets::Scale;
use crate::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use crate::metrics::Recorder;
use crate::util::bench::table_header;
use crate::util::json::{num, obj, s, Json};

use super::{make_runtime, run_config};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let n = match scale {
        Scale::Smoke => 1024,
        Scale::Full => 16384,
    };
    let base_cfg = {
        let mut c = RunConfig::new(
            "fig10",
            "mlp_cifar10",
            DatasetConfig::SynthCifar { n, classes: 10, label_noise: 0.05, hard_frac: 0.2 },
        );
        c.epochs = match scale {
            Scale::Smoke => 6,
            Scale::Full => 30,
        };
        c.meta_batch = 128;
        c.mini_batch = 32;
        c.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
        c.eval_every = 1;
        c.test_n = 512;
        c
    };
    let rec = Recorder::new("fig10_bp_efficiency")?;
    table_header(
        "Fig. 10 — accuracy vs cumulative BP samples",
        &["method", "series (bp_samples:acc%) ..."],
    );
    let mut rt = make_runtime(&base_cfg)?;
    for (tag, sampler) in [
        ("baseline", SamplerConfig::Uniform),
        ("es", SamplerConfig::es_default()),
        ("eswp", SamplerConfig::eswp_default()),
    ] {
        let mut cfg = base_cfg.clone();
        cfg.name = format!("fig10/{tag}");
        cfg.sampler = sampler;
        let rs = run_config(&cfg, rt.as_mut(), 1)?;
        let r = &rs[0];
        let series: Vec<String> = r
            .bp_at_eval
            .iter()
            .zip(&r.eval_curve)
            .map(|(&bp, &(_, _, acc))| format!("{bp}:{:.1}", acc * 100.0))
            .collect();
        println!("{tag:<9} | {}", series.join("  "));
        rec.record(&obj(vec![
            ("fig", s("fig10")),
            ("method", s(tag)),
            (
                "series",
                Json::Arr(
                    r.bp_at_eval
                        .iter()
                        .zip(&r.eval_curve)
                        .map(|(&bp, &(_, _, acc))| {
                            Json::Arr(vec![num(bp as f64), num(acc * 100.0)])
                        })
                        .collect(),
                ),
            ),
        ]))?;
    }
    Ok(())
}
