"""Pallas kernel: flash-style single-head attention (L1 hot-spot).

TPU rethink of the FlashAttention GPU kernel: the GPU version assigns one
threadblock per (head, q-tile) and stages K/V through shared memory with
warp-level softmax reductions. On TPU the same insight — never materialize
the [T, T] score matrix in HBM — maps to:

  * grid over q-tiles; for each q-tile the kernel *loops over k-tiles*
    with `jax.lax.fori_loop`, streaming K/V tiles HBM→VMEM via the
    BlockSpec pipeline (double-buffered by Mosaic on real hardware);
  * the running max `m`, normalizer `l`, and output accumulator live in
    VMEM scratch for the whole k-sweep (the shared-memory analogue);
  * q·kᵀ and p·v hit the MXU (f32 here; bf16-ready — the systolic array
    natively accumulates bf16 inputs in f32);
  * the online-softmax rescale (`exp(m_old - m_new)`) runs on the VPU.

Causal masking is applied with tile-local iota offsets so fully-masked
k-tiles still compute (grid shapes must be static); the -inf guard keeps
them exact zeros after the softmax.

interpret=True for CPU-PJRT execution; see ce_loss.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int, seq: int, causal: bool):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)  # [block_q, d]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    q = q * scale

    num_k = seq // block_k

    def body(kj, carry):
        acc, m_prev, l_prev = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], kj * block_k, block_k, axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], kj * block_k, block_k, axis=0).astype(jnp.float32)
        s = q @ k.T  # MXU: [block_q, block_k]
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard: a fully-masked row has m_new == -inf-ish; exp underflows to 0.
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v  # MXU: [block_q, d]
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_k, body, (acc0, m0, l0))
    o_ref[...] = acc / jnp.maximum(l, 1e-30)[:, None]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 32,
    block_k: int = 32,
) -> jax.Array:
    """Single-head attention, tiled online-softmax. Drop-in for attention_ref.

    Args:
      q, k, v: f32[seq, head_dim]; seq must be divisible by the block sizes
        (aot.py emits power-of-two sequence lengths).

    Returns:
      f32[seq, head_dim]
    """
    seq, d = q.shape
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    if seq % block_q != 0 or seq % block_k != 0:
        block_q = block_k = seq
    grid = (seq // block_q,)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq=seq, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            # K/V: whole-sequence blocks; the k-sweep slices tiles inside
            # the kernel (VMEM-resident for the seq lengths we emit —
            # 128x64 f32 = 32KB; a production TPU kernel would instead
            # use a 2-D grid with per-(q,k) BlockSpecs + carry semantics).
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def multi_head_attention(q, k, v, *, causal: bool = True) -> jax.Array:
    """vmap of the flash kernel over heads: f32[heads, seq, d] -> same."""
    return jax.vmap(lambda a, b, c: flash_attention(a, b, c, causal=causal))(q, k, v)


# ---------------------------------------------------------------------------
# Differentiable wrapper
# ---------------------------------------------------------------------------
#
# jax cannot JVP through a pallas_call, so the model-facing entry point is a
# custom_vjp: forward = the flash kernel, backward = the standard attention
# gradients recomputed from q/k/v (flash-style: nothing from the forward tile
# sweep is saved to HBM; a production TPU build would kernelize the backward
# the same way — see DESIGN.md §Hardware-Adaptation).


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention_vjp(q, k, v, causal=True):
    """Differentiable flash attention; fwd is the Pallas kernel."""
    return flash_attention(q, k, v, causal=causal)


def _attn_fwd(q, k, v, causal):
    return flash_attention(q, k, v, causal=causal), (q, k, v)


def _attn_bwd(causal, res, g):
    q, k, v = res
    t, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = (q @ k.T) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)  # [t, t]
    dv = p.T @ g
    dp = g @ v.T
    # softmax backward: ds = p * (dp - rowsum(dp * p))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = (ds @ k) * scale
    dk = (ds.T @ q) * scale
    return dq, dk, dv


flash_attention_vjp.defvjp(_attn_fwd, _attn_bwd)
