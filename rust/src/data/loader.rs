//! Epoch loader: shuffled meta-batch iteration over (possibly pruned) sets.
//!
//! Every meta-batch has exactly `meta_batch` samples so batch shapes always
//! match an AOT artifact; a ragged tail is padded by wrapping around the
//! shuffled order (each padded sample is a legitimate training sample, just
//! seen twice that epoch — standard drop-last-free practice).

use crate::util::Pcg64;

/// Iterator state for one epoch over a kept-index set.
pub struct EpochLoader {
    order: Vec<u32>,
    meta_batch: usize,
    cursor: usize,
}

impl EpochLoader {
    /// `kept` are dataset indices that survived set-level pruning.
    pub fn new(kept: &[u32], meta_batch: usize, rng: &mut Pcg64) -> Self {
        assert!(meta_batch > 0, "meta_batch must be positive");
        assert!(!kept.is_empty(), "cannot iterate an empty kept set");
        let mut order = kept.to_vec();
        rng.shuffle(&mut order);
        EpochLoader { order, meta_batch, cursor: 0 }
    }

    /// Number of meta-batches this epoch (ceil(kept / B)).
    pub fn num_batches(&self) -> usize {
        self.order.len().div_ceil(self.meta_batch)
    }

    /// Fill `out` with the next meta-batch of exactly `meta_batch` indices;
    /// returns false when the epoch is exhausted. The engine's hot path —
    /// reuses the caller's buffer so steady-state iteration allocates
    /// nothing.
    pub fn next_batch_into(&mut self, out: &mut Vec<u32>) -> bool {
        if self.cursor >= self.order.len() {
            return false;
        }
        out.clear();
        out.reserve(self.meta_batch);
        for k in 0..self.meta_batch {
            // Wrap around for the ragged tail.
            out.push(self.order[(self.cursor + k) % self.order.len()]);
        }
        self.cursor += self.meta_batch;
        true
    }

    /// Allocating convenience wrapper around `next_batch_into`.
    pub fn next_batch(&mut self) -> Option<Vec<u32>> {
        let mut batch = Vec::with_capacity(self.meta_batch);
        if self.next_batch_into(&mut batch) {
            Some(batch)
        } else {
            None
        }
    }
}

/// Background prefetcher: streams a loader's meta-batches through a
/// double-buffered channel so index assembly overlaps the training step.
///
/// Buffer lifecycle: `depth` (≥2) index buffers circulate between an
/// `empty` channel (consumer → worker) and a `full` channel (worker →
/// consumer). The worker fills each buffer with `next_batch_into`, so the
/// steady state allocates nothing; consumers hand buffers back with
/// [`Prefetcher::recycle`]. The same channel pattern covers future
/// gather-offload (moving `BatchBuf::fill` off the compute thread).
pub struct Prefetcher {
    full_rx: Option<std::sync::mpsc::Receiver<Vec<u32>>>,
    empty_tx: Option<std::sync::mpsc::SyncSender<Vec<u32>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Stream an existing loader (already shuffled — the caller's RNG has
    /// been consumed exactly as in direct iteration, so prefetching never
    /// perturbs determinism).
    pub fn from_loader(mut loader: EpochLoader, depth: usize) -> Self {
        let depth = depth.max(2); // double-buffered at minimum
        let (full_tx, full_rx) = std::sync::mpsc::sync_channel::<Vec<u32>>(depth);
        let (empty_tx, empty_rx) = std::sync::mpsc::sync_channel::<Vec<u32>>(depth);
        for _ in 0..depth {
            let _ = empty_tx.send(Vec::new());
        }
        let handle = std::thread::spawn(move || {
            while let Ok(mut buf) = empty_rx.recv() {
                if !loader.next_batch_into(&mut buf) {
                    return; // epoch exhausted
                }
                if full_tx.send(buf).is_err() {
                    return; // consumer dropped
                }
            }
        });
        Prefetcher { full_rx: Some(full_rx), empty_tx: Some(empty_tx), handle: Some(handle) }
    }

    /// Shuffle + stream a kept set with an owned RNG.
    pub fn spawn(kept: Vec<u32>, meta_batch: usize, mut rng: Pcg64, depth: usize) -> Self {
        let loader = EpochLoader::new(&kept, meta_batch, &mut rng);
        Self::from_loader(loader, depth)
    }

    /// Next prefetched meta-batch, or None when the epoch is done.
    pub fn next(&mut self) -> Option<Vec<u32>> {
        let rx = self.full_rx.as_ref()?;
        if crate::obs::counters_on() {
            // The recv wait IS the stall: with the worker keeping the
            // channel full it is ~0; a growing p90 means index assembly
            // can't keep up with the step (DESIGN.md §11).
            let t0 = crate::util::timer::Stopwatch::start();
            let out = rx.recv().ok();
            let reg = crate::obs::registry();
            reg.histogram("data.prefetch_stall_s").record(t0.elapsed().as_secs_f64());
            if out.is_some() {
                reg.counter("data.prefetch_batches").add(1);
            }
            out
        } else {
            rx.recv().ok()
        }
    }

    /// Hand a consumed buffer back for reuse. Optional — dropping the
    /// buffer instead merely costs the worker a fresh allocation.
    pub fn recycle(&mut self, buf: Vec<u32>) {
        if let Some(tx) = &self.empty_tx {
            let _ = tx.try_send(buf);
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Close both channels first so a worker blocked on either side
        // observes the disconnect, then join.
        drop(self.full_rx.take());
        drop(self.empty_tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    #[test]
    fn covers_all_indices_once_when_divisible() {
        let mut rng = Pcg64::new(1);
        let kept: Vec<u32> = (0..64).collect();
        let mut loader = EpochLoader::new(&kept, 16, &mut rng);
        let mut seen = Vec::new();
        while let Some(b) = loader.next_batch() {
            assert_eq!(b.len(), 16);
            seen.extend(b);
        }
        seen.sort_unstable();
        assert_eq!(seen, kept);
    }

    #[test]
    fn ragged_tail_pads_by_wraparound() {
        let mut rng = Pcg64::new(2);
        let kept: Vec<u32> = (0..10).collect();
        let mut loader = EpochLoader::new(&kept, 4, &mut rng);
        assert_eq!(loader.num_batches(), 3);
        let mut count = 0;
        let mut seen = std::collections::HashSet::new();
        while let Some(b) = loader.next_batch() {
            assert_eq!(b.len(), 4);
            seen.extend(b);
            count += 1;
        }
        assert_eq!(count, 3);
        assert_eq!(seen.len(), 10, "every sample seen at least once");
    }

    #[test]
    fn ragged_wraparound_property() {
        // Every kept index appears >= 1x per epoch; the pad (duplicate
        // appearances) is bounded by meta_batch - 1 in total.
        check("loader ragged wraparound", 120, |g| {
            let kept_n = g.usize_in(1, 300);
            let meta_batch = g.usize_in(1, 64);
            let kept: Vec<u32> = (0..kept_n as u32).map(|i| i * 3 + 1).collect();
            let mut loader = EpochLoader::new(&kept, meta_batch, g.rng());
            let mut counts = std::collections::BTreeMap::<u32, usize>::new();
            let mut batches = 0usize;
            let mut buf = Vec::new();
            while loader.next_batch_into(&mut buf) {
                prop_assert!(buf.len() == meta_batch, "short batch {}", buf.len());
                for &i in &buf {
                    *counts.entry(i).or_default() += 1;
                }
                batches += 1;
            }
            prop_assert!(batches == kept_n.div_ceil(meta_batch), "batches {batches}");
            for &i in &kept {
                prop_assert!(counts.contains_key(&i), "index {i} never emitted");
            }
            let total: usize = counts.values().sum();
            let padded = total - kept_n;
            prop_assert!(
                padded <= meta_batch.saturating_sub(1),
                "padded {padded} > meta_batch-1 ({})",
                meta_batch - 1
            );
            Ok(())
        });
    }

    #[test]
    fn next_batch_into_matches_next_batch() {
        let kept: Vec<u32> = (0..50).collect();
        let mut a = EpochLoader::new(&kept, 8, &mut Pcg64::new(9));
        let mut b = EpochLoader::new(&kept, 8, &mut Pcg64::new(9));
        let mut buf = Vec::new();
        loop {
            let via_into = if a.next_batch_into(&mut buf) { Some(buf.clone()) } else { None };
            let via_alloc = b.next_batch();
            assert_eq!(via_into, via_alloc);
            if via_alloc.is_none() {
                break;
            }
        }
    }

    #[test]
    fn shuffles_between_epochs() {
        let kept: Vec<u32> = (0..32).collect();
        let mut rng = Pcg64::new(3);
        let a: Vec<u32> = EpochLoader::new(&kept, 32, &mut rng).next_batch().unwrap();
        let b: Vec<u32> = EpochLoader::new(&kept, 32, &mut rng).next_batch().unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn respects_kept_subset() {
        let mut rng = Pcg64::new(4);
        let kept = vec![3u32, 7, 11, 15];
        let mut loader = EpochLoader::new(&kept, 2, &mut rng);
        while let Some(b) = loader.next_batch() {
            for i in b {
                assert!(kept.contains(&i));
            }
        }
    }

    #[test]
    fn prefetcher_yields_same_multiset_as_loader() {
        let kept: Vec<u32> = (0..40).collect();
        let mut pf = Prefetcher::spawn(kept.clone(), 8, Pcg64::new(5), 2);
        let mut seen = Vec::new();
        while let Some(b) = pf.next() {
            seen.extend(b.iter().copied());
            pf.recycle(b);
        }
        seen.sort_unstable();
        assert_eq!(seen, kept);
    }

    #[test]
    fn prefetcher_matches_direct_iteration_exactly() {
        // Same loader state streamed through the channel == direct calls.
        let kept: Vec<u32> = (0..100).collect();
        let rng = Pcg64::new(8);
        let direct_loader = EpochLoader::new(&kept, 16, &mut rng.clone());
        let mut direct = Vec::new();
        {
            let mut l = direct_loader;
            while let Some(b) = l.next_batch() {
                direct.push(b);
            }
        }
        let loader = EpochLoader::new(&kept, 16, &mut rng.clone());
        let mut pf = Prefetcher::from_loader(loader, 2);
        let mut streamed = Vec::new();
        while let Some(b) = pf.next() {
            streamed.push(b);
        }
        assert_eq!(direct, streamed);
    }

    #[test]
    fn prefetcher_drop_mid_stream_is_clean() {
        let kept: Vec<u32> = (0..1000).collect();
        let mut pf = Prefetcher::spawn(kept, 8, Pcg64::new(6), 2);
        let _ = pf.next();
        drop(pf); // must not deadlock or panic
    }
}
