//! Explicit SIMD fast path for the kernel layer.
//!
//! [`super::gemm`] is written so LLVM's SLP pass *can* vectorize it, but
//! each dot product there carries a single 8-lane accumulator — one
//! vector dependency chain, so the forward is FMA-latency-bound rather
//! than load-bound. This module makes the vector shape explicit with a
//! portable [`F32x8`] lane struct over `[f32; 8]` blocks (the layout
//! LLVM reliably lowers to one YMM/2×XMM register) and restructures the
//! hot loops around it:
//!
//! * [`dot`] — four independent `F32x8` accumulators (32 scalar lanes)
//!   folded in a fixed tree, breaking the dependency chain 4× further
//!   than `gemm::dot`;
//! * [`hidden_fwd`] — register-blocks **four hidden units per pass** so
//!   each loaded `x` chunk feeds four FMA chains (4× fewer x loads, 4
//!   independent chains in flight);
//! * [`logits_fwd`] / [`axpy`] — elementwise, bit-identical to the
//!   scalar-blocked versions (no reductions to reorder);
//! * [`ce_loss_row`] / [`ce_loss_grad_row`] — vectorized max sweep
//!   (max is order-insensitive), exp/summation kept in scalar row order,
//!   so the results are bit-identical to `gemm`'s fused CE;
//! * [`backward_row`] — relu-gated rows through the simd dot/axpy.
//!
//! Reduction-carrying kernels (`dot`, `hidden_fwd`, `backward_row`'s
//! `dh`) use a different — but still *fixed* — summation shape than
//! `gemm`, so they are deterministic for a given input and thread count
//! never changes bits, but they are only tolerance-equal (not
//! bit-equal) to the scalar-blocked path. Selection between the two
//! lives in [`super::KernelDispatch`]; a runtime never mixes them.
//!
//! The bf16 variants ([`dot4_bf16`], [`hidden_fwd_bf16`],
//! [`logits_fwd_bf16`]) read weights from the [`super::pack::PackedBf16`]
//! shadow, dequantizing 8-blocks on the fly (a u16→u32 widen + shift —
//! two cheap integer ops per vector). Halving the weight-stream
//! bandwidth is what makes the reduced-precision scoring forward faster
//! than the exact one at CIFAR dims, where `W1` spills L1 by ~25×.

use super::pack::bf16_to_f32;

/// Portable 8-lane f32 block. Plain `[f32; 8]` arithmetic written
/// elementwise — the exact shape LLVM's loop/SLP vectorizers lower to a
/// single vector register on AVX2/NEON targets without `std::arch`.
#[derive(Clone, Copy, Debug)]
pub struct F32x8(pub [f32; 8]);

impl F32x8 {
    pub const ZERO: F32x8 = F32x8([0.0; 8]);

    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; 8])
    }

    /// Load 8 consecutive floats from the head of `s`.
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        let mut out = [0.0f32; 8];
        out.copy_from_slice(&s[..8]);
        F32x8(out)
    }

    /// Dequantizing load: 8 consecutive bf16 (u16) values.
    #[inline(always)]
    pub fn load_bf16(s: &[u16]) -> F32x8 {
        let mut out = [0.0f32; 8];
        for (o, &b) in out.iter_mut().zip(&s[..8]) {
            *o = bf16_to_f32(b);
        }
        F32x8(out)
    }

    #[inline(always)]
    pub fn store(self, d: &mut [f32]) {
        d[..8].copy_from_slice(&self.0);
    }

    /// Elementwise `self + a·b` (mul-then-add; rustc does not contract
    /// to FMA by default, keeping numerics aligned with the scalar path).
    #[inline(always)]
    pub fn fma(self, a: F32x8, b: F32x8) -> F32x8 {
        let mut out = self.0;
        for ((o, &x), &y) in out.iter_mut().zip(&a.0).zip(&b.0) {
            *o += x * y;
        }
        F32x8(out)
    }

    #[inline(always)]
    pub fn max(self, other: F32x8) -> F32x8 {
        let mut out = self.0;
        for (o, &v) in out.iter_mut().zip(&other.0) {
            *o = o.max(v);
        }
        F32x8(out)
    }

    /// Horizontal sum with the same fixed fold tree as `gemm::dot`:
    /// `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))`.
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let v = self.0;
        ((v[0] + v[4]) + (v[1] + v[5])) + ((v[2] + v[6]) + (v[3] + v[7]))
    }

    /// Horizontal max (order-insensitive for NaN-free input).
    #[inline(always)]
    pub fn hmax(self) -> f32 {
        let v = self.0;
        ((v[0].max(v[4])).max(v[1].max(v[5]))).max((v[2].max(v[6])).max(v[3].max(v[7])))
    }
}

/// Unit-stride dot with four `F32x8` accumulators (32 scalar lanes) and
/// a fixed reduction tree: deterministic for a given input, 4× the
/// independent FMA chains of `gemm::dot`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n32 = n & !31;
    let (mut s0, mut s1, mut s2, mut s3) = (F32x8::ZERO, F32x8::ZERO, F32x8::ZERO, F32x8::ZERO);
    let mut i = 0;
    while i < n32 {
        s0 = s0.fma(F32x8::load(&a[i..]), F32x8::load(&b[i..]));
        s1 = s1.fma(F32x8::load(&a[i + 8..]), F32x8::load(&b[i + 8..]));
        s2 = s2.fma(F32x8::load(&a[i + 16..]), F32x8::load(&b[i + 16..]));
        s3 = s3.fma(F32x8::load(&a[i + 24..]), F32x8::load(&b[i + 24..]));
        i += 32;
    }
    let n8 = n & !7;
    while i < n8 {
        s0 = s0.fma(F32x8::load(&a[i..]), F32x8::load(&b[i..]));
        i += 8;
    }
    let mut tail = 0.0f32;
    while i < n {
        tail += a[i] * b[i];
        i += 1;
    }
    ((s0.hsum() + s2.hsum()) + (s1.hsum() + s3.hsum())) + tail
}

/// `y[i] += alpha * x[i]`. Elementwise — bit-identical to `gemm::axpy`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let n8 = n & !7;
    let va = F32x8::splat(alpha);
    let mut i = 0;
    while i < n8 {
        let acc = F32x8::load(&y[i..]).fma(va, F32x8::load(&x[i..]));
        acc.store(&mut y[i..]);
        i += 8;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

/// Four dots of `x` against four consecutive `d`-length rows of `w`
/// (`w[0..d]`, `w[d..2d]`, …): each loaded `x` chunk feeds four
/// independent accumulator chains.
#[inline]
fn dot4(x: &[f32], w: &[f32], d: usize) -> [f32; 4] {
    debug_assert!(w.len() >= 4 * d);
    let (r0, rest) = w.split_at(d);
    let (r1, rest) = rest.split_at(d);
    let (r2, rest) = rest.split_at(d);
    let r3 = &rest[..d];
    let d8 = d & !7;
    let (mut a0, mut a1, mut a2, mut a3) = (F32x8::ZERO, F32x8::ZERO, F32x8::ZERO, F32x8::ZERO);
    let mut i = 0;
    while i < d8 {
        let vx = F32x8::load(&x[i..]);
        a0 = a0.fma(vx, F32x8::load(&r0[i..]));
        a1 = a1.fma(vx, F32x8::load(&r1[i..]));
        a2 = a2.fma(vx, F32x8::load(&r2[i..]));
        a3 = a3.fma(vx, F32x8::load(&r3[i..]));
        i += 8;
    }
    let (mut t0, mut t1, mut t2, mut t3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    while i < d {
        let xv = x[i];
        t0 += xv * r0[i];
        t1 += xv * r1[i];
        t2 += xv * r2[i];
        t3 += xv * r3[i];
        i += 1;
    }
    [a0.hsum() + t0, a1.hsum() + t1, a2.hsum() + t2, a3.hsum() + t3]
}

/// bf16-weight variant of [`dot4`]: same blocking, rows dequantized
/// 8-wide on the fly.
#[inline]
fn dot4_bf16(x: &[f32], w: &[u16], d: usize) -> [f32; 4] {
    debug_assert!(w.len() >= 4 * d);
    let (r0, rest) = w.split_at(d);
    let (r1, rest) = rest.split_at(d);
    let (r2, rest) = rest.split_at(d);
    let r3 = &rest[..d];
    let d8 = d & !7;
    let (mut a0, mut a1, mut a2, mut a3) = (F32x8::ZERO, F32x8::ZERO, F32x8::ZERO, F32x8::ZERO);
    let mut i = 0;
    while i < d8 {
        let vx = F32x8::load(&x[i..]);
        a0 = a0.fma(vx, F32x8::load_bf16(&r0[i..]));
        a1 = a1.fma(vx, F32x8::load_bf16(&r1[i..]));
        a2 = a2.fma(vx, F32x8::load_bf16(&r2[i..]));
        a3 = a3.fma(vx, F32x8::load_bf16(&r3[i..]));
        i += 8;
    }
    let (mut t0, mut t1, mut t2, mut t3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    while i < d {
        let xv = x[i];
        t0 += xv * bf16_to_f32(r0[i]);
        t1 += xv * bf16_to_f32(r1[i]);
        t2 += xv * bf16_to_f32(r2[i]);
        t3 += xv * bf16_to_f32(r3[i]);
        i += 1;
    }
    [a0.hsum() + t0, a1.hsum() + t1, a2.hsum() + t2, a3.hsum() + t3]
}

/// bf16-weight dot for remainder hidden units (single row).
#[inline]
fn dot_bf16(x: &[f32], w: &[u16]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let n = x.len();
    let n8 = n & !7;
    let (mut s0, mut s1) = (F32x8::ZERO, F32x8::ZERO);
    let mut i = 0;
    let n16 = n & !15;
    while i < n16 {
        s0 = s0.fma(F32x8::load(&x[i..]), F32x8::load_bf16(&w[i..]));
        s1 = s1.fma(F32x8::load(&x[i + 8..]), F32x8::load_bf16(&w[i + 8..]));
        i += 16;
    }
    while i < n8 {
        s0 = s0.fma(F32x8::load(&x[i..]), F32x8::load_bf16(&w[i..]));
        i += 8;
    }
    let mut tail = 0.0f32;
    while i < n {
        tail += x[i] * bf16_to_f32(w[i]);
        i += 1;
    }
    (s0.hsum() + s1.hsum()) + tail
}

/// Hidden-layer forward, register-blocked four hidden units per pass:
/// same relu/bias semantics as `gemm::hidden_fwd`, tolerance-equal
/// numerics (the dot reduction shape differs).
pub fn hidden_fwd(x: &[f32], w1t: &[f32], b1: &[f32], d: usize, h: usize, h_out: &mut [f32]) {
    debug_assert_eq!(x.len() % d.max(1), 0);
    debug_assert_eq!(w1t.len(), d * h);
    let h4 = h & !3;
    for (xi, hrow) in x.chunks_exact(d).zip(h_out.chunks_exact_mut(h)) {
        let mut j = 0;
        while j < h4 {
            let acc = dot4(xi, &w1t[j * d..(j + 4) * d], d);
            hrow[j] = (b1[j] + acc[0]).max(0.0);
            hrow[j + 1] = (b1[j + 1] + acc[1]).max(0.0);
            hrow[j + 2] = (b1[j + 2] + acc[2]).max(0.0);
            hrow[j + 3] = (b1[j + 3] + acc[3]).max(0.0);
            j += 4;
        }
        while j < h {
            hrow[j] = (b1[j] + dot(xi, &w1t[j * d..(j + 1) * d])).max(0.0);
            j += 1;
        }
    }
}

/// Output-layer forward: identical structure to `gemm::logits_fwd`
/// (dead-unit skip included) with the vector axpy. Elementwise — the
/// results are bit-identical to the scalar-blocked path.
pub fn logits_fwd(hrows: &[f32], w2: &[f32], b2: &[f32], h: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(w2.len(), h * c);
    for (hi, li) in hrows.chunks_exact(h).zip(out.chunks_exact_mut(c)) {
        li.copy_from_slice(b2);
        for (k, &hk) in hi.iter().enumerate() {
            if hk != 0.0 {
                axpy(hk, &w2[k * c..(k + 1) * c], li);
            }
        }
    }
}

/// bf16-weight hidden forward for the reduced-precision scoring path:
/// [`hidden_fwd`]'s blocking with dequantize-on-load weight rows and a
/// bf16 bias.
pub fn hidden_fwd_bf16(x: &[f32], w1t: &[u16], b1: &[u16], d: usize, h: usize, h_out: &mut [f32]) {
    debug_assert_eq!(x.len() % d.max(1), 0);
    debug_assert_eq!(w1t.len(), d * h);
    let h4 = h & !3;
    for (xi, hrow) in x.chunks_exact(d).zip(h_out.chunks_exact_mut(h)) {
        let mut j = 0;
        while j < h4 {
            let acc = dot4_bf16(xi, &w1t[j * d..(j + 4) * d], d);
            hrow[j] = (bf16_to_f32(b1[j]) + acc[0]).max(0.0);
            hrow[j + 1] = (bf16_to_f32(b1[j + 1]) + acc[1]).max(0.0);
            hrow[j + 2] = (bf16_to_f32(b1[j + 2]) + acc[2]).max(0.0);
            hrow[j + 3] = (bf16_to_f32(b1[j + 3]) + acc[3]).max(0.0);
            j += 4;
        }
        while j < h {
            hrow[j] = (bf16_to_f32(b1[j]) + dot_bf16(xi, &w1t[j * d..(j + 1) * d])).max(0.0);
            j += 1;
        }
    }
}

/// bf16-weight output forward: logits accumulate in f32, weight rows
/// dequantized per active hidden unit.
pub fn logits_fwd_bf16(hrows: &[f32], w2: &[u16], b2: &[u16], h: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(w2.len(), h * c);
    for (hi, li) in hrows.chunks_exact(h).zip(out.chunks_exact_mut(c)) {
        for (o, &b) in li.iter_mut().zip(b2) {
            *o = bf16_to_f32(b);
        }
        for (k, &hk) in hi.iter().enumerate() {
            if hk != 0.0 {
                for (o, &w) in li.iter_mut().zip(&w2[k * c..(k + 1) * c]) {
                    *o += hk * bf16_to_f32(w);
                }
            }
        }
    }
}

/// Row max with a vectorized sweep. Max is order-insensitive for
/// NaN-free logits, so this matches `gemm`'s sequential fold bit for
/// bit.
#[inline]
fn row_max(li: &[f32]) -> f32 {
    let n = li.len();
    if n < 8 {
        let mut m = f32::NEG_INFINITY;
        for &v in li {
            m = m.max(v);
        }
        return m;
    }
    let n8 = n & !7;
    let mut vm = F32x8::load(li);
    let mut i = 8;
    while i < n8 {
        vm = vm.max(F32x8::load(&li[i..]));
        i += 8;
    }
    let mut m = vm.hmax();
    while i < n {
        m = m.max(li[i]);
        i += 1;
    }
    m
}

/// Per-sample CE loss. Bit-identical to `gemm::ce_loss_row`: same max
/// (order-insensitive), same scalar exp/summation order.
#[inline]
pub fn ce_loss_row(li: &[f32], y: usize) -> f32 {
    let m = row_max(li);
    let mut z = 0.0f32;
    for &v in li {
        z += (v - m).exp();
    }
    z.ln() + m - li[y]
}

/// Fused softmax-CE, mirroring `gemm::ce_loss_grad_row` (loss bits
/// identical to [`ce_loss_row`]); only the max sweep is vectorized.
#[inline]
pub fn ce_loss_grad_row(li: &[f32], y: usize, scale: f32, dl: &mut [f32]) -> f32 {
    debug_assert_eq!(li.len(), dl.len());
    let m = row_max(li);
    let mut z = 0.0f32;
    for (dj, &v) in dl.iter_mut().zip(li) {
        let e = (v - m).exp();
        z += e;
        *dj = e;
    }
    let loss = z.ln() + m - li[y];
    let inv = scale / z;
    for dj in dl.iter_mut() {
        *dj *= inv;
    }
    dl[y] -= scale;
    loss
}

/// Relu-gated backward row through the simd dot/axpy — same structure
/// and skip predicates as `gemm::backward_row`.
#[allow(clippy::too_many_arguments)]
pub fn backward_row(
    xi: &[f32],
    hi: &[f32],
    dl: &[f32],
    w2: &[f32],
    d: usize,
    c: usize,
    gw1t: &mut [f32],
    gb1: &mut [f32],
    gw2: &mut [f32],
    gb2: &mut [f32],
    dh: &mut [f32],
) {
    axpy(1.0, dl, gb2);
    for (k, &hk) in hi.iter().enumerate() {
        if hk > 0.0 {
            dh[k] = dot(dl, &w2[k * c..(k + 1) * c]);
            axpy(hk, dl, &mut gw2[k * c..(k + 1) * c]);
        } else {
            dh[k] = 0.0;
        }
    }
    for (k, &g) in dh.iter().enumerate() {
        if g != 0.0 {
            gb1[k] += g;
            axpy(g, xi, &mut gw1t[k * d..(k + 1) * d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::gemm;
    use super::super::pack::f32_to_bf16;
    use super::*;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    fn wave(n: usize, k: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * k).sin()).collect()
    }

    #[test]
    fn dot_matches_gemm_on_ragged_lengths() {
        for len in [0usize, 1, 7, 8, 9, 15, 31, 32, 33, 63, 64, 100, 257] {
            let a = wave(len, 1.0);
            let b = wave(len, 0.3);
            assert!(close(dot(&a, &b), gemm::dot(&a, &b), 1e-5), "len={len}");
        }
    }

    #[test]
    fn axpy_is_bit_identical_to_gemm() {
        for len in [0usize, 3, 8, 17, 40] {
            let x = wave(len, 0.7);
            let mut y1 = wave(len, 0.2);
            let mut y2 = y1.clone();
            axpy(0.37, &x, &mut y1);
            gemm::axpy(0.37, &x, &mut y2);
            assert_eq!(y1, y2, "len={len}");
        }
    }

    #[test]
    fn hidden_fwd_matches_gemm_on_ragged_shapes() {
        for (d, h, rows) in [(1, 1, 1), (7, 3, 2), (8, 4, 3), (33, 5, 2), (40, 13, 4)] {
            let x = wave(rows * d, 0.9);
            let w1t = wave(d * h, 0.11);
            let b1 = wave(h, 0.5);
            let mut out_s = vec![0.0f32; rows * h];
            let mut out_v = vec![0.0f32; rows * h];
            gemm::hidden_fwd(&x, &w1t, &b1, d, h, &mut out_s);
            hidden_fwd(&x, &w1t, &b1, d, h, &mut out_v);
            for (i, (&a, &b)) in out_v.iter().zip(&out_s).enumerate() {
                assert!(close(a, b, 1e-5), "d={d} h={h} [{i}]: simd={a} gemm={b}");
            }
        }
    }

    #[test]
    fn logits_fwd_is_bit_identical_to_gemm() {
        let (h, c, rows) = (9usize, 10usize, 3usize);
        let mut hrows = wave(rows * h, 0.4);
        hrows[2] = 0.0; // dead unit must be skipped identically
        let hrows: Vec<f32> = hrows.iter().map(|v| v.max(0.0)).collect();
        let w2 = wave(h * c, 0.21);
        let b2 = wave(c, 0.6);
        let mut out_s = vec![0.0f32; rows * c];
        let mut out_v = vec![0.0f32; rows * c];
        gemm::logits_fwd(&hrows, &w2, &b2, h, c, &mut out_s);
        logits_fwd(&hrows, &w2, &b2, h, c, &mut out_v);
        assert_eq!(out_s, out_v);
    }

    #[test]
    fn ce_rows_are_bit_identical_to_gemm() {
        for c in [2usize, 3, 8, 10, 16, 19] {
            let li = wave(c, 1.3);
            for y in 0..c {
                assert_eq!(ce_loss_row(&li, y), gemm::ce_loss_row(&li, y), "c={c} y={y}");
                let mut dl_s = vec![0.0f32; c];
                let mut dl_v = vec![0.0f32; c];
                let ls = gemm::ce_loss_grad_row(&li, y, 0.25, &mut dl_s);
                let lv = ce_loss_grad_row(&li, y, 0.25, &mut dl_v);
                assert_eq!(ls, lv, "c={c} y={y}");
                assert_eq!(dl_s, dl_v, "c={c} y={y}");
            }
        }
    }

    #[test]
    fn backward_row_matches_gemm_within_tolerance() {
        let (d, h, c) = (19usize, 6usize, 5usize);
        let xi = wave(d, 0.8);
        let hi: Vec<f32> = wave(h, 1.1).iter().map(|v| v.max(0.0)).collect();
        let dl = wave(c, 0.9);
        let w2 = wave(h * c, 0.3);
        let run = |simd: bool| -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
            let mut gw1t = vec![0.0f32; h * d];
            let mut gb1 = vec![0.0f32; h];
            let mut gw2 = vec![0.0f32; h * c];
            let mut gb2 = vec![0.0f32; c];
            let mut dh = vec![0.0f32; h];
            if simd {
                backward_row(
                    &xi, &hi, &dl, &w2, d, c, &mut gw1t, &mut gb1, &mut gw2, &mut gb2, &mut dh,
                );
            } else {
                gemm::backward_row(
                    &xi, &hi, &dl, &w2, d, c, &mut gw1t, &mut gb1, &mut gw2, &mut gb2, &mut dh,
                );
            }
            (gw1t, gb1, gw2, gb2)
        };
        let (a1, a2, a3, a4) = run(true);
        let (b1, b2, b3, b4) = run(false);
        for (va, vb) in [(&a1, &b1), (&a2, &b2), (&a3, &b3), (&a4, &b4)] {
            for (&x, &y) in va.iter().zip(vb) {
                assert!(close(x, y, 1e-5), "simd={x} gemm={y}");
            }
        }
    }

    #[test]
    fn bf16_forward_tracks_exact_within_bf16_resolution() {
        let (d, h, c, rows) = (37usize, 7usize, 10usize, 3usize);
        let x = wave(rows * d, 0.6);
        let w1t = wave(d * h, 0.13);
        let b1 = wave(h, 0.9);
        let w2 = wave(h * c, 0.27);
        let b2 = wave(c, 0.4);
        let q16 = |v: &[f32]| -> Vec<u16> { v.iter().map(|&f| f32_to_bf16(f)).collect() };

        let mut h_exact = vec![0.0f32; rows * h];
        let mut h_bf16 = vec![0.0f32; rows * h];
        hidden_fwd(&x, &w1t, &b1, d, h, &mut h_exact);
        hidden_fwd_bf16(&x, &q16(&w1t), &q16(&b1), d, h, &mut h_bf16);
        for (&a, &b) in h_bf16.iter().zip(&h_exact) {
            // bf16 carries ~8 mantissa bits: relative error ~2^-8 per
            // weight, growing ~sqrt(d) through the dot.
            assert!(close(a, b, 3e-2), "hidden bf16={a} exact={b}");
        }

        let mut l_exact = vec![0.0f32; rows * c];
        let mut l_bf16 = vec![0.0f32; rows * c];
        logits_fwd(&h_exact, &w2, &b2, h, c, &mut l_exact);
        logits_fwd_bf16(&h_exact, &q16(&w2), &q16(&b2), h, c, &mut l_bf16);
        for (&a, &b) in l_bf16.iter().zip(&l_exact) {
            assert!(close(a, b, 3e-2), "logits bf16={a} exact={b}");
        }
    }
}
