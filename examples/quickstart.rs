//! Quickstart for the public session API: everything comes in through
//! `evosample::prelude`.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! The flow is three steps:
//!
//! 1. **Describe the run** with [`SessionBuilder`]: dataset → batching →
//!    schedule → sampler → event sinks. `build()` validates the config,
//!    generates the data split, and picks the runtime (AOT XLA artifacts
//!    when `artifacts/` exists, else the pure-rust native runtime — same
//!    coordinator, no python either way).
//! 2. **Run it**: `session.run()` executes the paper's Alg. 1 loop and
//!    returns a typed [`RunResult`] (accuracy, loss curves, BP/FP sample
//!    counts, per-phase wall-clock). Sinks subscribed with `.sink(...)`
//!    observe the typed event stream (epoch starts, evals, sync rounds)
//!    as the engine runs.
//! 3. **Compare methods** by swapping the sampler on the same session —
//!    the runtime and data split are reused; each `run()` is a fresh
//!    trial. Any policy registered in `sampler::registry` (including
//!    external crates' own) can be selected with `.sampler_named(...)`.

use evosample::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Describe the run: model, data, batching, schedule.
    let dataset = DatasetConfig::SynthCifar {
        n: 2048,
        classes: 10,
        label_noise: 0.05,
        hard_frac: 0.2,
    };
    let mut session = SessionBuilder::new("mlp_cifar10", dataset)
        .named("quickstart")
        .epochs(10)
        .batch_sizes(128, 32) // B drawn uniformly, b/B = 25% kept for BP
        .lr(LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 })
        .test_n(512)
        .seed(42)
        .sink(Box::new(ProgressSink::new()))
        .build()?;

    // 2. Baseline: no data selection.
    session.set_sampler(SamplerConfig::Uniform);
    let base = session.run()?;

    // 3. Evolved Sampling (paper defaults β1=0.2, β2=0.9, 5% annealing).
    session.set_sampler(SamplerConfig::es_default());
    let es = session.run()?;

    // 4. ESWP: + set-level pruning (r=0.2).
    session.set_sampler(SamplerConfig::eswp_default());
    let eswp = session.run()?;

    println!("\n{:<10} {:>7} {:>12} {:>12} {:>10}", "method", "acc%", "bp samples", "fp samples", "wall s");
    for r in [&base, &es, &eswp] {
        println!(
            "{:<10} {:>7.2} {:>12} {:>12} {:>10.2}",
            r.sampler,
            r.accuracy_pct(),
            r.cost.bp_samples,
            r.cost.fp_samples,
            r.cost.train_wall_s()
        );
    }
    println!(
        "\nES saved {:.1}% wall-clock, ESWP {:.1}% (vs baseline), with accuracies within noise.",
        saved_time_pct(&base.cost, &es.cost),
        saved_time_pct(&base.cost, &eswp.cost),
    );
    Ok(())
}
