//! Ordered SGD (Kawaguchi & Lu 2020): deterministic top-q batch-level
//! selection — take the `mini` highest-loss samples of each meta-batch.
//! The paper treats this as the deterministic limit of loss-weighted
//! sampling (a realization of Kumar et al. 2023's g(·) re-weighting).

use super::{Sampler, Selection};
use crate::util::math;
use crate::util::Pcg64;

pub struct OrderedSgd {
    last: Vec<f32>,
    scratch: Vec<f32>,
}

impl OrderedSgd {
    pub fn new(n: usize) -> Self {
        OrderedSgd { last: vec![1.0 / n as f32; n], scratch: Vec::new() }
    }
}

impl Sampler for OrderedSgd {
    fn name(&self) -> &'static str {
        "order"
    }

    fn n(&self) -> usize {
        self.last.len()
    }

    fn needs_meta_losses(&self, _epoch: usize) -> bool {
        true
    }

    fn observe_meta(&mut self, indices: &[u32], losses: &[f32], _epoch: usize) {
        for (&i, &l) in indices.iter().zip(losses) {
            self.last[i as usize] = l;
        }
    }

    fn select(&mut self, meta: &[u32], mini: usize, _epoch: usize, _rng: &mut Pcg64) -> Selection {
        if mini >= meta.len() {
            return Selection::unweighted(meta.to_vec());
        }
        self.scratch.clear();
        self.scratch.extend(meta.iter().map(|&i| self.last[i as usize]));
        let top = math::top_k_indices(&self.scratch, mini);
        Selection::unweighted(top.into_iter().map(|p| meta[p as usize]).collect())
    }

    // Batch-level only: selection state is per-shard-local by construction
    // (a worker only selects within its own shard), so no §D.5 sync.

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_exact_top_q() {
        let mut s = OrderedSgd::new(8);
        let idx: Vec<u32> = (0..8).collect();
        let losses = [0.1, 5.0, 0.2, 4.0, 0.3, 3.0, 0.4, 0.5];
        s.observe_meta(&idx, &losses, 0);
        let sel = s.select(&idx, 3, 0, &mut Pcg64::new(0));
        let mut got = sel.indices.clone();
        got.sort_unstable();
        assert_eq!(got, vec![1, 3, 5]);
    }

    #[test]
    fn deterministic_across_rng_states() {
        let mut s = OrderedSgd::new(8);
        let idx: Vec<u32> = (0..8).collect();
        s.observe_meta(&idx, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], 0);
        let a = s.select(&idx, 2, 0, &mut Pcg64::new(1)).indices;
        let b = s.select(&idx, 2, 0, &mut Pcg64::new(999)).indices;
        assert_eq!(a, b);
    }

    #[test]
    fn full_mini_returns_meta() {
        let mut s = OrderedSgd::new(4);
        let idx: Vec<u32> = (0..4).collect();
        assert_eq!(s.select(&idx, 4, 0, &mut Pcg64::new(0)).indices, idx);
    }
}
