//! Kernel-layer regression tests: the blocked/threaded kernels — under
//! both the blocked-scalar and the SIMD dispatch — must match the scalar
//! reference implementation within 1e-5 on random shapes (including
//! ragged tails and batches smaller than the shard count), training must
//! be bit-identical across kernel thread counts within a dispatch, and
//! the new write-into runtime surface must honor its contracts.

// These tests intentionally pin the deprecated `coordinator::train` shim.
#![allow(deprecated)]

use evosample::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use evosample::coordinator::{train, TrainResult};
use evosample::data;
use evosample::runtime::kernel::reference::ScalarMlp;
use evosample::runtime::kernel::KernelDispatch;
use evosample::runtime::native::NativeRuntime;
use evosample::runtime::{BatchX, ModelRuntime};
use evosample::util::proptest::check;

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn assert_all_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(close(x, y, tol), "{what}[{i}]: kernel={x} scalar={y}");
    }
}

/// Random shapes (ragged dims, n below the shard count, zero weights,
/// 1-4 kernel threads, both dispatches): kernels must track the scalar
/// reference within 1e-5 through loss_fwd and several train steps.
#[test]
fn kernel_matches_scalar_reference_on_random_shapes() {
    check("kernel == scalar reference", 25, |g| {
        let d = g.usize_in(1, 40);
        let h = g.usize_in(1, 33);
        let c = g.usize_in(2, 11);
        let n = g.usize_in(1, 19);
        let threads = g.usize_in(1, 4);
        let dispatch = [KernelDispatch::Scalar, KernelDispatch::Simd][g.usize_in(0, 1)];

        let mut rt =
            NativeRuntime::new(d, h, c).with_kernel_threads(threads).with_dispatch(dispatch);
        rt.init(7).unwrap();
        let mut sc = ScalarMlp::new(d, h, c);
        sc.set_params(&rt.get_params().unwrap());

        let x = g.vec_f32(n * d, -2.0, 2.0);
        let y: Vec<i32> = (0..n).map(|_| g.usize_in(0, c - 1) as i32).collect();
        let w: Vec<f32> = (0..n)
            .map(|_| if g.f32_in(0.0, 1.0) < 0.2 { 0.0 } else { g.f32_in(0.1, 2.0) })
            .collect();

        let fwd_k = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
        let fwd_s = sc.loss_fwd(&x, &y, n);
        for (i, (&a, &b)) in fwd_k.iter().zip(&fwd_s).enumerate() {
            if !close(a, b, 1e-5) {
                return Err(format!(
                    "loss_fwd[{i}] diverged: kernel={a} scalar={b} \
                     (d={d} h={h} c={c} n={n} t={threads} dispatch={})",
                    dispatch.as_str()
                ));
            }
        }

        for step in 0..3 {
            let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.05, n).unwrap();
            let (losses_s, mean_s) = sc.train_step(&x, &y, &w, 0.05, n);
            for (i, (&a, &b)) in out.losses.iter().zip(&losses_s).enumerate() {
                if !close(a, b, 1e-5) {
                    return Err(format!("step {step} losses[{i}]: kernel={a} scalar={b}"));
                }
            }
            if !close(out.mean_loss, mean_s, 1e-5) {
                return Err(format!(
                    "step {step} mean loss: kernel={} scalar={mean_s}",
                    out.mean_loss
                ));
            }
            let pk = rt.get_params().unwrap();
            for (i, (&a, &b)) in pk.iter().zip(&sc.params).enumerate() {
                if !close(a, b, 1e-4) {
                    return Err(format!("step {step} params[{i}]: kernel={a} scalar={b}"));
                }
            }
        }
        Ok(())
    });
}

/// The CIFAR-scale shape the make_runtime fallback uses — big enough to
/// exercise the pooled (multi-lane) forward and backward paths — under
/// both dispatches at 1, 2, and 4 kernel threads.
#[test]
fn kernel_matches_scalar_at_cifar_dims() {
    let (d, h, c, n) = (3072usize, 64usize, 10usize, 6usize);
    for dispatch in [KernelDispatch::Scalar, KernelDispatch::Simd] {
        for threads in [1usize, 2, 4] {
            let mut rt =
                NativeRuntime::new(d, h, c).with_kernel_threads(threads).with_dispatch(dispatch);
            rt.init(1).unwrap();
            let mut sc = ScalarMlp::new(d, h, c);
            sc.set_params(&rt.get_params().unwrap());

            let mut rng = evosample::util::Pcg64::new(11);
            let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            let y: Vec<i32> = (0..n).map(|_| rng.int_in(0, c as i64) as i32).collect();
            let w = vec![1.0f32; n];

            // f32 summation-order error grows with the dot length: at
            // d=3072 the sequential-vs-tree difference alone reaches
            // ~1e-4, so this shape uses a proportionally looser tolerance
            // than the small random shapes (which assert 1e-5).
            let what = format!("{}/t{threads}", dispatch.as_str());
            let fwd_k = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
            let fwd_s = sc.loss_fwd(&x, &y, n);
            assert_all_close(&fwd_k, &fwd_s, 1e-3, &format!("{what} loss_fwd"));

            let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.01, n).unwrap();
            let (losses_s, _) = sc.train_step(&x, &y, &w, 0.01, n);
            assert_all_close(&out.losses, &losses_s, 1e-3, &format!("{what} train losses"));
            assert_all_close(
                &rt.get_params().unwrap(),
                &sc.params,
                1e-3,
                &format!("{what} params after step"),
            );
        }
    }
}

fn det_run(kernel_threads: usize, dispatch: KernelDispatch) -> TrainResult {
    let ds = DatasetConfig::SynthCifar { n: 256, classes: 4, label_noise: 0.05, hard_frac: 0.2 };
    let split = data::build(&ds, 64, 42);
    let mut cfg = RunConfig::new("kernel_det", "native", ds);
    cfg.epochs = 3;
    cfg.meta_batch = 32;
    cfg.mini_batch = 8;
    cfg.lr = LrSchedule::Const { lr: 0.02 };
    cfg.test_n = 64;
    cfg.sampler = SamplerConfig::es_default();
    let mut rt = NativeRuntime::new(split.train.x_len(), 24, 4)
        .with_kernel_threads(kernel_threads)
        .with_dispatch(dispatch);
    train(&cfg, &mut rt, &split).unwrap()
}

/// A full training run (CIFAR-scale feature dim, ES sampler, scoring FP
/// + weighted BP) must produce bit-identical loss and eval curves at 1,
/// 2, and 4 kernel threads — the fixed-shard determinism contract, end
/// to end, under both the blocked-scalar and the SIMD dispatch. (The two
/// dispatches are NOT bit-identical to each other — they sum dots in
/// different orders — which is why the contract is scoped per dispatch.)
#[test]
fn loss_curves_identical_across_kernel_thread_counts() {
    for dispatch in [KernelDispatch::Scalar, KernelDispatch::Simd] {
        let r1 = det_run(1, dispatch);
        for t in [2usize, 4] {
            let rt = det_run(t, dispatch);
            let tag = dispatch.as_str();
            assert_eq!(r1.loss_curve, rt.loss_curve, "[{tag}] loss curve diverged at {t} threads");
            assert_eq!(r1.eval_curve, rt.eval_curve, "[{tag}] eval curve diverged at {t} threads");
            assert_eq!(r1.cost.fp_samples, rt.cost.fp_samples);
            assert_eq!(r1.cost.bp_samples, rt.cost.bp_samples);
        }
    }
}

/// `loss_fwd_into` APPENDS (callers clear) and matches `loss_fwd`
/// bit for bit; `train_step_into` appends across micro-batches and
/// returns the same mean as `train_step`.
#[test]
fn write_into_variants_match_allocating_api() {
    let (d, h, c, n) = (16usize, 8usize, 3usize, 12usize);
    let mut rng = evosample::util::Pcg64::new(5);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.int_in(0, c as i64) as i32).collect();
    let w = vec![1.0f32; n];

    let mut rt = NativeRuntime::new(d, h, c);
    rt.init(3).unwrap();
    let fwd = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
    let mut buf = vec![99.0f32]; // pre-existing content must survive
    rt.loss_fwd_into(BatchX::F32(&x), &y, n, &mut buf).unwrap();
    assert_eq!(buf.len(), n + 1);
    assert_eq!(buf[0], 99.0);
    assert_eq!(&buf[1..], fwd.as_slice());

    // Two identical runtimes: one steps through train_step, the other
    // through train_step_into; losses and means must agree exactly.
    let mut rt_a = NativeRuntime::new(d, h, c);
    rt_a.init(9).unwrap();
    let mut rt_b = NativeRuntime::new(d, h, c);
    rt_b.init(9).unwrap();
    let out = rt_a.train_step(BatchX::F32(&x), &y, &w, 0.05, n).unwrap();
    let mut losses_b = Vec::new();
    let mean_b =
        rt_b.train_step_into(BatchX::F32(&x), &y, &w, 0.05, n, &mut losses_b).unwrap();
    assert_eq!(out.losses, losses_b);
    assert_eq!(out.mean_loss, mean_b);
    assert_eq!(rt_a.get_params().unwrap(), rt_b.get_params().unwrap());

    // Micro-batched accumulation: two halves append into one buffer.
    let mut acc = Vec::new();
    let half = n / 2;
    rt_b.train_step_into(BatchX::F32(&x[..half * d]), &y[..half], &w[..half], 0.05, half, &mut acc)
        .unwrap();
    rt_b.train_step_into(
        BatchX::F32(&x[half * d..]),
        &y[half..],
        &w[half..],
        0.05,
        n - half,
        &mut acc,
    )
    .unwrap();
    assert_eq!(acc.len(), n, "train_step_into must append, not clear");
}

/// `read_params_into` mirrors `get_params` without allocating, and
/// rejects wrong-size buffers.
#[test]
fn read_params_into_matches_get_params() {
    let mut rt = NativeRuntime::new(7, 5, 3);
    rt.init(2).unwrap();
    let p = rt.get_params().unwrap();
    let mut buf = vec![0.0f32; p.len()];
    rt.read_params_into(&mut buf).unwrap();
    assert_eq!(buf, p);
    let mut wrong = vec![0.0f32; p.len() + 1];
    assert!(rt.read_params_into(&mut wrong).is_err());
}
