//! Persistent worker pool for the kernel layer.
//!
//! A [`KernelPool`] spawns its workers **once** (per runtime) and parks
//! them on a condvar between dispatches, so the per-step dispatch cost
//! is a couple of mutex/condvar round-trips instead of thread spawns.
//! [`KernelPool::run`] executes one *job* — a `Fn(lane)` closure — on
//! every lane concurrently: lane 0 runs on the calling thread, lanes
//! `1..threads` on the pooled workers, and the call only returns once
//! every lane has finished. That blocking property is what makes the
//! lifetime-erased job pointer sound: the closure (and everything it
//! borrows) outlives every dereference.
//!
//! Shutdown: dropping the pool flips a flag under the lock, wakes every
//! worker, and joins them.
//!
//! The pool itself imposes no work-partitioning policy; callers slice
//! their buffers into disjoint regions per lane (see [`SharedRows`] /
//! [`SharedSlots`]) and must keep kernel closures panic-light — a panic
//! on any lane is caught, the barrier still completes, and the dispatch
//! re-panics on the calling thread.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A shared cap on the aggregate number of *spawned* kernel worker
/// threads across any number of [`KernelPool`]s (one per runtime).
///
/// The serve scheduler hands one budget to every concurrent session so a
/// multi-tenant process never oversubscribes the machine: each pool
/// acquires tokens for its extra lanes (`threads - 1`; lane 0 is the
/// caller's thread and is never counted) and may be granted *fewer* than
/// requested when the budget is tight — safe, because the kernel layer's
/// deterministic sharded reduction makes results bit-identical at any
/// lane count (DESIGN.md §7). Tokens are held for the pool's lifetime
/// and released on drop, so queued jobs regain headroom as running jobs
/// finish.
pub struct KernelBudget {
    total: usize,
    used: Mutex<usize>,
}

impl KernelBudget {
    /// A budget of `total` spawnable worker threads (min 0 — a zero
    /// budget forces every pool into single-lane inline execution).
    pub fn new(total: usize) -> Arc<KernelBudget> {
        Arc::new(KernelBudget { total, used: Mutex::new(0) })
    }

    /// The configured cap.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Tokens currently held by live pools.
    pub fn in_use(&self) -> usize {
        *self.used.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Acquire up to `want` tokens, returning how many were granted
    /// (possibly 0). Never blocks: callers degrade to fewer lanes.
    pub fn acquire_up_to(&self, want: usize) -> usize {
        let mut used = self.used.lock().unwrap_or_else(|e| e.into_inner());
        let granted = want.min(self.total.saturating_sub(*used));
        *used += granted;
        granted
    }

    /// Return `n` previously acquired tokens.
    pub fn release(&self, n: usize) {
        let mut used = self.used.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert!(*used >= n, "budget release of unacquired tokens");
        *used = used.saturating_sub(n);
    }
}

/// Lifetime-erased pointer to the current job closure.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is only dereferenced between a `run` dispatch and
// its completion barrier; `run` borrows the closure for that whole span.
unsafe impl Send for Job {}

struct State {
    job: Option<Job>,
    /// Bumped once per dispatch; workers use it to run each job once.
    generation: u64,
    /// Workers that have finished the current job.
    done: usize,
    /// Set when any lane's job panicked (reported by `run`).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// A fixed-size pool of kernel worker threads (see module docs).
pub struct KernelPool {
    threads: usize,
    shared: Arc<Shared>,
    /// Serializes `run` dispatches: overlapping jobs would cross their
    /// completion counts (and dangle the erased job pointer).
    dispatch: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
    /// Budget tokens held for the spawned lanes (returned on drop).
    budget: Option<(Arc<KernelBudget>, usize)>,
}

impl KernelPool {
    /// Spawn a pool with `threads` total lanes (min 1). `threads == 1`
    /// spawns no workers at all — `run` degenerates to a direct call.
    pub fn new(threads: usize) -> KernelPool {
        Self::build(threads.max(1), None)
    }

    /// Like [`KernelPool::new`], but the `threads - 1` spawned worker
    /// lanes are charged against `budget`. When the budget can only
    /// grant `g < threads - 1` tokens the pool spawns `1 + g` lanes —
    /// results are unchanged (lane count never changes bits), only
    /// parallelism degrades.
    pub fn with_budget(threads: usize, budget: Arc<KernelBudget>) -> KernelPool {
        let want = threads.max(1) - 1;
        let granted = budget.acquire_up_to(want);
        // Occupancy telemetry: requested vs granted is the live signal of
        // lane degradation under KernelBudget pressure (DESIGN.md §11).
        if crate::obs::counters_on() {
            let reg = crate::obs::registry();
            reg.counter("kernel.lanes_requested").add(want as u64);
            reg.counter("kernel.lanes_granted").add(granted as u64);
            reg.gauge("kernel.lanes_in_use").add(granted as i64);
        }
        Self::build(1 + granted, Some((budget, granted)))
    }

    fn build(threads: usize, budget: Option<(Arc<KernelBudget>, usize)>) -> KernelPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                generation: 0,
                done: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for lane in 1..threads {
            let shared = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || worker_loop(&shared, lane)));
        }
        KernelPool { threads, shared, dispatch: Mutex::new(()), handles, budget }
    }

    /// Total lanes, including the caller's.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(lane)` for every lane in `0..threads()`; returns after all
    /// lanes complete. Lanes must write only to disjoint data.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if crate::obs::counters_on() {
            crate::obs::registry().counter("kernel.dispatches").add(1);
        }
        // Delay-only injection point (DESIGN.md §12): dispatch sits on
        // the numerics hot path, so the fault layer may stall it to
        // surface straggler behavior but never alter its result.
        crate::fault::maybe_delay(crate::fault::sites::KERNEL_DISPATCH);
        if self.threads == 1 {
            f(0);
            return;
        }
        // Poison-tolerant: a propagated job panic unwinds through `run`
        // with this guard held, but the `()` it protects has no state to
        // corrupt — keep the pool usable afterwards.
        let _serialized = self.dispatch.lock().unwrap_or_else(|e| e.into_inner());
        let job = Job(erase(f));
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job);
            st.generation = st.generation.wrapping_add(1);
            st.done = 0;
            self.shared.work_cv.notify_all();
        }
        // Lane 0 runs on this thread. Catch a panic so we still hold the
        // completion barrier (workers may be mid-job borrowing `f`).
        let main_res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        let worker_panicked = {
            let mut st = self.shared.state.lock().unwrap();
            while st.done < self.threads - 1 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            std::mem::take(&mut st.panicked)
        };
        if let Err(p) = main_res {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("kernel pool worker panicked");
        }
    }
}

/// Erase the borrow lifetime of a job closure.
///
/// SAFETY (for callers): the returned pointer must not be dereferenced
/// after the borrow of `f` ends. `KernelPool::run` guarantees this by
/// blocking until every lane has finished the job.
fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> *const (dyn Fn(usize) + Sync + 'static) {
    let ptr = f as *const (dyn Fn(usize) + Sync + 'a);
    unsafe {
        std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + 'a),
            *const (dyn Fn(usize) + Sync + 'static),
        >(ptr)
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen {
                    if let Some(job) = st.job {
                        seen = st.generation;
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: the dispatching `run` blocks until `done` reaches
        // threads-1, so the closure outlives this call (see `erase`).
        let f = unsafe { &*job.0 };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(lane)));
        let mut st = shared.state.lock().unwrap();
        st.done += 1;
        if res.is_err() {
            st.panicked = true;
        }
        shared.done_cv.notify_one();
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Release only after the lanes are actually gone, so the budget
        // never under-counts live threads.
        if let Some((budget, tokens)) = self.budget.take() {
            budget.release(tokens);
            if crate::obs::counters_on() {
                crate::obs::registry().gauge("kernel.lanes_in_use").add(-(tokens as i64));
            }
        }
    }
}

/// Shared mutable view over a flat `f32` buffer for disjoint-range
/// writes from pool lanes.
#[derive(Clone, Copy)]
pub struct SharedRows {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: lanes only touch disjoint ranges (the `range` contract).
unsafe impl Send for SharedRows {}
unsafe impl Sync for SharedRows {}

impl SharedRows {
    pub fn new(buf: &mut [f32]) -> SharedRows {
        SharedRows { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// Mutable subslice `[a, b)`.
    ///
    /// # Safety
    /// Concurrent callers must use pairwise-disjoint ranges, and the
    /// buffer passed to `new` must outlive every use.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, a: usize, b: usize) -> &mut [f32] {
        debug_assert!(a <= b && b <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(a), b - a)
    }
}

/// Shared mutable view over a slice of `T` for one-lane-per-element
/// access from pool lanes.
pub struct SharedSlots<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for SharedSlots<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SharedSlots<T> {}

// SAFETY: lanes only touch distinct elements (the `get_mut` contract).
unsafe impl<T: Send> Send for SharedSlots<T> {}
unsafe impl<T: Send> Sync for SharedSlots<T> {}

impl<T> SharedSlots<T> {
    pub fn new(buf: &mut [T]) -> SharedSlots<T> {
        SharedSlots { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// Mutable reference to element `i`.
    ///
    /// # Safety
    /// Each element index must be touched by at most one lane during a
    /// dispatch, and the slice passed to `new` must outlive every use.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = KernelPool::new(1);
        let hits = AtomicUsize::new(0);
        pool.run(&|lane| {
            assert_eq!(lane, 0);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn every_lane_runs_exactly_once_per_dispatch() {
        let pool = KernelPool::new(4);
        for _ in 0..50 {
            let mut marks = vec![0u32; 4];
            let slots = SharedSlots::new(&mut marks);
            pool.run(&|lane| {
                // SAFETY: each lane writes only its own slot.
                unsafe { *slots.get_mut(lane) += 1 };
            });
            assert_eq!(marks, vec![1, 1, 1, 1]);
        }
    }

    #[test]
    fn disjoint_row_writes_land() {
        let pool = KernelPool::new(3);
        let n = 31usize;
        let mut buf = vec![0.0f32; n];
        let rows = SharedRows::new(&mut buf);
        pool.run(&|lane| {
            let (a, b) = crate::runtime::kernel::split_range(n, 3, lane);
            // SAFETY: split_range produces disjoint ranges.
            let dst = unsafe { rows.range(a, b) };
            for (k, v) in dst.iter_mut().enumerate() {
                *v = (a + k) as f32;
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn budget_caps_aggregate_spawned_lanes() {
        let budget = KernelBudget::new(4);
        // First pool wants 3 extra lanes: all granted.
        let a = KernelPool::with_budget(4, Arc::clone(&budget));
        assert_eq!(a.threads(), 4);
        assert_eq!(budget.in_use(), 3);
        // Second pool wants 3 but only 1 token remains: degrades to 2 lanes.
        let b = KernelPool::with_budget(4, Arc::clone(&budget));
        assert_eq!(b.threads(), 2);
        assert_eq!(budget.in_use(), 4);
        // Third pool gets nothing: runs inline on the caller's thread.
        let c = KernelPool::with_budget(4, Arc::clone(&budget));
        assert_eq!(c.threads(), 1);
        let hits = AtomicUsize::new(0);
        c.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        // Dropping a pool returns its tokens.
        drop(a);
        assert_eq!(budget.in_use(), 1);
        let d = KernelPool::with_budget(3, Arc::clone(&budget));
        assert_eq!(d.threads(), 3);
        drop(b);
        drop(c);
        drop(d);
        assert_eq!(budget.in_use(), 0);
    }

    #[test]
    fn zero_budget_forces_inline_pools() {
        let budget = KernelBudget::new(0);
        let p = KernelPool::with_budget(8, Arc::clone(&budget));
        assert_eq!(p.threads(), 1);
        assert_eq!(budget.in_use(), 0);
        let mut out = vec![0.0f32; 5];
        let rows = SharedRows::new(&mut out);
        p.run(&|lane| {
            assert_eq!(lane, 0);
            // SAFETY: single lane, whole range.
            let dst = unsafe { rows.range(0, 5) };
            for v in dst.iter_mut() {
                *v = 2.0;
            }
        });
        assert!(out.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn budgeted_pool_produces_same_results_as_unbudgeted() {
        let n = 29usize;
        let run_with = |pool: &KernelPool| -> Vec<f32> {
            let mut buf = vec![0.0f32; n];
            let rows = SharedRows::new(&mut buf);
            let lanes = pool.threads();
            pool.run(&|lane| {
                let (a, b) = crate::runtime::kernel::split_range(n, lanes, lane);
                // SAFETY: split_range produces disjoint ranges.
                let dst = unsafe { rows.range(a, b) };
                for (k, v) in dst.iter_mut().enumerate() {
                    *v = ((a + k) * 3) as f32;
                }
            });
            buf
        };
        let budget = KernelBudget::new(1);
        let budgeted = KernelPool::with_budget(4, budget);
        let free = KernelPool::new(4);
        assert_eq!(run_with(&budgeted), run_with(&free));
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = KernelPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|lane| {
                if lane == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // The pool must still be usable afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
