"""L1: Pallas kernels for the compute hot-spots + pure-jnp oracles.

Kernels (interpret=True — see each module's docstring for the TPU story):
  * ce_loss.cross_entropy      — fused per-sample softmax cross-entropy
  * attention.flash_attention  — flash-style tiled online-softmax attention
  * es_update.es_update        — fused dual-EMA score/weight table refresh

Oracles in ref.py; pinned by python/tests/test_kernels.py.
"""

from compile.kernels.attention import flash_attention, multi_head_attention
from compile.kernels.ce_loss import cross_entropy, cross_entropy_vjp
from compile.kernels.es_update import es_update
from compile.kernels import ref

__all__ = [
    "flash_attention",
    "multi_head_attention",
    "cross_entropy",
    "cross_entropy_vjp",
    "es_update",
    "ref",
]
