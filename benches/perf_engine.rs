//! Engine throughput: sequential data-parallel simulation vs real
//! `std::thread` worker replicas at W=4 on the NativeRuntime.
//!
//! The acceptance bar for the threaded engine is >1.5x step throughput at
//! W=4 over the sequential simulation on a 4-core box (the workload is
//! BP-dominated, so data-parallel replicas scale near-linearly until the
//! sync rounds bite). `EVOSAMPLE_BENCH_FULL=1` runs the larger shape.
//!
//! Emits machine-readable `BENCH_engine.json` (steps/sec per engine
//! mode, threaded-vs-sim speedup) so the perf trajectory is tracked
//! across PRs.

use std::time::Instant;

use evosample::coordinator::train_with_sampler;
use evosample::prelude::*;
use evosample::runtime::native::NativeRuntime;
use evosample::util::bench::smoke_mode;
use evosample::util::json::{num, obj, s};

fn base_cfg(n: usize, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::new(
        "perf_engine",
        "native",
        DatasetConfig::SynthCifar { n, classes: 10, label_noise: 0.05, hard_frac: 0.2 },
    );
    cfg.epochs = epochs;
    // No batch-level selection: every step is one full-batch BP, the
    // §D.5 pre-training shape (B == b), so the comparison isolates the
    // execution engine rather than the sampler.
    cfg.meta_batch = 64;
    cfg.mini_batch = 64;
    cfg.lr = LrSchedule::Const { lr: 0.01 };
    cfg.test_n = 64; // keep the (excluded) eval cheap
    cfg.sampler = SamplerConfig::Uniform;
    cfg
}

/// Train once and report steps/second of wall-clock (eval excluded by
/// subtracting the measured eval phase from elapsed).
///
/// Uses `train_with_sampler` (the Engine escape hatch) rather than a
/// `Session` so the big split stays borrowed instead of owned per run —
/// this bench measures engine throughput, not the session wiring.
fn throughput(cfg: &RunConfig, split: &SplitDataset, hidden: usize) -> (f64, u64) {
    // One kernel lane everywhere: threaded-engine replicas are pinned to
    // 1 lane by spawn_replica, so the main runtime must match or the
    // single/sim anchors would get intra-step parallelism the threaded
    // mode doesn't, invalidating the engine-scaling comparison.
    let mut rt = NativeRuntime::new(split.train.x_len(), hidden, 10).with_kernel_threads(1);
    let sampler =
        evosample::sampler::build(&cfg.sampler, split.train.n, cfg.epochs).expect(&cfg.name);
    let t0 = Instant::now();
    let r = train_with_sampler(cfg, &mut rt, split, sampler).expect(&cfg.name);
    let elapsed = t0.elapsed().as_secs_f64() - r.cost.eval_s;
    (r.steps as f64 / elapsed.max(1e-9), r.steps)
}

fn main() {
    let (n, epochs, hidden) = if smoke_mode() { (2048, 3, 48) } else { (8192, 6, 96) };
    let workers = 4usize;

    let mut cfg = base_cfg(n, epochs);
    let split = data::build(&cfg.dataset, cfg.test_n, 42);

    println!(
        "== engine throughput (n={n}, B=b={}, hidden={hidden}, W={workers}, {} cores) ==",
        cfg.meta_batch,
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
    );

    // Single worker (the refactored legacy path) as the anchor.
    cfg.workers = 1;
    let (tput_single, steps_single) = throughput(&cfg, &split, hidden);
    println!("single worker            {tput_single:8.1} steps/s  ({steps_single} steps)");

    // Sequential simulation at W=4.
    cfg.workers = workers;
    cfg.threaded_workers = false;
    let (tput_sim, steps_sim) = throughput(&cfg, &split, hidden);
    println!("sequential sim   (W={workers})   {tput_sim:8.1} steps/s  ({steps_sim} steps)");

    // Real threads at W=4, epoch-boundary sync only.
    cfg.threaded_workers = true;
    cfg.sync_every = 0;
    let (tput_thr, steps_thr) = throughput(&cfg, &split, hidden);
    println!("threaded         (W={workers})   {tput_thr:8.1} steps/s  ({steps_thr} steps)");

    // Real threads with a mid-epoch parameter sync every 8 steps.
    cfg.sync_every = 8;
    let (tput_thr_sync, _) = throughput(&cfg, &split, hidden);
    println!("threaded + sync8 (W={workers})   {tput_thr_sync:8.1} steps/s");

    let speedup = tput_thr / tput_sim;
    println!(
        "\nthreaded vs sequential sim: {speedup:.2}x step throughput (target > 1.5x at W=4)"
    );
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    if speedup < 1.5 {
        println!(
            "NOTE: below target — expected on boxes with < {workers} free cores \
             (this host reports {cores})"
        );
    }

    let out = obj(vec![
        ("bench", s("perf_engine")),
        ("backend", s("native")),
        ("mode", s(if smoke_mode() { "smoke" } else { "full" })),
        ("cores", num(cores as f64)),
        (
            "shape",
            obj(vec![
                ("n", num(n as f64)),
                ("epochs", num(epochs as f64)),
                ("hidden", num(hidden as f64)),
                ("batch", num(cfg.meta_batch as f64)),
                ("workers", num(workers as f64)),
            ]),
        ),
        (
            "steps_per_s",
            obj(vec![
                ("single", num(tput_single)),
                ("sim_w4", num(tput_sim)),
                ("threaded_w4", num(tput_thr)),
                ("threaded_w4_sync8", num(tput_thr_sync)),
            ]),
        ),
        ("threaded_vs_sim", num(speedup)),
    ]);
    let payload = out.to_string_compact() + "\n";
    std::fs::write("BENCH_engine.json", payload).expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}
