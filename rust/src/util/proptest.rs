//! Mini property-testing harness (proptest is not available offline).
//!
//! Deterministic: every case is derived from a fixed master seed, so a
//! failure report's `case` number is enough to replay it. Shrinking is
//! "lite": on failure the harness retries the predicate on a handful of
//! size-reduced generator scales and reports the smallest failing scale.
//!
//! ```ignore
//! check("probs normalize", 200, |g| {
//!     let ws = g.vec_f32(g.usize_in(1, 64), 0.0, 10.0);
//!     let p = normalize_probs(&ws);
//!     prop_assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-4, "sum off");
//!     Ok(())
//! });
//! ```

use super::rng::Pcg64;

/// Generator handed to each property case. `scale` shrinks sizes on replay.
pub struct Gen {
    rng: Pcg64,
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Self { rng: Pcg64::new(seed), scale }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// usize in [lo, hi], scaled down during shrinking (never below lo).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + (((hi - lo) as f64) * self.scale) as usize;
        self.rng.int_in(lo as i64, hi_scaled as i64 + 1) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vector of positive weights with occasional extreme spread — the
    /// shapes that break naive weighted-sampling implementations.
    pub fn weights(&mut self, n: usize) -> Vec<f32> {
        let spread = self.usize_in(0, 2);
        (0..n)
            .map(|_| match spread {
                0 => self.f32_in(0.1, 1.0),
                1 => self.f32_in(1e-6, 1e3),
                _ => 10f32.powf(self.f32_in(-8.0, 8.0)),
            })
            .collect()
    }
}

/// Run `cases` random cases of `prop`. Panics with a replayable report on
/// the first failure (after attempting scale shrinking).
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    const MASTER: u64 = 0x5eed_c0de;
    for case in 0..cases {
        let seed = MASTER ^ case.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink-lite: replay the same seed at smaller scales.
            let mut smallest: Option<(f64, String)> = None;
            for &scale in &[0.05, 0.1, 0.25, 0.5] {
                let mut g = Gen::new(seed, scale);
                if let Err(m) = prop(&mut g) {
                    smallest = Some((scale, m));
                    break;
                }
            }
            match smallest {
                Some((scale, m)) => panic!(
                    "property '{name}' failed at case {case} (seed {seed:#x}), \
                     shrunk to scale {scale}: {m}"
                ),
                None => panic!(
                    "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
                ),
            }
        }
    }
}

/// assert-style helper usable inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // Count via a side effect to prove all cases execute.
        let counter = std::cell::Cell::new(0u64);
        check("trivial", 50, |g| {
            counter.set(counter.get() + 1);
            let n = g.usize_in(1, 10);
            if n >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_report() {
        check("always fails", 10, |_g| Err("nope".into()));
    }

    #[test]
    fn generator_is_deterministic_per_case() {
        let mut first = Vec::new();
        check("capture", 3, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second = Vec::new();
        check("capture", 3, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn weights_are_positive_finite() {
        check("weights gen", 100, |g| {
            let n = g.usize_in(1, 100);
            for w in g.weights(n) {
                prop_assert!(w.is_finite() && w > 0.0, "bad weight {w}");
            }
            Ok(())
        });
    }
}
