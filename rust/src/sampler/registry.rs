//! Open sampler registry: name → factory over a generic parameter bag.
//!
//! The paper positions ES(WP) as a plug-and-play framework; this registry
//! is the plug socket. Every built-in method is an entry, and external
//! crates add policies with [`register`] — no edits to this crate:
//!
//! ```ignore
//! use evosample::prelude::*;
//! use evosample::sampler::registry::{self, SamplerEntry};
//!
//! registry::register(
//!     SamplerEntry::new("my_policy", SamplerKind::BatchLevel, |p, n, epochs| {
//!         Ok(Box::new(MyPolicy::new(n, epochs, p.get("tau") as f32)))
//!     })
//!     .param("tau", 0.5, "selection temperature"),
//! )?;
//! let report = SessionBuilder::new("mlp_cifar10", dataset)
//!     .sampler_named("my_policy", &[("tau", 0.7)])
//!     .build()?
//!     .run()?;
//! ```
//!
//! Registered policies are first-class everywhere a built-in is: TOML
//! configs (`sampler.kind = "my_policy"` parses to
//! [`SamplerConfig::Custom`]), the CLI (`evosample list-samplers`), and
//! the threaded engine (worker replicas are rebuilt through the registry,
//! so the §D.5 shard-merge hooks of a custom [`Sampler`] participate in
//! sync rounds like any built-in).

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::config::SamplerConfig;

use super::{
    evolved, infobatch, kakurenbo, loss_based, ordered, ucb, uniform, Sampler, SamplerKind,
};

/// Free-form numeric parameters for a sampler factory. Every sampler
/// hyper-parameter in this crate is numeric (ratios, betas, thresholds),
/// so a flat f64 bag covers the whole policy space while staying open.
pub type ParamBag = BTreeMap<String, f64>;

/// Build a [`ParamBag`] from literal pairs.
pub fn bag(pairs: &[(&str, f64)]) -> ParamBag {
    pairs.iter().map(|&(k, v)| (k.to_string(), v)).collect()
}

/// One declared parameter of a registry entry (defaults + self-docs for
/// `list-samplers`).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub default: f64,
    pub doc: String,
}

/// Parameter view handed to factories: bag values with declared defaults.
pub struct Params<'a> {
    bag: &'a ParamBag,
    specs: &'a [ParamSpec],
}

impl<'a> Params<'a> {
    /// Value of `name`, falling back to the declared default. Panics on a
    /// parameter the entry never declared — declare it with
    /// [`SamplerEntry::param`].
    pub fn get(&self, name: &str) -> f64 {
        if let Some(v) = self.bag.get(name) {
            return *v;
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.default)
            .unwrap_or_else(|| panic!("sampler factory read undeclared param {name:?}"))
    }

    pub fn get_f32(&self, name: &str) -> f32 {
        self.get(name) as f32
    }
}

type Factory =
    Arc<dyn Fn(&Params<'_>, usize, usize) -> Result<Box<dyn Sampler>, String> + Send + Sync>;
type ParseFn = fn(&Params<'_>) -> SamplerConfig;

/// One registered sampling policy: canonical name, taxonomy kind
/// (paper Tab. 1), declared parameters, and the factory.
#[derive(Clone)]
pub struct SamplerEntry {
    name: String,
    aliases: Vec<String>,
    kind: SamplerKind,
    params: Vec<ParamSpec>,
    factory: Factory,
    /// Built-ins parse to their typed [`SamplerConfig`] variant; external
    /// entries (None) parse to [`SamplerConfig::Custom`].
    parse: Option<ParseFn>,
}

impl SamplerEntry {
    /// A new entry. `factory` receives (params, dataset n, total epochs).
    pub fn new<F>(name: &str, kind: SamplerKind, factory: F) -> SamplerEntry
    where
        F: Fn(&Params<'_>, usize, usize) -> Result<Box<dyn Sampler>, String>
            + Send
            + Sync
            + 'static,
    {
        SamplerEntry {
            name: name.to_string(),
            aliases: Vec::new(),
            kind,
            params: Vec::new(),
            factory: Arc::new(factory),
            parse: None,
        }
    }

    /// Declare a parameter with its default (repeatable, fluent).
    pub fn param(mut self, name: &str, default: f64, doc: &str) -> SamplerEntry {
        self.params.push(ParamSpec {
            name: name.to_string(),
            default,
            doc: doc.to_string(),
        });
        self
    }

    /// Declare an alternate lookup name (repeatable, fluent).
    pub fn alias(mut self, name: &str) -> SamplerEntry {
        self.aliases.push(name.to_string());
        self
    }

    fn with_parse(mut self, f: ParseFn) -> SamplerEntry {
        self.parse = Some(f);
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn aliases(&self) -> &[String] {
        &self.aliases
    }

    pub fn kind(&self) -> SamplerKind {
        self.kind
    }

    /// Whether this policy runs a per-step scoring FP that
    /// `run.score_every` can stride (frequency tuning, DESIGN.md §8):
    /// batch-level methods score meta-batches, so their scoring cost
    /// amortizes ~1/k; set-level/baseline methods never score and the
    /// knob is a no-op for them.
    pub fn frequency_tunable(&self) -> bool {
        matches!(self.kind, SamplerKind::BatchLevel | SamplerKind::Both)
    }

    pub fn params(&self) -> &[ParamSpec] {
        &self.params
    }

    /// Reject bag keys this entry never declared (typo tripwire shared by
    /// TOML parsing and direct construction).
    fn check_bag(&self, bag: &ParamBag) -> Result<(), String> {
        for key in bag.keys() {
            if !self.params.iter().any(|s| &s.name == key) {
                let known: Vec<&str> = self.params.iter().map(|s| s.name.as_str()).collect();
                return Err(format!(
                    "unknown param {key:?} for sampler {:?} (declared: [{}])",
                    self.name,
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }

    /// Instantiate this entry's sampler for a dataset of `n` samples
    /// trained for `epochs` epochs.
    pub fn build(
        &self,
        bag: &ParamBag,
        n: usize,
        epochs: usize,
    ) -> Result<Box<dyn Sampler>, String> {
        self.check_bag(bag)?;
        (self.factory)(&Params { bag, specs: &self.params }, n, epochs)
    }

    /// Parse a bag into a [`SamplerConfig`]: typed variants for built-ins,
    /// [`SamplerConfig::Custom`] for external registrations. The Custom
    /// params are stored fully resolved (defaults filled in) so equal
    /// configs compare equal regardless of which defaults were spelled.
    pub fn parse(&self, bag: &ParamBag) -> Result<SamplerConfig, String> {
        self.check_bag(bag)?;
        let params = Params { bag, specs: &self.params };
        if let Some(f) = self.parse {
            return Ok(f(&params));
        }
        let resolved: Vec<(String, f64)> = self
            .params
            .iter()
            .map(|s| (s.name.clone(), params.get(&s.name)))
            .collect();
        Ok(SamplerConfig::Custom { name: self.name.clone(), params: resolved })
    }
}

struct Registry {
    entries: BTreeMap<String, SamplerEntry>,
    /// alias → canonical name.
    aliases: BTreeMap<String, String>,
}

impl Registry {
    fn insert(&mut self, entry: SamplerEntry) -> Result<(), String> {
        let mut names = vec![entry.name.clone()];
        names.extend(entry.aliases.iter().cloned());
        for n in &names {
            if self.entries.contains_key(n) || self.aliases.contains_key(n) {
                return Err(format!("sampler {n:?} is already registered"));
            }
        }
        for a in &entry.aliases {
            self.aliases.insert(a.clone(), entry.name.clone());
        }
        self.entries.insert(entry.name.clone(), entry);
        Ok(())
    }

    fn resolve(&self, name: &str) -> Option<&SamplerEntry> {
        if let Some(e) = self.entries.get(name) {
            return Some(e);
        }
        self.aliases.get(name).and_then(|c| self.entries.get(c))
    }
}

fn global() -> &'static RwLock<Registry> {
    static REG: OnceLock<RwLock<Registry>> = OnceLock::new();
    REG.get_or_init(|| {
        let mut r = Registry { entries: BTreeMap::new(), aliases: BTreeMap::new() };
        for e in builtin_entries() {
            r.insert(e).expect("built-in sampler names must be unique");
        }
        RwLock::new(r)
    })
}

/// Register an external sampling policy. Fails on a name or alias that is
/// already taken (built-in or previously registered).
pub fn register(entry: SamplerEntry) -> Result<(), String> {
    global().write().unwrap().insert(entry)
}

/// Look up an entry by canonical name or alias.
pub fn lookup(name: &str) -> Option<SamplerEntry> {
    global().read().unwrap().resolve(name).cloned()
}

/// Every registered entry, sorted by canonical name.
pub fn entries() -> Vec<SamplerEntry> {
    global().read().unwrap().entries.values().cloned().collect()
}

/// Canonical names of every registered entry, sorted.
pub fn names() -> Vec<String> {
    global().read().unwrap().entries.keys().cloned().collect()
}

fn unknown(name: &str) -> String {
    format!("unknown sampler {name:?}; available: [{}]", names().join(", "))
}

/// Instantiate a sampler by registry name.
pub fn build_named(
    name: &str,
    bag: &ParamBag,
    n: usize,
    epochs: usize,
) -> Result<Box<dyn Sampler>, String> {
    lookup(name).ok_or_else(|| unknown(name))?.build(bag, n, epochs)
}

/// Parse (name, params) into a [`SamplerConfig`] — the single entry point
/// TOML/CLI sampler parsing delegates to.
pub fn parse(name: &str, bag: &ParamBag) -> Result<SamplerConfig, String> {
    lookup(name).ok_or_else(|| unknown(name))?.parse(bag)
}

/// Taxonomy kind of a registered sampler, if known.
pub fn kind_of(name: &str) -> Option<SamplerKind> {
    lookup(name).map(|e| e.kind())
}

fn ratio(p: &Params<'_>, name: &str) -> Result<f64, String> {
    let v = p.get(name);
    if !(0.0..1.0).contains(&v) {
        return Err(format!("{name} = {v} out of [0, 1)"));
    }
    Ok(v)
}

fn beta(p: &Params<'_>, name: &str) -> Result<f32, String> {
    let v = p.get_f32(name);
    if !(0.0..=1.0).contains(&v) {
        return Err(format!("{name} = {v} out of [0, 1]"));
    }
    Ok(v)
}

/// The eight Tab. 1 methods plus the random-prune ablation, as registry
/// entries. Canonical names match the historical `SamplerConfig::name()`
/// strings so configs, result records, and presets stay stable.
fn builtin_entries() -> Vec<SamplerEntry> {
    vec![
        SamplerEntry::new("baseline", SamplerKind::Baseline, |_, n, _| {
            Ok(Box::new(uniform::Uniform::new(n)))
        })
        .alias("uniform")
        .with_parse(|_| SamplerConfig::Uniform),
        SamplerEntry::new("loss", SamplerKind::BatchLevel, |_, n, _| {
            Ok(Box::new(loss_based::LossSampler::new(n)))
        })
        .with_parse(|_| SamplerConfig::Loss),
        SamplerEntry::new("order", SamplerKind::BatchLevel, |_, n, _| {
            Ok(Box::new(ordered::OrderedSgd::new(n)))
        })
        .alias("ordered")
        .with_parse(|_| SamplerConfig::Ordered),
        SamplerEntry::new("es", SamplerKind::BatchLevel, |p, n, epochs| {
            Ok(Box::new(evolved::Evolved::new(
                n,
                epochs,
                beta(p, "beta1")?,
                beta(p, "beta2")?,
                ratio(p, "anneal_frac")?,
                0.0,
            )))
        })
        .param("beta1", 0.2, "loss EMA decay (Eq. 3.1)")
        .param("beta2", 0.9, "score EMA decay (Eq. 3.1)")
        .param("anneal_frac", 0.05, "warm-up fraction of epochs")
        .with_parse(|p| SamplerConfig::Es {
            beta1: p.get_f32("beta1"),
            beta2: p.get_f32("beta2"),
            anneal_frac: p.get("anneal_frac"),
        }),
        SamplerEntry::new("eswp", SamplerKind::Both, |p, n, epochs| {
            Ok(Box::new(evolved::Evolved::new(
                n,
                epochs,
                beta(p, "beta1")?,
                beta(p, "beta2")?,
                ratio(p, "anneal_frac")?,
                ratio(p, "prune_ratio")?,
            )))
        })
        .param("beta1", 0.2, "loss EMA decay (Eq. 3.1)")
        .param("beta2", 0.8, "score EMA decay (Eq. 3.1)")
        .param("anneal_frac", 0.05, "warm-up fraction of epochs")
        .param("prune_ratio", 0.2, "set-level prune ratio r")
        .with_parse(|p| SamplerConfig::Eswp {
            beta1: p.get_f32("beta1"),
            beta2: p.get_f32("beta2"),
            anneal_frac: p.get("anneal_frac"),
            prune_ratio: p.get("prune_ratio"),
        }),
        SamplerEntry::new("infobatch", SamplerKind::SetLevel, |p, n, epochs| {
            Ok(Box::new(infobatch::InfoBatch::new(
                n,
                epochs,
                ratio(p, "prune_ratio")?,
                ratio(p, "anneal_frac")?,
            )))
        })
        .param("prune_ratio", 0.5, "below-mean prune probability")
        .param("anneal_frac", 0.125, "final full-data fraction (1-δ)")
        .with_parse(|p| SamplerConfig::InfoBatch {
            prune_ratio: p.get("prune_ratio"),
            anneal_frac: p.get("anneal_frac"),
        }),
        SamplerEntry::new("ka", SamplerKind::SetLevel, |p, n, _| {
            Ok(Box::new(kakurenbo::Kakurenbo::new(
                n,
                ratio(p, "prune_ratio")?,
                p.get_f32("conf_threshold"),
            )))
        })
        .alias("kakurenbo")
        .param("prune_ratio", 0.3, "max hidden fraction")
        .param("conf_threshold", 0.7, "move-back confidence τ")
        .with_parse(|p| SamplerConfig::Kakurenbo {
            prune_ratio: p.get("prune_ratio"),
            conf_threshold: p.get_f32("conf_threshold"),
        }),
        SamplerEntry::new("ucb", SamplerKind::SetLevel, |p, n, _| {
            Ok(Box::new(ucb::Ucb::new(
                n,
                ratio(p, "prune_ratio")?,
                p.get_f32("decay"),
                p.get_f32("c"),
            )))
        })
        .param("prune_ratio", 0.3, "pruned fraction per epoch")
        .param("decay", 0.8, "reward EMA decay")
        .param("c", 1.0, "exploration coefficient")
        .with_parse(|p| SamplerConfig::Ucb {
            prune_ratio: p.get("prune_ratio"),
            decay: p.get_f32("decay"),
            c: p.get_f32("c"),
        }),
        SamplerEntry::new("random_prune", SamplerKind::SetLevel, |p, n, _| {
            Ok(Box::new(uniform::RandomPrune::new(n, ratio(p, "prune_ratio")?)))
        })
        .param("prune_ratio", 0.2, "random pruned fraction")
        .with_parse(|p| SamplerConfig::RandomPrune { prune_ratio: p.get("prune_ratio") }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_cover_every_method() {
        for name in ["baseline", "loss", "order", "es", "eswp", "infobatch", "ka", "ucb", "random_prune"]
        {
            let s = build_named(name, &ParamBag::new(), 64, 10)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(s.n(), 64, "{name}");
        }
    }

    #[test]
    fn aliases_resolve_to_canonical() {
        assert_eq!(lookup("uniform").unwrap().name(), "baseline");
        assert_eq!(lookup("ordered").unwrap().name(), "order");
        assert_eq!(lookup("kakurenbo").unwrap().name(), "ka");
    }

    #[test]
    fn unknown_name_lists_available() {
        let err = build_named("nope", &ParamBag::new(), 10, 2).unwrap_err();
        assert!(err.contains("unknown sampler"), "{err}");
        assert!(err.contains("baseline") && err.contains("eswp"), "{err}");
    }

    #[test]
    fn unknown_param_rejected() {
        let err = build_named("es", &bag(&[("beta3", 0.5)]), 10, 2).unwrap_err();
        assert!(err.contains("beta3") && err.contains("beta1"), "{err}");
    }

    #[test]
    fn out_of_range_param_rejected() {
        assert!(build_named("es", &bag(&[("beta1", 1.5)]), 10, 2).is_err());
        assert!(build_named("eswp", &bag(&[("prune_ratio", 1.0)]), 10, 2).is_err());
    }

    #[test]
    fn parse_builds_typed_configs_with_defaults() {
        assert_eq!(parse("baseline", &ParamBag::new()).unwrap(), SamplerConfig::Uniform);
        assert_eq!(parse("es", &ParamBag::new()).unwrap(), SamplerConfig::es_default());
        assert_eq!(parse("eswp", &ParamBag::new()).unwrap(), SamplerConfig::eswp_default());
        assert_eq!(
            parse("infobatch", &ParamBag::new()).unwrap(),
            SamplerConfig::infobatch_default()
        );
        assert_eq!(
            parse("eswp", &bag(&[("prune_ratio", 0.3)])).unwrap(),
            SamplerConfig::Eswp { beta1: 0.2, beta2: 0.8, anneal_frac: 0.05, prune_ratio: 0.3 }
        );
    }

    #[test]
    fn kinds_match_table1_taxonomy() {
        assert_eq!(kind_of("baseline"), Some(SamplerKind::Baseline));
        assert_eq!(kind_of("es"), Some(SamplerKind::BatchLevel));
        assert_eq!(kind_of("eswp"), Some(SamplerKind::Both));
        assert_eq!(kind_of("infobatch"), Some(SamplerKind::SetLevel));
        assert_eq!(kind_of("nope"), None);
    }

    #[test]
    fn frequency_tunable_tracks_scoring_methods() {
        // Exactly the methods that pay the per-step scoring FP can have
        // it strided by run.score_every.
        for name in ["loss", "order", "es", "eswp"] {
            assert!(lookup(name).unwrap().frequency_tunable(), "{name}");
        }
        for name in ["baseline", "infobatch", "ka", "ucb", "random_prune"] {
            assert!(!lookup(name).unwrap().frequency_tunable(), "{name}");
        }
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mk = || {
            SamplerEntry::new("registry_test_dup", SamplerKind::Baseline, |_, n, _| {
                Ok(Box::new(uniform::Uniform::new(n)))
            })
        };
        register(mk()).unwrap();
        let err = register(mk()).unwrap_err();
        assert!(err.contains("already registered"), "{err}");
        // Colliding with a built-in name or alias is rejected too.
        assert!(register(SamplerEntry::new("uniform", SamplerKind::Baseline, |_, n, _| {
            Ok(Box::new(uniform::Uniform::new(n)))
        }))
        .is_err());
    }

    #[test]
    fn external_entry_parses_to_custom_with_resolved_defaults() {
        register(
            SamplerEntry::new("registry_test_custom", SamplerKind::BatchLevel, |p, n, _| {
                let _ = p.get("tau");
                Ok(Box::new(uniform::Uniform::new(n)))
            })
            .param("tau", 0.5, "temperature"),
        )
        .unwrap();
        let cfg = parse("registry_test_custom", &bag(&[("tau", 0.9)])).unwrap();
        assert_eq!(
            cfg,
            SamplerConfig::Custom {
                name: "registry_test_custom".into(),
                params: vec![("tau".into(), 0.9)],
            }
        );
        // Defaults are resolved into the Custom params.
        let cfg = parse("registry_test_custom", &ParamBag::new()).unwrap();
        assert_eq!(
            cfg,
            SamplerConfig::Custom {
                name: "registry_test_custom".into(),
                params: vec![("tau".into(), 0.5)],
            }
        );
    }
}
