//! Weighted sampling without replacement — the numerical workhorse behind
//! both batch-level selection (b from B, probability ∝ w) and set-level
//! pruning (keep (1−r)·n, probability ∝ w).
//!
//! Implementation: Gumbel top-k (equivalent to Efraimidis–Spirakis A-Res):
//! key_i = ln(w_i) + Gumbel_i; the k largest keys are a without-replacement
//! sample from the normalized weight distribution. Selection uses
//! `select_nth_unstable` for O(n) average time — this is the sampler's
//! hot path (called every training step).
//!
//! Degenerate weights (zero/negative/NaN) are floored to a tiny positive
//! value rather than excluded: the paper's Remark 1 keeps low-weight
//! samples reachable to reduce bias, and a sampler must never stall on a
//! degenerate score table.

use crate::util::Pcg64;

const FLOOR: f64 = 1e-30;

#[inline]
fn key(w: f32, rng: &mut Pcg64) -> f64 {
    let w = if w.is_finite() && w > 0.0 { w as f64 } else { FLOOR };
    w.max(FLOOR).ln() + rng.gumbel()
}

/// Sample `k` distinct positions from `0..weights.len()` with probability
/// proportional to `weights` (without replacement).
pub fn sample_without_replacement(weights: &[f32], k: usize, rng: &mut Pcg64) -> Vec<u32> {
    let n = weights.len();
    assert!(k <= n, "k={k} > n={n}");
    if k == 0 {
        return Vec::new();
    }
    if k == n {
        return (0..n as u32).collect();
    }
    let mut keyed: Vec<(f64, u32)> =
        weights.iter().enumerate().map(|(i, &w)| (key(w, rng), i as u32)).collect();
    // Partition so the k largest keys land in the front, then sort just
    // that prefix for determinism of the output order.
    keyed.select_nth_unstable_by(k - 1, |a, b| b.0.total_cmp(&a.0));
    keyed.truncate(k);
    keyed.sort_unstable_by(|a, b| b.0.total_cmp(&a.0));
    keyed.into_iter().map(|(_, i)| i).collect()
}

/// Set-level pruning: keep `keep_n` of `n` dataset indices, probability
/// proportional to the global weight table. Returns sorted indices.
pub fn prune_keep(weights: &[f32], keep_n: usize, rng: &mut Pcg64) -> Vec<u32> {
    let mut kept = sample_without_replacement(weights, keep_n.min(weights.len()), rng);
    kept.sort_unstable();
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    #[test]
    fn returns_k_distinct_indices() {
        let mut rng = Pcg64::new(1);
        let w = vec![1.0f32; 100];
        for k in [0, 1, 10, 99, 100] {
            let s = sample_without_replacement(&w, k, &mut rng);
            assert_eq!(s.len(), k);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), k);
        }
    }

    #[test]
    fn heavy_weight_dominates() {
        // One sample with 100x weight should appear in a k=1 draw ~91% of
        // the time with 10 others at weight 1 (100/110).
        let mut rng = Pcg64::new(2);
        let mut w = vec![1.0f32; 11];
        w[5] = 100.0;
        let trials = 5000;
        let hits = (0..trials)
            .filter(|_| sample_without_replacement(&w, 1, &mut rng)[0] == 5)
            .count();
        let p = hits as f64 / trials as f64;
        assert!((p - 100.0 / 110.0).abs() < 0.03, "p={p}");
    }

    #[test]
    fn matches_expected_inclusion_probability() {
        // For k=2 of [2, 1, 1]: P(include idx0) = 2/4 + (1/4)(2/3) + (1/4)(2/3) = 5/6.
        let mut rng = Pcg64::new(3);
        let w = [2.0f32, 1.0, 1.0];
        let trials = 20_000;
        let hits = (0..trials)
            .filter(|_| sample_without_replacement(&w, 2, &mut rng).contains(&0))
            .count();
        let p = hits as f64 / trials as f64;
        assert!((p - 5.0 / 6.0).abs() < 0.015, "p={p}");
    }

    #[test]
    fn zero_and_nan_weights_still_sampleable() {
        let mut rng = Pcg64::new(4);
        let w = [0.0f32, f32::NAN, -3.0, 0.0];
        // k == n: everything must be returned without panicking.
        let all = sample_without_replacement(&w, 4, &mut rng);
        assert_eq!(all.len(), 4);
        // k < n: draws still succeed.
        let one = sample_without_replacement(&w, 2, &mut rng);
        assert_eq!(one.len(), 2);
    }

    #[test]
    fn extreme_spread_prefers_large() {
        let mut rng = Pcg64::new(5);
        let w = [1e-20f32, 1e20];
        let hits = (0..1000)
            .filter(|_| sample_without_replacement(&w, 1, &mut rng)[0] == 1)
            .count();
        assert!(hits > 990, "hits={hits}");
    }

    #[test]
    fn prune_keep_sorted_and_sized() {
        let mut rng = Pcg64::new(6);
        let w = vec![1.0f32; 50];
        let kept = prune_keep(&w, 30, &mut rng);
        assert_eq!(kept.len(), 30);
        assert!(kept.windows(2).all(|p| p[0] < p[1]));
    }

    #[test]
    fn prop_distinct_and_in_range() {
        check("swor distinct+range", 150, |g| {
            let n = g.usize_in(1, 200);
            let k = g.usize_in(0, n);
            let w = g.weights(n);
            let s = sample_without_replacement(&w, k, g.rng());
            prop_assert!(s.len() == k, "len {} != {k}", s.len());
            let mut d = s.clone();
            d.sort_unstable();
            for win in d.windows(2) {
                prop_assert!(win[0] != win[1], "duplicate {}", win[0]);
            }
            for &i in &s {
                prop_assert!((i as usize) < n, "oob {i}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_uniform_weights_are_unbiased() {
        // Under equal weights, inclusion frequency must be ~k/n for all i.
        let mut rng = Pcg64::new(7);
        let n = 20;
        let k = 5;
        let w = vec![1.0f32; n];
        let mut counts = vec![0u32; n];
        let trials = 20_000;
        for _ in 0..trials {
            for i in sample_without_replacement(&w, k, &mut rng) {
                counts[i as usize] += 1;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let p = c as f64 / trials as f64;
            assert!((p - 0.25).abs() < 0.02, "idx {i}: p={p}");
        }
    }
}
