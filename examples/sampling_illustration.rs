//! Fig. 1 / Fig. 8 illustration: how the ES weight signal (Eq. 3.1) tracks
//! a noisy decaying loss while damping oscillations, vs raw loss weights
//! (Eq. 2.3). Prints an ASCII plot + the transfer-function story. No
//! training involved — this drives the prelude's `analysis` helpers
//! directly.
//!
//!     cargo run --release --example sampling_illustration

use evosample::prelude::*;
use evosample::sampler::analysis::{fig1_traces, total_variation, transfer_magnitude};

fn ascii_plot(name: &str, xs: &[f32], rows: usize) {
    let max = xs.iter().cloned().fold(f32::MIN, f32::max);
    let min = xs.iter().cloned().fold(f32::MAX, f32::min);
    println!("\n{name}  (min {min:.2}, max {max:.2})");
    let cols = xs.len().min(100);
    let stride = xs.len() / cols;
    for r in (0..rows).rev() {
        let lo = min + (max - min) * r as f32 / rows as f32;
        let hi = min + (max - min) * (r + 1) as f32 / rows as f32;
        let line: String = (0..cols)
            .map(|c| {
                let v = xs[c * stride];
                if v >= lo && v < hi { '*' } else { ' ' }
            })
            .collect();
        println!("|{line}");
    }
    println!("+{}", "-".repeat(100));
}

fn main() {
    let (b1, b2) = (0.5f32, 0.9f32); // Fig. 1's betas
    let mut rng = Pcg64::new(1234);
    let (loss, w_loss, w_es) = fig1_traces(400, b1, b2, &mut rng);

    ascii_plot("loss signal l(t) == Loss-sampling weights (Eq. 2.3)", &loss, 12);
    ascii_plot(&format!("ES weights (Eq. 3.1, beta1={b1}, beta2={b2})"), &w_es, 12);

    println!("\ntotal variation: loss {:.1}  es {:.1}  (smoothing {:.2}x)",
        total_variation(&w_loss), total_variation(&w_es),
        total_variation(&w_loss) / total_variation(&w_es));
    println!("Thm 3.2: |H(i w->inf)| = |beta2-beta1| = {:.2}; measured {:.4}",
        (b2 - b1).abs(), transfer_magnitude(b1 as f64, b2 as f64, 1e9));
    println!("=> ES keeps the trend (low freq, |H|->1) and keeps a tunable {:.0}% of the detail.",
        100.0 * (b2 - b1).abs());
}
