//! Minimal CLI argument parser (clap is not available offline).
//!
//! Grammar: `evosample <subcommand> [--flag value]... [--switch]...`.
//! Unknown flags are an error (no silent typo-swallowing).

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]). `known_switches` take no value.
    pub fn parse(argv: &[String], known_switches: &[&str]) -> Result<Args, String> {
        let mut it = argv.iter().peekable();
        let subcommand = it.next().cloned().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {arg:?}"));
            };
            if known_switches.contains(&name) {
                switches.push(name.to_string());
            } else {
                let val = it
                    .next()
                    .ok_or_else(|| format!("flag --{name} needs a value"))?;
                flags.insert(name.to_string(), val.clone());
            }
        }
        Ok(Args { subcommand, flags, switches })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn usize_flag(&self, name: &str) -> Result<Option<usize>, String> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(&argv("train --config run.toml --full"), &["full"]).unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.flag("config"), Some("run.toml"));
        assert!(a.has("full"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv("train --config"), &[]).is_err());
    }

    #[test]
    fn positional_after_subcommand_is_error() {
        assert!(Args::parse(&argv("train oops"), &[]).is_err());
    }

    #[test]
    fn usize_flag_validates() {
        let a = Args::parse(&argv("x --n 12"), &[]).unwrap();
        assert_eq!(a.usize_flag("n").unwrap(), Some(12));
        let a = Args::parse(&argv("x --n twelve"), &[]).unwrap();
        assert!(a.usize_flag("n").is_err());
    }

    #[test]
    fn empty_argv_gives_help() {
        let a = Args::parse(&[], &[]).unwrap();
        assert_eq!(a.subcommand, "help");
    }
}
