"""Pallas kernel: Evolved-Sampling dual-EMA score/weight update (Eq. 3.1).

The paper's sampler state is two f32 tables (scores `s`, weights `w`) over
all n samples. At epoch boundaries (set-level pruning) ESWP refreshes the
whole table from a dense loss snapshot — an HBM-bandwidth-bound sweep when
n is web-scale. The kernel is a fused dual EMA:

    w' = mask ? β1·s + (1-β1)·l : w
    s' = mask ? β2·s + (1-β2)·l : s

TPU adaptation: a GPU version is a trivially-coalesced elementwise kernel;
the TPU insight is purely about the HBM↔VMEM schedule — 1-D tiles sized so
the four input streams (s, w, l, mask) and two output streams fit VMEM with
room for double buffering, giving one fully-pipelined HBM sweep. With
block_n = 4096: 6 streams * 16KB = 96KB of VMEM per stage.

Both outputs are produced in one pass (single read of `s`), which is the
fusion the pure-jnp ref does not guarantee.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_N = 4096


def _es_kernel(beta_ref, s_ref, w_ref, l_ref, mask_ref, s_out_ref, w_out_ref):
    b1 = beta_ref[0]
    b2 = beta_ref[1]
    s = s_ref[...]
    w = w_ref[...]
    l = l_ref[...]
    m = mask_ref[...]
    new_w = b1 * s + (1.0 - b1) * l
    new_s = b2 * s + (1.0 - b2) * l
    s_out_ref[...] = m * new_s + (1.0 - m) * s
    w_out_ref[...] = m * new_w + (1.0 - m) * w


def es_update(
    s: jax.Array,
    w: jax.Array,
    losses: jax.Array,
    mask: jax.Array,
    betas: jax.Array,
    *,
    block_n: int = _BLOCK_N,
) -> tuple[jax.Array, jax.Array]:
    """Fused ES table refresh. Drop-in for ref.es_update_ref.

    Args:
      s, w, losses, mask: f32[n]
      betas: f32[2] = [beta1, beta2] (runtime-tunable without recompiling)

    Returns:
      (s', w'): f32[n] each.
    """
    (n,) = s.shape
    block_n = min(block_n, n)
    if n % block_n != 0:
        block_n = n
    grid = (n // block_n,)
    return pl.pallas_call(
        _es_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # betas broadcast to every tile
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(betas.astype(jnp.float32), s, w, losses, mask)
