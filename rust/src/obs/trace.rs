//! Span tracer: bounded ring buffer of completed spans with per-thread
//! track ids and monotonic timestamps, exported as Chrome-trace JSON
//! (chrome://tracing / Perfetto's legacy JSON format).
//!
//! Recording is active only at level [`TRACE`](super::TRACE); the
//! [`span`] guard and [`record_elapsed`] both bail on one relaxed load
//! otherwise. Timestamps are microseconds since a process-start anchor
//! (`Instant`-based, so monotonic — wall-clock is never consulted and
//! nothing here can perturb the run).
//!
//! The ring holds the most recent [`RING_CAP`] spans; older spans are
//! overwritten (the overwrite count is visible in exports as
//! `spans_dropped`, so truncation is never silent).

use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::{num, obj, s, Json};

/// Ring capacity: at 5 spans/step this holds ~13k steps of trace —
/// bounded memory (~3 MB) no matter how long a traced run goes.
pub const RING_CAP: usize = 1 << 16;

/// One completed span.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    /// Category (Chrome-trace `cat`): "stage", "sync", "serve", …
    pub cat: &'static str,
    /// Span name (Chrome-trace `name`): "scoring_fp", "train_bp", …
    pub name: &'static str,
    /// Track id: stable per thread (1 = first thread to record), so the
    /// threaded engine's workers render on distinct Perfetto tracks.
    pub tid: u64,
    /// Start, microseconds since the process trace anchor.
    pub ts_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
}

struct Ring {
    buf: Vec<SpanRec>,
    /// Next overwrite position once `buf` is full.
    next: usize,
    dropped: u64,
}

static RING: Mutex<Ring> = Mutex::new(Ring { buf: Vec::new(), next: 0, dropped: 0 });

fn ring() -> std::sync::MutexGuard<'static, Ring> {
    RING.lock().unwrap_or_else(|e| e.into_inner())
}

/// Microseconds since the process trace anchor (first use wins).
fn now_us() -> u64 {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Stable small integer per thread (allocation order). `thread::id()`'s
/// numeric form is unstable API, and names are absent on scoped worker
/// threads — a thread-local counter gives compact, deterministic-shape
/// track ids instead.
fn thread_track_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

fn push(rec: SpanRec) {
    let mut r = ring();
    if r.buf.len() < RING_CAP {
        r.buf.push(rec);
    } else {
        let i = r.next;
        r.buf[i] = rec;
        r.next = (i + 1) % RING_CAP;
        r.dropped += 1;
    }
}

/// RAII span: records `[construction, drop)` into the ring when tracing
/// is on; a no-op (one relaxed load, no clock read) otherwise.
pub struct SpanGuard {
    cat: &'static str,
    name: &'static str,
    start_us: Option<u64>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start_us {
            let end = now_us();
            push(SpanRec {
                cat: self.cat,
                name: self.name,
                tid: thread_track_id(),
                ts_us: start,
                dur_us: end.saturating_sub(start),
            });
        }
    }
}

/// Open a span: `let _sp = obs::span("sync", "sync_round");`.
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    let start_us = super::trace_on().then(now_us);
    SpanGuard { cat, name, start_us }
}

/// Record a span retroactively from an already-measured duration (ends
/// now, started `dur` ago). The engine's `staged()` uses this so the
/// span shares the stage timer's single `Instant` reads — tracing adds
/// no extra clock calls to the step hot path.
pub fn record_elapsed(cat: &'static str, name: &'static str, dur: Duration) {
    if !super::trace_on() {
        return;
    }
    let end = now_us();
    let dur_us = dur.as_micros() as u64;
    push(SpanRec {
        cat,
        name,
        tid: thread_track_id(),
        ts_us: end.saturating_sub(dur_us),
        dur_us,
    });
}

/// Number of spans currently buffered.
pub fn span_count() -> usize {
    ring().buf.len()
}

/// Total order over spans for exports: start time, then track id, then
/// category/name/duration. The tie-break matters for determinism — at
/// microsecond resolution concurrent workers DO collide on `ts_us`, and
/// a bare sort-by-start would leave ring arrival order (a thread race)
/// visible in the exported JSON.
fn span_sort_key(sp: &SpanRec) -> (u64, u64, &'static str, &'static str, u64) {
    (sp.ts_us, sp.tid, sp.cat, sp.name, sp.dur_us)
}

/// Drain the ring, returning spans in the total export order.
pub fn take_spans() -> Vec<SpanRec> {
    let mut r = ring();
    let mut out = std::mem::take(&mut r.buf);
    r.next = 0;
    r.dropped = 0;
    drop(r);
    out.sort_by_key(span_sort_key);
    out
}

/// Discard all buffered spans (bench/test isolation).
pub fn clear_spans() {
    let mut r = ring();
    r.buf.clear();
    r.next = 0;
    r.dropped = 0;
}

/// Render the current ring (non-destructively) as Chrome-trace JSON:
/// `{"traceEvents":[...], "spans_dropped": N}` with one complete
/// (`"ph":"X"`) event per span and a thread-name metadata event per
/// track, loadable in chrome://tracing and Perfetto.
pub fn chrome_trace_json() -> Json {
    let (recs, dropped) = {
        let r = ring();
        (r.buf.clone(), r.dropped)
    };
    let mut recs = recs;
    recs.sort_by_key(span_sort_key);
    let mut tids: Vec<u64> = recs.iter().map(|sp| sp.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    let mut events: Vec<Json> = tids
        .iter()
        .map(|tid| {
            obj(vec![
                ("name", s("thread_name")),
                ("ph", s("M")),
                ("pid", num(1.0)),
                ("tid", num(*tid as f64)),
                ("args", obj(vec![("name", s(format!("worker-{tid}")))])),
            ])
        })
        .collect();
    events.extend(recs.iter().map(|sp| {
        obj(vec![
            ("name", s(sp.name)),
            ("cat", s(sp.cat)),
            ("ph", s("X")),
            ("ts", num(sp.ts_us as f64)),
            ("dur", num(sp.dur_us as f64)),
            ("pid", num(1.0)),
            ("tid", num(sp.tid as f64)),
        ])
    }));
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", s("ms")),
        ("spans_dropped", num(dropped as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The ring is process-global; tests that clear/drain serialize.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static L: Mutex<()> = Mutex::new(());
        L.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn spans_record_only_when_tracing() {
        let _g = test_lock();
        let prev = super::super::level();
        super::super::set_level(super::super::OFF);
        clear_spans();
        drop(span("t", "quiet"));
        record_elapsed("t", "quiet2", Duration::from_micros(5));
        assert!(
            !ring().buf.iter().any(|sp| sp.cat == "t"),
            "no spans recorded at level off"
        );

        super::super::set_level(super::super::TRACE);
        {
            let _sp = span("t", "loud");
            std::thread::sleep(Duration::from_millis(1));
        }
        record_elapsed("t", "loud2", Duration::from_micros(250));
        let spans: Vec<SpanRec> =
            take_spans().into_iter().filter(|sp| sp.cat == "t").collect();
        super::super::set_level(prev);
        assert_eq!(spans.len(), 2);
        let loud = spans.iter().find(|sp| sp.name == "loud").unwrap();
        assert!(loud.dur_us >= 1000, "guard measured the sleep: {}", loud.dur_us);
        let loud2 = spans.iter().find(|sp| sp.name == "loud2").unwrap();
        assert_eq!(loud2.dur_us, 250);
        assert!(loud2.ts_us >= loud.ts_us, "retro span is anchored after the guard span");
    }

    #[test]
    fn threads_get_distinct_track_ids() {
        let _g = test_lock();
        let prev = super::super::level();
        super::super::set_level(super::super::TRACE);
        clear_spans();
        std::thread::scope(|scope| {
            for _ in 0..2 {
                scope.spawn(|| record_elapsed("t", "worker", Duration::from_micros(10)));
            }
        });
        record_elapsed("t", "main", Duration::from_micros(10));
        let spans: Vec<SpanRec> =
            take_spans().into_iter().filter(|sp| sp.cat == "t").collect();
        super::super::set_level(prev);
        let mut tids: Vec<u64> = spans.iter().map(|sp| sp.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        assert_eq!(tids.len(), 3, "3 threads → 3 tracks: {spans:?}");
    }

    #[test]
    fn chrome_export_shapes_trace_events() {
        let _g = test_lock();
        let prev = super::super::level();
        super::super::set_level(super::super::TRACE);
        clear_spans();
        record_elapsed("test_export", "scoring_fp", Duration::from_micros(42));
        let j = chrome_trace_json();
        super::super::set_level(prev);
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        // A thread-name metadata event plus the complete ("X") span.
        assert!(events
            .iter()
            .any(|e| e.get("ph").and_then(Json::as_str) == Some("M")));
        let sp = events
            .iter()
            .find(|e| e.get("cat").and_then(Json::as_str) == Some("test_export"))
            .expect("exported span present");
        assert_eq!(sp.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(sp.get("name").and_then(Json::as_str), Some("scoring_fp"));
        assert_eq!(sp.get("dur").and_then(Json::as_f64), Some(42.0));
        assert!(j.get("spans_dropped").and_then(Json::as_f64).is_some());
        clear_spans();
    }

    #[test]
    fn export_order_is_total_even_on_timestamp_ties() {
        let _g = test_lock();
        let prev = super::super::level();
        super::super::set_level(super::super::OFF);
        clear_spans();
        // Same start microsecond from three "threads", pushed in an
        // arbitrary arrival order (the race the tie-break erases).
        push(SpanRec { cat: "t", name: "b", tid: 3, ts_us: 100, dur_us: 4 });
        push(SpanRec { cat: "t", name: "a", tid: 1, ts_us: 100, dur_us: 9 });
        push(SpanRec { cat: "t", name: "c", tid: 2, ts_us: 100, dur_us: 1 });
        push(SpanRec { cat: "t", name: "z", tid: 1, ts_us: 50, dur_us: 2 });
        let first = chrome_trace_json().to_string_compact();
        let second = chrome_trace_json().to_string_compact();
        assert_eq!(first, second, "export is byte-stable");
        let spans = take_spans();
        super::super::set_level(prev);
        let order: Vec<(u64, u64)> = spans.iter().map(|sp| (sp.ts_us, sp.tid)).collect();
        assert_eq!(order, vec![(50, 1), (100, 1), (100, 2), (100, 3)]);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let _g = test_lock();
        // Exercise the ring via `push` directly with the level off, so
        // no concurrent instrumented code can interleave extra spans.
        let prev = super::super::level();
        super::super::set_level(super::super::OFF);
        clear_spans();
        for _ in 0..(RING_CAP + 7) {
            push(SpanRec { cat: "t", name: "x", tid: 1, ts_us: 0, dur_us: 1 });
        }
        {
            let r = ring();
            assert_eq!(r.buf.len(), RING_CAP);
            assert_eq!(r.dropped, 7);
        }
        clear_spans();
        super::super::set_level(prev);
    }
}
