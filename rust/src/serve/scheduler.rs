//! Worker threads draining the job queue, and the checkpoint plumbing
//! that makes served jobs survive a server kill.
//!
//! Each of the `serve.max_concurrent` workers loops: claim the next
//! pending job under the queue mutex (condvar-waiting when idle), run
//! it as a [`Session`](crate::api::Session) wired to the job's event
//! stream and epoch hook, then release the slot. All workers share one
//! [`KernelBudget`], so the aggregate kernel lanes spawned by
//! concurrently running jobs never exceed `serve.kernel_budget` —
//! budget pressure degrades lane counts, never numerics (DESIGN.md §7),
//! keeping served results bit-identical to standalone runs.
//!
//! The epoch hook is also the cancellation point: it polls the job's
//! interrupt flag at every epoch boundary, checkpointing first on a
//! shutdown-abort so the restarted server resumes from the epoch that
//! just finished rather than re-running it.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::api::{Event, SessionBuilder};
use crate::config::ServeConfig;
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::engine::{EngineResume, EpochHook, RunSnapshot, StepStats};
use crate::metrics::{event_to_json, result_to_json};
use crate::runtime::kernel::pool::KernelBudget;
use crate::runtime::make_runtime_with_budget;
use crate::util::json::{num, obj, s, Json};

use super::job::{self, JobState, INTERRUPT_CANCEL, INTERRUPT_SHUTDOWN};
use super::queue::{ClaimedJob, JobQueue};

/// The scheduler's shared state: the job queue behind its mutex plus
/// the condvar workers park on when the queue is empty.
pub type SharedQueue = Arc<(Mutex<JobQueue>, Condvar)>;

/// Spawn `cfg.max_concurrent` worker threads draining `state`.
///
/// Thread-spawn failure (fd/thread exhaustion) is surfaced instead of
/// panicking: the partially-spawned pool is shut down and joined before
/// the error returns, so the caller never leaks orphan workers.
pub fn spawn_workers(
    state: SharedQueue,
    budget: Arc<KernelBudget>,
    cfg: ServeConfig,
) -> std::io::Result<Vec<JoinHandle<()>>> {
    let mut handles = Vec::with_capacity(cfg.max_concurrent);
    for i in 0..cfg.max_concurrent {
        let worker_state = Arc::clone(&state);
        let budget = Arc::clone(&budget);
        let cfg = cfg.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("serve-worker-{i}"))
            .spawn(move || worker_loop(worker_state, budget, cfg));
        match spawned {
            Ok(h) => handles.push(h),
            Err(e) => {
                let (lock, cvar) = &*state;
                lock.lock().unwrap_or_else(|p| p.into_inner()).begin_shutdown(true);
                cvar.notify_all();
                for h in handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        }
    }
    Ok(handles)
}

fn worker_loop(state: SharedQueue, budget: Arc<KernelBudget>, cfg: ServeConfig) {
    let (lock, cvar) = &*state;
    loop {
        let claimed = {
            let mut q = lock.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(c) = q.claim_next() {
                    break Some(c);
                }
                if q.workers_should_exit() {
                    break None;
                }
                q = cvar.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(claimed) = claimed else { return };
        run_claimed(&claimed, &budget, &cfg);
        lock.lock().unwrap_or_else(|e| e.into_inner()).release();
        cvar.notify_all();
    }
}

/// Run one claimed job end to end and record its outcome (state, final
/// event, durable record, result file).
///
/// Transient failures (injected faults, timeouts, interrupted syscalls —
/// see [`crate::fault::is_transient_error_msg`]) are retried up to
/// `serve.retry_max` times with exponential backoff, each attempt
/// announced on the job's event stream as `retrying{attempt, error}`.
/// Cooperative stops (cancel/shutdown acknowledged by the hook) and
/// non-transient errors fail through immediately; a job that spends its
/// whole budget fails with a `retries_exhausted:`-prefixed message
/// (DESIGN.md §12).
fn run_claimed(claim: &ClaimedJob, budget: &Arc<KernelBudget>, serve: &ServeConfig) {
    let state_dir = PathBuf::from(&serve.state_dir);
    claim.shared.mark_running();
    let _ = job::write_record(&state_dir, &claim.shared, &claim.config_toml);
    let mut attempt = 0usize;
    let outcome = loop {
        match run_session(claim, budget, serve, &state_dir, attempt) {
            Ok(j) => break Ok(j),
            Err(e) => {
                let msg = format!("{e:#}");
                let transient = claim.shared.fired_interrupt() == job::INTERRUPT_NONE
                    && crate::fault::is_transient_error_msg(&msg);
                if !transient || attempt >= serve.retry_max {
                    break Err((e, transient));
                }
                attempt += 1;
                claim.shared.push_event(obj(vec![
                    ("event", s("retrying")),
                    ("attempt", num(attempt as f64)),
                    ("error", s(msg)),
                ]));
                if crate::obs::counters_on() {
                    crate::obs::registry().counter("retry.attempts").add(1);
                }
                let backoff =
                    serve.retry_backoff_ms.saturating_mul(1u64 << (attempt - 1).min(16));
                if backoff > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(backoff));
                }
            }
        }
    };
    match outcome {
        Ok(result_json) => {
            let path = state_dir.join(format!("{}.result.json", claim.id));
            let _ = crate::fault::write_atomic(
                &path,
                result_json.to_string_compact().as_bytes(),
            );
            let accuracy = result_json
                .get("accuracy_pct")
                .and_then(Json::as_f64)
                .map(|pct| pct / 100.0);
            let mut final_ev = result_json;
            if let Json::Obj(map) = &mut final_ev {
                map.insert("event".to_string(), Json::Str("result".to_string()));
            }
            claim.shared.finish(JobState::Done, accuracy, None, Some(final_ev));
        }
        // Classify by the interrupt the hook *acknowledged* when it
        // aborted the run, not the request flag: a real failure that
        // merely races a cancel/shutdown request must still end the job
        // as Failed, not masquerade as a cooperative stop.
        Err((e, transient)) => match claim.shared.fired_interrupt() {
            INTERRUPT_CANCEL => {
                let msg = "cancelled by client".to_string();
                claim.shared.finish(JobState::Cancelled, None, Some(msg), None);
            }
            INTERRUPT_SHUTDOWN => {
                // Checkpoint retained — the next server life resumes it.
                let msg = "interrupted by shutdown".to_string();
                claim.shared.finish(JobState::Interrupted, None, Some(msg), None);
            }
            _ => {
                let msg = if transient {
                    format!("retries_exhausted: {e:#}")
                } else {
                    format!("{e:#}")
                };
                claim.shared.finish(JobState::Failed, None, Some(msg), None);
            }
        },
    }
    let _ = job::write_record(&state_dir, &claim.shared, &claim.config_toml);
}

/// Resolve a claimed job's resume point. A corrupt or unreadable
/// checkpoint is treated exactly like a missing one — the job restarts
/// from scratch (with the reason surfaced on its event stream) rather
/// than permanently failing a run that would succeed without it.
fn resolve_resume(
    state_dir: &Path,
    id: &str,
    has_checkpoint: bool,
) -> (Option<EngineResume>, Option<String>) {
    if !has_checkpoint {
        return (None, None);
    }
    match load_resume(state_dir, id) {
        Ok(Some(r)) => (Some(r), None),
        Ok(None) => (None, Some("no usable checkpoint".to_string())),
        Err(e) => (None, Some(format!("unreadable checkpoint: {e:#}"))),
    }
}

fn run_session(
    claim: &ClaimedJob,
    budget: &Arc<KernelBudget>,
    serve: &ServeConfig,
    state_dir: &Path,
    attempt: usize,
) -> anyhow::Result<Json> {
    crate::fault::hit_io(crate::fault::sites::SERVE_JOB_CLAIM)?;
    let cfg = claim.cfg.clone();
    let rt = make_runtime_with_budget(&cfg, Some(Arc::clone(budget)))?;
    // Retries additionally probe the disk: a checkpoint written *during*
    // the failed attempt post-dates the claim's `has_checkpoint` snapshot
    // and must be resumed, not re-run. The first attempt keeps the
    // snapshot semantics so a reused job id never picks up a stale file.
    let has_checkpoint = claim.has_checkpoint
        || (attempt > 0 && state_dir.join(format!("{}.ckpt", claim.id)).exists());
    let (resume, restart_reason) = resolve_resume(state_dir, &claim.id, has_checkpoint);
    if let Some(reason) = restart_reason {
        claim.shared.push_event(obj(vec![("event", s("restarted")), ("reason", s(reason))]));
    }
    if let Some(r) = &resume {
        claim.shared.push_event(obj(vec![
            ("event", s("resumed")),
            ("from_epoch", num(r.next_epoch as f64)),
        ]));
    }
    let sink_shared = Arc::clone(&claim.shared);
    let mut session = SessionBuilder::from_config(cfg.clone())
        .runtime(rt)
        .on_event(move |ev: &Event| {
            // Selection health per job: the epoch-start keep rate feeds
            // the `status`/`metrics` responses (DESIGN.md §11).
            if let Event::EpochStart { kept, dataset_n, .. } = ev {
                sink_shared.note_selection(*kept, *dataset_n);
            }
            sink_shared.push_event(event_to_json(ev));
        })
        .build()?;
    let hook = make_hook(claim, serve, state_dir, cfg.model.clone(), cfg.seed);
    let result = session.run_resumable(resume, Some(hook))?;
    Ok(result_to_json(&result))
}

/// The per-epoch hook: interrupt polling, live accounting, and periodic
/// checkpoint writes. Checkpoints are only written when the sampler
/// supports state capture ([`Sampler::state_json`] is `Some`) — jobs
/// whose samplers cannot be captured simply restart from scratch after
/// a server kill.
fn make_hook(
    claim: &ClaimedJob,
    serve: &ServeConfig,
    state_dir: &Path,
    model: String,
    seed: u64,
) -> Box<dyn EpochHook> {
    let shared = Arc::clone(&claim.shared);
    let dir = state_dir.to_path_buf();
    let id = claim.id.clone();
    let config_toml = claim.config_toml.clone();
    let every = serve.checkpoint_every;
    Box::new(move |snap: &RunSnapshot<'_>| -> anyhow::Result<()> {
        if shared.interrupt_kind() == INTERRUPT_CANCEL {
            shared.acknowledge_interrupt(INTERRUPT_CANCEL);
            anyhow::bail!("cancelled by client");
        }
        shared.progress(snap.epoch + 1, snap.stats.fp_passes, snap.stats.bp_samples);
        let shutting_down = shared.interrupt_kind() == INTERRUPT_SHUTDOWN;
        if shutting_down {
            // Acknowledge before the final checkpoint write: even if
            // that write fails, the stop is still the shutdown's doing —
            // the job parks as Interrupted and resumes (from an older
            // checkpoint, or scratch) in the next server life.
            shared.acknowledge_interrupt(INTERRUPT_SHUTDOWN);
        }
        let due = every > 0 && ((snap.epoch + 1) % every == 0 || shutting_down);
        if due {
            if let Some(sampler_state) = snap.sampler.state_json() {
                write_checkpoint(&dir, &id, &model, seed, snap, sampler_state)?;
                let _ = job::write_record(&dir, &shared, &config_toml);
            }
        }
        if shutting_down {
            anyhow::bail!("interrupted by shutdown");
        }
        Ok(())
    })
}

/// Persist a resumable checkpoint for job `id`: the model params go in
/// the binary `<id>.ckpt` via [`Checkpoint`], everything else
/// (RNG/sampler/accounting/curves) rides the JSON sidecar's `extra`
/// field, and the optimizer state lands in a sibling `<id>_opt.ckpt`.
pub fn write_checkpoint(
    dir: &Path,
    id: &str,
    model: &str,
    seed: u64,
    snap: &RunSnapshot<'_>,
    sampler_state: Json,
) -> anyhow::Result<()> {
    let stats = obj(vec![
        ("fp_samples", num(snap.stats.fp_samples as f64)),
        ("fp_passes", num(snap.stats.fp_passes as f64)),
        ("bp_samples", num(snap.stats.bp_samples as f64)),
        ("bp_passes", num(snap.stats.bp_passes as f64)),
        ("steps", num(snap.stats.steps as f64)),
    ]);
    let eval_curve = Json::Arr(
        snap.eval_curve
            .iter()
            .map(|&(e, l, a)| Json::Arr(vec![num(e as f64), num(l), num(a)]))
            .collect(),
    );
    let timer_secs = obj(snap
        .timers
        .phases()
        .map(|(label, d)| (label, num(d.as_secs_f64())))
        .collect());
    let extra = obj(vec![
        ("next_epoch", num((snap.epoch + 1) as f64)),
        ("step_idx", num(snap.step_idx as f64)),
        ("rng_state", s(format!("{:032x}:{:032x}", snap.rng_state.0, snap.rng_state.1))),
        ("sampler_state", sampler_state),
        ("stats", stats),
        ("score_ticks", Json::Arr(snap.score_ticks.iter().map(|&t| num(t as f64)).collect())),
        ("loss_curve", Json::Arr(snap.loss_curve.iter().map(|&l| num(l)).collect())),
        ("eval_curve", eval_curve),
        ("bp_at_eval", Json::Arr(snap.bp_at_eval.iter().map(|&b| num(b as f64)).collect())),
        ("timer_secs", timer_secs),
    ]);
    let ck = Checkpoint {
        model: model.to_string(),
        step: snap.step_idx as u64,
        seed,
        params: snap.params.to_vec(),
    };
    ck.save_with_extra(dir, id, &extra)?;
    let opt = Checkpoint {
        model: format!("{model}.opt"),
        step: snap.step_idx as u64,
        seed,
        params: snap.opt_state.to_vec(),
    };
    opt.save(dir, &format!("{id}_opt"))?;
    Ok(())
}

fn want_f64(extra: &Json, key: &str) -> anyhow::Result<f64> {
    extra
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("checkpoint extra: missing {key}"))
}

fn f64_list(extra: &Json, key: &str) -> anyhow::Result<Vec<f64>> {
    let arr = extra
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("checkpoint extra: missing {key}"))?;
    Ok(arr.iter().filter_map(Json::as_f64).collect())
}

/// Load the resume point [`write_checkpoint`] persisted for `id`, or
/// `None` when no (usable) checkpoint exists — the caller then runs the
/// job from scratch.
pub fn load_resume(dir: &Path, id: &str) -> anyhow::Result<Option<EngineResume>> {
    let ck = match Checkpoint::load(dir, id) {
        Ok(ck) => ck,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let extra = Checkpoint::load_extra(dir, id)?;
    if extra == Json::Null {
        return Ok(None);
    }
    let rng_text = extra
        .get("rng_state")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("checkpoint extra: missing rng_state"))?;
    let (hi, lo) = rng_text
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("checkpoint extra: malformed rng_state"))?;
    let rng_state = (u128::from_str_radix(hi, 16)?, u128::from_str_radix(lo, 16)?);
    let stats_j = extra
        .get("stats")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("checkpoint extra: missing stats"))?;
    let stats = StepStats {
        fp_samples: want_f64(&stats_j, "fp_samples")? as u64,
        fp_passes: want_f64(&stats_j, "fp_passes")? as u64,
        bp_samples: want_f64(&stats_j, "bp_samples")? as u64,
        bp_passes: want_f64(&stats_j, "bp_passes")? as u64,
        steps: want_f64(&stats_j, "steps")? as u64,
    };
    let eval_curve = extra
        .get("eval_curve")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(|row| {
                    let row = row.as_arr()?;
                    let e = row.first().and_then(Json::as_f64)? as usize;
                    let l = row.get(1).and_then(Json::as_f64)?;
                    let a = row.get(2).and_then(Json::as_f64)?;
                    Some((e, l, a))
                })
                .collect()
        })
        .unwrap_or_default();
    let timer_secs = extra
        .get("timer_secs")
        .and_then(Json::as_obj)
        .map(|map| {
            map.iter()
                .filter_map(|(k, v)| v.as_f64().map(|secs| (k.clone(), secs)))
                .collect()
        })
        .unwrap_or_default();
    let opt_state = match Checkpoint::load(dir, &format!("{id}_opt")) {
        Ok(opt) => opt.params,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(e.into()),
    };
    Ok(Some(EngineResume {
        next_epoch: want_f64(&extra, "next_epoch")? as usize,
        step_idx: want_f64(&extra, "step_idx")? as usize,
        params: ck.params,
        opt_state,
        rng_state,
        sampler_state: extra.get("sampler_state").cloned(),
        stats,
        score_ticks: f64_list(&extra, "score_ticks")?.into_iter().map(|t| t as u64).collect(),
        loss_curve: f64_list(&extra, "loss_curve")?,
        eval_curve,
        bp_at_eval: f64_list(&extra, "bp_at_eval")?.into_iter().map(|b| b as u64).collect(),
        timer_secs,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerConfig;
    use crate::sampler;
    use crate::util::timer::PhaseTimers;

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("evosample_sched_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Satellite: a mid-run checkpoint restores the cost accounting
    /// (`fp_passes` / `bp_samples`) and every other resume field exactly.
    #[test]
    fn checkpoint_roundtrips_accounting_exactly() {
        let dir = fresh_dir("roundtrip");
        let smp = sampler::build(&SamplerConfig::Uniform, 32, 4).unwrap();
        let stats =
            StepStats { fp_samples: 96, fp_passes: 3, bp_samples: 512, bp_passes: 16, steps: 16 };
        let mut timers = PhaseTimers::new();
        timers.add("train", std::time::Duration::from_secs_f64(1.25));
        let snap = RunSnapshot {
            epoch: 2,
            step_idx: 12,
            params: &[1.0, -2.5, 0.0625],
            opt_state: &[0.5, 0.25],
            rng_state: (0x0123_4567_89ab_cdef_u128 << 32, 0xfeed_face_u128),
            sampler: smp.as_ref(),
            stats: &stats,
            score_ticks: &[3, 1],
            loss_curve: &[0.9, 0.8, 0.7],
            eval_curve: &[(1, 0.5, 0.625)],
            bp_at_eval: &[256],
            timers: &timers,
        };
        write_checkpoint(&dir, "jobx", "mlp", 7, &snap, Json::Null).unwrap();
        let r = load_resume(&dir, "jobx").unwrap().expect("checkpoint present");
        assert_eq!(r.next_epoch, 3);
        assert_eq!(r.step_idx, 12);
        assert_eq!(r.params, vec![1.0, -2.5, 0.0625]);
        assert_eq!(r.opt_state, vec![0.5, 0.25]);
        assert_eq!(r.rng_state, snap.rng_state, "u128 RNG state survives the hex round-trip");
        assert_eq!(r.sampler_state, Some(Json::Null));
        assert_eq!(r.stats.fp_passes, 3, "fp accounting must restore exactly");
        assert_eq!(r.stats.bp_samples, 512, "bp accounting must restore exactly");
        assert_eq!(r.stats.fp_samples, 96);
        assert_eq!(r.stats.bp_passes, 16);
        assert_eq!(r.stats.steps, 16);
        assert_eq!(r.score_ticks, vec![3, 1]);
        assert_eq!(r.loss_curve, vec![0.9, 0.8, 0.7]);
        assert_eq!(r.eval_curve, vec![(1, 0.5, 0.625)]);
        assert_eq!(r.bp_at_eval, vec![256]);
        assert_eq!(r.timer_secs, vec![("train".to_string(), 1.25)]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_checkpoint_resumes_from_scratch() {
        let dir = fresh_dir("missing");
        assert!(load_resume(&dir, "nope").unwrap().is_none());
        let (resume, reason) = resolve_resume(&dir, "nope", false);
        assert!(resume.is_none() && reason.is_none(), "no checkpoint expected, no restart note");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A corrupt checkpoint must behave like a missing one: restart from
    /// scratch with a surfaced reason, never fail the job outright.
    #[test]
    fn corrupt_checkpoint_restarts_instead_of_failing() {
        let dir = fresh_dir("corrupt");
        std::fs::write(dir.join("jobc.ckpt"), b"definitely not a checkpoint").unwrap();
        assert!(load_resume(&dir, "jobc").is_err(), "corrupt file still surfaces as an error");
        let (resume, reason) = resolve_resume(&dir, "jobc", true);
        assert!(resume.is_none());
        assert!(reason.unwrap().contains("unreadable checkpoint"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Outcome classification keys off the interrupt the hook
    /// acknowledged, not the request flag: a genuine failure racing a
    /// shutdown request stays Failed, a hook-driven stop does not.
    #[test]
    fn interrupts_classify_by_acknowledgement_not_request() {
        use crate::serve::job::{JobShared, INTERRUPT_NONE};
        let shared = JobShared::new("jx", "n", "es", 4);
        // Shutdown requested, but the run dies on its own before the
        // hook acts on it → nothing acknowledged → Failed path.
        shared.request_interrupt(INTERRUPT_SHUTDOWN);
        assert_eq!(shared.fired_interrupt(), INTERRUPT_NONE);
        // The hook acting on the request marks the cooperative stop.
        shared.acknowledge_interrupt(INTERRUPT_SHUTDOWN);
        assert_eq!(shared.fired_interrupt(), INTERRUPT_SHUTDOWN);
    }
}
