//! Threaded data-parallel mode: W real `std::thread` worker replicas.
//!
//! Topology per epoch (DESIGN.md §2.3):
//!
//! 1. Epoch-start selection runs on a dedicated forked RNG stream that is
//!    *replayed* on every worker sampler replica: identical tables (kept
//!    in sync by the merge rounds) plus an identical RNG stream give every
//!    replica the same epoch-start decisions — ESWP's pruned set,
//!    InfoBatch's rescale table, Kakurenbo's move-back snapshot.
//! 2. The kept set is sharded round-robin across `min(W, max(1, kept/B))`
//!    effective workers, so shards are always disjoint, non-empty, and at
//!    least one meta-batch long (DESIGN.md §8.4 — a shorter shard would
//!    wrap around inside a single meta-batch and emit duplicate indices).
//! 3. Each effective worker owns a runtime replica (`spawn_replica`) and a
//!    sampler replica, and steps its shard through the shared
//!    [`StepPipeline`] with worker-local RNG, timers, and counters. A
//!    panic inside a step is caught and demoted to an error so the worker
//!    can keep honoring the barrier schedule.
//! 4. Mid-epoch (every `sync_every` local steps, if configured) workers
//!    rendezvous on a barrier and average parameters through
//!    `read_params_into`/`set_params` (worker-owned snapshot buffers are
//!    moved through the slots and reclaimed, so steady-state sync rounds
//!    allocate nothing).
//! 5. At the epoch boundary the main thread all-gathers every replica's
//!    shard observation log and replays it into the canonical sampler and
//!    all peer replicas (`merge_observations`), then averages parameters
//!    into every replica and the main runtime — the paper's §D.5
//!    "additional round of synchronization".
//!
//! Because shards are disjoint, per-index observation order is preserved
//! under the all-gather and every sampler table converges to the state a
//! single shared sampler would have reached (property-tested in
//! tests/engine_determinism.rs).
//!
//! Degraded mode (DESIGN.md §12): a worker whose epoch ends in an error
//! (a caught panic included) is *quarantined* instead of aborting the
//! run — `Event::WorkerLost` is emitted, its report (observations,
//! parameters, accounting) is dropped, and the §D.5 merge runs over the
//! survivors only, in worker-slot order, so the degraded result is still
//! a deterministic function of (seed, surviving shard set). The next
//! epoch re-shards the kept set over the remaining workers, which is the
//! shard redistribution: no sample is orphaned. Only zero survivors
//! aborts the run.
//!
//! Accounting: per-worker phase timers are merged at scale 1/W_eff, so a
//! threaded run's `train_wall_s` stays wall-clock-equivalent (ideal
//! scaling) instead of summed CPU-seconds; sync rounds book under `sync`.

use std::sync::{Barrier, Mutex};

use crate::api::events::{emit_into, Event, EventBus};
use crate::config::RunConfig;
use crate::data::loader::EpochLoader;
use crate::data::SplitDataset;
use crate::runtime::ModelRuntime;
use crate::sampler::{self, Sampler, ShardObservations};
use crate::util::timer::{phase, PhaseTimers};
use crate::util::Pcg64;

use super::super::trainer::TrainResult;
use super::pipeline::{ObservationRoute, StepCtx, StepPipeline, StepStats};
use super::{assemble_result, evaluate};

/// Everything one worker hands back at the epoch boundary.
struct WorkerReport {
    timers: PhaseTimers,
    stats: StepStats,
    class_bp_counts: Vec<u64>,
    loss_sum: f64,
    loss_cnt: u64,
    observations: ShardObservations,
}

/// Shared state for the mid-epoch parameter-averaging rendezvous.
struct SyncShared {
    barrier: Barrier,
    /// Per-worker parameter snapshots published before the barrier.
    slots: Mutex<Vec<Option<Vec<f32>>>>,
    /// The averaged parameters, written by the barrier leader.
    avg: Mutex<Vec<f32>>,
}

/// Element-wise mean of parameter snapshots, written into a reusable
/// buffer (cleared first; empty iterator => empty buffer).
fn mean_params_into<'p>(avg: &mut Vec<f32>, snaps: impl Iterator<Item = &'p Vec<f32>>) {
    avg.clear();
    let mut count = 0usize;
    for p in snaps {
        if avg.is_empty() {
            avg.extend_from_slice(p);
        } else {
            for (a, b) in avg.iter_mut().zip(p.iter()) {
                *a += *b;
            }
        }
        count += 1;
    }
    if count > 0 {
        let inv = 1.0 / count as f32;
        for a in avg.iter_mut() {
            *a *= inv;
        }
    }
}

pub(super) fn run(
    cfg: &RunConfig,
    rt: &mut dyn ModelRuntime,
    data: &SplitDataset,
    canonical: &mut dyn Sampler,
    mut events: Option<&mut EventBus>,
) -> anyhow::Result<TrainResult> {
    let workers = cfg.workers;
    rt.init(cfg.seed as i32)?;

    // Replicas spawn AFTER init so every worker starts from the same
    // parameters.
    let mut replicas: Vec<Box<dyn ModelRuntime + Send>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        replicas.push(rt.spawn_replica()?);
    }
    let train_ds = &data.train;
    let n = train_ds.n;
    // Worker sampler replicas are rebuilt from the config; refuse a
    // mismatched custom sampler rather than silently selecting with the
    // wrong method (the canonical only drives epoch-start pruning).
    let mut worker_samplers: Vec<Box<dyn Sampler>> = (0..workers)
        .map(|_| sampler::build(&cfg.sampler, n, cfg.epochs))
        .collect::<anyhow::Result<Vec<_>>>()?;
    anyhow::ensure!(
        worker_samplers[0].name() == canonical.name(),
        "threaded_workers rebuilds worker samplers from cfg.sampler ({:?}), which does \
         not match the provided sampler ({:?}); construct the sampler from the config \
         (coordinator::train) or disable threaded_workers",
        worker_samplers[0].name(),
        canonical.name()
    );

    let mut rng = Pcg64::new(cfg.seed);
    let mut timers = PhaseTimers::new();
    let mut stats = StepStats::default();
    let mut class_bp_counts = vec![0u64; train_ds.classes.max(1)];

    // Reusable §D.5 sync buffers: one parameter snapshot per worker plus
    // the averaged vector, allocated once for the whole run.
    let pc = rt.param_count();
    let mut snap_bufs: Vec<Vec<f32>> = (0..workers).map(|_| vec![0.0f32; pc]).collect();
    let mut avg_buf: Vec<f32> = Vec::with_capacity(pc);

    let total_steps = cfg.epochs * n.div_ceil(cfg.meta_batch);
    let mut base_step = 0usize;

    // Degraded mode (DESIGN.md §12): a worker that fails an epoch is
    // quarantined here and never scheduled again; its replica and sampler
    // stay allocated but unread. All-true when no faults fire, in which
    // case every loop below visits exactly the slots the pre-quarantine
    // code visited, in the same order.
    let mut alive = vec![true; workers];

    let mut loss_curve = Vec::with_capacity(cfg.epochs);
    let mut eval_curve = Vec::new();
    let mut bp_at_eval = Vec::new();

    // Event stream: the threaded engine announces the epoch-level subset
    // only (per-step events stay worker-local; DESIGN.md §6).
    emit_into(
        &mut events,
        Event::RunStart {
            name: cfg.name.clone(),
            sampler: canonical.name().to_string(),
            epochs: cfg.epochs,
        },
    );

    for epoch in 0..cfg.epochs {
        // ---- set-level selection, replayed on every replica ------------
        // Identical tables + an identical (cloned) RNG stream reproduce
        // the canonical's epoch-start decisions on each worker sampler.
        let prune_rng = rng.fork(0x5e1ec7 + epoch as u64);
        let kept = timers.time(phase::PRUNE, || {
            let kept = canonical.on_epoch_start(epoch, &mut prune_rng.clone());
            for (v, ws) in worker_samplers.iter_mut().enumerate() {
                if alive[v] {
                    let _ = ws.on_epoch_start(epoch, &mut prune_rng.clone());
                }
            }
            kept
        });
        anyhow::ensure!(!kept.is_empty(), "sampler kept nothing at epoch {epoch}");
        // Floor the kept set at one meta-batch (DESIGN.md §8.4): only the
        // canonical's kept set is sharded, so clamping here covers every
        // worker; replica sampler state stays consistent because the clamp
        // touches no tables and no RNG.
        let kept = sampler::enforce_min_keep(kept, cfg.meta_batch, n);
        super::note_epoch_obs(kept.len(), n);
        emit_into(&mut events, Event::EpochStart { epoch, kept: kept.len(), dataset_n: n });

        // ---- disjoint round-robin shards over effective workers --------
        // Clamping keeps every shard non-empty, disjoint (the §D.5 merge
        // relies on disjointness), AND at least one meta-batch long — a
        // shorter shard would wrap around inside a single meta-batch and
        // emit duplicate indices (DESIGN.md §8.4). Surplus replicas sit
        // the epoch out and are re-synced at the boundary. Quarantined
        // slots are excluded, which is the degraded-mode shard
        // redistribution: the full kept set re-shards over the survivors,
        // so no sample is orphaned by a lost worker. Shard rank j (the
        // RNG fork tag and barrier slot) equals worker slot j whenever no
        // slot below it has been lost — i.e. always, until a fault fires.
        let avail = alive.iter().filter(|a| **a).count();
        anyhow::ensure!(avail > 0, "no threaded workers left alive at epoch {epoch}");
        let eff = avail.min((kept.len() / cfg.meta_batch).max(1));
        let active: Vec<usize> = (0..workers).filter(|&i| alive[i]).take(eff).collect();
        let shards: Vec<Vec<u32>> = (0..eff)
            .map(|w| kept.iter().copied().skip(w).step_by(eff).collect())
            .collect();
        let mut inputs: Vec<(EpochLoader, Pcg64)> = Vec::with_capacity(eff);
        for (j, shard) in shards.iter().enumerate() {
            let mut wrng = rng.fork(0xd15c0 + j as u64);
            let loader = EpochLoader::new(shard, cfg.meta_batch, &mut wrng);
            worker_samplers[active[j]].begin_shard(shard);
            inputs.push((loader, wrng));
        }

        // Mid-epoch sync schedule: only rounds every worker can reach
        // (ragged shards stop syncing after the shortest one is done).
        let min_batches = inputs.iter().map(|(l, _)| l.num_batches()).min().unwrap_or(0);
        let n_syncs = if cfg.sync_every > 0 { min_batches / cfg.sync_every } else { 0 };

        let shared = SyncShared {
            barrier: Barrier::new(eff),
            slots: Mutex::new((0..eff).map(|_| None).collect()),
            avg: Mutex::new(Vec::new()),
        };

        // ---- run the epoch on real threads -----------------------------
        let epoch_base = base_step;
        let reports: Vec<(usize, anyhow::Result<WorkerReport>)> =
            std::thread::scope(|scope| {
                let shared = &shared;
                let mut handles = Vec::with_capacity(eff);
                for (j, ((slot, (replica, wsampler)), (loader, wrng))) in replicas
                    .iter_mut()
                    .zip(worker_samplers.iter_mut())
                    .enumerate()
                    .filter(|(slot, _)| active.contains(slot))
                    .zip(inputs.into_iter())
                    .enumerate()
                {
                    handles.push((
                        slot,
                        scope.spawn(move || {
                            run_worker(
                                cfg,
                                train_ds,
                                epoch,
                                j,
                                slot,
                                eff,
                                epoch_base,
                                total_steps,
                                n_syncs,
                                shared,
                                replica.as_mut(),
                                wsampler.as_mut(),
                                loader,
                                wrng,
                            )
                        }),
                    ));
                }
                handles
                    .into_iter()
                    .map(|(slot, h)| {
                        let r = h.join().unwrap_or_else(|_| {
                            Err(anyhow::anyhow!("threaded worker panicked"))
                        });
                        (slot, r)
                    })
                    .collect()
            });

        // ---- quarantine failed workers (degraded mode, DESIGN.md §12) --
        // A failed worker's report (observations, parameters, accounting)
        // is dropped whole; the run continues on the survivors, and the
        // lost shard re-enters via next epoch's re-sharding.
        let mut ok_reports: Vec<(usize, WorkerReport)> = Vec::with_capacity(eff);
        for (slot, res) in reports {
            match res {
                Ok(r) => ok_reports.push((slot, r)),
                Err(e) => {
                    alive[slot] = false;
                    if crate::obs::counters_on() {
                        crate::obs::registry().counter("worker.lost").add(1);
                    }
                    emit_into(
                        &mut events,
                        Event::WorkerLost { epoch, worker: slot, error: format!("{e:#}") },
                    );
                }
            }
        }
        anyhow::ensure!(
            !ok_reports.is_empty(),
            "all {eff} threaded workers failed at epoch {epoch}"
        );

        // ---- reduce worker accounting ----------------------------------
        // Workers ran concurrently: merge their phase times at 1/eff so
        // totals stay wall-clock-equivalent under ideal scaling.
        let mut epoch_loss_sum = 0.0f64;
        let mut epoch_loss_cnt = 0u64;
        for (_, r) in &ok_reports {
            timers.merge_scaled(&r.timers, 1.0 / eff as f64);
            stats.accumulate(&r.stats);
            for (t, &c) in class_bp_counts.iter_mut().zip(&r.class_bp_counts) {
                *t += c;
            }
            epoch_loss_sum += r.loss_sum;
            epoch_loss_cnt += r.loss_cnt;
            base_step += r.stats.steps as usize;
        }

        // ---- §D.5 sync round: tables + parameters ----------------------
        timers.time(phase::SYNC, || -> anyhow::Result<()> {
            // All-gather shard observation logs in worker-slot order: the
            // canonical gets every surviving log, every live replica
            // (idle ones included) gets every live peer's (its own is
            // already applied). Quarantined samplers are skipped — their
            // tables are never read again.
            for (slot, r) in &ok_reports {
                canonical.merge_observations(&r.observations, epoch);
                for (v, ws) in worker_samplers.iter_mut().enumerate() {
                    if alive[v] && v != *slot {
                        ws.merge_observations(&r.observations, epoch);
                    }
                }
            }
            // Average the SURVIVING replicas' parameters, install into
            // every live replica and the main runtime for eval. Snapshots
            // land in the run-owned reusable buffers — no per-round Vec
            // cloning.
            for (k, (slot, _)) in ok_reports.iter().enumerate() {
                replicas[*slot].read_params_into(&mut snap_bufs[k])?;
            }
            mean_params_into(&mut avg_buf, snap_bufs[..ok_reports.len()].iter());
            for (v, replica) in replicas.iter_mut().enumerate() {
                if alive[v] {
                    replica.set_params(&avg_buf)?;
                }
            }
            rt.set_params(&avg_buf)?;
            Ok(())
        })?;
        emit_into(&mut events, Event::SyncRound { epoch, workers: ok_reports.len() });

        let epoch_mean = if epoch_loss_cnt > 0 {
            epoch_loss_sum / epoch_loss_cnt as f64
        } else {
            f64::NAN
        };
        loss_curve.push(epoch_mean);

        // ---- eval ------------------------------------------------------
        let at_eval_point = cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0;
        if at_eval_point || epoch + 1 == cfg.epochs {
            let s = timers.time(phase::EVAL, || evaluate(rt, data))?;
            eval_curve.push((epoch, s.loss, s.accuracy));
            bp_at_eval.push(stats.bp_samples);
            emit_into(
                &mut events,
                Event::EvalDone {
                    epoch,
                    loss: s.loss,
                    accuracy: s.accuracy,
                    bp_samples: stats.bp_samples,
                },
            );
        }
        emit_into(&mut events, Event::EpochEnd { epoch, mean_train_loss: epoch_mean });
    }

    emit_into(
        &mut events,
        Event::RunEnd {
            steps: stats.steps,
            accuracy: eval_curve.last().map(|&(_, _, a)| a).unwrap_or(f64::NAN),
        },
    );

    Ok(assemble_result(
        cfg,
        canonical.name(),
        rt,
        &timers,
        &stats,
        loss_curve,
        eval_curve,
        bp_at_eval,
        class_bp_counts,
    ))
}

/// One worker's epoch: step the shard, rendezvous at each scheduled sync.
///
/// `w` is the epoch rank (barrier slot, RNG fork tag, step interleave);
/// `slot` is the stable worker-slot id used for fault scoping and the
/// degraded-mode quarantine — the two coincide until a lower slot is
/// lost.
///
/// Failures do not abort the barrier schedule — panics are caught and
/// demoted to errors, and a failed worker keeps publishing its (stale)
/// parameters at every remaining sync so peers never deadlock; the error
/// surfaces after the epoch joins.
#[allow(clippy::too_many_arguments)]
fn run_worker(
    cfg: &RunConfig,
    train_ds: &crate::data::TensorDataset,
    epoch: usize,
    w: usize,
    slot: usize,
    eff_workers: usize,
    epoch_base: usize,
    total_steps: usize,
    n_syncs: usize,
    shared: &SyncShared,
    replica: &mut dyn ModelRuntime,
    wsampler: &mut dyn Sampler,
    mut loader: EpochLoader,
    mut wrng: Pcg64,
) -> anyhow::Result<WorkerReport> {
    let mut pipeline = StepPipeline::new(train_ds.classes);
    let mut timers = PhaseTimers::new();
    let mut loss_sum = 0.0f64;
    let mut loss_cnt = 0u64;
    let mut meta = Vec::new();
    let mut local_step = 0usize;
    let mut first_err: Option<anyhow::Error> = None;
    // Worker-owned parameter snapshot buffer, reused across sync rounds.
    let mut params_scratch = vec![0.0f32; replica.param_count()];

    for sync_round in 0..=n_syncs {
        let target = if sync_round < n_syncs {
            (sync_round + 1) * cfg.sync_every
        } else {
            usize::MAX
        };
        if first_err.is_none() {
            // Catch panics so a poisoned step cannot strand peers at the
            // barrier; the worker degrades to sync-only participation.
            let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || -> anyhow::Result<()> {
                    while local_step < target {
                        if !loader.next_batch_into(&mut meta) {
                            break;
                        }
                        // Deterministic fault injection (DESIGN.md §12):
                        // scoped by stable slot id so a chaos scenario can
                        // target one worker across epochs.
                        crate::fault::hit_worker(
                            crate::fault::sites::ENGINE_WORKER_STEP,
                            slot,
                        )?;
                        // Global-step approximation for the LR schedule:
                        // the sim interleaves workers round-robin, so
                        // local step r of worker w lands near global step
                        // r*W + w.
                        let step_idx = epoch_base + local_step * eff_workers + w;
                        let ctx = StepCtx {
                            cfg,
                            train_ds,
                            epoch,
                            lr: cfg.lr.lr_at(step_idx, total_steps) as f32,
                            // Every worker owns its pipeline (fresh per
                            // epoch), so stream 0 gives each replica its
                            // own cadence: all workers score their 1st,
                            // (k+1)th, ... eligible local step — the
                            // shared `cfg.score_every` is the §D.5
                            // cadence agreement (DESIGN.md §8.3).
                            stream: 0,
                        };
                        let mut route = ObservationRoute::Replica;
                        let step_mean = pipeline.run_step(
                            &ctx,
                            replica,
                            wsampler,
                            &meta,
                            &mut wrng,
                            &mut timers,
                            None,
                            &mut route,
                            None,
                        )?;
                        loss_sum += step_mean;
                        loss_cnt += 1;
                        local_step += 1;
                    }
                    Ok(())
                },
            ));
            match stepped {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = Some(e),
                Err(_) => {
                    first_err = Some(anyhow::anyhow!("worker {slot} panicked mid-step"));
                }
            }
        }
        if sync_round < n_syncs {
            sync_params(shared, w, replica, &mut timers, &mut params_scratch);
        }
    }

    let observations = wsampler.export_observations();
    match first_err {
        Some(e) => Err(e),
        None => Ok(WorkerReport {
            timers,
            stats: pipeline.stats.clone(),
            class_bp_counts: pipeline.class_bp_counts,
            loss_sum,
            loss_cnt,
            observations,
        }),
    }
}

/// One mid-epoch parameter-averaging rendezvous: publish → barrier →
/// leader averages → barrier → install. Always runs to completion so the
/// barrier schedule stays aligned across workers.
///
/// Allocation-free in steady state: the worker snapshots into its own
/// `scratch` buffer via `read_params_into`, MOVES the buffer into its
/// slot for the leader's reduction, and reclaims it afterwards; the
/// leader averages into the shared reusable `avg` buffer.
fn sync_params(
    shared: &SyncShared,
    w: usize,
    replica: &mut dyn ModelRuntime,
    timers: &mut PhaseTimers,
    scratch: &mut Vec<f32>,
) {
    // Delay-only injection point (the barrier schedule makes any other
    // action here a deadlock; enforced at fault-spec parse time).
    crate::fault::maybe_delay(crate::fault::sites::ENGINE_SYNC);
    let t0 = crate::util::timer::Stopwatch::start();
    let published = replica.read_params_into(scratch).is_ok();
    shared.slots.lock().unwrap()[w] =
        if published { Some(std::mem::take(scratch)) } else { None };
    let wait = shared.barrier.wait();
    if wait.is_leader() {
        let slots = shared.slots.lock().unwrap();
        let mut avg = shared.avg.lock().unwrap();
        mean_params_into(&mut avg, slots.iter().flatten());
    }
    shared.barrier.wait();
    {
        let avg = shared.avg.lock().unwrap();
        if !avg.is_empty() {
            let _ = replica.set_params(&avg);
        }
    }
    // Reclaim the published buffer so the next round allocates nothing.
    if let Some(buf) = shared.slots.lock().unwrap()[w].take() {
        *scratch = buf;
    } else if scratch.len() != replica.param_count() {
        scratch.resize(replica.param_count(), 0.0);
    }
    let elapsed = t0.elapsed();
    timers.add(phase::SYNC, elapsed);
    // Sync rounds trace per worker thread — barrier waits show up as the
    // span's width, so stragglers are visible across Perfetto tracks.
    if crate::obs::counters_on() {
        crate::obs::registry().counter("engine.sync_rounds").add(1);
        crate::obs::registry().histogram("stage.sync").record(elapsed.as_secs_f64());
    }
    crate::obs::record_elapsed("sync", "sync_round", elapsed);
}
