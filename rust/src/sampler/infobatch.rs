//! InfoBatch (Qin et al. 2024): unbiased set-level dynamic pruning.
//!
//! Per epoch: samples whose running score (last observed loss) is *below
//! the mean* are pruned with probability `r`; the survivors among them get
//! their gradients rescaled by 1/(1−r) so the expected gradient matches
//! full-data training (the method's unbiasedness trick). The final
//! `anneal_frac` of epochs trains on the full set (the paper's δ).
//!
//! Scores update from training-step losses — InfoBatch performs no extra
//! forward pass (set-level only; "# of samples for BP" = (1−r) in Tab. 1).

use super::{Sampler, Selection, ShardLog, ShardObservations};
use crate::util::Pcg64;

pub struct InfoBatch {
    prune_ratio: f64,
    /// Selection is active for epochs < active_end (then annealed).
    active_end: usize,
    /// Running score: last observed loss; NaN = never seen (kept + no rescale).
    score: Vec<f32>,
    /// Rescale factor to apply to each sample's next gradient contribution.
    rescale: Vec<f32>,
    /// Applied-observation buffer for worker-replica mode (§D.5 sync).
    shard_log: ShardLog,
}

impl InfoBatch {
    pub fn new(n: usize, epochs: usize, prune_ratio: f64, anneal_frac: f64) -> Self {
        assert!((0.0..1.0).contains(&prune_ratio));
        let anneal_epochs = (epochs as f64 * anneal_frac).ceil() as usize;
        InfoBatch {
            prune_ratio,
            active_end: epochs.saturating_sub(anneal_epochs),
            score: vec![f32::NAN; n],
            rescale: vec![1.0; n],
            shard_log: ShardLog::default(),
        }
    }

    fn mean_score(&self) -> f32 {
        let mut sum = 0.0f64;
        let mut cnt = 0usize;
        for &s in &self.score {
            if s.is_finite() {
                sum += s as f64;
                cnt += 1;
            }
        }
        if cnt == 0 {
            f32::INFINITY // nothing seen yet => nobody is "below mean"
        } else {
            (sum / cnt as f64) as f32
        }
    }
}

impl Sampler for InfoBatch {
    fn name(&self) -> &'static str {
        "infobatch"
    }

    fn n(&self) -> usize {
        self.score.len()
    }

    fn on_epoch_start(&mut self, epoch: usize, rng: &mut Pcg64) -> Vec<u32> {
        let n = self.n();
        self.rescale.iter_mut().for_each(|r| *r = 1.0);
        if epoch >= self.active_end {
            return (0..n as u32).collect();
        }
        let mean = self.mean_score();
        let mut kept = Vec::with_capacity(n);
        for i in 0..n {
            let below = self.score[i].is_finite() && self.score[i] < mean;
            if below {
                if rng.f64() < self.prune_ratio {
                    continue; // pruned this epoch
                }
                // Survivor below the mean: rescale to stay unbiased.
                self.rescale[i] = (1.0 / (1.0 - self.prune_ratio)) as f32;
            }
            kept.push(i as u32);
        }
        if kept.is_empty() {
            // Pathological (r≈1 with all-below-mean): keep everything.
            return (0..n as u32).collect();
        }
        kept
    }

    fn observe_train(&mut self, indices: &[u32], losses: &[f32], _epoch: usize) {
        self.shard_log.record(indices, losses);
        for (&i, &l) in indices.iter().zip(losses) {
            self.score[i as usize] = l;
        }
    }

    fn select(&mut self, meta: &[u32], _mini: usize, _epoch: usize, _rng: &mut Pcg64) -> Selection {
        // Set-level only: BP on the whole meta-batch with rescale weights.
        let weights = meta.iter().map(|&i| self.rescale[i as usize]).collect();
        Selection { indices: meta.to_vec(), weights }
    }

    fn begin_shard(&mut self, _shard: &[u32]) {
        self.shard_log.begin();
    }

    fn export_observations(&mut self) -> ShardObservations {
        self.shard_log.export()
    }

    fn merge_observations(&mut self, obs: &[(Vec<u32>, Vec<f32>)], _epoch: usize) {
        // Last-loss score table: apply directly, skipping the local log so
        // merged peer state is not re-exported.
        for (indices, losses) in obs {
            for (&i, &l) in indices.iter().zip(losses) {
                self.score[i as usize] = l;
            }
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_epoch_keeps_all_unseen() {
        let mut ib = InfoBatch::new(32, 10, 0.5, 0.125);
        let kept = ib.on_epoch_start(0, &mut Pcg64::new(0));
        assert_eq!(kept.len(), 32, "no scores yet -> nothing below mean");
    }

    #[test]
    fn prunes_below_mean_at_rate_r() {
        let mut ib = InfoBatch::new(1000, 10, 0.5, 0.0);
        let idx: Vec<u32> = (0..1000).collect();
        // Half the samples at loss 0.1 (below), half at 10.0 (above mean 5.05).
        let losses: Vec<f32> = (0..1000).map(|i| if i < 500 { 0.1 } else { 10.0 }).collect();
        ib.observe_train(&idx, &losses, 0);
        let kept = ib.on_epoch_start(1, &mut Pcg64::new(1));
        let below_kept = kept.iter().filter(|&&i| i < 500).count();
        let above_kept = kept.iter().filter(|&&i| i >= 500).count();
        assert_eq!(above_kept, 500, "above-mean never pruned");
        let rate = below_kept as f64 / 500.0;
        assert!((rate - 0.5).abs() < 0.08, "kept rate={rate}");
    }

    #[test]
    fn survivors_below_mean_get_rescaled() {
        let mut ib = InfoBatch::new(100, 10, 0.5, 0.0);
        let idx: Vec<u32> = (0..100).collect();
        let losses: Vec<f32> = (0..100).map(|i| if i < 50 { 0.1 } else { 10.0 }).collect();
        ib.observe_train(&idx, &losses, 0);
        let kept = ib.on_epoch_start(1, &mut Pcg64::new(2));
        let sel = ib.select(&kept, kept.len(), 1, &mut Pcg64::new(3));
        for (pos, &i) in sel.indices.iter().enumerate() {
            if i < 50 {
                assert!((sel.weights[pos] - 2.0).abs() < 1e-6, "below-mean survivor w=2");
            } else {
                assert_eq!(sel.weights[pos], 1.0, "above-mean w=1");
            }
        }
    }

    #[test]
    fn annealing_tail_trains_full_set() {
        // epochs=8, anneal=0.125 -> last epoch (7) is annealed.
        let mut ib = InfoBatch::new(50, 8, 0.5, 0.125);
        let idx: Vec<u32> = (0..50).collect();
        let losses: Vec<f32> = (0..50).map(|i| if i < 25 { 0.1 } else { 10.0 }).collect();
        ib.observe_train(&idx, &losses, 0);
        assert!(ib.on_epoch_start(6, &mut Pcg64::new(4)).len() < 50);
        assert_eq!(ib.on_epoch_start(7, &mut Pcg64::new(4)).len(), 50);
    }

    #[test]
    fn no_extra_forward_pass_needed() {
        let ib = InfoBatch::new(10, 10, 0.5, 0.0);
        assert!(!ib.needs_meta_losses(3));
    }

    #[test]
    fn expected_gradient_mass_preserved() {
        // Sum of selection weights over many epochs ≈ n per epoch
        // (the unbiasedness property, in expectation).
        let mut ib = InfoBatch::new(400, 10, 0.5, 0.0);
        let idx: Vec<u32> = (0..400).collect();
        let losses: Vec<f32> = (0..400).map(|i| (i % 20) as f32 / 10.0).collect();
        ib.observe_train(&idx, &losses, 0);
        let mut total = 0.0f64;
        let trials = 200;
        let mut rng = Pcg64::new(5);
        for _ in 0..trials {
            let kept = ib.on_epoch_start(1, &mut rng);
            let sel = ib.select(&kept, kept.len(), 1, &mut rng);
            total += sel.weights.iter().map(|&w| w as f64).sum::<f64>();
        }
        let per_epoch = total / trials as f64;
        assert!((per_epoch - 400.0).abs() < 12.0, "mass={per_epoch}");
    }
}
