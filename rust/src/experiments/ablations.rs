//! Tab. 6 / 7 / 8 ablations:
//!   6 — loss differences + annealing (Loss / NonDif / Dif × ±A),
//!   7 — pruning strategies (Baseline / Random / ES / ESWP) on NLU,
//!   8 — annealing-ratio sweep.
//! Paper shape: "Dif" (β1≠β2) beats "NonDif" (β1=β2) consistently;
//! annealing helps; random pruning is strictly worse than ESWP.

use crate::config::presets::{tab6, tab7, tab8, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;

use super::{make_runtime, mean_acc, run_config, total_cost, trials};

pub fn run_tab6(scale: Scale) -> anyhow::Result<()> {
    let rows = tab6(scale);
    let rec = Recorder::new("tab6_differences")?;
    let n_trials = trials(scale);
    table_header("Table 6 — loss differences & annealing", &["variant", "acc%"]);
    let mut rt = make_runtime(&rows[0].1)?;
    for (label, cfg) in &rows {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        println!("{label:<12} | {:5.1}", mean_acc(&rs));
    }
    Ok(())
}

pub fn run_tab7(scale: Scale) -> anyhow::Result<()> {
    let rows = tab7(scale);
    let rec = Recorder::new("tab7_pruning")?;
    let n_trials = trials(scale);
    table_header("Table 7 — pruning strategies", &["task", "method", "acc%", "time saved"]);
    let mut rt = make_runtime(&rows[0].2)?;
    let mut base: Option<(f64, crate::coordinator::CostSummary)> = None;
    let mut current_task = String::new();
    for (task, label, cfg) in &rows {
        if *task != current_task {
            current_task = task.clone();
            base = None;
        }
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        let acc = mean_acc(&rs);
        let cost = total_cost(&rs);
        match &base {
            None => {
                println!("{task:<6} | {label:<9} | {acc:5.1}       | —");
                base = Some((acc, cost));
            }
            Some((bacc, bcost)) => {
                println!(
                    "{task:<6} | {label:<9} | {} | {}",
                    super::fmt_acc(acc, *bacc),
                    super::fmt_saved(bcost, &cost)
                );
            }
        }
    }
    Ok(())
}

pub fn run_tab8(scale: Scale) -> anyhow::Result<()> {
    let rows = tab8(scale);
    let rec = Recorder::new("tab8_annealing")?;
    let n_trials = trials(scale);
    table_header("Table 8 — annealing ratio", &["ar", "acc%"]);
    let mut rt = make_runtime(&rows[0].1)?;
    for (ar, cfg) in &rows {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        println!("{ar:5.3} | {:5.2}", mean_acc(&rs));
    }
    Ok(())
}
