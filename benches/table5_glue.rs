//! Regenerates paper Table 5 (GLUE fine-tuning, 8 synthetic NLU tasks).
fn main() {
    evosample::experiments::table5::run(evosample::config::presets::Scale::from_env())
        .expect("table5");
}
