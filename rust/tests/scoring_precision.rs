//! Reduced-precision scoring tests (DESIGN.md §9): the bf16 ranked
//! forward (`loss_fwd_ranked`) must be a faithful *ranking* surrogate
//! for the exact scoring FP — selection built on it agrees with the
//! exact selection on ≥99% of indices across random ragged shapes —
//! while staying run-to-run deterministic, and a full bf16 session must
//! train, learn, and keep the exact FP/BP accounting (precision changes
//! loss *values*, never the schedule).

use evosample::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig, ScoringPrecision};
use evosample::prelude::SessionBuilder;
use evosample::runtime::native::NativeRuntime;
use evosample::runtime::{BatchX, ModelRuntime};
use evosample::util::Pcg64;

/// Rank descending by loss, tie-break ascending by index (the
/// deterministic order a ranked sampler consumes), keep the top q.
fn top_q(losses: &[f32], q: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..losses.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        losses[b as usize]
            .partial_cmp(&losses[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    idx.truncate(q);
    idx.sort_unstable();
    idx
}

fn overlap(a: &[u32], b: &[u32]) -> usize {
    // Both sorted ascending.
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                k += 1;
                i += 1;
                j += 1;
            }
        }
    }
    k
}

/// The selection-agreement property: over random ragged shapes with a
/// wide difficulty spread (per-sample input scales span 16x, as pruned
/// real batches do), top-quartile selection from bf16 losses matches
/// top-quartile selection from exact losses on at least 99% of indices
/// in aggregate. Disagreements are only ever boundary swaps between
/// near-tied samples, so each shape also has a hard per-shape floor.
#[test]
fn bf16_selection_agrees_with_exact_on_99_percent_of_indices() {
    let mut selected_total = 0usize;
    let mut agreed_total = 0usize;
    for seed in 0..24u64 {
        let mut rng = Pcg64::new(1000 + seed);
        let d = 32 + rng.int_in(0, 269) as usize;
        let h = 8 + rng.int_in(0, 41) as usize;
        let c = 2 + rng.int_in(0, 9) as usize;
        let n = 96 + rng.int_in(0, 161) as usize;
        let q = n / 4;

        let mut rt = NativeRuntime::new(d, h, c);
        rt.init(seed as i32).unwrap();

        let mut x = vec![0.0f32; n * d];
        for row in x.chunks_mut(d) {
            // Per-sample scale in [2^-2, 2^2]: spreads the loss
            // distribution the way mixed-difficulty data does.
            let scale = (2.0f32).powf(rng.f32() * 4.0 - 2.0);
            for v in row.iter_mut() {
                *v = rng.normal() * scale;
            }
        }
        let y: Vec<i32> = (0..n).map(|_| rng.int_in(0, c as i64) as i32).collect();

        let exact = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
        let mut ranked = Vec::new();
        rt.loss_fwd_ranked(BatchX::F32(&x), &y, n, &mut ranked).unwrap();
        assert_eq!(ranked.len(), n);

        let sel_exact = top_q(&exact, q);
        let sel_bf16 = top_q(&ranked, q);
        let k = overlap(&sel_exact, &sel_bf16);
        assert!(
            k * 100 >= q * 90,
            "seed {seed} (d={d} h={h} c={c} n={n}): only {k}/{q} agree — \
             bf16 ranking is broken, not merely boundary-noisy"
        );
        selected_total += q;
        agreed_total += k;
    }
    assert!(
        agreed_total * 100 >= selected_total * 99,
        "aggregate agreement {agreed_total}/{selected_total} below 99%"
    );
}

/// Ranked losses are a pure function of (params, batch): two runtimes
/// with the same init and data produce bit-identical bf16 scores, and
/// the induced selection is identical — run-to-run determinism survives
/// the precision reduction.
#[test]
fn bf16_ranking_is_run_to_run_deterministic() {
    let (d, h, c, n) = (257usize, 24usize, 6usize, 128usize);
    let mut rng = Pcg64::new(9);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.int_in(0, c as i64) as i32).collect();

    let run = |threads: usize| {
        let mut rt = NativeRuntime::new(d, h, c).with_kernel_threads(threads);
        rt.init(4).unwrap();
        let mut out = Vec::new();
        rt.loss_fwd_ranked(BatchX::F32(&x), &y, n, &mut out).unwrap();
        out
    };
    let a = run(1);
    let b = run(1);
    assert_eq!(a, b, "fresh identical runtimes must score identically");
    for t in [2usize, 4] {
        assert_eq!(a, run(t), "bf16 scores diverged at {t} kernel threads");
    }
    assert_eq!(top_q(&a, n / 4), top_q(&b, n / 4));
}

fn session_cfg(precision: ScoringPrecision) -> RunConfig {
    let ds = DatasetConfig::SynthCifar { n: 256, classes: 4, label_noise: 0.05, hard_frac: 0.2 };
    let mut cfg = RunConfig::new("scoring_precision", "native", ds);
    cfg.epochs = 5;
    cfg.meta_batch = 64;
    cfg.mini_batch = 16;
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
    cfg.test_n = 128;
    cfg.seed = 21;
    cfg.sampler = SamplerConfig::es_default();
    cfg.scoring_precision = precision;
    cfg
}

fn session_run(precision: ScoringPrecision) -> evosample::coordinator::TrainResult {
    let cfg = session_cfg(precision);
    let mut rt = NativeRuntime::new(3072, 24, 4);
    SessionBuilder::from_config(cfg)
        .runtime_mut(&mut rt)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

/// End to end: a bf16-scored ES session completes, learns past 4-class
/// chance, is seed-deterministic, and its FP/BP *accounting* is
/// identical to the exact session's — the precision knob changes what
/// the scoring FP computes, never how often it runs or what gets
/// backpropagated.
#[test]
fn bf16_session_trains_deterministically_with_exact_accounting() {
    let exact = session_run(ScoringPrecision::Exact);
    let a = session_run(ScoringPrecision::Bf16);
    let b = session_run(ScoringPrecision::Bf16);

    assert_eq!(a.loss_curve, b.loss_curve, "bf16 runs must be seed-deterministic");
    assert_eq!(a.eval_curve, b.eval_curve);

    assert!(a.steps > 0);
    assert!(
        a.final_eval.accuracy > 0.3,
        "bf16-scored acc {} should beat 4-class chance",
        a.final_eval.accuracy
    );
    assert!(a.loss_curve.first().unwrap() > a.loss_curve.last().unwrap());

    assert_eq!(a.steps, exact.steps);
    assert_eq!(a.cost.fp_samples, exact.cost.fp_samples);
    assert_eq!(a.cost.fp_passes, exact.cost.fp_passes);
    assert_eq!(a.cost.bp_passes, exact.cost.bp_passes);
}

/// The builder knob reaches the engine: `scoring_precision(Bf16)` on the
/// fluent API produces the same run as the TOML/config field.
#[test]
fn builder_knob_matches_config_field() {
    let via_field = session_run(ScoringPrecision::Bf16);

    let cfg = session_cfg(ScoringPrecision::Exact);
    let mut rt = NativeRuntime::new(3072, 24, 4);
    let via_builder = SessionBuilder::from_config(cfg)
        .scoring_precision(ScoringPrecision::Bf16)
        .runtime_mut(&mut rt)
        .build()
        .unwrap()
        .run()
        .unwrap();

    assert_eq!(via_field.loss_curve, via_builder.loss_curve);
    assert_eq!(via_field.eval_curve, via_builder.eval_curve);
}
