//! End-to-end serve-service integration: concurrent jobs behind the
//! JSONL-over-TCP protocol, shared kernel budget, admission control,
//! and kill-then-restart checkpoint resume.
//!
//! The load-bearing claims (ISSUE acceptance criteria):
//!
//! * ≥ 4 jobs over a 2-slot concurrency limit on one shared kernel
//!   budget all complete, each bit-identical to a standalone
//!   `Session::run()` of the same config.
//! * Queue / admission events (`queued`, `admitted`, `rejected`) are
//!   observable over the socket.
//! * A `shutdown abort` parks an in-flight job with its checkpoint; a
//!   fresh server on the same state dir resumes it and finishes with
//!   exactly the standalone result (accounting included).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use evosample::config::{Doc, RunConfig, ServeConfig};
use evosample::prelude::*;
use evosample::serve::{Server, ServerHandle};
use evosample::util::json::{obj, s as jstr, Json};

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("evosample_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn start_server(dir: &Path, max_concurrent: usize, max_queue: usize, ckpt: usize) -> ServerHandle {
    Server::start(ServeConfig {
        port: 0, // ephemeral; the handle reports the bound address
        max_concurrent,
        max_queue,
        kernel_budget: 2, // deliberately scarce: all jobs share 2 lanes
        state_dir: dir.to_string_lossy().into_owned(),
        checkpoint_every: ckpt,
        ..ServeConfig::default()
    })
    .unwrap()
}

fn job_toml(name: &str, seed: u64, epochs: usize, sampler: &str) -> String {
    format!(
        "[run]\nmodel = \"native\"\nname = \"{name}\"\nepochs = {epochs}\n\
         meta_batch = 32\nmini_batch = 8\ntest_n = 64\nseed = {seed}\neval_every = 1\n\n\
         [dataset]\nkind = \"synth_cifar\"\nn = 192\nclasses = 4\n\n\
         [sampler]\nkind = \"{sampler}\"\n\n\
         [lr]\nschedule = \"const\"\nlr = 0.02\n"
    )
}

/// One request, one response line, over a fresh connection.
fn request(addr: SocketAddr, req: &Json) -> Json {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(req.to_string_compact().as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

fn submit(addr: SocketAddr, toml: &str, job_id: &str) -> Json {
    let req = obj(vec![
        ("cmd", jstr("submit")),
        ("config", jstr(toml)),
        ("job_id", jstr(job_id)),
    ]);
    request(addr, &req)
}

/// Stream a job's events until the server sends the final `ok` line
/// (which only happens once the job reaches a terminal/parked state).
fn stream_events(addr: SocketAddr, job: &str) -> Vec<Json> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let req = obj(vec![("cmd", jstr("events")), ("job", jstr(job))]);
    conn.write_all(req.to_string_compact().as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let reader = BufReader::new(conn);
    let mut out = Vec::new();
    for line in reader.lines() {
        let j = Json::parse(line.unwrap().trim()).unwrap();
        let done = j.get("ok").is_some();
        out.push(j);
        if done {
            break;
        }
    }
    out
}

fn event_names(events: &[Json]) -> Vec<String> {
    events
        .iter()
        .filter_map(|e| e.get("event").and_then(Json::as_str).map(str::to_string))
        .collect()
}

/// The same config run through the public session API, standalone.
fn standalone(toml: &str) -> RunResult {
    let cfg = RunConfig::from_doc(&Doc::parse(toml).unwrap()).unwrap();
    let rt = evosample::runtime::make_runtime(&cfg).unwrap();
    SessionBuilder::from_config(cfg).runtime(rt).build().unwrap().run().unwrap()
}

/// Served results are compared field-by-field against the standalone
/// run. Wall-clock fields are excluded; everything deterministic must
/// match exactly (f64 JSON round-trips are lossless).
fn assert_matches_standalone(result: &Json, reference: &RunResult, tag: &str) {
    let f = |k: &str| result.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    assert_eq!(f("accuracy_pct"), reference.accuracy_pct(), "{tag}: accuracy");
    assert_eq!(f("eval_loss"), reference.final_eval.loss, "{tag}: eval loss");
    assert_eq!(f("steps") as u64, reference.steps, "{tag}: steps");
    assert_eq!(f("fp_passes") as u64, reference.cost.fp_passes, "{tag}: fp_passes");
    assert_eq!(f("bp_samples") as u64, reference.cost.bp_samples, "{tag}: bp_samples");
    let served_curve: Vec<f64> = result
        .get("loss_curve")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_f64)
        .collect();
    assert_eq!(served_curve, reference.loss_curve, "{tag}: loss curve must be bit-identical");
}

#[test]
fn four_jobs_two_slots_bit_identical_and_observable() {
    let dir = fresh_dir("fleet");
    let handle = start_server(&dir, 2, 16, 0);
    let addr = handle.addr();

    let jobs: Vec<(String, String)> = (0..4)
        .map(|i| {
            let sampler = if i % 2 == 0 { "es" } else { "baseline" };
            let id = format!("fleet{i}");
            (id.clone(), job_toml(&id, 100 + i, 3, sampler))
        })
        .collect();
    for (id, toml) in &jobs {
        let resp = submit(addr, toml, id);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{id}: {resp:?}");
        assert_eq!(resp.get("state").and_then(Json::as_str), Some("queued"));
    }

    for (id, toml) in &jobs {
        let events = stream_events(addr, id);
        let names = event_names(&events);
        // Queue/admission milestones are observable over the socket…
        assert!(names.contains(&"queued".to_string()), "{id}: {names:?}");
        assert!(names.contains(&"admitted".to_string()), "{id}: {names:?}");
        // …as is the engine's own stream, bridged through the job.
        assert!(names.contains(&"run_start".to_string()), "{id}: {names:?}");
        assert!(names.contains(&"run_end".to_string()), "{id}: {names:?}");
        let result = events
            .iter()
            .find(|e| e.get("event").and_then(Json::as_str) == Some("result"))
            .unwrap_or_else(|| panic!("{id}: no result event in {names:?}"));
        assert_matches_standalone(result, &standalone(toml), id);
    }

    // Per-job accounting lands in status.
    let status = request(addr, &obj(vec![("cmd", jstr("status")), ("job", jstr("fleet0"))]));
    let job0 = &status.get("jobs").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(job0.get("state").and_then(Json::as_str), Some("done"));
    assert_eq!(job0.get("epochs_done").and_then(Json::as_f64), Some(3.0));
    assert!(job0.get("fp_passes").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(job0.get("wall_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(job0.get("queue_s").and_then(Json::as_f64).unwrap() >= 0.0);

    // Aggregate status reports the shared budget.
    let status = request(addr, &obj(vec![("cmd", jstr("status"))]));
    assert_eq!(status.get("kernel_budget").and_then(Json::as_f64), Some(2.0));
    assert_eq!(status.get("jobs").and_then(Json::as_arr).unwrap().len(), 4);

    handle.shutdown(false);
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn admission_control_sheds_over_quota_submissions() {
    let dir = fresh_dir("quota");
    let handle = start_server(&dir, 1, 1, 0);
    let addr = handle.addr();

    // Fill the single run slot with a deliberately long job, so the
    // admission assertions below can't race its completion…
    let toml_a = job_toml("quota_a", 7, 30, "es");
    assert_eq!(submit(addr, &toml_a, "qa").get("ok"), Some(&Json::Bool(true)));
    // …wait until it is admitted (the queue is empty again)…
    let mut conn = TcpStream::connect(addr).unwrap();
    let req = obj(vec![("cmd", jstr("events")), ("job", jstr("qa"))]);
    conn.write_all(req.to_string_compact().as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(conn);
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stream ended before admission");
        let j = Json::parse(line.trim()).unwrap();
        if j.get("event").and_then(Json::as_str) == Some("admitted") {
            break;
        }
    }
    // …fill the one queue slot…
    let toml_b = job_toml("quota_b", 8, 2, "baseline");
    assert_eq!(submit(addr, &toml_b, "qb").get("ok"), Some(&Json::Bool(true)));
    // …and watch the next submission get shed, explicitly.
    let resp = submit(addr, &job_toml("quota_c", 9, 2, "baseline"), "qc");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
    assert_eq!(resp.get("rejected"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(resp.get("reason").and_then(Json::as_str), Some("queue_full"));
    // Duplicate ids are shed too, with their own reason.
    let resp = submit(addr, &toml_a, "qa");
    assert_eq!(resp.get("reason").and_then(Json::as_str), Some("duplicate_id"));

    // Cancelling the queued job frees it without running it.
    let resp = request(addr, &obj(vec![("cmd", jstr("cancel")), ("job", jstr("qb"))]));
    assert_eq!(resp.get("state").and_then(Json::as_str), Some("cancelled"));

    // Drain shutdown finishes the running job, then stops cleanly.
    let resp = request(addr, &obj(vec![("cmd", jstr("shutdown"))]));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    handle.wait();
    assert_eq!(record_json(&dir, "qa").get("state").and_then(Json::as_str), Some("done"));
    assert_eq!(record_json(&dir, "qb").get("state").and_then(Json::as_str), Some("cancelled"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Read a job's durable record back (post-shutdown assertions).
fn record_json(dir: &Path, id: &str) -> Json {
    let src = std::fs::read_to_string(dir.join(format!("{id}.job.json"))).unwrap();
    Json::parse(&src).unwrap()
}

#[test]
fn metrics_verb_reports_queue_kernel_and_selection_health() {
    let dir = fresh_dir("metrics");
    let handle = start_server(&dir, 2, 8, 0);
    let addr = handle.addr();

    let toml = job_toml("metrics_job", 31, 3, "es");
    assert_eq!(submit(addr, &toml, "mj").get("ok"), Some(&Json::Bool(true)));
    let events = stream_events(addr, "mj");
    assert!(event_names(&events).contains(&"run_end".to_string()));

    // One scrape carries the queue section, the shared kernel budget,
    // and the live process obs registry (the serve bootstrap raises the
    // telemetry level to counters, so the snapshot is never empty).
    let resp = request(addr, &obj(vec![("cmd", jstr("metrics"))]));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    let global = resp.get("global").unwrap();
    let queue = global.get("queue").unwrap();
    assert_eq!(queue.get("pending").and_then(Json::as_f64), Some(0.0));
    assert!(queue.get("running").and_then(Json::as_f64).is_some());
    let kernel = global.get("kernel").unwrap();
    assert_eq!(kernel.get("budget").and_then(Json::as_f64), Some(2.0));
    assert!(kernel.get("in_use").and_then(Json::as_f64).unwrap() >= 0.0);
    let obs = global.get("obs").unwrap();
    let level = obs.get("telemetry").and_then(Json::as_str).unwrap();
    assert_ne!(level, "off", "serve must raise the telemetry level");
    let counters = obs.get("metrics").and_then(|m| m.get("counters")).unwrap();
    assert!(
        counters.get("serve.submitted").and_then(Json::as_f64).unwrap() >= 1.0,
        "{counters:?}"
    );
    assert!(
        counters.get("engine.steps").and_then(Json::as_f64).unwrap() > 0.0,
        "{counters:?}"
    );

    // Per-job selection health: the scheduler feeds each epoch-start
    // keep rate into the job record the metrics verb returns.
    let jobs = resp.get("jobs").and_then(Json::as_arr).unwrap();
    let job = jobs
        .iter()
        .find(|j| j.get("job").and_then(Json::as_str) == Some("mj"))
        .unwrap_or_else(|| panic!("mj missing from {jobs:?}"));
    assert_eq!(job.get("state").and_then(Json::as_str), Some("done"));
    let keep = job.get("keep_rate_pct").and_then(Json::as_f64).unwrap();
    assert!(keep > 0.0 && keep <= 100.0, "keep rate {keep}");
    assert!(job.get("fp_passes").and_then(Json::as_f64).unwrap() > 0.0);

    // The job filter narrows the response; unknown ids are an error,
    // not an empty list.
    let one = request(addr, &obj(vec![("cmd", jstr("metrics")), ("job", jstr("mj"))]));
    assert_eq!(one.get("jobs").and_then(Json::as_arr).unwrap().len(), 1);
    let bad = request(addr, &obj(vec![("cmd", jstr("metrics")), ("job", jstr("nope"))]));
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{bad:?}");

    handle.shutdown(false);
    handle.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn terminal_job_accounting_survives_server_restart() {
    let dir = fresh_dir("terminal_acct");
    let life1 = start_server(&dir, 1, 4, 0);
    let addr = life1.addr();
    let toml = job_toml("acct_job", 33, 2, "baseline");
    assert_eq!(submit(addr, &toml, "aj").get("ok"), Some(&Json::Bool(true)));
    let events = stream_events(addr, "aj");
    assert!(event_names(&events).contains(&"run_end".to_string()));
    life1.shutdown(false);
    life1.wait();

    // The durable record carries the finished job's full accounting…
    let rec = record_json(&dir, "aj");
    assert_eq!(rec.get("state").and_then(Json::as_str), Some("done"));
    let wall = rec.get("wall_s").and_then(Json::as_f64).unwrap();
    assert!(wall > 0.0, "finished job must have nonzero wall: {rec:?}");

    // …and a fresh server life reports exactly those numbers in
    // `status`, not zeros (the rescan restores timing, counters, and
    // outcome — f64 JSON round-trips are lossless).
    let life2 = start_server(&dir, 1, 4, 0);
    let status =
        request(life2.addr(), &obj(vec![("cmd", jstr("status")), ("job", jstr("aj"))]));
    let job = &status.get("jobs").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(job.get("state").and_then(Json::as_str), Some("done"));
    assert_eq!(job.get("wall_s"), rec.get("wall_s"), "wall accounting lost in rescan");
    assert_eq!(job.get("queue_s"), rec.get("queue_s"));
    assert_eq!(job.get("fp_passes"), rec.get("fp_passes"));
    assert_eq!(job.get("bp_samples"), rec.get("bp_samples"));
    assert_eq!(job.get("epochs_done"), rec.get("epochs_done"));
    assert_eq!(job.get("accuracy"), rec.get("accuracy"));
    life2.shutdown(false);
    life2.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn abort_then_restart_resumes_from_checkpoint_to_identical_result() {
    let dir = fresh_dir("resume");
    let toml = job_toml("resume_job", 21, 40, "es");
    let reference = standalone(&toml);
    let toml_q = job_toml("parked_job", 22, 2, "baseline");
    let reference_q = standalone(&toml_q);

    // Life 1: run the job, interrupt it mid-flight. A second job sits
    // queued behind the single slot the whole time.
    let life1 = start_server(&dir, 1, 4, 1);
    let addr = life1.addr();
    assert_eq!(submit(addr, &toml, "rj").get("ok"), Some(&Json::Bool(true)));
    assert_eq!(submit(addr, &toml_q, "rq").get("ok"), Some(&Json::Bool(true)));
    let mut conn = TcpStream::connect(addr).unwrap();
    let req = obj(vec![("cmd", jstr("events")), ("job", jstr("rj"))]);
    conn.write_all(req.to_string_compact().as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(conn);
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stream ended before epoch 1");
        let j = Json::parse(line.trim()).unwrap();
        if j.get("event").and_then(Json::as_str) == Some("epoch_end")
            && j.get("epoch").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0
        {
            break;
        }
    }
    let resp = request(addr, &obj(vec![("cmd", jstr("shutdown")), ("mode", jstr("abort"))]));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    life1.wait();

    // The job is parked resumable, with its checkpoint on disk.
    let rec = record_json(&dir, "rj");
    assert_eq!(rec.get("state").and_then(Json::as_str), Some("interrupted"), "{rec:?}");
    let epochs_done = rec.get("epochs_done").and_then(Json::as_f64).unwrap();
    assert!(epochs_done >= 1.0 && epochs_done < 40.0, "interrupted mid-run: {epochs_done}");
    assert!(dir.join("rj.ckpt").exists(), "checkpoint retained for resume");
    // Abort parks the backlog: the queued job was never started — its
    // record still says queued and no checkpoint exists for it.
    let rec_q = record_json(&dir, "rq");
    assert_eq!(rec_q.get("state").and_then(Json::as_str), Some("queued"), "{rec_q:?}");
    assert_eq!(rec_q.get("epochs_done").and_then(Json::as_f64), Some(0.0));
    assert!(!dir.join("rq.ckpt").exists(), "queued job must not have run during abort");

    // Life 2: a fresh server on the same state dir resumes and finishes.
    let life2 = start_server(&dir, 1, 4, 1);
    let events = stream_events(life2.addr(), "rj");
    let names = event_names(&events);
    assert!(names.contains(&"requeued".to_string()), "{names:?}");
    assert!(names.contains(&"resumed".to_string()), "{names:?}");
    let resumed = events
        .iter()
        .find(|e| e.get("event").and_then(Json::as_str) == Some("resumed"))
        .unwrap();
    let from_epoch = resumed.get("from_epoch").and_then(Json::as_f64).unwrap();
    assert!(from_epoch >= 1.0, "resume continues, not restarts: {from_epoch}");
    let result = events
        .iter()
        .find(|e| e.get("event").and_then(Json::as_str) == Some("result"))
        .unwrap_or_else(|| panic!("no result event after resume: {names:?}"));

    // The resumed run's final report — curves, accuracy, and the
    // fp/bp accounting restored from the checkpoint — is exactly the
    // uninterrupted standalone run.
    assert_matches_standalone(result, &reference, "resumed");

    // The job parked queued by the abort is re-enqueued, runs from
    // scratch, and matches its standalone reference too.
    let events_q = stream_events(life2.addr(), "rq");
    let names_q = event_names(&events_q);
    assert!(names_q.contains(&"requeued".to_string()), "{names_q:?}");
    let result_q = events_q
        .iter()
        .find(|e| e.get("event").and_then(Json::as_str) == Some("result"))
        .unwrap_or_else(|| panic!("no result event for parked job: {names_q:?}"));
    assert_matches_standalone(result_q, &reference_q, "parked");

    life2.shutdown(false);
    life2.wait();
    let rec = record_json(&dir, "rj");
    assert_eq!(rec.get("state").and_then(Json::as_str), Some("done"));
    assert_eq!(record_json(&dir, "rq").get("state").and_then(Json::as_str), Some("done"));
    let _ = std::fs::remove_dir_all(&dir);
}
