//! Deterministic, seedable RNG (PCG64 DXSM) + sampling distributions.
//!
//! No external RNG crates are available offline, so the coordinator ships
//! its own generator. PCG64-DXSM is the numpy default generator family:
//! 128-bit LCG state with a double-xor-shift-multiply output permutation —
//! small, fast, and statistically solid for simulation workloads.
//!
//! Everything downstream (dataset synthesis, shuffling, weighted sampling,
//! trial seeds) flows from this type, which is what makes whole training
//! runs bit-reproducible from a single seed.

/// PCG64 DXSM generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0xda94_2042_e4dd_58b5;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream id fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xa02b_df4a_57e8_5a5a)
    }

    /// Create a generator with an explicit stream (used to give each
    /// worker in the distributed simulation an independent sequence).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: (seed as u128).wrapping_add(inc), inc };
        // Burn a few outputs so low-entropy seeds decorrelate.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Export the raw generator state `(state, inc)` for checkpointing.
    /// Restoring via [`Pcg64::from_state`] resumes the exact sequence.
    pub fn state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg64::state`] export. No burn-in:
    /// the pair already encodes an in-flight sequence position.
    pub fn from_state(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    /// Derive a child generator; deterministic function of (self, tag).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::with_stream(s, tag | 1)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // DXSM output permutation over the pre-advance state.
        let state = self.state;
        self.state = state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (state >> 64) as u64;
        let lo = (state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(PCG_MULT as u64);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller (cached second draw omitted to keep
    /// the generator state a pure function of the draw count).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Gumbel(0, 1) draw — the key ingredient of top-k weighted sampling.
    pub fn gumbel(&mut self) -> f64 {
        let u = self.f64().max(1e-300);
        -(-u.ln()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Sample k distinct indices uniformly from 0..n (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut idx: Vec<u32> = (0..n as u32).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Zipf-ish draw over [0, n): rank r with probability ∝ 1/(r+1)^a.
    /// Uses inverse-CDF over a precomputed table-free approximation
    /// (rejection sampling per Devroye).
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        debug_assert!(a > 0.0);
        if a == 1.0 {
            // Harmonic special case via inverse CDF approximation.
            let h = (n as f64).ln() + 0.5772;
            let target = self.f64() * h;
            return ((target.exp() - 1.0).max(0.0) as usize).min(n - 1);
        }
        let b = 1.0 - a;
        loop {
            let u = self.f64();
            // Inverse CDF of density ∝ x^{-a} on [1, n+1); rank = floor(x)-1.
            let x = (u * (((n + 1) as f64).powf(b) - 1.0) + 1.0).powf(1.0 / b);
            let k = (x as usize).saturating_sub(1);
            if k < n {
                return k;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_round_trip_resumes_exact_sequence() {
        let mut a = Pcg64::new(97);
        for _ in 0..37 {
            a.next_u64();
        }
        let (state, inc) = a.state();
        let mut b = Pcg64::from_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn forked_streams_decorrelate() {
        let mut root = Pcg64::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut rng = Pcg64::new(17);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.gumbel()).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Pcg64::new(23);
        for _ in 0..50 {
            let k = rng.below(64) as usize + 1;
            let picked = rng.choose_k(64, k);
            let mut s = picked.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k);
        }
    }

    #[test]
    fn choose_k_uniformity() {
        // Each of n=8 indices should appear in a k=4 draw about half the time.
        let mut rng = Pcg64::new(29);
        let mut counts = [0u32; 8];
        let trials = 20_000;
        for _ in 0..trials {
            for i in rng.choose_k(8, 4) {
                counts[i as usize] += 1;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let p = c as f64 / trials as f64;
            assert!((p - 0.5).abs() < 0.02, "idx {i}: p={p}");
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Pcg64::new(31);
        let mut counts = vec![0u32; 50];
        for _ in 0..20_000 {
            let k = rng.zipf(50, 1.2);
            assert!(k < 50);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[40]);
    }
}
