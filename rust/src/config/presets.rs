//! Experiment presets: one constructor per paper table/figure.
//!
//! Every preset exists at two scales:
//!   * `Scale::Smoke` — minutes-not-hours sizes used by default in the
//!     bench targets (`cargo bench`), preserving the workload *shape*
//!     (who wins, roughly by what factor) rather than absolute numbers.
//!   * `Scale::Full`  — the paper-faithful substitute sizes, enabled with
//!     `EVOSAMPLE_BENCH_FULL=1`.
//!
//! DESIGN.md §4 maps each preset to the table/figure it regenerates.

use super::schema::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        if std::env::var("EVOSAMPLE_BENCH_FULL").as_deref() == Ok("1") {
            Scale::Full
        } else {
            Scale::Smoke
        }
    }

    fn pick(self, smoke: usize, full: usize) -> usize {
        match self {
            Scale::Smoke => smoke,
            Scale::Full => full,
        }
    }
}

/// All eight methods compared in Tab. 2/3 (order matches the paper rows).
pub fn all_samplers() -> Vec<SamplerConfig> {
    vec![
        SamplerConfig::Uniform,
        SamplerConfig::ucb_default(),
        SamplerConfig::kakurenbo_default(),
        SamplerConfig::infobatch_default(),
        SamplerConfig::Loss,
        SamplerConfig::Ordered,
        SamplerConfig::es_default(),
        SamplerConfig::eswp_default(),
    ]
}

fn cifar(n: usize, classes: usize) -> DatasetConfig {
    DatasetConfig::SynthCifar { n, classes, label_noise: 0.05, hard_frac: 0.2 }
}

/// Tab. 2: CIFAR-scale classification, 3 workload columns.
/// Paper: R-18/CIFAR-10, R-18/CIFAR-100, R-50/CIFAR-100 (200 epochs,
/// B=128/256, b/B=25%/50%, OneCycle SGD). Substitutes per DESIGN.md §3.
pub fn table2(scale: Scale) -> Vec<RunConfig> {
    let n = scale.pick(1024, 16384);
    let epochs = scale.pick(6, 60);
    let workloads = [
        ("cifar10_small", "cnn_small_c10", 10usize, 32usize, 128usize, 0.02),
        ("cifar100_small", "cnn_small_c100", 100, 32, 128, 0.02),
        ("cifar100_deep", "cnn_deep_c100", 100, 64, 128, 0.02),
    ];
    let mut runs = Vec::new();
    for (wname, model, classes, b, bb, max_lr) in workloads {
        for s in all_samplers() {
            let mut cfg = RunConfig::new(
                &format!("table2/{wname}/{}", s.name()),
                model,
                cifar(n, classes),
            );
            cfg.epochs = epochs;
            cfg.meta_batch = bb;
            cfg.mini_batch = b;
            cfg.lr = LrSchedule::OneCycle { max_lr, warmup_frac: 0.3 };
            cfg.test_n = scale.pick(256, 2048);
            cfg.sampler = s;
            runs.push(cfg);
        }
    }
    runs
}

/// Tab. 3: full fine-tuning a large vision transformer (substitute:
/// txf_cls "pre-trained" via a warmup phase, then fine-tuned per method).
pub fn table3(scale: Scale) -> Vec<RunConfig> {
    let n = scale.pick(512, 8192);
    let epochs = scale.pick(3, 10);
    all_samplers()
        .into_iter()
        .map(|s| {
            let mut cfg = RunConfig::new(
                &format!("table3/vit_ft/{}", s.name()),
                "txf_cls",
                DatasetConfig::Nlu {
                    task: "imagenet_ft".into(),
                    n,
                    vocab: 512,
                    seq: 64,
                    classes: 16,
                },
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 64;
            cfg.mini_batch = 16;
            cfg.lr = LrSchedule::WarmupCosine { base_lr: 2e-4, warmup_frac: 0.1, min_lr: 0.0 };
            cfg.test_n = scale.pick(256, 1024);
            cfg.sampler = s;
            cfg
        })
        .collect()
}

/// Tab. 4 + Fig. 3: MAE pre-training under data-parallel simulation.
/// Rows: Baseline, InfoBatch, ESWP r=0.3, ESWP r=0.5.
pub fn table4(scale: Scale) -> Vec<RunConfig> {
    let n = scale.pick(2048, 16384);
    let epochs = scale.pick(5, 30);
    let samplers = vec![
        ("baseline", SamplerConfig::Uniform),
        ("infobatch", SamplerConfig::infobatch_default()),
        (
            "eswp_r0.3",
            SamplerConfig::Eswp { beta1: 0.2, beta2: 0.8, anneal_frac: 0.05, prune_ratio: 0.3 },
        ),
        (
            "eswp_r0.5",
            SamplerConfig::Eswp { beta1: 0.2, beta2: 0.8, anneal_frac: 0.05, prune_ratio: 0.5 },
        ),
    ];
    samplers
        .into_iter()
        .map(|(tag, s)| {
            let mut cfg = RunConfig::new(
                &format!("table4/mae/{tag}"),
                "mae_mlp",
                DatasetConfig::MaeImages { n, dim: 3072 },
            );
            cfg.epochs = epochs;
            // Paper: (B, b) = (256, 256) per GPU — no batch-level selection.
            cfg.meta_batch = 256;
            cfg.mini_batch = 256;
            cfg.workers = 4; // 4 simulated data-parallel workers
            cfg.lr = LrSchedule::WarmupCosine { base_lr: 1.5e-3, warmup_frac: 0.13, min_lr: 0.0 };
            cfg.sampler = s;
            cfg.test_n = scale.pick(256, 1024);
            cfg
        })
        .collect()
}

/// Tab. 5: the eight GLUE tasks (synthetic NLU substitutes with per-task
/// difficulty roughly matching the paper's score spread).
pub const GLUE_TASKS: [(&str, usize); 8] = [
    ("cola", 2),
    ("sst2", 2),
    ("qnli", 2),
    ("qqp", 2),
    ("mnli", 3),
    ("mrpc", 2),
    ("rte", 2),
    ("stsb", 4), // regression bucketed to 4 classes
];

pub fn table5(scale: Scale, samplers: &[SamplerConfig]) -> Vec<RunConfig> {
    let n = scale.pick(512, 8192);
    let epochs = scale.pick(3, 15);
    let mut runs = Vec::new();
    for (task, classes) in GLUE_TASKS {
        for s in samplers {
            let mut cfg = RunConfig::new(
                &format!("table5/{task}/{}", s.name()),
                "txf_nlu",
                DatasetConfig::Nlu { task: task.into(), n, vocab: 512, seq: 48, classes },
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 64;
            cfg.mini_batch = 16;
            cfg.lr = LrSchedule::Poly { base_lr: 5e-4, power: 1.0, warmup_frac: 0.1 };
            cfg.test_n = scale.pick(256, 1024);
            cfg.sampler = s.clone();
            runs.push(cfg);
        }
    }
    runs
}

/// Fig. 4 / Tab. 9: low-resource LM SFT with gradient accumulation.
/// Paper: Qwen2.5-Math-1.5B, B=32, b=8, b_micro=8, ESWP r=0.2.
pub fn fig4(scale: Scale) -> Vec<RunConfig> {
    let n = scale.pick(1024, 8192);
    let epochs = scale.pick(3, 10);
    [
        ("baseline", SamplerConfig::Uniform),
        ("eswp", SamplerConfig::Eswp { beta1: 0.2, beta2: 0.8, anneal_frac: 0.05, prune_ratio: 0.2 }),
    ]
    .into_iter()
    .map(|(tag, s)| {
        let mut cfg = RunConfig::new(
            &format!("fig4/sft/{tag}"),
            "txf_lm",
            DatasetConfig::LmCorpus { n, vocab: 1024, seq: 64 },
        );
        cfg.epochs = epochs;
        cfg.meta_batch = 32;
        cfg.mini_batch = 8;
        cfg.micro_batch = 8; // gradient accumulation granularity
        cfg.lr = LrSchedule::WarmupCosine { base_lr: 1e-4, warmup_frac: 0.1, min_lr: 0.0 };
        cfg.test_n = scale.pick(128, 512);
        cfg.sampler = s;
        cfg
    })
    .collect()
}

/// Fig. 5 (left): b/B sweep for ES on the fine-tune workload.
pub fn fig5_bb_sweep(scale: Scale) -> Vec<RunConfig> {
    let n = scale.pick(1024, 8192);
    let epochs = scale.pick(6, 30);
    let bs = [4usize, 8, 16, 32, 64, 128];
    let mut runs: Vec<RunConfig> = bs
        .iter()
        .map(|&b| {
            let mut cfg = RunConfig::new(
                &format!("fig5/bb/es_b{b}"),
                "mlp_cifar10",
                cifar(n, 10),
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 128;
            cfg.mini_batch = b;
            cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
            cfg.sampler = SamplerConfig::es_default();
            cfg.test_n = scale.pick(512, 1024);
            cfg
        })
        .collect();
    // Baseline anchor.
    let mut base = runs[0].clone();
    base.name = "fig5/bb/baseline".into();
    base.mini_batch = 128;
    base.sampler = SamplerConfig::Uniform;
    runs.insert(0, base);
    runs
}

/// Fig. 5 (right): pruning-ratio sweep for ESWP on CIFAR-100.
pub fn fig5_prune_sweep(scale: Scale) -> Vec<RunConfig> {
    let n = scale.pick(1024, 16384);
    let epochs = scale.pick(6, 40);
    let ratios = [0.0, 0.1, 0.2, 0.3, 0.5, 0.7];
    ratios
        .iter()
        .map(|&r| {
            let mut cfg = RunConfig::new(
                &format!("fig5/prune/r{r}"),
                "cnn_small_c100",
                cifar(n, 100),
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 128;
            cfg.mini_batch = 32;
            cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
            cfg.sampler = if r == 0.0 {
                SamplerConfig::es_default()
            } else {
                SamplerConfig::Eswp { beta1: 0.2, beta2: 0.8, anneal_frac: 0.05, prune_ratio: r }
            };
            cfg.test_n = scale.pick(512, 1024);
            cfg
        })
        .collect()
}

/// Fig. 6/7: (β1, β2) grid for ES. Returns (β1, β2, config) triples.
pub fn fig6_beta_grid(scale: Scale, dense: bool) -> Vec<(f32, f32, RunConfig)> {
    let n = scale.pick(1024, 8192);
    let epochs = scale.pick(5, 30);
    let (b1s, b2s): (Vec<f32>, Vec<f32>) = if dense {
        // Fig. 7: dense grid around the default (0.2, 0.9).
        (vec![0.1, 0.15, 0.2, 0.25, 0.3], vec![0.8, 0.85, 0.9, 0.95])
    } else {
        // Fig. 6: coarse sweep.
        (vec![0.0, 0.2, 0.5, 0.8, 1.0], vec![0.0, 0.5, 0.8, 0.9, 1.0])
    };
    let mut out = Vec::new();
    for &b1 in &b1s {
        for &b2 in &b2s {
            let mut cfg = RunConfig::new(
                &format!("fig6/betas/b1_{b1}_b2_{b2}"),
                "mlp_cifar10",
                cifar(n, 10),
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 128;
            cfg.mini_batch = 32;
            cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
            cfg.sampler = SamplerConfig::Es { beta1: b1, beta2: b2, anneal_frac: 0.05 };
            cfg.test_n = scale.pick(512, 1024);
            out.push((b1, b2, cfg));
        }
    }
    out
}

/// Tab. 6 ablation rows: Loss / Loss+A / NonDif+A / Dif / NonDif / Dif+A.
/// "NonDif" is β1=β2 (historical EMA only, no difference augmentation);
/// "Dif" is the full ES; "+A" adds annealing.
pub fn tab6(scale: Scale) -> Vec<(String, RunConfig)> {
    let n = scale.pick(1024, 16384);
    let epochs = scale.pick(6, 40);
    let rows: Vec<(&str, SamplerConfig)> = vec![
        ("Loss", SamplerConfig::Loss),
        ("Loss+A", SamplerConfig::Es { beta1: 0.0, beta2: 0.0, anneal_frac: 0.05 }),
        ("NonDif", SamplerConfig::Es { beta1: 0.9, beta2: 0.9, anneal_frac: 0.0 }),
        ("Dif", SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.0 }),
        ("NonDif+A", SamplerConfig::Es { beta1: 0.9, beta2: 0.9, anneal_frac: 0.05 }),
        ("Dif+A (ES)", SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.05 }),
    ];
    rows.into_iter()
        .map(|(label, s)| {
            let mut cfg = RunConfig::new(
                &format!("tab6/{label}"),
                "cnn_small_c100",
                cifar(n, 100),
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 128;
            cfg.mini_batch = 32;
            cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
            cfg.test_n = scale.pick(512, 1024);
            cfg.sampler = s;
            (label.to_string(), cfg)
        })
        .collect()
}

/// Tab. 7: pruning strategies (Baseline / Random / ES / ESWP) on NLU tasks.
pub fn tab7(scale: Scale) -> Vec<(String, String, RunConfig)> {
    let n = scale.pick(512, 8192);
    let epochs = scale.pick(3, 15);
    let rows = vec![
        ("Baseline", SamplerConfig::Uniform),
        ("Random", SamplerConfig::RandomPrune { prune_ratio: 0.2 }),
        ("ES", SamplerConfig::es_default()),
        ("ESWP", SamplerConfig::eswp_default()),
    ];
    let mut out = Vec::new();
    for task in ["cola", "sst2"] {
        for (label, s) in &rows {
            let mut cfg = RunConfig::new(
                &format!("tab7/{task}/{label}"),
                "txf_nlu",
                DatasetConfig::Nlu { task: task.into(), n, vocab: 512, seq: 48, classes: 2 },
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 64;
            cfg.mini_batch = 16;
            cfg.lr = LrSchedule::Poly { base_lr: 5e-4, power: 1.0, warmup_frac: 0.1 };
            cfg.test_n = scale.pick(256, 1024);
            cfg.sampler = s.clone();
            out.push((task.to_string(), label.to_string(), cfg));
        }
    }
    out
}

/// Tab. 8: annealing-ratio sweep for ES on CIFAR-100.
pub fn tab8(scale: Scale) -> Vec<(f64, RunConfig)> {
    let n = scale.pick(1024, 16384);
    let epochs = scale.pick(6, 40);
    [0.0, 0.05, 0.075, 0.1]
        .into_iter()
        .map(|ar| {
            let mut cfg = RunConfig::new(
                &format!("tab8/ar{ar}"),
                "cnn_small_c100",
                cifar(n, 100),
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 128;
            cfg.mini_batch = 32;
            cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
            cfg.sampler = SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: ar };
            cfg.test_n = scale.pick(512, 1024);
            (ar, cfg)
        })
        .collect()
}

/// Frequency-tuning ablation: ES on the CIFAR-dims MLP with the scoring
/// FP amortized over k ∈ {1, 2, 4, 8} steps (the paper's "flexible
/// frequency tuning"; §3.3 cost analysis + DESIGN.md §8). anneal_frac is
/// 0 so every step is scoring-eligible and the k-fold fp_samples saving
/// is exact — ⌈steps/k⌉·B.
pub fn frequency_sweep(scale: Scale) -> Vec<(usize, RunConfig)> {
    let n = scale.pick(1024, 8192);
    let epochs = scale.pick(6, 30);
    [1usize, 2, 4, 8]
        .into_iter()
        .map(|k| {
            let mut cfg = RunConfig::new(
                &format!("freq/es_k{k}"),
                "mlp_cifar10",
                cifar(n, 10),
            );
            cfg.epochs = epochs;
            cfg.meta_batch = 128;
            cfg.mini_batch = 32;
            cfg.score_every = k;
            cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
            cfg.sampler = SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.0 };
            cfg.test_n = scale.pick(512, 1024);
            (k, cfg)
        })
        .collect()
}

/// End-to-end pre-training driver (examples/end_to_end_pretrain.rs):
/// a real LM trained for a few hundred steps, ES vs Baseline.
pub fn e2e_pretrain(scale: Scale) -> Vec<RunConfig> {
    let n = scale.pick(1024, 8192);
    let epochs = scale.pick(3, 8);
    [
        ("baseline", SamplerConfig::Uniform),
        ("es", SamplerConfig::es_default()),
        ("eswp", SamplerConfig::eswp_default()),
    ]
    .into_iter()
    .map(|(tag, s)| {
        let mut cfg = RunConfig::new(
            &format!("e2e/pretrain/{tag}"),
            "txf_lm",
            DatasetConfig::LmCorpus { n, vocab: 1024, seq: 64 },
        );
        cfg.epochs = epochs;
        cfg.meta_batch = 32;
        cfg.mini_batch = 8;
        cfg.lr = LrSchedule::WarmupCosine { base_lr: 3e-4, warmup_frac: 0.1, min_lr: 3e-5 };
        cfg.test_n = scale.pick(128, 512);
        cfg.sampler = s;
        cfg
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for scale in [Scale::Smoke, Scale::Full] {
            for cfg in table2(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for cfg in table3(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for cfg in table4(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for cfg in table5(scale, &all_samplers()) {
                cfg.validate().expect(&cfg.name);
            }
            for cfg in fig4(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for cfg in fig5_bb_sweep(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for cfg in fig5_prune_sweep(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for (_, _, cfg) in fig6_beta_grid(scale, false) {
                cfg.validate().expect(&cfg.name);
            }
            for (_, cfg) in tab6(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for (_, _, cfg) in tab7(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for (_, cfg) in tab8(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for cfg in e2e_pretrain(scale) {
                cfg.validate().expect(&cfg.name);
            }
            for (_, cfg) in frequency_sweep(scale) {
                cfg.validate().expect(&cfg.name);
            }
        }
    }

    #[test]
    fn frequency_sweep_covers_k_1_2_4_8() {
        let ks: Vec<usize> = frequency_sweep(Scale::Smoke).iter().map(|&(k, _)| k).collect();
        assert_eq!(ks, vec![1, 2, 4, 8]);
        for (k, cfg) in frequency_sweep(Scale::Smoke) {
            assert_eq!(cfg.score_every, k);
            assert!(cfg.mini_batch < cfg.meta_batch, "must select for scoring to matter");
        }
    }

    #[test]
    fn table2_has_8_methods_3_workloads() {
        let runs = table2(Scale::Smoke);
        assert_eq!(runs.len(), 24);
        assert!(runs.iter().any(|r| r.name.contains("eswp")));
    }

    #[test]
    fn table4_uses_workers_and_no_batch_selection() {
        for cfg in table4(Scale::Smoke) {
            assert_eq!(cfg.workers, 4);
            assert_eq!(cfg.meta_batch, cfg.mini_batch);
        }
    }

    #[test]
    fn fig4_uses_grad_accum() {
        for cfg in fig4(Scale::Smoke) {
            assert_eq!(cfg.micro_batch, 8);
        }
    }

    #[test]
    fn beta_grid_covers_corners() {
        let grid = fig6_beta_grid(Scale::Smoke, false);
        assert!(grid.iter().any(|&(b1, b2, _)| b1 == 0.0 && b2 == 0.0));
        assert!(grid.iter().any(|&(b1, b2, _)| b1 == 1.0 && b2 == 1.0));
        assert_eq!(grid.len(), 25);
    }

    #[test]
    fn batch_sizes_match_artifact_plan() {
        // Every preset's (mini, meta) must have train_step artifacts
        // emitted by aot.py's PLANS (kept in sync by hand; this test is
        // the tripwire).
        let allowed: &[(&str, &[usize])] = &[
            ("mlp_cifar10", &[4, 8, 16, 32, 64, 128]),
            ("cnn_small_c10", &[32, 128]),
            ("cnn_small_c100", &[32, 128]),
            ("cnn_deep_c100", &[64, 128]),
            ("txf_cls", &[16, 64]),
            ("txf_nlu", &[16, 64]),
            ("txf_lm", &[8, 32]),
            ("txf_lm_large", &[4, 16]),
            ("mae_mlp", &[64, 256]),
        ];
        let check = |cfg: &RunConfig| {
            let sizes = allowed
                .iter()
                .find(|(m, _)| *m == cfg.model)
                .unwrap_or_else(|| panic!("{}: unknown model {}", cfg.name, cfg.model))
                .1;
            assert!(sizes.contains(&cfg.mini_batch), "{}: b={}", cfg.name, cfg.mini_batch);
            assert!(sizes.contains(&cfg.meta_batch), "{}: B={}", cfg.name, cfg.meta_batch);
        };
        table2(Scale::Smoke).iter().for_each(check);
        table3(Scale::Smoke).iter().for_each(check);
        table4(Scale::Smoke).iter().for_each(check);
        table5(Scale::Smoke, &all_samplers()).iter().for_each(check);
        fig4(Scale::Smoke).iter().for_each(check);
        fig5_bb_sweep(Scale::Smoke).iter().for_each(check);
        fig5_prune_sweep(Scale::Smoke).iter().for_each(check);
        e2e_pretrain(Scale::Smoke).iter().for_each(check);
        frequency_sweep(Scale::Smoke).iter().for_each(|(_, cfg)| check(cfg));
    }
}
