//! Evolved Sampling (ES) and ES With Pruning (ESWP) — paper §3, Alg. 1.
//!
//! State per sample i (Eq. 3.1), with s(0) = w(0) = 1/n:
//!
//! ```text
//! w_i(t) = β1·s_i(t-1) + (1-β1)·ℓ_i(θ(t))
//! s_i(t) = β2·s_i(t-1) + (1-β2)·ℓ_i(θ(t))
//! ```
//!
//! Prop. 3.1 shows w implicitly augments discounted historical losses with
//! discounted loss *differences* (the (β2-β1) term of Eq. 3.2) — no loss
//! history is stored; the dual EMA is the entire memory cost (2 f32 per
//! sample).
//!
//! Per step (Alg. 1): the trainer draws a uniform meta-batch, obtains its
//! fresh losses (scoring FP at the *latest* parameters), calls
//! `observe_meta` (the Eq. 3.1 update), then `select` draws the BP
//! mini-batch with probability ∝ w (without replacement). During annealing
//! epochs selection is off, but losses from the standard training steps
//! still warm the tables via `observe_train`.
//!
//! ESWP (prune_ratio > 0) additionally prunes the dataset at each active
//! epoch start, keeping (1−r)·n samples with probability ∝ w — the paper's
//! set-level extension. Both selections use the shared Gumbel top-k
//! machinery in `weights.rs`, which floors degenerate weights so
//! low-weight samples stay reachable (Remark 1).

use super::annealing::Annealing;
use super::{
    json_to_table, table_to_json, weights, Sampler, Selection, ShardLog, ShardObservations,
};
use crate::util::json::{obj, Json};
use crate::util::Pcg64;

pub struct Evolved {
    beta1: f32,
    beta2: f32,
    prune_ratio: f64,
    anneal: Annealing,
    /// Score state s (Eq. 3.1).
    s: Vec<f32>,
    /// Sampling weight w (Eq. 3.1).
    w: Vec<f32>,
    /// Scratch for gathering meta-batch weights in `select` (no per-step
    /// allocation on the hot path).
    scratch: Vec<f32>,
    /// Applied-observation buffer for worker-replica mode (§D.5 sync).
    shard_log: ShardLog,
}

impl Evolved {
    pub fn new(
        n: usize,
        epochs: usize,
        beta1: f32,
        beta2: f32,
        anneal_frac: f64,
        prune_ratio: f64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&beta1) && (0.0..=1.0).contains(&beta2));
        assert!((0.0..1.0).contains(&prune_ratio));
        let init = 1.0 / n as f32;
        Evolved {
            beta1,
            beta2,
            prune_ratio,
            anneal: Annealing::new(epochs, anneal_frac),
            s: vec![init; n],
            w: vec![init; n],
            scratch: Vec::new(),
            shard_log: ShardLog::default(),
        }
    }

    /// The Eq. 3.1 dual-EMA update for one batch of fresh losses.
    /// (Same computation as the L1 `es_update` Pallas kernel; the rust
    /// path handles the scattered per-step updates, the kernel handles
    /// dense full-table refreshes.)
    fn update(&mut self, indices: &[u32], losses: &[f32]) {
        debug_assert_eq!(indices.len(), losses.len());
        for (&i, &l) in indices.iter().zip(losses) {
            let i = i as usize;
            let s_old = self.s[i];
            self.w[i] = self.beta1 * s_old + (1.0 - self.beta1) * l;
            self.s[i] = self.beta2 * s_old + (1.0 - self.beta2) * l;
        }
    }

    pub fn weights_table(&self) -> &[f32] {
        &self.w
    }

    pub fn scores_table(&self) -> &[f32] {
        &self.s
    }

    /// Replace both tables (used by the distributed simulation to install
    /// the synchronized state, and by the XLA-kernel refresh path).
    pub fn install_tables(&mut self, s: Vec<f32>, w: Vec<f32>) {
        assert_eq!(s.len(), self.s.len());
        assert_eq!(w.len(), self.w.len());
        self.s = s;
        self.w = w;
    }

    pub fn betas(&self) -> (f32, f32) {
        (self.beta1, self.beta2)
    }

    pub fn is_pruning(&self) -> bool {
        self.prune_ratio > 0.0
    }
}

impl Sampler for Evolved {
    fn name(&self) -> &'static str {
        if self.is_pruning() {
            "eswp"
        } else {
            "es"
        }
    }

    fn n(&self) -> usize {
        self.s.len()
    }

    fn on_epoch_start(&mut self, epoch: usize, rng: &mut Pcg64) -> Vec<u32> {
        let n = self.n();
        if !self.is_pruning() || !self.anneal.active(epoch) {
            return (0..n as u32).collect();
        }
        let keep = ((1.0 - self.prune_ratio) * n as f64).ceil() as usize;
        weights::prune_keep(&self.w, keep.max(1), rng)
    }

    fn needs_meta_losses(&self, epoch: usize) -> bool {
        self.anneal.active(epoch)
    }

    fn observe_meta(&mut self, indices: &[u32], losses: &[f32], _epoch: usize) {
        self.shard_log.record(indices, losses);
        self.update(indices, losses);
    }

    fn observe_train(&mut self, indices: &[u32], losses: &[f32], epoch: usize) {
        // During annealing the BP batch *is* the meta-batch and its losses
        // already flowed through observe_meta when selection was active;
        // only warm the tables here when selection is off.
        if !self.anneal.active(epoch) {
            self.shard_log.record(indices, losses);
            self.update(indices, losses);
        }
    }

    fn begin_shard(&mut self, _shard: &[u32]) {
        self.shard_log.begin();
    }

    fn export_observations(&mut self) -> ShardObservations {
        self.shard_log.export()
    }

    fn merge_observations(&mut self, obs: &[(Vec<u32>, Vec<f32>)], _epoch: usize) {
        // Peers export only observations they *applied* (the annealing
        // gate already ran on the owning worker), so replay them raw —
        // re-gating through observe_train would drop active-epoch scoring
        // losses and leave the canonical tables stale. Not routed through
        // the shard log: merged batches are peer state, not local
        // observations, and must not be re-exported next round.
        for (indices, losses) in obs {
            self.update(indices, losses);
        }
    }

    fn state_json(&self) -> Option<Json> {
        // The dual EMA *is* the entire evolving state (Eq. 3.1); betas,
        // annealing, and prune ratio are config-derived and rebuilt.
        Some(obj(vec![("s", table_to_json(&self.s)), ("w", table_to_json(&self.w))]))
    }

    fn restore_state(&mut self, state: &Json) -> anyhow::Result<()> {
        let n = self.n();
        let s = json_to_table(
            state.get("s").ok_or_else(|| anyhow::anyhow!("es state: missing s"))?,
            n,
        )?;
        let w = json_to_table(
            state.get("w").ok_or_else(|| anyhow::anyhow!("es state: missing w"))?,
            n,
        )?;
        self.install_tables(s, w);
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn select(&mut self, meta: &[u32], mini: usize, epoch: usize, rng: &mut Pcg64) -> Selection {
        if !self.anneal.active(epoch) || mini >= meta.len() {
            return Selection::unweighted(meta.to_vec());
        }
        self.scratch.clear();
        self.scratch.extend(meta.iter().map(|&i| self.w[i as usize]));
        let picked = weights::sample_without_replacement(&self.scratch, mini, rng);
        Selection::unweighted(picked.into_iter().map(|p| meta[p as usize]).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sampler::analysis;
    use crate::util::proptest::check;

    fn es(n: usize) -> Evolved {
        Evolved::new(n, 10, 0.2, 0.9, 0.0, 0.0)
    }

    #[test]
    fn initial_state_uniform() {
        let e = es(4);
        assert!(e.w.iter().all(|&w| (w - 0.25).abs() < 1e-7));
        assert!(e.s.iter().all(|&s| (s - 0.25).abs() < 1e-7));
    }

    #[test]
    fn update_follows_eq_3_1() {
        let mut e = es(2);
        e.observe_meta(&[0], &[2.0], 0);
        // w = 0.2*0.5 + 0.8*2.0 = 1.7 ; s = 0.9*0.5 + 0.1*2.0 = 0.65
        assert!((e.w[0] - 1.7).abs() < 1e-6, "w={}", e.w[0]);
        assert!((e.s[0] - 0.65).abs() < 1e-6, "s={}", e.s[0]);
        // Untouched sample unchanged.
        assert!((e.w[1] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn recursion_equals_explicit_expansion() {
        // Prop. 3.1: run the recursion for T steps on one sample, compare
        // to the explicit Eq. 3.2 expansion (up to the O(β2^T) remainder).
        check("es recursion == eq 3.2", 60, |g| {
            let t_max = g.usize_in(5, 40);
            let b1 = g.f32_in(0.0, 1.0);
            let b2 = g.f32_in(0.05, 0.95);
            let losses: Vec<f32> = g.vec_f32(t_max + 1, 0.01, 5.0);
            let n = 8.0f32;
            let mut e = Evolved::new(8, 10, b1, b2, 0.0, 0.0);
            for t in 1..=t_max {
                e.observe_meta(&[0], &[losses[t]], 0);
            }
            let w_rec = e.w[0];
            let w_exp = analysis::explicit_weight(&losses[1..=t_max], b1, b2, 1.0 / n);
            let tol = 8.0 * (b2 as f64).powi(t_max as i32) as f32 + 1e-4;
            prop_assert!(
                (w_rec - w_exp).abs() <= tol,
                "rec={w_rec} exp={w_exp} tol={tol} (b1={b1} b2={b2} T={t_max})"
            );
            Ok(())
        });
    }

    #[test]
    fn beta_zero_reduces_to_loss_sampling() {
        // β1=β2=0 => w == current loss (Eq. 2.3).
        let mut e = Evolved::new(3, 10, 0.0, 0.0, 0.0, 0.0);
        e.observe_meta(&[0, 1, 2], &[1.0, 2.0, 3.0], 0);
        assert_eq!(e.w, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn beta_one_is_standard_sampling() {
        // β1=β2=1 => w frozen at the uniform init regardless of losses.
        let mut e = Evolved::new(4, 10, 1.0, 1.0, 0.0, 0.0);
        e.observe_meta(&[0, 1], &[9.0, 9.0], 0);
        assert!(e.w.iter().all(|&w| (w - 0.25).abs() < 1e-7));
    }

    #[test]
    fn select_prefers_high_weight_samples() {
        let mut e = es(8);
        // Sample 3 has seen large losses repeatedly.
        for _ in 0..5 {
            e.observe_meta(&[3], &[10.0], 1);
            e.observe_meta(&[0, 1, 2], &[0.01, 0.01, 0.01], 1);
        }
        let meta: Vec<u32> = (0..8).collect();
        let mut rng = Pcg64::new(1);
        let hits = (0..500)
            .filter(|_| e.select(&meta, 2, 1, &mut rng).indices.contains(&3))
            .count();
        assert!(hits > 450, "hits={hits}");
    }

    #[test]
    fn select_returns_subset_of_meta_without_duplicates() {
        check("es select subset", 80, |g| {
            let n = g.usize_in(8, 128);
            let mut e = es(n);
            let losses = g.vec_f32(n, 0.0, 4.0);
            let all: Vec<u32> = (0..n as u32).collect();
            e.observe_meta(&all, &losses, 1);
            let meta: Vec<u32> = all.iter().copied().take(n.min(32)).collect();
            let mini = g.usize_in(1, meta.len());
            let sel = e.select(&meta, mini, 1, g.rng());
            prop_assert!(sel.indices.len() == mini, "len {}", sel.indices.len());
            let mut sorted = sel.indices.clone();
            sorted.sort_unstable();
            let before = sorted.len();
            sorted.dedup();
            prop_assert!(sorted.len() == before, "duplicates in selection");
            for i in &sel.indices {
                prop_assert!(meta.contains(i), "{i} not in meta");
            }
            Ok(())
        });
    }

    #[test]
    fn annealing_disables_selection_and_scoring() {
        let e = Evolved::new(16, 20, 0.2, 0.9, 0.05, 0.0);
        assert!(!e.needs_meta_losses(0), "first epoch annealed");
        assert!(e.needs_meta_losses(1));
        assert!(!e.needs_meta_losses(19), "last epoch annealed");
        let mut e = e;
        let meta: Vec<u32> = (0..16).collect();
        let sel = e.select(&meta, 4, 0, &mut Pcg64::new(0));
        assert_eq!(sel.indices, meta, "annealed select = whole meta");
    }

    #[test]
    fn observe_train_warms_tables_only_when_annealed() {
        let mut e = Evolved::new(4, 20, 0.2, 0.9, 0.05, 0.0);
        let w0 = e.w[0];
        e.observe_train(&[0], &[5.0], 1); // active epoch: ignored
        assert_eq!(e.w[0], w0);
        e.observe_train(&[0], &[5.0], 0); // annealed epoch: applied
        assert_ne!(e.w[0], w0);
    }

    #[test]
    fn eswp_prunes_to_keep_ratio() {
        let mut e = Evolved::new(100, 10, 0.2, 0.8, 0.0, 0.3);
        let kept = e.on_epoch_start(5, &mut Pcg64::new(2));
        assert_eq!(kept.len(), 70);
        assert!(kept.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn eswp_pruning_prefers_high_weight() {
        let mut e = Evolved::new(50, 10, 0.2, 0.8, 0.0, 0.5);
        // First half of the dataset has 100x the loss of the second half.
        let idx: Vec<u32> = (0..50).collect();
        let losses: Vec<f32> = (0..50).map(|i| if i < 25 { 10.0 } else { 0.1 }).collect();
        for _ in 0..4 {
            e.observe_meta(&idx, &losses, 1);
        }
        let mut low_kept = 0;
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            let kept = e.on_epoch_start(1, &mut rng);
            low_kept += kept.iter().filter(|&&i| i >= 25).count();
        }
        // Of 25 kept per trial, high-loss samples should dominate.
        let frac_low = low_kept as f64 / (200.0 * 25.0);
        assert!(frac_low < 0.25, "frac_low={frac_low}");
    }

    #[test]
    fn es_never_prunes() {
        let mut e = es(30);
        let kept = e.on_epoch_start(3, &mut Pcg64::new(4));
        assert_eq!(kept.len(), 30);
    }

    #[test]
    fn name_reflects_pruning() {
        assert_eq!(es(4).name(), "es");
        assert_eq!(Evolved::new(4, 10, 0.2, 0.8, 0.0, 0.2).name(), "eswp");
    }

    #[test]
    fn export_then_merge_reproduces_replica_tables() {
        // A replica that observed a shard, exported, and a fresh peer that
        // merges the export must end with identical tables (the §D.5 sync
        // contract). install_tables rebases both to a common start state.
        let start_s: Vec<f32> = (0..8).map(|i| 0.1 * i as f32 + 0.05).collect();
        let start_w: Vec<f32> = (0..8).map(|i| 0.2 * i as f32 + 0.01).collect();

        let mut replica = es(8);
        replica.install_tables(start_s.clone(), start_w.clone());
        replica.begin_shard(&[0, 2, 4, 6]);
        replica.observe_meta(&[0, 2], &[1.5, 0.3], 1);
        replica.observe_meta(&[4, 6], &[2.0, 0.9], 1);
        replica.observe_meta(&[0], &[0.7], 1);
        let exported = replica.export_observations();
        assert_eq!(exported.len(), 3);

        let mut peer = es(8);
        peer.install_tables(start_s, start_w);
        peer.merge_observations(&exported, 1);
        assert_eq!(peer.weights_table(), replica.weights_table());
        assert_eq!(peer.scores_table(), replica.scores_table());
        // The merge must not be re-exported by the peer.
        peer.begin_shard(&[1, 3, 5, 7]);
        peer.merge_observations(&[(vec![1], vec![4.0])], 1);
        assert!(peer.export_observations().is_empty());
    }

    #[test]
    fn merge_bypasses_annealing_gate() {
        // Peer scoring losses from an active epoch must land even though
        // observe_train would drop them.
        let mut e = Evolved::new(4, 20, 0.2, 0.9, 0.05, 0.0);
        let w0 = e.w[0];
        assert!(e.anneal.active(1), "epoch 1 is active");
        e.merge_observations(&[(vec![0], vec![5.0])], 1);
        assert_ne!(e.w[0], w0, "merged observation applied raw");
    }

    #[test]
    fn shard_log_only_buffers_applied_observations() {
        let mut e = Evolved::new(4, 20, 0.2, 0.9, 0.05, 0.0);
        e.begin_shard(&[0, 1]);
        e.observe_train(&[0], &[5.0], 1); // active epoch: dropped, not logged
        e.observe_train(&[1], &[5.0], 0); // annealed epoch: applied + logged
        e.observe_meta(&[0], &[2.0], 1); // always applied + logged
        let obs = e.export_observations();
        assert_eq!(obs.len(), 2);
        assert_eq!(obs[0].0, vec![1]);
        assert_eq!(obs[1].0, vec![0]);
    }
}
