//! Fig. 9: per-class BP-sample counts under ESWP — the visualization that
//! selection automatically re-balances effort across classes as training
//! proceeds (harder classes get more BP samples; ranks shift per epoch).

use crate::config::presets::Scale;
use crate::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use crate::metrics::Recorder;
use crate::util::bench::table_header;
use crate::util::json::{num, obj, s, Json};

use super::{make_runtime, run_config};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let n = match scale {
        Scale::Smoke => 1024,
        Scale::Full => 16384,
    };
    let classes = 10; // paper shows CIFAR-100's first 50; we use c10 scale
    let mut cfg = RunConfig::new(
        "fig9/class_counts",
        "mlp_cifar10",
        DatasetConfig::SynthCifar { n, classes, label_noise: 0.05, hard_frac: 0.2 },
    );
    cfg.epochs = match scale {
        Scale::Smoke => 6,
        Scale::Full => 30,
    };
    cfg.meta_batch = 128;
    cfg.mini_batch = 32;
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
    cfg.sampler = SamplerConfig::eswp_default();
    cfg.test_n = 512;

    let mut rt = make_runtime(&cfg)?;
    let rs = run_config(&cfg, rt.as_mut(), 1)?;
    let r = &rs[0];

    // Rank classes by BP count (descending), like the paper's column labels.
    let mut order: Vec<usize> = (0..classes).collect();
    order.sort_by_key(|&c| std::cmp::Reverse(r.class_bp_counts[c]));

    table_header("Fig. 9 — BP samples per class (ESWP)", &["class", "bp samples", "rank"]);
    for c in 0..classes {
        let rank = order.iter().position(|&x| x == c).unwrap() + 1;
        println!("{c:>5} | {:>10} | {rank:>4}", r.class_bp_counts[c]);
    }
    let rec = Recorder::new("fig9_class_counts")?;
    rec.record(&obj(vec![
        ("fig", s("fig9")),
        (
            "counts",
            Json::Arr(r.class_bp_counts.iter().map(|&c| num(c as f64)).collect()),
        ),
    ]))?;

    // Shape check the paper implies: selection is NOT uniform over classes.
    let max = *r.class_bp_counts.iter().max().unwrap() as f64;
    let min = *r.class_bp_counts.iter().min().unwrap() as f64;
    println!("class imbalance max/min = {:.2}", max / min.max(1.0));
    Ok(())
}
