//! Cross-method property tests: invariants every dynamic sampler must
//! satisfy regardless of its policy. These run the real Sampler trait
//! objects through randomized observe/prune/select schedules (no model
//! runtime needed), pinning the contracts the trainer depends on.

use evosample::config::SamplerConfig;
use evosample::prop_assert;
use evosample::sampler::{build, Selection};
use evosample::util::proptest::check;
use evosample::util::Pcg64;

fn all_methods() -> Vec<SamplerConfig> {
    vec![
        SamplerConfig::Uniform,
        SamplerConfig::Loss,
        SamplerConfig::Ordered,
        SamplerConfig::es_default(),
        SamplerConfig::eswp_default(),
        SamplerConfig::infobatch_default(),
        SamplerConfig::kakurenbo_default(),
        SamplerConfig::ucb_default(),
        SamplerConfig::RandomPrune { prune_ratio: 0.2 },
    ]
}

/// Drive one sampler through a random epoch schedule, checking contracts.
fn drive(cfg: &SamplerConfig, n: usize, epochs: usize, rng_seed: u64) -> Result<(), String> {
    let mut sampler = build(cfg, n, epochs).unwrap();
    let mut rng = Pcg64::new(rng_seed);
    for epoch in 0..epochs {
        let kept = sampler.on_epoch_start(epoch, &mut rng);
        prop_assert!(!kept.is_empty(), "{}: empty kept set", cfg.name());
        prop_assert!(kept.len() <= n, "{}: kept > n", cfg.name());
        let mut sorted = kept.clone();
        sorted.dedup();
        prop_assert!(sorted.len() == kept.len(), "{}: duplicate kept indices", cfg.name());
        for &i in &kept {
            prop_assert!((i as usize) < n, "{}: kept idx {i} out of range", cfg.name());
        }
        // Simulate a few steps.
        for _ in 0..3 {
            let bsz = kept.len().min(16);
            let meta: Vec<u32> = (0..bsz).map(|k| kept[k * kept.len() / bsz.max(1)]).collect();
            let losses: Vec<f32> = meta.iter().map(|_| rng.f32() * 4.0).collect();
            if sampler.needs_meta_losses(epoch) {
                sampler.observe_meta(&meta, &losses, epoch);
            }
            let mini = (bsz / 2).max(1);
            let sel: Selection = sampler.select(&meta, mini, epoch, &mut rng);
            prop_assert!(!sel.indices.is_empty(), "{}: empty selection", cfg.name());
            prop_assert!(
                sel.indices.len() == sel.weights.len(),
                "{}: weights/indices length mismatch",
                cfg.name()
            );
            for &i in &sel.indices {
                prop_assert!(meta.contains(&i), "{}: selected {i} not in meta", cfg.name());
            }
            for &w in &sel.weights {
                prop_assert!(w.is_finite() && w > 0.0, "{}: bad weight {w}", cfg.name());
            }
            // Batch-level methods must respect the mini budget when active;
            // set-level/annealed return the full meta. Either is legal, but
            // nothing in between or beyond.
            prop_assert!(
                sel.indices.len() == mini || sel.indices.len() == meta.len(),
                "{}: selection size {} (mini {mini}, meta {})",
                cfg.name(),
                sel.indices.len(),
                meta.len()
            );
            let train_losses: Vec<f32> = sel.indices.iter().map(|_| rng.f32() * 4.0).collect();
            sampler.observe_train(&sel.indices, &train_losses, epoch);
        }
    }
    Ok(())
}

#[test]
fn all_samplers_uphold_contracts_under_random_schedules() {
    for cfg in all_methods() {
        check(&format!("contract:{}", cfg.name()), 40, |g| {
            let n = g.usize_in(16, 300);
            let epochs = g.usize_in(1, 12);
            let seed = g.rng().next_u64();
            drive(&cfg, n, epochs, seed)
        });
    }
}

#[test]
fn samplers_are_deterministic_given_rng_seed() {
    for cfg in all_methods() {
        let run = |seed: u64| -> Vec<u32> {
            let mut s = build(&cfg, 64, 6).unwrap();
            let mut rng = Pcg64::new(seed);
            let mut out = Vec::new();
            for epoch in 0..6 {
                let kept = s.on_epoch_start(epoch, &mut rng);
                let meta: Vec<u32> = kept.iter().copied().take(16).collect();
                let losses: Vec<f32> = meta.iter().map(|&i| (i % 7) as f32).collect();
                s.observe_meta(&meta, &losses, epoch);
                s.observe_train(&meta, &losses, epoch);
                out.extend(s.select(&meta, 4, epoch, &mut rng).indices);
            }
            out
        };
        assert_eq!(run(9), run(9), "{} nondeterministic", cfg.name());
    }
}

#[test]
fn degenerate_loss_tables_never_break_selection() {
    // NaN/inf/zero losses must degrade gracefully (Remark 1 / weights.rs
    // flooring), never panic or return empty selections.
    for cfg in all_methods() {
        let mut s = build(&cfg, 32, 4).unwrap();
        let mut rng = Pcg64::new(3);
        let meta: Vec<u32> = (0..16).collect();
        let horror = vec![
            f32::NAN,
            f32::INFINITY,
            -1.0,
            0.0,
            1e38,
            1e-38,
            f32::NEG_INFINITY,
            2.0,
            f32::NAN,
            0.0,
            0.0,
            0.0,
            5.0,
            f32::INFINITY,
            -0.0,
            1.0,
        ];
        s.observe_meta(&meta, &horror, 1);
        s.observe_train(&meta, &horror, 1);
        let kept = s.on_epoch_start(2, &mut rng);
        assert!(!kept.is_empty(), "{}", cfg.name());
        let sel = s.select(&meta, 4, 2, &mut rng);
        assert!(!sel.indices.is_empty(), "{}", cfg.name());
        assert!(sel.weights.iter().all(|w| w.is_finite()), "{}", cfg.name());
    }
}

#[test]
fn batch_level_methods_skew_selection_toward_high_loss() {
    // Loss, Order and ES must all prefer high-loss samples; set-level
    // methods pass the meta-batch through untouched.
    for cfg in [SamplerConfig::Loss, SamplerConfig::Ordered, SamplerConfig::es_default()] {
        let mut s = build(&cfg, 32, 4).unwrap();
        let mut rng = Pcg64::new(11);
        let meta: Vec<u32> = (0..16).collect();
        // First half high loss, second half near zero — observed repeatedly.
        let losses: Vec<f32> =
            (0..16).map(|i| if i < 8 { 5.0 } else { 0.01 }).collect();
        for _ in 0..4 {
            s.observe_meta(&meta, &losses, 1);
        }
        let mut high = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let sel = s.select(&meta, 4, 1, &mut rng);
            high += sel.indices.iter().filter(|&&i| i < 8).count();
            total += sel.indices.len();
        }
        let frac = high as f64 / total as f64;
        assert!(frac > 0.75, "{}: high-loss fraction {frac}", cfg.name());
    }
}

#[test]
fn set_level_methods_reduce_epoch_size_by_configured_ratio() {
    let cases = [
        (SamplerConfig::eswp_default(), 0.2),
        (SamplerConfig::ucb_default(), 0.3),
        (SamplerConfig::RandomPrune { prune_ratio: 0.2 }, 0.2),
    ];
    for (cfg, r) in cases {
        let n = 200;
        let mut s = build(&cfg, n, 10).unwrap();
        let mut rng = Pcg64::new(5);
        // Warm the state so pruning has scores to act on.
        let all: Vec<u32> = (0..n as u32).collect();
        let losses: Vec<f32> = (0..n).map(|i| (i % 13) as f32).collect();
        s.observe_train(&all, &losses, 0);
        s.observe_meta(&all, &losses, 1);
        let kept = s.on_epoch_start(2, &mut rng);
        let expected = ((1.0 - r) * n as f64).ceil() as usize;
        assert_eq!(kept.len(), expected, "{}", cfg.name());
    }
}
