//! Regenerates paper Fig. 10 (accuracy vs cumulative BP samples).
fn main() {
    evosample::experiments::fig10::run(evosample::config::presets::Scale::from_env())
        .expect("fig10");
}
