"""L2: JAX model zoo + train/eval step builders (build-time only).

Every workload in the paper's evaluation has a CPU-feasible stand-in here
(see DESIGN.md §3 for the substitution table):

  * ``mlp``             — dense classifier (CIFAR-scale substitute)
  * ``cnn``             — small/deep conv nets (ResNet-18/50 substitutes)
  * ``transformer_cls`` — encoder classifier (ViT-L / ALBERT substitute)
  * ``transformer_lm``  — decoder LM (Qwen-SFT / pre-training substitute)
  * ``mae``             — masked autoencoder (MAE ViT-L substitute)

All models expose the same functional surface so aot.py can emit a uniform
artifact family and the rust runtime can stay model-agnostic:

  init_params(key) -> pytree
  per_sample_loss(params, x, y) -> f32[batch]
  metrics(params, x, y) -> (losses f32[batch], correct f32[batch])

Parameters cross the FFI as a single flat f32 vector (ravel_pytree); the
unflattener is closed over inside the lowered computation, so the rust side
only ever sees ``f32[param_count]``.

Compute hot-spots route through the L1 Pallas kernels
(``kernels.cross_entropy_vjp``, ``kernels.flash_attention``); set
``use_kernels=False`` to lower a pure-jnp reference variant of the same
model (used for L2 A/B checks in python/tests).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile.kernels import ref
from compile.kernels.attention import flash_attention_vjp
from compile.kernels.ce_loss import cross_entropy_vjp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense_init(key, n_in, n_out):
    """He-normal weight + zero bias."""
    wkey, _ = jax.random.split(key)
    std = jnp.sqrt(2.0 / n_in)
    return {
        "w": jax.random.normal(wkey, (n_in, n_out), jnp.float32) * std,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _layernorm(x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


# ---------------------------------------------------------------------------
# Model definitions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of one model variant (mirrors manifest.json)."""

    name: str
    kind: str  # mlp | cnn | transformer_cls | transformer_lm | mae
    x_shape: tuple[int, ...]  # per-sample input shape
    x_dtype: str  # "f32" | "i32"
    y_shape: tuple[int, ...]  # per-sample label shape (() scalar for cls)
    classes: int
    flops_per_sample_fwd: int  # analytic FP cost (for the L3 cost model)

    def x_batch_shape(self, n):
        return (n, *self.x_shape)

    def y_batch_shape(self, n):
        return (n, *self.y_shape)


class Mlp:
    """Dense classifier over flat features."""

    def __init__(self, name, in_dim, hidden, classes, use_kernels=True):
        self.in_dim, self.hidden, self.classes = in_dim, tuple(hidden), classes
        self.use_kernels = use_kernels
        dims = [in_dim, *hidden, classes]
        flops = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
        self.spec = ModelSpec(name, "mlp", (in_dim,), "f32", (), classes, flops)

    def init_params(self, key):
        dims = [self.in_dim, *self.hidden, self.classes]
        keys = jax.random.split(key, len(dims) - 1)
        return [_dense_init(k, a, b) for k, a, b in zip(keys, dims[:-1], dims[1:])]

    def logits(self, params, x):
        h = x
        for layer in params[:-1]:
            h = jax.nn.relu(_dense(layer, h))
        return _dense(params[-1], h)

    def per_sample_loss(self, params, x, y):
        logits = self.logits(params, x)
        if self.use_kernels:
            return cross_entropy_vjp(logits, y)
        return ref.cross_entropy_ref(logits, y)

    def metrics(self, params, x, y):
        logits = self.logits(params, x)
        losses = ref.cross_entropy_ref(logits, y)
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return losses, correct


class Cnn:
    """Small conv classifier on 32x32x3 images passed as flat f32[3072].

    conv(3x3) → relu → avgpool(2) per stage, then a dense head. The
    ResNet-18/50 substitutes use 2 and 3 stages respectively.
    """

    def __init__(self, name, channels, classes, use_kernels=True, image=32):
        self.channels = tuple(channels)
        self.classes = classes
        self.image = image
        self.use_kernels = use_kernels
        # FLOPs: conv = 2 * H*W*Cin*Cout*9 per stage (H,W halve per stage).
        flops, hw, cin = 0, image, 3
        for cout in self.channels:
            flops += 2 * hw * hw * cin * cout * 9
            hw //= 2
            cin = cout
        feat = hw * hw * self.channels[-1]
        flops += 2 * feat * classes
        self._feat = feat
        self.spec = ModelSpec(name, "cnn", (image * image * 3,), "f32", (), classes, flops)

    def init_params(self, key):
        keys = jax.random.split(key, len(self.channels) + 1)
        params = []
        cin = 3
        for k, cout in zip(keys[:-1], self.channels):
            std = jnp.sqrt(2.0 / (9 * cin))
            params.append(
                {
                    "w": jax.random.normal(k, (3, 3, cin, cout), jnp.float32) * std,
                    "b": jnp.zeros((cout,), jnp.float32),
                }
            )
            cin = cout
        params.append(_dense_init(keys[-1], self._feat, self.classes))
        return params

    def logits(self, params, x):
        n = x.shape[0]
        h = x.reshape(n, self.image, self.image, 3)
        for layer in params[:-1]:
            h = jax.lax.conv_general_dilated(
                h,
                layer["w"],
                window_strides=(1, 1),
                padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            h = jax.nn.relu(h + layer["b"])
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            ) / 4.0
        return _dense(params[-1], h.reshape(n, -1))

    def per_sample_loss(self, params, x, y):
        logits = self.logits(params, x)
        if self.use_kernels:
            return cross_entropy_vjp(logits, y)
        return ref.cross_entropy_ref(logits, y)

    def metrics(self, params, x, y):
        logits = self.logits(params, x)
        losses = ref.cross_entropy_ref(logits, y)
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return losses, correct


class Transformer:
    """Decoder-only transformer; LM and classifier heads share the trunk.

    Layers are stacked (params have a leading [layers] axis) and walked
    with lax.scan so the lowered HLO stays compact at any depth.
    """

    def __init__(
        self,
        name,
        vocab,
        d_model,
        layers,
        heads,
        seq,
        classes=0,
        causal=True,
        use_kernels=True,
    ):
        assert d_model % heads == 0
        self.vocab, self.d, self.layers, self.heads, self.seq = vocab, d_model, layers, heads, seq
        self.classes = classes  # 0 => LM head (tied embedding)
        self.causal = causal
        self.use_kernels = use_kernels
        d, t = d_model, seq
        per_layer = 2 * t * (4 * d * d) + 2 * t * (2 * d * 4 * d) + 2 * t * t * d * 2
        head = 2 * t * d * (classes if classes else vocab)
        kind = "transformer_cls" if classes else "transformer_lm"
        y_shape = () if classes else (seq,)
        self.spec = ModelSpec(
            name,
            kind,
            (seq,),
            "i32",
            y_shape,
            classes if classes else vocab,
            layers * per_layer + head,
        )

    def init_params(self, key):
        keys = jax.random.split(key, 8)
        d, L = self.d, self.layers
        scale = 0.02

        def stack(k, shape):
            return jax.random.normal(k, (L, *shape), jnp.float32) * scale

        params = {
            "embed": jax.random.normal(keys[0], (self.vocab, d), jnp.float32) * scale,
            "pos": jax.random.normal(keys[1], (self.seq, d), jnp.float32) * scale,
            "qkv": stack(keys[2], (d, 3 * d)),
            "proj": stack(keys[3], (d, d)),
            "fc1": stack(keys[4], (d, 4 * d)),
            "fc1_b": jnp.zeros((L, 4 * d), jnp.float32),
            "fc2": stack(keys[5], (4 * d, d)),
            "fc2_b": jnp.zeros((L, d), jnp.float32),
        }
        if self.classes:
            params["head"] = _dense_init(keys[6], d, self.classes)
        return params

    def _attention(self, q, k, v):
        """q,k,v: [heads, seq, hd] -> [heads, seq, hd]."""
        if self.use_kernels:
            return jax.vmap(lambda a, b, c: flash_attention_vjp(a, b, c, self.causal))(q, k, v)
        return jax.vmap(lambda a, b, c: ref.attention_ref(a, b, c, causal=self.causal))(q, k, v)

    def trunk(self, params, tokens):
        """tokens: i32[n, seq] -> activations f32[n, seq, d]."""
        h = params["embed"][tokens] + params["pos"][None, :, :]
        hd = self.d // self.heads

        def layer(h, lp):
            x = _layernorm(h)
            qkv = x @ lp["qkv"]  # [n, t, 3d]
            q, k, v = jnp.split(qkv, 3, axis=-1)

            def split_heads(a):
                n, t, _ = a.shape
                return a.reshape(n, t, self.heads, hd).transpose(0, 2, 1, 3)

            q, k, v = split_heads(q), split_heads(k), split_heads(v)
            o = jax.vmap(self._attention)(q, k, v)  # [n, heads, t, hd]
            n, _, t, _ = o.shape
            o = o.transpose(0, 2, 1, 3).reshape(n, t, self.d)
            h = h + o @ lp["proj"]
            x = _layernorm(h)
            x = jax.nn.gelu(x @ lp["fc1"] + lp["fc1_b"])
            h = h + x @ lp["fc2"] + lp["fc2_b"]
            return h, None

        layer_params = {
            k: params[k] for k in ("qkv", "proj", "fc1", "fc1_b", "fc2", "fc2_b")
        }
        h, _ = jax.lax.scan(layer, h, layer_params)
        return _layernorm(h)

    # -- LM head ---------------------------------------------------------
    def lm_logits(self, params, tokens):
        h = self.trunk(params, tokens)
        return h @ params["embed"].T  # tied embedding

    def _token_ce(self, logits2d, labels1d):
        if self.use_kernels:
            return cross_entropy_vjp(logits2d, labels1d)
        return ref.cross_entropy_ref(logits2d, labels1d)

    def per_sample_loss(self, params, x, y):
        if self.classes:
            logits = self.cls_logits(params, x)
            return self._token_ce(logits, y)
        n = x.shape[0]
        logits = self.lm_logits(params, x).reshape(n * self.seq, self.vocab)
        tok_loss = self._token_ce(logits, y.reshape(n * self.seq))
        return tok_loss.reshape(n, self.seq).mean(axis=-1)

    # -- classifier head --------------------------------------------------
    def cls_logits(self, params, tokens):
        h = self.trunk(params, tokens)
        pooled = h.mean(axis=1)
        return _dense(params["head"], pooled)

    def metrics(self, params, x, y):
        if self.classes:
            logits = self.cls_logits(params, x)
            losses = ref.cross_entropy_ref(logits, y)
            correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
            return losses, correct
        n = x.shape[0]
        logits = self.lm_logits(params, x)
        flat = ref.cross_entropy_ref(
            logits.reshape(n * self.seq, self.vocab), y.reshape(n * self.seq)
        )
        losses = flat.reshape(n, self.seq).mean(axis=-1)
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32).mean(axis=-1)
        return losses, correct


class Mae:
    """MLP masked autoencoder over patch grids (MAE ViT-L substitute).

    Input images arrive as flat f32[in_dim]; they are cut into ``patches``
    patches of ``patch_dim`` features. A per-step pseudo-random mask hides
    ``mask_ratio`` of the patches; the encoder sees masked input, the
    decoder reconstructs everything, and the per-sample loss is the MSE on
    the *masked* patches only (the paper's reconstruction loss).
    """

    def __init__(self, name, in_dim, patches, enc_dim, dec_dim, mask_ratio=0.5):
        assert in_dim % patches == 0
        self.in_dim, self.patches = in_dim, patches
        self.patch_dim = in_dim // patches
        self.enc_dim, self.dec_dim, self.mask_ratio = enc_dim, dec_dim, mask_ratio
        flops = 2 * in_dim * enc_dim + 2 * enc_dim * dec_dim + 2 * dec_dim * in_dim
        self.spec = ModelSpec(name, "mae", (in_dim,), "f32", (), 0, flops)

    def init_params(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "enc1": _dense_init(k1, self.patch_dim, self.enc_dim),
            "enc2": _dense_init(k2, self.enc_dim, self.enc_dim),
            "dec1": _dense_init(k3, self.enc_dim, self.dec_dim),
            "dec2": _dense_init(k4, self.dec_dim, self.patch_dim),
        }

    def _mask(self, step, n):
        """Deterministic pseudo-random patch mask [n, patches] from the step."""
        key = jax.random.fold_in(jax.random.PRNGKey(17), step.astype(jnp.int32))
        u = jax.random.uniform(key, (n, self.patches))
        return (u < self.mask_ratio).astype(jnp.float32)  # 1 = hidden

    def per_sample_loss(self, params, x, y, step=None):
        if step is None:
            step = jnp.int32(0)
        n = x.shape[0]
        patches = x.reshape(n, self.patches, self.patch_dim)
        mask = self._mask(step, n)  # [n, p]
        visible = patches * (1.0 - mask)[..., None]
        h = jax.nn.relu(_dense(params["enc1"], visible))
        h = jax.nn.relu(_dense(params["enc2"], h))
        h = jax.nn.relu(_dense(params["dec1"], h))
        recon = _dense(params["dec2"], h)
        se = jnp.mean((recon - patches) ** 2, axis=-1)  # [n, p]
        denom = jnp.maximum(mask.sum(axis=-1), 1.0)
        return (se * mask).sum(axis=-1) / denom

    def metrics(self, params, x, y):
        losses = self.per_sample_loss(params, x, y, step=jnp.int32(1))
        return losses, jnp.zeros_like(losses)


# ---------------------------------------------------------------------------
# Optimizers over flat vectors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OptSpec:
    kind: str  # "sgdm" | "adamw"
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


def apply_optimizer(opt: OptSpec, flat, m, v, grads, lr, step):
    """One optimizer update over flat f32 vectors.

    Returns (flat', m', v'). SGD-momentum uses the ``m`` slot only and
    passes ``v`` through untouched, so every train_step artifact has the
    same arity regardless of optimizer.
    """
    if opt.kind == "sgdm":
        g = grads + opt.weight_decay * flat
        m_new = opt.momentum * m + g
        return flat - lr * m_new, m_new, v
    if opt.kind == "adamw":
        m_new = opt.beta1 * m + (1 - opt.beta1) * grads
        v_new = opt.beta2 * v + (1 - opt.beta2) * grads * grads
        t = step + 1.0
        mhat = m_new / (1 - opt.beta1**t)
        vhat = v_new / (1 - opt.beta2**t)
        upd = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * flat
        return flat - lr * upd, m_new, v_new
    raise ValueError(f"unknown optimizer {opt.kind}")


# ---------------------------------------------------------------------------
# Step builders (what aot.py lowers)
# ---------------------------------------------------------------------------

# Global-norm gradient clip applied in every train_step artifact.
GRAD_CLIP_NORM = 5.0


def build_fns(model, opt: OptSpec, seed: int = 0):
    """Build the uniform artifact function family for ``model``.

    Returns a dict of pure functions, each returning a tuple (lowered with
    return_tuple=True for the rust side):

      init:       (seed i32)                               -> (flat,)
      loss_fwd:   (flat, x, y)                             -> (losses,)
      train_step: (flat, m, v, x, y, wts, lr, step)        -> (flat', m', v',
                                                               losses, mean)
      eval_step:  (flat, x, y)                             -> (losses, correct)
    """
    template = model.init_params(jax.random.PRNGKey(seed))
    flat0, unravel = ravel_pytree(template)
    param_count = flat0.shape[0]

    is_mae = isinstance(model, Mae)

    def _tie_y(losses, y):
        # Unsupervised models (MAE) ignore labels; keep `y` in the graph
        # anyway so every artifact family has identical parameter arity
        # (jax prunes unused parameters from the lowered module).
        return losses + 0.0 * y.reshape(y.shape[0], -1)[:, 0].astype(jnp.float32)

    def _losses(flat, x, y, step):
        params = unravel(flat)
        if is_mae:
            return _tie_y(model.per_sample_loss(params, x, y, step=step.astype(jnp.int32)), y)
        return model.per_sample_loss(params, x, y)

    def init(seed_scalar):
        params = model.init_params(jax.random.PRNGKey(seed_scalar))
        flat, _ = ravel_pytree(params)
        return (flat,)

    def loss_fwd(flat, x, y):
        return (_losses(flat, x, y, jnp.float32(0)),)

    def train_step(flat, m, v, x, y, weights, lr, step):
        # Keep `step` in the graph even for optimizers that ignore it, so
        # every train_step artifact has the same 8-parameter signature
        # (jax prunes unused parameters from the lowered module otherwise).
        lr = lr + 0.0 * step

        def objective(f):
            losses = _losses(f, x, y, step)
            wsum = jnp.maximum(weights.sum(), 1e-12)
            return (weights * losses).sum() / wsum, losses

        (mean_loss, losses), grads = jax.value_and_grad(objective, has_aux=True)(flat)
        # Global-norm gradient clipping. Selection-heavy samplers repeatedly
        # concentrate BP on the hardest/noisiest samples, which can spiral
        # SGD-momentum; a high threshold leaves normal training untouched
        # while keeping every method in the stable regime (DESIGN.md §3).
        gnorm = jnp.sqrt(jnp.sum(grads * grads))
        grads = grads * jnp.minimum(1.0, GRAD_CLIP_NORM / jnp.maximum(gnorm, 1e-12))
        flat2, m2, v2 = apply_optimizer(opt, flat, m, v, grads, lr, step)
        return flat2, m2, v2, losses, mean_loss

    def eval_step(flat, x, y):
        params = unravel(flat)
        losses, correct = model.metrics(params, x, y)
        if is_mae:
            losses = _tie_y(losses, y)
        return losses, correct

    return {
        "init": init,
        "loss_fwd": loss_fwd,
        "train_step": train_step,
        "eval_step": eval_step,
        "param_count": param_count,
        "flat0": flat0,
    }


# ---------------------------------------------------------------------------
# Registry (names referenced by aot.py and the rust config presets)
# ---------------------------------------------------------------------------


def make_model(name: str, use_kernels: bool = True):
    """Factory for every model variant shipped in the artifact set."""
    k = dict(use_kernels=use_kernels)
    registry: dict[str, Callable[[], object]] = {
        # CIFAR-scale classifiers (Table 2).
        "mlp_cifar10": lambda: Mlp("mlp_cifar10", 3072, (256, 128), 10, **k),
        "cnn_small_c10": lambda: Cnn("cnn_small_c10", (16, 32), 10, **k),
        "cnn_small_c100": lambda: Cnn("cnn_small_c100", (16, 32), 100, **k),
        "cnn_deep_c100": lambda: Cnn("cnn_deep_c100", (32, 64, 128), 100, **k),
        # ViT-L fine-tune substitute (Table 3) + GLUE substitute (Table 5).
        "txf_cls": lambda: Transformer(
            "txf_cls", 512, 128, 2, 4, 64, classes=16, causal=False, **k
        ),
        "txf_nlu": lambda: Transformer(
            "txf_nlu", 512, 96, 2, 4, 48, classes=4, causal=False, **k
        ),
        # LM for SFT / end-to-end pre-training (Fig. 4, e2e example).
        "txf_lm": lambda: Transformer("txf_lm", 1024, 128, 4, 4, 64, classes=0, **k),
        "txf_lm_large": lambda: Transformer(
            "txf_lm_large", 4096, 256, 6, 8, 128, classes=0, **k
        ),
        # MAE pre-training substitute (Table 4 / Fig. 3).
        "mae_mlp": lambda: Mae("mae_mlp", 3072, 64, 192, 128, mask_ratio=0.5),
    }
    if name not in registry:
        raise KeyError(f"unknown model {name!r}; known: {sorted(registry)}")
    return registry[name]()


DEFAULT_OPTS = {
    "mlp_cifar10": OptSpec("sgdm", momentum=0.9, weight_decay=5e-4),
    "cnn_small_c10": OptSpec("sgdm", momentum=0.9, weight_decay=5e-4),
    "cnn_small_c100": OptSpec("sgdm", momentum=0.9, weight_decay=5e-4),
    "cnn_deep_c100": OptSpec("sgdm", momentum=0.9, weight_decay=5e-4),
    "txf_cls": OptSpec("adamw", weight_decay=0.01),
    "txf_nlu": OptSpec("adamw", weight_decay=0.01),
    "txf_lm": OptSpec("adamw", weight_decay=0.01),
    "txf_lm_large": OptSpec("adamw", weight_decay=0.01),
    "mae_mlp": OptSpec("adamw", weight_decay=0.05),
}
