//! Tab. 5: GLUE fine-tuning (8 synthetic NLU tasks). Rows: Baseline /
//! InfoBatch / Loss / Order / ES / ESWP. Paper shape: ES best average with
//! ~20% savings; ESWP close with the largest (~33%) savings; Order
//! degrades on the unstable tasks (RTE/MNLI analogues).

use crate::config::presets::{table5, Scale, GLUE_TASKS};
use crate::config::SamplerConfig;
use crate::metrics::Recorder;
use crate::util::bench::table_header;

use super::{make_runtime, mean_acc, run_config, total_cost, trials};

pub fn samplers() -> Vec<SamplerConfig> {
    vec![
        SamplerConfig::Uniform,
        SamplerConfig::infobatch_default(),
        SamplerConfig::Loss,
        SamplerConfig::Ordered,
        SamplerConfig::es_default(),
        SamplerConfig::eswp_default(),
    ]
}

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let methods = samplers();
    let runs = table5(scale, &methods);
    let rec = Recorder::new("table5_glue")?;
    let n_trials = trials(scale);

    // results[method][task] = (acc, cost)
    let mut accs = vec![vec![0.0f64; GLUE_TASKS.len()]; methods.len()];
    let mut costs: Vec<crate::coordinator::CostSummary> = vec![Default::default(); methods.len()];
    let mut rt = make_runtime(&runs[0])?;
    for (ti, (task, _)) in GLUE_TASKS.iter().enumerate() {
        for (mi, _) in methods.iter().enumerate() {
            let cfg = &runs[ti * methods.len() + mi];
            assert!(cfg.name.contains(task));
            let rs = run_config(cfg, rt.as_mut(), n_trials)?;
            for r in &rs {
                rec.record_result(r)?;
            }
            accs[mi][ti] = mean_acc(&rs);
            costs[mi].accumulate(&total_cost(&rs));
        }
    }

    let mut cols: Vec<&str> = vec!["method"];
    cols.extend(GLUE_TASKS.iter().map(|(t, _)| *t));
    cols.extend(["avg", "time saved"]);
    table_header("Table 5 — GLUE (synthetic NLU substitutes)", &cols);
    for (mi, m) in methods.iter().enumerate() {
        let avg = accs[mi].iter().sum::<f64>() / accs[mi].len() as f64;
        let mut row = format!("{:<10}", m.name());
        for a in &accs[mi] {
            row += &format!(" | {a:5.1}");
        }
        row += &format!(" | {avg:5.1}");
        if mi == 0 {
            row += " | —";
        } else {
            row += &format!(" | {}", super::fmt_saved(&costs[0], &costs[mi]));
        }
        println!("{row}");
    }
    Ok(())
}
