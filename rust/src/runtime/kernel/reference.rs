//! Scalar reference implementation: the pre-kernel `NativeRuntime`
//! forward/backward, kept **verbatim** (same loops, same strided walks,
//! same accumulation order) as an executable specification.
//!
//! Two consumers:
//! * `tests/kernel_equivalence.rs` asserts the blocked/threaded kernels
//!   match this implementation within 1e-5 on random shapes;
//! * `benches/perf_runtime.rs` times it as the baseline the kernel
//!   speedups in `BENCH_native.json` are measured against.
//!
//! Operates on the CANONICAL flat layout
//! `[W1 (d·h) | b1 (h) | W2 (h·c) | b2 (c)]` — deliberately including
//! the historical stride-`h` walk over `W1` that the kernel layer
//! exists to eliminate. Do not "fix" the access patterns here; the
//! whole point is to preserve the original arithmetic.

/// The pre-kernel scalar MLP: one hidden layer, relu, softmax CE,
/// SGD-momentum with weight decay.
pub struct ScalarMlp {
    pub d: usize,
    pub h: usize,
    pub c: usize,
    pub momentum: f32,
    pub weight_decay: f32,
    pub params: Vec<f32>,
    pub velocity: Vec<f32>,
    pub grads: Vec<f32>,
    h_buf: Vec<f32>,
    logits_buf: Vec<f32>,
}

impl ScalarMlp {
    pub fn new(d: usize, h: usize, c: usize) -> ScalarMlp {
        let pc = d * h + h + h * c + c;
        ScalarMlp {
            d,
            h,
            c,
            momentum: 0.9,
            weight_decay: 0.0,
            params: vec![0.0; pc],
            velocity: vec![0.0; pc],
            grads: vec![0.0; pc],
            h_buf: Vec::new(),
            logits_buf: Vec::new(),
        }
    }

    /// Canonical flat offsets (w1, b1, w2, b2).
    fn layout(&self) -> (usize, usize, usize, usize) {
        let w1 = 0;
        let b1 = self.d * self.h;
        let w2 = b1 + self.h;
        let b2 = w2 + self.h * self.c;
        (w1, b1, w2, b2)
    }

    pub fn set_params(&mut self, p: &[f32]) {
        assert_eq!(p.len(), self.params.len(), "param count mismatch");
        self.params.copy_from_slice(p);
    }

    /// Forward one batch; fills h_buf `[n·h]` and logits_buf `[n·c]`.
    /// (Verbatim pre-kernel loops, stride-h walk over W1 included.)
    pub fn forward(&mut self, x: &[f32], n: usize) {
        let (w1, b1, w2, b2) = self.layout();
        let (d, h, c) = (self.d, self.h, self.c);
        self.h_buf.resize(n * h, 0.0);
        self.logits_buf.resize(n * c, 0.0);
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            for j in 0..h {
                // W1 stored row-major [d][h]: column j dotted with x.
                let mut acc = self.params[b1 + j];
                for (k, &xk) in xi.iter().enumerate() {
                    acc += self.params[w1 + k * h + j] * xk;
                }
                self.h_buf[i * h + j] = acc.max(0.0); // relu
            }
            for j in 0..c {
                let mut acc = self.params[b2 + j];
                for k in 0..h {
                    acc += self.params[w2 + k * c + j] * self.h_buf[i * h + k];
                }
                self.logits_buf[i * c + j] = acc;
            }
        }
    }

    /// Per-sample CE losses from logits_buf.
    pub fn ce_losses(&self, y: &[i32], n: usize) -> Vec<f32> {
        let c = self.c;
        (0..n)
            .map(|i| {
                let li = &self.logits_buf[i * c..(i + 1) * c];
                let m = li.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let lse = li.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
                lse - li[y[i] as usize]
            })
            .collect()
    }

    pub fn loss_fwd(&mut self, x: &[f32], y: &[i32], n: usize) -> Vec<f32> {
        self.forward(x, n);
        self.ce_losses(y, n)
    }

    /// One weighted SGD-momentum step; returns (per-sample losses,
    /// weighted mean loss). Verbatim pre-kernel backward: recomputed
    /// softmax, scalar outer products, strided grad walks.
    pub fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
    ) -> (Vec<f32>, f32) {
        self.forward(x, n);
        let losses = self.ce_losses(y, n);
        let wsum: f32 = weights.iter().sum::<f32>().max(1e-12);
        let mean_loss = losses.iter().zip(weights).map(|(&l, &w)| l * w).sum::<f32>() / wsum;

        // Backward: dlogits = w_i/Σw * (softmax - onehot).
        let (w1o, b1o, w2o, b2o) = self.layout();
        let (d, h, c) = (self.d, self.h, self.c);
        self.grads.iter_mut().for_each(|g| *g = 0.0);
        let mut dh = vec![0.0f32; h];
        for i in 0..n {
            let scale = weights[i] / wsum;
            if scale == 0.0 {
                continue;
            }
            let li = &self.logits_buf[i * c..(i + 1) * c];
            let m = li.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = li.iter().map(|&v| (v - m).exp()).sum();
            let hi = &self.h_buf[i * h..(i + 1) * h];
            let xi = &x[i * d..(i + 1) * d];
            dh.iter_mut().for_each(|v| *v = 0.0);
            for j in 0..c {
                let p = (li[j] - m).exp() / z;
                let dl = scale * (p - if y[i] as usize == j { 1.0 } else { 0.0 });
                self.grads[b2o + j] += dl;
                for k in 0..h {
                    self.grads[w2o + k * c + j] += dl * hi[k];
                    dh[k] += dl * self.params[w2o + k * c + j];
                }
            }
            for k in 0..h {
                if hi[k] <= 0.0 {
                    continue; // relu gate
                }
                self.grads[b1o + k] += dh[k];
                let g = dh[k];
                for (q, &xq) in xi.iter().enumerate() {
                    self.grads[w1o + q * h + k] += g * xq;
                }
            }
        }
        // SGD momentum + weight decay.
        for i in 0..self.params.len() {
            let g = self.grads[i] + self.weight_decay * self.params[i];
            self.velocity[i] = self.momentum * self.velocity[i] + g;
            self.params[i] -= lr * self.velocity[i];
        }
        (losses, mean_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_learns_a_separable_toy() {
        let (d, h, c, n) = (4usize, 8usize, 2usize, 8usize);
        let mut mlp = ScalarMlp::new(d, h, c);
        // Tiny deterministic init.
        for (i, p) in mlp.params.iter_mut().enumerate() {
            *p = ((i * 2654435761) % 97) as f32 / 970.0 - 0.05;
        }
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0i32; n];
        for i in 0..n {
            y[i] = (i % 2) as i32;
            x[i * d + (i % 2)] = 2.0;
        }
        let w = vec![1.0f32; n];
        let (first, _) = mlp.train_step(&x, &y, &w, 0.1, n);
        let mut last = f32::INFINITY;
        for _ in 0..60 {
            let (_, m) = mlp.train_step(&x, &y, &w, 0.1, n);
            last = m;
        }
        let first_mean: f32 = first.iter().sum::<f32>() / n as f32;
        assert!(last < first_mean, "{last} !< {first_mean}");
    }

    #[test]
    fn zero_lr_step_leaves_params_unchanged() {
        let mut mlp = ScalarMlp::new(3, 4, 2);
        for (i, p) in mlp.params.iter_mut().enumerate() {
            *p = (i as f32 * 0.01).sin();
        }
        let before = mlp.params.clone();
        let x = vec![0.5f32; 2 * 3];
        let y = vec![0i32, 1];
        mlp.train_step(&x, &y, &[1.0, 1.0], 0.0, 2);
        assert_eq!(mlp.params, before);
    }
}
