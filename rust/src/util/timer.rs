//! Section timers: accumulate wall-clock per labeled phase of training.
//!
//! The paper's headline metric is *saved wall-clock time*, which requires
//! attributing every second of a run to forward-pass scoring (FP), backward
//! training steps (BP), selection overhead, or data movement. `PhaseTimers`
//! is that ledger; `coordinator::accounting` turns it into the paper's
//! "Time ↓" percentages.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Well-known phase labels (free-form labels also allowed).
pub mod phase {
    pub const SCORING_FP: &str = "scoring_fp";
    pub const TRAIN_BP: &str = "train_bp";
    pub const SELECT: &str = "select";
    pub const DATA: &str = "data";
    pub const EVAL: &str = "eval";
    pub const PRUNE: &str = "prune";
    /// Data-parallel synchronization rounds (§D.5): parameter averaging
    /// and cross-shard sampler-table merges in the threaded engine.
    pub const SYNC: &str = "sync";
}

/// A started wall-clock measurement — the blessed way to time code
/// outside the telemetry/serve/fault layers.
///
/// evolint's `determinism/no-wallclock-in-pipeline` rule (DESIGN.md §13)
/// keeps raw `Instant`/`SystemTime` reads out of engine, data, and bench
/// code; those sites time through this one type instead, so every clock
/// read in the pipeline is attributable to a single audited entry point
/// (timing feeds ledgers and telemetry only — never arithmetic, so the
/// determinism pins hold regardless of what the clock returns).
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[derive(Default, Clone, Debug)]
pub struct PhaseTimers {
    acc: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `label`.
    pub fn time<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(label, t0.elapsed());
        out
    }

    pub fn add(&mut self, label: &str, d: Duration) {
        *self.acc.entry(label.to_string()).or_default() += d;
        *self.counts.entry(label.to_string()).or_default() += 1;
    }

    pub fn get(&self, label: &str) -> Duration {
        self.acc.get(label).copied().unwrap_or_default()
    }

    pub fn count(&self, label: &str) -> u64 {
        self.counts.get(label).copied().unwrap_or_default()
    }

    /// Total across all phases.
    pub fn total(&self) -> Duration {
        self.acc.values().sum()
    }

    /// Merge another ledger into this one (distributed-sim reduction).
    ///
    /// Contract: `merge(o)` ≡ `merge_scaled(o, 1.0)` — durations and
    /// counts both sum exactly. There is a single merge implementation;
    /// any divergence between the two paths (e.g. one scaling counts)
    /// would skew per-phase mean durations in the threaded reduction.
    pub fn merge(&mut self, other: &PhaseTimers) {
        self.merge_scaled(other, 1.0);
    }

    /// Merge with durations scaled by `scale`. The threaded engine merges
    /// each of W concurrent workers at scale 1/W so phase totals stay
    /// wall-clock-equivalent (ideal scaling) rather than summed
    /// CPU-seconds; counts are always summed unscaled.
    pub fn merge_scaled(&mut self, other: &PhaseTimers, scale: f64) {
        for (k, v) in &other.acc {
            *self.acc.entry(k.clone()).or_default() += v.mul_f64(scale);
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
    }

    pub fn summary(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        let mut parts: Vec<String> = self
            .acc
            .iter()
            .map(|(k, v)| {
                format!("{k}={:.2}s ({:.0}%)", v.as_secs_f64(), 100.0 * v.as_secs_f64() / total)
            })
            .collect();
        parts.push(format!("total={total:.2}s"));
        parts.join(" ")
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.acc.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_elapsed_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let d = sw.elapsed();
        assert!(d >= Duration::from_millis(2), "elapsed {d:?}");
        assert!(sw.elapsed() >= d, "elapsed is monotonic");
    }

    #[test]
    fn accumulates_and_counts() {
        let mut t = PhaseTimers::new();
        t.add("a", Duration::from_millis(10));
        t.add("a", Duration::from_millis(20));
        t.add("b", Duration::from_millis(5));
        assert_eq!(t.get("a"), Duration::from_millis(30));
        assert_eq!(t.count("a"), 2);
        assert_eq!(t.total(), Duration::from_millis(35));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = PhaseTimers::new();
        let x = t.time("work", || 42);
        assert_eq!(x, 42);
        assert!(t.get("work") > Duration::ZERO);
    }

    #[test]
    fn merge_scaled_divides_durations_keeps_counts() {
        let mut a = PhaseTimers::new();
        let mut b = PhaseTimers::new();
        b.add("x", Duration::from_millis(40));
        b.add("x", Duration::from_millis(40));
        a.merge_scaled(&b, 0.25);
        assert_eq!(a.get("x"), Duration::from_millis(20));
        assert_eq!(a.count("x"), 2);
    }

    #[test]
    fn merge_sums_ledgers() {
        let mut a = PhaseTimers::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimers::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.get("x"), Duration::from_millis(3));
        assert_eq!(a.get("y"), Duration::from_millis(3));
    }

    #[test]
    fn merge_is_merge_scaled_at_one() {
        // The documented contract: the two merge paths must agree on
        // durations AND counts — `merge` is `merge_scaled(_, 1.0)`, and
        // counts sum unscaled under ANY scale (the threaded engine's
        // 1/W reduction divides wall-clock but must preserve how many
        // phase entries fed each mean).
        let mut src = PhaseTimers::new();
        src.add("x", Duration::from_millis(12));
        src.add("x", Duration::from_millis(8));
        src.add("y", Duration::from_millis(3));
        let mut via_merge = PhaseTimers::new();
        via_merge.add("x", Duration::from_millis(5));
        let mut via_scaled = via_merge.clone();
        via_merge.merge(&src);
        via_scaled.merge_scaled(&src, 1.0);
        for label in ["x", "y"] {
            assert_eq!(via_merge.get(label), via_scaled.get(label), "{label} durations");
            assert_eq!(via_merge.count(label), via_scaled.count(label), "{label} counts");
        }
        // Counts are scale-invariant even when durations are not.
        let mut quarter = PhaseTimers::new();
        quarter.merge_scaled(&src, 0.25);
        assert_eq!(quarter.count("x"), 2);
        assert_eq!(quarter.count("y"), 1);
        assert_eq!(quarter.get("x"), Duration::from_millis(5));
    }

    #[test]
    fn summary_mentions_phases() {
        let mut t = PhaseTimers::new();
        t.add(phase::TRAIN_BP, Duration::from_millis(90));
        t.add(phase::SCORING_FP, Duration::from_millis(10));
        let s = t.summary();
        assert!(s.contains("train_bp") && s.contains("scoring_fp"));
    }
}
