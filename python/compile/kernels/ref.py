"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact (up to float tolerance)
pure-`jax.numpy` counterpart here. The pytest suite (python/tests) sweeps
shapes/dtypes with hypothesis and asserts allclose between kernel and ref.
These refs are also usable directly by model.py, which lets aot.py emit a
"reference lowering" of every model for L2-level A/B checks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-sample softmax cross-entropy.

    Args:
      logits: f32[batch, classes]
      labels: i32[batch] in [0, classes)

    Returns:
      f32[batch] — per-sample loss, numerically stabilized log-softmax.
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - gold


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """Single-head scaled-dot-product attention.

    Args:
      q, k, v: f32[seq, head_dim]
      causal: apply a lower-triangular mask.

    Returns:
      f32[seq, head_dim]
    """
    t, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    scores = (q @ k.T) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, jnp.asarray(-jnp.inf, scores.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    return p @ v


def es_update_ref(
    s: jax.Array,
    w: jax.Array,
    losses: jax.Array,
    mask: jax.Array,
    beta1,
    beta2,
) -> tuple[jax.Array, jax.Array]:
    """Evolved-Sampling dual-EMA score/weight update (paper Eq. 3.1).

    For masked-in entries (mask == 1):
        w' = beta1 * s + (1 - beta1) * loss
        s' = beta2 * s + (1 - beta2) * loss
    Masked-out entries keep their previous s/w.

    Args:
      s, w, losses, mask: f32[n]
      beta1, beta2: scalars in [0, 1]

    Returns:
      (s', w'): updated f32[n] arrays.
    """
    s = s.astype(jnp.float32)
    w = w.astype(jnp.float32)
    losses = losses.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    new_w = beta1 * s + (1.0 - beta1) * losses
    new_s = beta2 * s + (1.0 - beta2) * losses
    return (mask * new_s + (1.0 - mask) * s, mask * new_w + (1.0 - mask) * w)
