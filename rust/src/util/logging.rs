//! Minimal leveled logger (no `log`/`env_logger` crates offline).
//!
//! Level comes from `EVOSAMPLE_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr so benches/examples can pipe stdout
//! tables cleanly.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static INIT: OnceLock<()> = OnceLock::new();

fn init() {
    INIT.get_or_init(|| {
        let lvl = match std::env::var("EVOSAMPLE_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(lvl: Level) {
    init();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    init();
    (lvl as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{}] {}", label(lvl), args);
    }
}

fn label(lvl: Level) -> &'static str {
    match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore default-ish for other tests
    }
}
