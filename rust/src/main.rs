//! `evosample` CLI — train with any sampler, inspect artifacts and
//! registered samplers, run the paper experiments.
//!
//! Subcommands:
//!   train          --config <run.toml> [--trials N] [--workers W]
//!                  [--threaded-workers] [--sync-every K] [--score-every K]
//!                  [--scoring-precision exact|bf16]
//!   list-models                       (artifact inventory)
//!   list-samplers                     (registry inventory: name/kind/params)
//!   experiment     --id <table2|table3|table4|table5|fig4|fig5|fig6|fig7|
//!                       fig1|fig9|fig10|tab6|tab7|tab8|freq|theory> [--full]
//!   illustrate                        (fig1 weight-signal traces)
//!   help
//!
//! Unknown subcommands are an error (exit 1); `help` is the only usage
//! path.

use evosample::cli::Args;
use evosample::config;
use evosample::config::presets::Scale;
use evosample::experiments;
use evosample::metrics::{EventLog, Recorder};
use evosample::prelude::{ProgressSink, SessionBuilder};
use evosample::runtime::manifest::Manifest;
use evosample::sampler::registry;

const USAGE: &str = "\
evosample — Data-Efficient Training by Evolved Sampling (ES/ESWP)

USAGE:
  evosample train --config <run.toml> [--trials N] [--workers W]
                  [--threaded-workers] [--sync-every K] [--score-every K]
                  [--scoring-precision exact|bf16]
                  (--score-every K re-scores the meta-batch every K-th
                   step and selects from cached weights in between;
                   --scoring-precision bf16 ranks the meta-batch from a
                   bf16 weight shadow — BP and eval stay exact)
  evosample list-models
  evosample list-samplers
  evosample experiment --id <table2|table3|table4|table5|fig1|fig4|fig5|
                             fig6|fig7|fig9|fig10|tab6|tab7|tab8|freq|
                             theory>
                       [--full]
  evosample illustrate
  evosample help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let args =
        Args::parse(argv, &["full", "threaded-workers"]).map_err(|e| anyhow::anyhow!("{e}\n{USAGE}"))?;
    match args.subcommand.as_str() {
        "train" => {
            let path = args
                .flag("config")
                .ok_or_else(|| anyhow::anyhow!("train needs --config <run.toml>"))?;
            let mut cfg = config::load(path).map_err(|e| anyhow::anyhow!("{e}"))?;
            let trials = args.usize_flag("trials").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap_or(1);
            // Engine knobs: CLI overrides on top of the TOML config.
            if let Some(w) = args.usize_flag("workers").map_err(|e| anyhow::anyhow!("{e}"))? {
                cfg.workers = w;
            }
            if args.has("threaded-workers") {
                cfg.threaded_workers = true;
            }
            if let Some(k) = args.usize_flag("sync-every").map_err(|e| anyhow::anyhow!("{e}"))? {
                cfg.sync_every = k;
            }
            if let Some(k) = args.usize_flag("score-every").map_err(|e| anyhow::anyhow!("{e}"))? {
                cfg.score_every = k;
            }
            if let Some(p) = args.flag("scoring-precision") {
                cfg.scoring_precision =
                    config::ScoringPrecision::parse(p).map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            cfg.validate().map_err(|e| anyhow::anyhow!("config: {e}"))?;
            if cfg.score_every > 1 {
                println!(
                    "scoring: every {} steps (stale-weight selection in between)",
                    cfg.score_every
                );
            }
            if cfg.scoring_precision != config::ScoringPrecision::Exact {
                println!("scoring: {} forward pass (BP and eval stay exact)", cfg.scoring_precision.as_str());
            }
            if cfg.threaded_workers {
                println!(
                    "engine: {} threaded workers (param sync every {})",
                    cfg.workers,
                    if cfg.sync_every > 0 {
                        format!("{} steps", cfg.sync_every)
                    } else {
                        "epoch".to_string()
                    }
                );
            }
            // One runtime serves every trial; each trial is an
            // independent session (own split from its trial seed) with
            // progress + event-log sinks on the typed event stream.
            let mut rt = experiments::make_runtime(&cfg)?;
            let rec = Recorder::new("cli_train")?;
            for t in 0..trials {
                let mut c = cfg.clone();
                c.seed = cfg.seed + 1000 * t as u64;
                let mut session = SessionBuilder::from_config(c)
                    .runtime_mut(rt.as_mut())
                    .sink(Box::new(ProgressSink::new()))
                    .sink(Box::new(EventLog::new("cli_train_events")?))
                    .build()?;
                let r = session.run()?;
                rec.record_result(&r)?;
                println!(
                    "trial {t}: acc {:.2}%  eval loss {:.4}  wall {:.2}s  bp_samples {}  ({})",
                    r.accuracy_pct(),
                    r.final_eval.loss,
                    r.cost.train_wall_s(),
                    r.cost.bp_samples,
                    r.timers.summary(),
                );
            }
            Ok(())
        }
        "list-models" => {
            let m = Manifest::load_default()?;
            println!("{:<16} {:>10} {:>8} {:>14} train_steps", "model", "params", "classes", "fwd GFLOP/sample");
            for (name, e) in &m.models {
                println!(
                    "{name:<16} {:>10} {:>8} {:>14.4} {:?}",
                    e.param_count,
                    e.classes,
                    e.flops_per_sample_fwd as f64 / 1e9,
                    e.train_step.keys().collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        "list-samplers" => {
            println!(
                "{:<14} {:<10} {:<8} {:<18} params",
                "name", "kind", "scoring", "aliases"
            );
            for e in registry::entries() {
                let params: Vec<String> = e
                    .params()
                    .iter()
                    .map(|p| format!("{}={} ({})", p.name, p.default, p.doc))
                    .collect();
                println!(
                    "{:<14} {:<10} {:<8} {:<18} {}",
                    e.name(),
                    e.kind(),
                    // "strided" = the per-step scoring FP honors
                    // run.score_every; "-" = the method never scores.
                    if e.frequency_tunable() { "strided" } else { "-" },
                    e.aliases().join(","),
                    if params.is_empty() { "-".to_string() } else { params.join("; ") },
                );
            }
            Ok(())
        }
        "experiment" => {
            let id = args
                .flag("id")
                .ok_or_else(|| anyhow::anyhow!("experiment needs --id <...>"))?;
            let scale = if args.has("full") { Scale::Full } else { Scale::from_env() };
            match id {
                "table2" => experiments::table2::run(scale),
                "table3" => experiments::table3::run(scale),
                "table4" => experiments::table4::run(scale),
                "table5" => experiments::table5::run(scale),
                "fig1" => experiments::fig1::run(400),
                "fig4" => experiments::fig4::run(scale),
                "fig5" => experiments::fig5::run(scale),
                "fig6" => experiments::fig6::run(scale, false),
                "fig7" => experiments::fig6::run(scale, true),
                "fig9" => experiments::fig9::run(scale),
                "fig10" => experiments::fig10::run(scale),
                "tab6" => experiments::ablations::run_tab6(scale),
                "tab7" => experiments::ablations::run_tab7(scale),
                "tab8" => experiments::ablations::run_tab8(scale),
                "freq" => experiments::frequency::run(scale),
                "theory" => experiments::theory::run_all(),
                other => anyhow::bail!("unknown experiment {other:?}\n{USAGE}"),
            }
        }
        "illustrate" => experiments::fig1::run(400),
        "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}
