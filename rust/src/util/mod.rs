//! Foundation substrates built from scratch (no external crates offline):
//! RNG, math helpers, JSON, property-testing, benchmarking, timing, logs.

pub mod bench;
pub mod json;
pub mod logging;
pub mod math;
pub mod proptest;
pub mod rng;
pub mod timer;

pub use rng::Pcg64;
