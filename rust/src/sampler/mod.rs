//! Dynamic data selection — the paper's contribution (ES/ESWP) plus every
//! baseline it compares against (Tab. 1).
//!
//! The trainer drives samplers through one trait with four hooks:
//!
//! 1. `on_epoch_start` — *set-level* selection: return the kept dataset
//!    indices for this epoch (pruning methods shrink the set; batch-level
//!    methods return everything).
//! 2. `needs_meta_losses` — whether this epoch's steps require a scoring
//!    forward pass over the meta-batch (batch-level methods only; this is
//!    the "extra FP" of the paper's §3.3 cost analysis).
//! 3. `observe_meta` / `observe_train` — fresh per-sample losses, either
//!    from the scoring FP (meta) or as a free byproduct of the training
//!    step (train). ES updates its Eq. 3.1 state from both, so the
//!    annealing epochs double as weight warm-up exactly as in Alg. 1.
//! 4. `select` — *batch-level* selection of the BP mini-batch from the
//!    meta-batch, with per-sample gradient weights (InfoBatch's rescale).

pub mod analysis;
pub mod annealing;
pub mod evolved;
pub mod infobatch;
pub mod kakurenbo;
pub mod loss_based;
pub mod ordered;
pub mod registry;
pub mod ucb;
pub mod uniform;
pub mod weights;

use crate::config::SamplerConfig;
use crate::util::json::Json;
use crate::util::Pcg64;

/// Serialize an f32 table as a JSON array. f32 → f64 is exact and the
/// writer emits shortest-roundtrip decimals, so `json_to_table` recovers
/// the identical bits — the property sampler checkpoints rely on.
pub fn table_to_json(t: &[f32]) -> Json {
    Json::Arr(t.iter().map(|&v| Json::Num(v as f64)).collect())
}

/// Inverse of [`table_to_json`]; checks the length against `n`.
pub fn json_to_table(j: &Json, n: usize) -> anyhow::Result<Vec<f32>> {
    let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("sampler state: expected array"))?;
    anyhow::ensure!(arr.len() == n, "sampler state: table len {} != n {}", arr.len(), n);
    arr.iter()
        .map(|v| {
            v.as_f64().map(|x| x as f32).ok_or_else(|| anyhow::anyhow!("sampler state: non-number"))
        })
        .collect()
}

/// The mini-batch chosen for the backward pass.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    /// Dataset indices to run BP on (subset or all of the meta-batch).
    pub indices: Vec<u32>,
    /// Per-sample gradient weights (all 1.0 unless the method rescales).
    pub weights: Vec<f32>,
}

impl Selection {
    pub fn unweighted(indices: Vec<u32>) -> Self {
        let weights = vec![1.0; indices.len()];
        Selection { indices, weights }
    }
}

/// One shard's buffered loss observations, exported by a worker-replica
/// sampler for the engine's §D.5 synchronization round: each entry is one
/// (indices, losses) batch in observation order.
pub type ShardObservations = Vec<(Vec<u32>, Vec<f32>)>;

/// Observation buffer for worker-replica samplers. Inert until `begin`
/// is called (zero overhead on the single-worker path); thereafter every
/// `record` appends one observed batch for the next `export`.
#[derive(Default, Debug)]
pub struct ShardLog {
    buf: Option<ShardObservations>,
}

impl ShardLog {
    /// Start (or restart) buffering. Called by the engine when the sampler
    /// becomes a worker-local replica.
    pub fn begin(&mut self) {
        if self.buf.is_none() {
            self.buf = Some(Vec::new());
        }
    }

    /// Record one applied observation batch (no-op unless begun).
    pub fn record(&mut self, indices: &[u32], losses: &[f32]) {
        if let Some(b) = &mut self.buf {
            b.push((indices.to_vec(), losses.to_vec()));
        }
    }

    /// Drain everything recorded since the last export.
    pub fn export(&mut self) -> ShardObservations {
        self.buf.as_mut().map(std::mem::take).unwrap_or_default()
    }
}

/// One dynamic sampling method. See module docs for the call protocol.
pub trait Sampler: Send {
    fn name(&self) -> &'static str;

    /// Set-level selection at epoch start; returns kept dataset indices.
    fn on_epoch_start(&mut self, _epoch: usize, _rng: &mut Pcg64) -> Vec<u32> {
        (0..self.n() as u32).collect()
    }

    /// Does this epoch's step loop need a scoring FP over meta-batches?
    fn needs_meta_losses(&self, _epoch: usize) -> bool {
        false
    }

    /// Fresh losses from the scoring FP on a meta-batch.
    fn observe_meta(&mut self, _indices: &[u32], _losses: &[f32], _epoch: usize) {}

    /// Fresh losses from the training step itself (free, no extra FP).
    fn observe_train(&mut self, _indices: &[u32], _losses: &[f32], _epoch: usize) {}

    /// Batch-level selection of `mini` samples from the meta-batch.
    /// Default: train on the whole meta-batch, unweighted.
    fn select(&mut self, meta: &[u32], _mini: usize, _epoch: usize, _rng: &mut Pcg64) -> Selection {
        Selection::unweighted(meta.to_vec())
    }

    /// Batch-level selection on a *non-scoring* step (`run.score_every`
    /// stride, DESIGN.md §8): no fresh meta losses were observed this
    /// step, so the selection must come from whatever weight state the
    /// sampler cached at the last scoring step. The default delegates to
    /// [`Sampler::select`], which is correct for every table-driven
    /// method (ES/ESWP/loss/order select from their stored tables and
    /// never read step-local losses); override only if `select` assumes
    /// an `observe_meta` immediately preceded it.
    fn select_cached(
        &mut self,
        meta: &[u32],
        mini: usize,
        epoch: usize,
        rng: &mut Pcg64,
    ) -> Selection {
        self.select(meta, mini, epoch, rng)
    }

    /// Dataset size this sampler was built for.
    fn n(&self) -> usize;

    // ---- shard synchronization (§D.5, threaded engine) -----------------
    //
    // In threaded data-parallel mode every worker drives its own sampler
    // replica over a disjoint index shard. At each sync round the engine
    // all-gathers the observations every replica *applied* since the last
    // round and replays them into the canonical sampler and all peers:
    // because shards are disjoint, per-index update order is preserved and
    // every table converges to the same state a single shared sampler
    // would have reached.

    /// Switch this sampler into worker-replica mode for `shard`: start
    /// buffering applied observations for later export. Default: no-op
    /// (samplers without cross-shard state need no synchronization).
    fn begin_shard(&mut self, _shard: &[u32]) {}

    /// Drain the observations buffered since `begin_shard` / the last
    /// export — the payload of the sync round. Default: empty.
    fn export_observations(&mut self) -> ShardObservations {
        Vec::new()
    }

    /// Apply a peer shard's exported observations. The default replays
    /// them through `observe_train`, matching the sequential simulation's
    /// epoch-end merge; samplers whose `observe_train` gates on epoch
    /// (e.g. ES annealing) override this to apply the updates raw.
    fn merge_observations(&mut self, obs: &[(Vec<u32>, Vec<f32>)], epoch: usize) {
        for (indices, losses) in obs {
            self.observe_train(indices, losses, epoch);
        }
    }

    // ---- checkpoint state (serve resume, DESIGN.md §10) -----------------

    /// Serialize the sampler's evolving state for an epoch-boundary job
    /// checkpoint. `None` (the default) means the method does not support
    /// mid-run state capture — the serve scheduler then falls back to
    /// restart-from-scratch on resume (still deterministic, just slower).
    /// Stateless methods return `Some(Json::Null)` so resume is exact.
    fn state_json(&self) -> Option<Json> {
        None
    }

    /// Restore state captured by [`Sampler::state_json`] into a freshly
    /// built sampler of the same config/`n`. Must reproduce the captured
    /// tables bit-for-bit. Default: unsupported.
    fn restore_state(&mut self, _state: &Json) -> anyhow::Result<()> {
        anyhow::bail!("sampler {} does not support state restore", self.name())
    }

    /// Concrete-type access for table inspection (tests, analysis).
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Floor a pruned kept set at `min_keep` indices (the engine passes the
/// meta-batch size): [`crate::data::loader::EpochLoader`] pads ragged
/// tails by wrapping around the shuffled order, so a kept set smaller
/// than one meta-batch would emit *duplicate indices inside a single
/// meta-batch* — violating the without-replacement contract of
/// [`weights::sample_without_replacement`] downstream. When the clamp
/// triggers, pruned indices are added back in ascending dataset order
/// (deterministic, so threaded replicas replaying the same epoch agree);
/// `kept.len() >= min_keep` inputs pass through untouched.
pub fn enforce_min_keep(kept: Vec<u32>, min_keep: usize, n: usize) -> Vec<u32> {
    if kept.len() >= min_keep.min(n) {
        return kept;
    }
    let mut in_kept = vec![false; n];
    for &i in &kept {
        in_kept[i as usize] = true;
    }
    let mut out = kept;
    for i in 0..n as u32 {
        if out.len() >= min_keep {
            break;
        }
        if !in_kept[i as usize] {
            out.push(i);
        }
    }
    out
}

/// Instantiate a sampler from config for a dataset of `n` samples trained
/// for `epochs` epochs. Construction routes through the open
/// [`registry`], so externally-registered policies
/// ([`SamplerConfig::Custom`]) build exactly like the built-ins.
pub fn build(cfg: &SamplerConfig, n: usize, epochs: usize) -> anyhow::Result<Box<dyn Sampler>> {
    let (name, bag) = cfg.to_spec();
    registry::build_named(&name, &bag, n, epochs).map_err(|e| anyhow::anyhow!("sampler: {e}"))
}

/// Taxonomy of a sampling method (paper Tab. 1): where in the loop it
/// intervenes. Carried as registry metadata (`SamplerEntry::kind`) and
/// surfaced by `evosample list-samplers`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// No selection (standard batched sampling).
    Baseline,
    /// Per-step mini-batch selection from the meta-batch.
    BatchLevel,
    /// Epoch-boundary dataset pruning.
    SetLevel,
    /// Both batch-level selection and set-level pruning (ESWP).
    Both,
}

impl std::fmt::Display for SamplerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // `pad` (not `write_str`) so `{:<10}`-style table columns align.
        f.pad(match self {
            SamplerKind::Baseline => "baseline",
            SamplerKind::BatchLevel => "batch",
            SamplerKind::SetLevel => "set",
            SamplerKind::Both => "batch+set",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerConfig as SC;

    #[test]
    fn build_constructs_every_method() {
        let cfgs = [
            SC::Uniform,
            SC::Loss,
            SC::Ordered,
            SC::es_default(),
            SC::eswp_default(),
            SC::infobatch_default(),
            SC::kakurenbo_default(),
            SC::ucb_default(),
            SC::RandomPrune { prune_ratio: 0.2 },
        ];
        for cfg in cfgs {
            let s = build(&cfg, 100, 10).unwrap();
            assert_eq!(s.n(), 100);
            assert_eq!(s.name(), cfg.name());
        }
    }

    #[test]
    fn default_epoch_start_keeps_everything() {
        let mut s = build(&SC::Uniform, 50, 10).unwrap();
        let kept = s.on_epoch_start(0, &mut Pcg64::new(0));
        assert_eq!(kept, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn selection_unweighted_has_unit_weights() {
        let sel = Selection::unweighted(vec![3, 1]);
        assert_eq!(sel.weights, vec![1.0, 1.0]);
    }

    #[test]
    fn shard_log_inert_until_begun() {
        let mut log = ShardLog::default();
        log.record(&[1, 2], &[0.5, 0.7]);
        assert!(log.export().is_empty(), "recording before begin is a no-op");
        log.begin();
        log.record(&[1, 2], &[0.5, 0.7]);
        log.record(&[3], &[0.1]);
        let obs = log.export();
        assert_eq!(obs.len(), 2);
        assert_eq!(obs[0], (vec![1, 2], vec![0.5, 0.7]));
        assert!(log.export().is_empty(), "export drains");
        log.record(&[4], &[9.0]);
        assert_eq!(log.export().len(), 1, "still buffering after export");
    }

    #[test]
    fn select_cached_defaults_to_select() {
        // The default cached path must make identical draws to `select`
        // under identical RNG state — the k=1 bit-for-bit guarantee rests
        // on both paths being the same computation for the built-ins.
        let mut a = build(&SC::es_default(), 32, 10).unwrap();
        let mut b = build(&SC::es_default(), 32, 10).unwrap();
        let idx: Vec<u32> = (0..32).collect();
        let losses: Vec<f32> = (0..32).map(|i| i as f32 * 0.1).collect();
        a.observe_meta(&idx, &losses, 1);
        b.observe_meta(&idx, &losses, 1);
        let rng = Pcg64::new(42);
        let sa = a.select(&idx, 8, 1, &mut rng.clone());
        let sb = b.select_cached(&idx, 8, 1, &mut rng.clone());
        assert_eq!(sa, sb);
    }

    #[test]
    fn enforce_min_keep_floors_small_kept_sets() {
        // Identity when already large enough.
        let kept = vec![3u32, 7, 9];
        assert_eq!(enforce_min_keep(kept.clone(), 3, 16), kept);
        assert_eq!(enforce_min_keep(kept.clone(), 2, 16), kept);
        // Tops up with pruned indices in ascending order.
        let out = enforce_min_keep(vec![5u32, 9], 5, 16);
        assert_eq!(out, vec![5, 9, 0, 1, 2]);
        // Capped at n (never invents indices).
        let out = enforce_min_keep(vec![0u32], 10, 3);
        assert_eq!(out, vec![0, 1, 2]);
        // Output is always duplicate-free.
        let mut sorted = out;
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3);
    }

    #[test]
    fn enforce_min_keep_property() {
        use crate::util::proptest::check;
        check("min_keep superset+unique", 80, |g| {
            let n = g.usize_in(1, 200);
            let keep = g.usize_in(1, n);
            let min_keep = g.usize_in(0, n + 8);
            let kept = g.rng().choose_k(n, keep);
            let out = enforce_min_keep(kept.clone(), min_keep, n);
            crate::prop_assert!(
                out.len() >= min_keep.min(n).max(kept.len().min(n)),
                "len {} < floor", out.len()
            );
            let mut sorted = out.clone();
            sorted.sort_unstable();
            let before = sorted.len();
            sorted.dedup();
            crate::prop_assert!(sorted.len() == before, "duplicates in clamped kept");
            for &i in &kept {
                crate::prop_assert!(out.contains(&i), "dropped kept index {i}");
            }
            for &i in &out {
                crate::prop_assert!((i as usize) < n, "oob {i}");
            }
            Ok(())
        });
    }

    #[test]
    fn state_json_round_trips_bit_for_bit() {
        // Serialize → JSON text → parse → restore must reproduce the
        // exact tables (and hence the exact selection sequence).
        for cfg in [SC::es_default(), SC::eswp_default(), SC::Loss] {
            let mut a = build(&cfg, 24, 10).unwrap();
            let idx: Vec<u32> = (0..24).collect();
            let losses: Vec<f32> = (0..24).map(|i| (i as f32 * 0.37 + 0.01).sin().abs()).collect();
            a.observe_meta(&idx, &losses, 1);
            let state = a.state_json().expect("table-driven samplers capture state");
            let wire = state.to_string_compact();
            let parsed = Json::parse(&wire).unwrap();
            let mut b = build(&cfg, 24, 10).unwrap();
            b.restore_state(&parsed).unwrap();
            let rng = Pcg64::new(77);
            for _ in 0..5 {
                let sa = a.select(&idx, 6, 1, &mut rng.clone());
                let sb = b.select(&idx, 6, 1, &mut rng.clone());
                assert_eq!(sa, sb, "restored sampler diverged ({})", cfg.name());
            }
        }
    }

    #[test]
    fn stateless_samplers_checkpoint_as_null() {
        let mut u = build(&SC::Uniform, 8, 2).unwrap();
        assert_eq!(u.state_json(), Some(Json::Null));
        u.restore_state(&Json::Null).unwrap();
        let mut rp = build(&SC::RandomPrune { prune_ratio: 0.5 }, 8, 2).unwrap();
        assert_eq!(rp.state_json(), Some(Json::Null));
        rp.restore_state(&Json::Null).unwrap();
        // Methods without capture support advertise it via None + Err.
        let mut ib = build(&SC::infobatch_default(), 8, 2).unwrap();
        assert_eq!(ib.state_json(), None);
        assert!(ib.restore_state(&Json::Null).is_err());
    }

    #[test]
    fn default_shard_api_is_inert() {
        let mut s = build(&SC::Uniform, 10, 4).unwrap();
        s.begin_shard(&[0, 1, 2]);
        s.observe_train(&[0], &[1.0], 0);
        assert!(s.export_observations().is_empty());
        // Default merge replays observe_train; for Uniform that's a no-op,
        // but it must not panic.
        s.merge_observations(&[(vec![1], vec![2.0])], 0);
    }
}
