//! Regenerates paper Fig. 6 (beta grid) and Fig. 7 (dense local grid).
fn main() {
    let scale = evosample::config::presets::Scale::from_env();
    evosample::experiments::fig6::run(scale, false).expect("fig6");
    evosample::experiments::fig6::run(scale, true).expect("fig7");
}
