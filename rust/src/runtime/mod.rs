//! Model runtimes: where the coordinator's compute actually runs.
//!
//! Two interchangeable backends behind one trait:
//!
//! * [`xla_rt::XlaRuntime`] — the production path. Loads the AOT-lowered
//!   HLO text artifacts (L2 JAX models + L1 Pallas kernels) through the
//!   PJRT C API and executes them natively. Python is never involved.
//! * [`native::NativeRuntime`] — a pure-rust MLP with hand-written
//!   forward/backward. Used by the test suite and the L3-isolation benches
//!   so coordinator logic is exercised without artifacts, and as an
//!   independent implementation to cross-check the XLA path's training
//!   behavior.
//!
//! The runtime owns the model/optimizer state; the coordinator only sees
//! batches in, per-sample losses out.

pub mod kernel;
pub mod manifest;
pub mod native;
pub mod xla_rt;

use crate::data::{Modality, TensorDataset};

/// Borrowed batch features, matching the dataset's modality.
#[derive(Clone, Copy, Debug)]
pub enum BatchX<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> BatchX<'a> {
    pub fn len_elems(&self) -> usize {
        match self {
            BatchX::F32(v) => v.len(),
            BatchX::I32(v) => v.len(),
        }
    }
}

/// Output of one training step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// Per-sample (unweighted) losses of the trained batch.
    pub losses: Vec<f32>,
    /// Weighted mean loss actually optimized.
    pub mean_loss: f32,
}

/// A loaded model + optimizer state that the trainer drives.
///
/// Contract notes:
/// * `batch` sizes passed to `train_step`/`loss_fwd`/`eval` must be among
///   `train_sizes()` / `fwd_size()` / `eval_size()` — artifact shapes are
///   static. The trainer guarantees this via config validation.
/// * `init` resets parameters AND optimizer state (fresh trial).
pub trait ModelRuntime {
    fn param_count(&self) -> usize;

    /// (Re-)initialize parameters from a seed; resets optimizer state.
    fn init(&mut self, seed: i32) -> anyhow::Result<()>;

    /// Forward-only per-sample losses (the sampler scoring pass).
    /// Implemented in terms of [`Self::loss_fwd_into`] — the write-into
    /// form is the required one, so the scoring hot path is
    /// allocation-free for every runtime, and this convenience wrapper
    /// just fronts it with a fresh `Vec`.
    fn loss_fwd(&mut self, x: BatchX<'_>, y: &[i32], n: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n);
        self.loss_fwd_into(x, y, n, &mut out)?;
        Ok(out)
    }

    /// Write-into scoring pass: APPENDS `n` losses to `out` (callers
    /// clear). This is the primitive the engine's step hot path drives
    /// with reusable scratch; `loss_fwd` is derived from it.
    fn loss_fwd_into(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()>;

    /// Reduced-precision *ranking* pass: like `loss_fwd_into`, but the
    /// losses only need to order samples for selection, so backends may
    /// serve it from lower-precision weights (NativeRuntime: a bf16
    /// shadow pack). Used by the engine's ScoringFp stage when
    /// `run.scoring_precision = "bf16"`; the BP batch and eval always go
    /// through the exact paths. Default: the exact `loss_fwd_into`.
    fn loss_fwd_ranked(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.loss_fwd_into(x, y, n, out)
    }

    /// One optimizer step on a weighted batch; increments the step count.
    fn train_step(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
    ) -> anyhow::Result<StepOutput>;

    /// Write-into variant of `train_step`: APPENDS the `n` per-sample
    /// losses to `losses` (so micro-batched gradient accumulation can
    /// share one buffer) and returns the weighted mean loss. Backends
    /// override to keep the step hot path allocation-free.
    #[allow(clippy::too_many_arguments)]
    fn train_step_into(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
        losses: &mut Vec<f32>,
    ) -> anyhow::Result<f32> {
        let out = self.train_step(x, y, weights, lr, n)?;
        losses.extend_from_slice(&out.losses);
        Ok(out.mean_loss)
    }

    /// Eval pass: per-sample (losses, correct∈[0,1]).
    fn eval(&mut self, x: BatchX<'_>, y: &[i32], n: usize) -> anyhow::Result<(Vec<f32>, Vec<f32>)>;

    /// Batch sizes with a compiled train_step.
    fn train_sizes(&self) -> Vec<usize>;

    /// Scoring-FP batch size (meta-batch).
    fn fwd_size(&self) -> usize;

    /// Eval chunk size.
    fn eval_size(&self) -> usize;

    /// Snapshot / install flat parameters (checkpointing, distributed sync).
    fn get_params(&mut self) -> anyhow::Result<Vec<f32>>;
    fn set_params(&mut self, params: &[f32]) -> anyhow::Result<()>;

    /// Write the canonical flat parameters into a caller-owned buffer of
    /// exactly `param_count()` elements — the allocation-free sibling of
    /// `get_params`, used by the threaded engine's §D.5 parameter
    /// averaging so sync rounds stop cloning a fresh `Vec` per replica.
    fn read_params_into(&mut self, out: &mut [f32]) -> anyhow::Result<()> {
        let p = self.get_params()?;
        anyhow::ensure!(out.len() == p.len(), "param count mismatch");
        out.copy_from_slice(&p);
        Ok(())
    }

    /// Snapshot the optimizer's evolving state (momentum buffers, …) for
    /// job checkpointing — without it a resumed run would restart the
    /// velocity at zero and drift off the uninterrupted trajectory.
    /// Backends without host-readable optimizer state return empty
    /// (resume then degrades to params-only restore). Paired with
    /// [`Self::set_opt_state`].
    fn get_opt_state(&mut self) -> anyhow::Result<Vec<f32>> {
        Ok(Vec::new())
    }

    /// Install optimizer state captured by [`Self::get_opt_state`].
    fn set_opt_state(&mut self, state: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(state.is_empty(), "this runtime has no optimizer state to restore");
        Ok(())
    }

    /// Analytic forward FLOPs per sample (for the accounting cost model).
    fn flops_per_sample_fwd(&self) -> u64;

    /// Spawn an independent replica — own parameters and optimizer state,
    /// initialized to a copy of this runtime's *current* state — for the
    /// engine's threaded data-parallel mode. Replicas synchronize through
    /// `get_params`/`set_params` averaging at sync rounds. Default:
    /// graceful Unsupported for backends whose device state cannot be
    /// duplicated across threads.
    fn spawn_replica(&self) -> anyhow::Result<Box<dyn ModelRuntime + Send>> {
        anyhow::bail!(
            "this runtime does not support threaded replicas (spawn_replica \
             unimplemented); run with threaded_workers = false"
        )
    }
}

/// Assemble a batch's features/labels from a dataset. Helper shared by the
/// trainer and tests.
pub struct BatchBuf {
    pub xf: Vec<f32>,
    pub xi: Vec<i32>,
    pub y: Vec<i32>,
}

impl BatchBuf {
    pub fn new() -> Self {
        BatchBuf { xf: Vec::new(), xi: Vec::new(), y: Vec::new() }
    }

    pub fn fill(&mut self, ds: &TensorDataset, indices: &[u32]) {
        match ds.modality {
            Modality::Float { .. } => ds.gather_x_f32(indices, &mut self.xf),
            Modality::Tokens { .. } => ds.gather_x_i32(indices, &mut self.xi),
        }
        ds.gather_y(indices, &mut self.y);
    }

    pub fn x(&self, ds: &TensorDataset) -> BatchX<'_> {
        match ds.modality {
            Modality::Float { .. } => BatchX::F32(&self.xf),
            Modality::Tokens { .. } => BatchX::I32(&self.xi),
        }
    }
}

impl Default for BatchBuf {
    fn default() -> Self {
        Self::new()
    }
}

/// Build the runtime a config asks for: the XLA artifact path when
/// available, otherwise a native fallback for float-feature models
/// (tests/dev boxes without `make artifacts`). The default runtime
/// chooser behind `api::SessionBuilder`.
pub fn make_runtime(cfg: &crate::config::RunConfig) -> anyhow::Result<Box<dyn ModelRuntime>> {
    make_runtime_with_budget(cfg, None)
}

/// [`make_runtime`] with an optional shared [`kernel::pool::KernelBudget`]
/// capping the aggregate spawned kernel lanes across runtimes (the serve
/// scheduler's per-process cap). The XLA path manages its own device
/// threads and ignores the budget; the native path charges its pool
/// against it.
pub fn make_runtime_with_budget(
    cfg: &crate::config::RunConfig,
    budget: Option<std::sync::Arc<kernel::pool::KernelBudget>>,
) -> anyhow::Result<Box<dyn ModelRuntime>> {
    let dir = manifest::Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let m = manifest::Manifest::load(&dir)?;
        if m.models.contains_key(&cfg.model) {
            return Ok(Box::new(xla_rt::XlaRuntime::load(&m, &cfg.model)?));
        }
    }
    // Native fallback (float features only).
    match &cfg.dataset {
        crate::config::DatasetConfig::SynthCifar { classes, .. } => {
            let mut rt = native::NativeRuntime::new(3072, 64, *classes)
                .with_kernel_threads(cfg.kernel_threads);
            if let Some(budget) = budget {
                rt = rt.with_kernel_budget(budget);
            }
            Ok(Box::new(rt))
        }
        _ => anyhow::bail!("model {} needs artifacts (run `make artifacts`)", cfg.model),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Modality, TensorDataset};

    fn float_ds() -> TensorDataset {
        TensorDataset {
            modality: Modality::Float { dim: 2 },
            n: 3,
            classes: 2,
            x_f32: vec![0., 1., 2., 3., 4., 5.],
            x_i32: vec![],
            y: vec![0, 1, 0],
            y_dim: 1,
            difficulty: vec![0.0; 3],
            clean_class: vec![0, 1, 0],
        }
    }

    #[test]
    fn batchbuf_fills_float() {
        let ds = float_ds();
        let mut buf = BatchBuf::new();
        buf.fill(&ds, &[2, 1]);
        match buf.x(&ds) {
            BatchX::F32(v) => assert_eq!(v, &[4., 5., 2., 3.]),
            _ => panic!("wrong modality"),
        }
        assert_eq!(buf.y, vec![0, 1]);
    }

    #[test]
    fn batchx_len() {
        assert_eq!(BatchX::F32(&[1.0, 2.0]).len_elems(), 2);
        assert_eq!(BatchX::I32(&[1, 2, 3]).len_elems(), 3);
    }
}
