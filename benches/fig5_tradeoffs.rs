//! Regenerates paper Fig. 5 (b/B sweep + pruning-ratio sweep).
fn main() {
    evosample::experiments::fig5::run(evosample::config::presets::Scale::from_env())
        .expect("fig5");
}
