//! artifacts/manifest.json loader — the contract between python's aot.py
//! and the rust runtime. Shapes, dtypes, parameter counts and artifact
//! file names for every model variant.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum XDtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String,
    pub param_count: usize,
    pub classes: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: XDtype,
    pub y_shape: Vec<usize>,
    pub flops_per_sample_fwd: u64,
    pub optimizer: String,
    pub init: PathBuf,
    /// batch size -> artifact path
    pub train_step: BTreeMap<usize, PathBuf>,
    pub loss_fwd: BTreeMap<usize, PathBuf>,
    pub eval_step: BTreeMap<usize, PathBuf>,
}

impl ModelEntry {
    /// Per-sample feature length (flattened).
    pub fn x_len(&self) -> usize {
        self.x_shape.iter().product::<usize>().max(1)
    }

    /// Per-sample label length.
    pub fn y_len(&self) -> usize {
        self.y_shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    /// kernel name -> (block size -> artifact path)
    pub kernels: BTreeMap<String, BTreeMap<usize, PathBuf>>,
}

impl Manifest {
    /// Default artifact directory: $EVOSAMPLE_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("EVOSAMPLE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> anyhow::Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&src, dir)
    }

    pub fn parse(src: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        let mut models = BTreeMap::new();
        let model_obj = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing models object"))?;
        for (name, entry) in model_obj {
            models.insert(name.clone(), Self::parse_model(name, entry, dir)?);
        }
        let mut kernels = BTreeMap::new();
        if let Some(kobj) = j.get("kernels").and_then(Json::as_obj) {
            for (kname, sizes) in kobj {
                let mut m = BTreeMap::new();
                for (sz, file) in sizes.as_obj().into_iter().flatten() {
                    let n: usize = sz.parse().map_err(|_| anyhow::anyhow!("bad kernel size {sz}"))?;
                    m.insert(n, dir.join(file.as_str().unwrap_or_default()));
                }
                kernels.insert(kname.clone(), m);
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), models, kernels })
    }

    fn parse_model(name: &str, j: &Json, dir: &Path) -> anyhow::Result<ModelEntry> {
        let req = |k: &str| {
            j.get(k).ok_or_else(|| anyhow::anyhow!("model {name}: missing key {k:?}"))
        };
        let shape = |k: &str| -> anyhow::Result<Vec<usize>> {
            Ok(req(k)?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_usize)
                .collect())
        };
        let arts = req("artifacts")?;
        let sized = |group: &str| -> anyhow::Result<BTreeMap<usize, PathBuf>> {
            let mut out = BTreeMap::new();
            let obj = arts
                .get(group)
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow::anyhow!("model {name}: missing artifacts.{group}"))?;
            for (sz, file) in obj {
                let n: usize = sz
                    .parse()
                    .map_err(|_| anyhow::anyhow!("model {name}: bad batch size {sz:?}"))?;
                let f = file
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("model {name}: non-string artifact"))?;
                out.insert(n, dir.join(f));
            }
            Ok(out)
        };
        let x_dtype = match req("x_dtype")?.as_str() {
            Some("f32") => XDtype::F32,
            Some("i32") => XDtype::I32,
            other => anyhow::bail!("model {name}: bad x_dtype {other:?}"),
        };
        Ok(ModelEntry {
            name: name.to_string(),
            kind: req("kind")?.as_str().unwrap_or_default().to_string(),
            param_count: req("param_count")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("model {name}: bad param_count"))?,
            classes: req("classes")?.as_usize().unwrap_or(0),
            x_shape: shape("x_shape")?,
            x_dtype,
            y_shape: shape("y_shape")?,
            flops_per_sample_fwd: req("flops_per_sample_fwd")?.as_f64().unwrap_or(0.0) as u64,
            optimizer: req("optimizer")?.as_str().unwrap_or_default().to_string(),
            init: dir.join(
                arts.get("init")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("model {name}: missing artifacts.init"))?,
            ),
            train_step: sized("train_step")?,
            loss_fwd: sized("loss_fwd")?,
            eval_step: sized("eval_step")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "mlp": {
          "kind": "mlp", "param_count": 100, "classes": 10,
          "x_shape": [8], "x_dtype": "f32", "y_shape": [],
          "flops_per_sample_fwd": 1234, "optimizer": "sgdm",
          "artifacts": {
            "init": "mlp_init.hlo.txt",
            "train_step": {"4": "mlp_ts4.hlo.txt", "16": "mlp_ts16.hlo.txt"},
            "loss_fwd": {"16": "mlp_lf.hlo.txt"},
            "eval_step": {"32": "mlp_ev.hlo.txt"}
          }
        }
      },
      "kernels": {"es_update": {"4096": "es.hlo.txt"}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let e = &m.models["mlp"];
        assert_eq!(e.param_count, 100);
        assert_eq!(e.x_len(), 8);
        assert_eq!(e.y_len(), 1, "scalar label");
        assert_eq!(e.x_dtype, XDtype::F32);
        assert_eq!(e.train_step.len(), 2);
        assert!(e.train_step[&4].ends_with("mlp_ts4.hlo.txt"));
        assert_eq!(m.kernels["es_update"][&4096], Path::new("/tmp/a/es.hlo.txt"));
    }

    #[test]
    fn missing_fields_error_clearly() {
        let bad = r#"{"models": {"m": {"kind": "mlp"}}}"#;
        let err = Manifest::parse(bad, Path::new(".")).unwrap_err().to_string();
        assert!(err.contains("missing key"), "{err}");
    }

    #[test]
    fn loads_real_manifest_when_present() {
        // Exercised for real by integration tests; here only if built.
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("mlp_cifar10"));
            let e = &m.models["mlp_cifar10"];
            assert_eq!(e.x_len(), 3072);
            assert!(e.init.exists());
        }
    }
}
