//! Fig. 4 / Tab. 9: low-resource LM SFT with gradient accumulation
//! (Qwen2.5-Math substitute: txf_lm on the synthetic corpus; B=32, b=8,
//! b_micro=8). Paper shape: ESWP reaches each eval budget in ~half the
//! wall-clock because baseline burns 4 BP passes per update vs ESWP's 1.

use crate::config::presets::{fig4, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;
use crate::util::json::{num, obj, s, Json};

use super::{make_runtime, run_config, total_cost, trials};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let runs = fig4(scale);
    let rec = Recorder::new("fig4_qwen_sft")?;
    let n_trials = trials(scale);
    table_header(
        "Fig. 4 / Tab. 9 — low-resource SFT (grad accumulation)",
        &["method", "final LM loss", "BP passes", "train wall s", "time saved"],
    );
    let mut rt = make_runtime(&runs[0])?;
    let mut base_cost = None;
    for cfg in &runs {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        let tag = cfg.name.split('/').next_back().unwrap_or("?");
        for r in &rs {
            rec.record_result(r)?;
            rec.record(&obj(vec![
                ("fig", s("fig4_curve")),
                ("method", s(tag)),
                (
                    "eval_curve",
                    Json::Arr(
                        r.eval_curve
                            .iter()
                            .map(|&(e, l, _)| Json::Arr(vec![num(e as f64), num(l)]))
                            .collect(),
                    ),
                ),
            ]))?;
        }
        let loss = super::mean_loss(&rs);
        let cost = total_cost(&rs);
        let saved = match &base_cost {
            None => "—".to_string(),
            Some(b) => super::fmt_saved(b, &cost),
        };
        println!(
            "{tag:<10} | {loss:8.4}      | {:>8} | {:>8.2} | {saved}",
            cost.bp_passes,
            cost.train_wall_s()
        );
        if tag == "baseline" {
            base_cost = Some(cost);
        }
    }
    Ok(())
}
