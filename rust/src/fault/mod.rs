//! Deterministic fault injection (DESIGN.md §12): a std-only failpoint
//! registry the crash-critical paths consult so chaos tests can script
//! real failures — IO errors, latency, panics, process kills — with
//! reproducible schedules.
//!
//! Sites are `&'static str` names (catalogued in [`sites`]); a site that
//! is not armed costs exactly one relaxed atomic load, the same
//! zero-cost-when-off contract the telemetry layer keeps
//! (`obs::counters_on`). Arming happens explicitly — the `[fault]`
//! config table ([`arm_from_doc`]), the `EVOSAMPLE_FAULTS` env var
//! ([`arm_from_env`]), or a literal spec ([`arm_spec`]) — and never from
//! library code, so production runs can only be chaotic on purpose.
//!
//! Rule spec grammar (semicolon-separated, one optional `seed=N` entry):
//!
//! ```text
//! seed=42;checkpoint.save=err,times=1;serve.socket_read=delay:50,p=0.5
//! site=action[:arg][,p=<prob>][,after=<hits>][,times=<fires>][,worker=<id>]
//! ```
//!
//! Actions: `err` (return an injected `io::Error`), `delay:<ms>`
//! (sleep), `panic`, `kill` (`process::abort` — the crash-durability
//! scenario). Modifiers: `p` fires probabilistically from the registry's
//! seeded PCG64 stream; `after` skips the first N matching hits;
//! `times` caps total fires; `worker` scopes the rule to one threaded
//! worker id so multi-thread sites stay deterministic regardless of
//! interleaving.
//!
//! Every fire bumps `fault.injected` (and `fault.injected.<site>`) when
//! counters are on, so chaos tests can reconcile telemetry against the
//! registry's own [`fired`] ledger: no injection goes unaccounted.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::config::Doc;
use crate::util::Pcg64;

mod atomic_io;

pub use atomic_io::write_atomic;

/// The failpoint site catalog. Arm specs must name one of these (typos
/// fail at parse time, not by silently never firing).
pub mod sites {
    /// `Checkpoint::save`/`save_with_extra` entry (before any write).
    pub const CHECKPOINT_SAVE: &str = "checkpoint.save";
    /// `Checkpoint::load` entry (before the file is opened).
    pub const CHECKPOINT_LOAD: &str = "checkpoint.load";
    /// Inside [`super::write_atomic`], between the tmp-file fsync and the
    /// rename — the torn-write crash window the helper closes.
    pub const ATOMIC_COMMIT: &str = "atomic.commit";
    /// Durable `.job.json` record writes in the serve layer.
    pub const SERVE_RECORD_WRITE: &str = "serve.record_write";
    /// Per-line socket reads in the serve connection handler.
    pub const SERVE_SOCKET_READ: &str = "serve.socket_read";
    /// Response-line socket writes in the serve connection handler.
    pub const SERVE_SOCKET_WRITE: &str = "serve.socket_write";
    /// Scheduler job execution, at the top of each (re)try of a claimed
    /// job — the cheap hook for exercising the retry/backoff path.
    pub const SERVE_JOB_CLAIM: &str = "serve.job_claim";
    /// Kernel pool dispatch (delay-only: `KernelPool::run` returns `()`
    /// and is called under the dispatch lock, so only latency is safe).
    pub const KERNEL_DISPATCH: &str = "kernel.dispatch";
    /// Threaded-engine mid-epoch sync rendezvous (delay-only: an error
    /// or panic here would strand peers at the barrier).
    pub const ENGINE_SYNC: &str = "engine.sync";
    /// Inside a threaded worker's step loop, within its catch-unwind
    /// region — `panic` here exercises degraded-mode quarantine.
    pub const ENGINE_WORKER_STEP: &str = "engine.worker_step";
    /// Reserved for unit tests, so in-crate tests can arm the process-
    /// global registry without perturbing real sites used by concurrent
    /// tests in the same process.
    pub const TEST_PROBE: &str = "test.probe";

    /// Every site, for spec validation and the DESIGN.md §12 catalog.
    pub const ALL: &[&str] = &[
        CHECKPOINT_SAVE,
        CHECKPOINT_LOAD,
        ATOMIC_COMMIT,
        SERVE_RECORD_WRITE,
        SERVE_SOCKET_READ,
        SERVE_SOCKET_WRITE,
        SERVE_JOB_CLAIM,
        KERNEL_DISPATCH,
        ENGINE_SYNC,
        ENGINE_WORKER_STEP,
        TEST_PROBE,
    ];

    /// Sites where only `delay` is performable (see the per-site docs).
    pub const DELAY_ONLY: &[&str] = &[KERNEL_DISPATCH, ENGINE_SYNC];
}

/// What an armed rule does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// Return an injected `io::Error` (kind `Interrupted`, message
    /// `"injected fault at <site>"` — classified transient by the
    /// scheduler's retry policy).
    Err,
    /// Sleep for the given number of milliseconds, then proceed.
    Delay(u64),
    /// Panic with `"injected panic at <site>"`.
    Panic,
    /// `std::process::abort()` — the kill-after-N-hits crash scenario.
    Kill,
}

#[derive(Clone, Debug)]
struct Rule {
    site: &'static str,
    action: Action,
    /// Fire probability once eligible (1.0 = always).
    p: f64,
    /// Skip the first `after` matching hits.
    after: u64,
    /// Fire at most this many times (0 = unlimited).
    times: u64,
    /// Only hits carrying this worker scope match ([`hit_worker`]).
    worker: Option<usize>,
    hits: u64,
    fired: u64,
}

struct Registry {
    rules: Vec<Rule>,
    rng: Pcg64,
}

/// The zero-cost-when-off gate: every failpoint checks this first.
static ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

fn lock() -> std::sync::MutexGuard<'static, Option<Registry>> {
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
}

/// True when any fault rules are armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Disarm everything; failpoints return to the one-load fast path.
pub fn disarm() {
    *lock() = None;
    ARMED.store(false, Ordering::SeqCst);
}

fn resolve_site(name: &str) -> Result<&'static str, String> {
    sites::ALL
        .iter()
        .copied()
        .find(|s| *s == name)
        .ok_or_else(|| format!("unknown fault site {name:?} (see fault::sites)"))
}

fn parse_action(token: &str) -> Result<Action, String> {
    if let Some(ms) = token.strip_prefix("delay:") {
        let ms: u64 =
            ms.parse().map_err(|_| format!("bad delay milliseconds in {token:?}"))?;
        return Ok(Action::Delay(ms));
    }
    match token {
        "err" => Ok(Action::Err),
        "panic" => Ok(Action::Panic),
        "kill" => Ok(Action::Kill),
        "delay" => Err("delay needs an argument: delay:<ms>".to_string()),
        other => Err(format!("unknown fault action {other:?} (err|delay:<ms>|panic|kill)")),
    }
}

/// Parse one `site=action[,key=val]*` rule.
fn parse_rule(spec: &str) -> Result<Rule, String> {
    let (site, body) = spec
        .split_once('=')
        .ok_or_else(|| format!("fault rule {spec:?} is not site=action[,...]"))?;
    let site = resolve_site(site.trim())?;
    let mut parts = body.split(',').map(str::trim);
    let action = parse_action(parts.next().unwrap_or(""))?;
    if sites::DELAY_ONLY.contains(&site) && !matches!(action, Action::Delay(_)) {
        return Err(format!("site {site:?} supports only delay:<ms> actions"));
    }
    let mut rule =
        Rule { site, action, p: 1.0, after: 0, times: 0, worker: None, hits: 0, fired: 0 };
    for part in parts {
        let (key, val) = part
            .split_once('=')
            .ok_or_else(|| format!("fault modifier {part:?} is not key=val"))?;
        match key.trim() {
            "p" => {
                let p: f64 = val.parse().map_err(|_| format!("bad probability {val:?}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("probability {p} outside [0, 1]"));
                }
                rule.p = p;
            }
            "after" => {
                rule.after = val.parse().map_err(|_| format!("bad after count {val:?}"))?;
            }
            "times" => {
                rule.times = val.parse().map_err(|_| format!("bad times count {val:?}"))?;
            }
            "worker" => {
                rule.worker =
                    Some(val.parse().map_err(|_| format!("bad worker id {val:?}"))?);
            }
            other => return Err(format!("unknown fault modifier {other:?}")),
        }
    }
    Ok(rule)
}

/// Arm a full spec: `[seed=N;]site=action[,mods];...`. Replaces any
/// previously armed rules. Returns the number of rules armed.
pub fn arm_spec(spec: &str) -> Result<usize, String> {
    let mut seed = 0u64;
    let mut rules = Vec::new();
    for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
        if let Some(v) = part.strip_prefix("seed=") {
            seed = v.trim().parse().map_err(|_| format!("bad fault seed {v:?}"))?;
            continue;
        }
        rules.push(parse_rule(part)?);
    }
    let n = rules.len();
    *lock() = Some(Registry { rules, rng: Pcg64::new(seed) });
    ARMED.store(n > 0, Ordering::SeqCst);
    Ok(n)
}

/// Arm from the `EVOSAMPLE_FAULTS` env var; unset/empty is a no-op.
pub fn arm_from_env() -> Result<usize, String> {
    match std::env::var("EVOSAMPLE_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            arm_spec(&spec).map_err(|e| format!("EVOSAMPLE_FAULTS: {e}"))
        }
        _ => Ok(0),
    }
}

/// Arm from a config document's `[fault]` table:
///
/// ```toml
/// [fault]
/// seed = 42
/// rules = ["checkpoint.save=err,times=1", "serve.socket_read=delay:50"]
/// ```
///
/// A document with no `[fault]` table is a no-op.
pub fn arm_from_doc(doc: &Doc) -> Result<usize, String> {
    let Some(rules_val) = doc.get("fault.rules") else {
        return Ok(0);
    };
    let arr = rules_val
        .as_array()
        .ok_or_else(|| "fault.rules must be an array of rule strings".to_string())?;
    let seed = doc.i64_or("fault.seed", 0);
    if seed < 0 {
        return Err(format!("fault.seed {seed} must be non-negative"));
    }
    let mut spec = format!("seed={seed}");
    for v in arr {
        let rule = v
            .as_str()
            .ok_or_else(|| "fault.rules entries must be strings".to_string())?;
        spec.push(';');
        spec.push_str(rule);
    }
    arm_spec(&spec)
}

/// Decide whether a failpoint hit at `site` (with optional worker scope)
/// fires, and which action. The armed-check is the only cost when off.
fn decide(site: &str, worker: Option<usize>) -> Option<Action> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut guard = lock();
    let reg = guard.as_mut()?;
    let Registry { rules, rng } = reg;
    for rule in rules.iter_mut() {
        if rule.site != site {
            continue;
        }
        if let Some(w) = rule.worker {
            if worker != Some(w) {
                continue;
            }
        }
        rule.hits += 1;
        if rule.hits <= rule.after {
            continue;
        }
        if rule.times > 0 && rule.fired >= rule.times {
            continue;
        }
        if rule.p < 1.0 && rng.f64() >= rule.p {
            continue;
        }
        rule.fired += 1;
        let action = rule.action;
        drop(guard);
        if crate::obs::counters_on() {
            let r = crate::obs::registry();
            r.counter("fault.injected").add(1);
            r.counter(&format!("fault.injected.{site}")).add(1);
        }
        return Some(action);
    }
    None
}

fn perform(site: &str, action: Action) -> std::io::Result<()> {
    match action {
        Action::Err => Err(std::io::Error::new(
            std::io::ErrorKind::Interrupted,
            format!("injected fault at {site}"),
        )),
        Action::Delay(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        // lint:allow(robustness/no-panic-in-serve): the panic IS the injected fault — chaos tests catch_unwind it
        Action::Panic => panic!("injected panic at {site}"),
        Action::Kill => std::process::abort(),
    }
}

/// The standard failpoint: no-op unless an armed rule at `site` fires.
#[inline]
pub fn hit_io(site: &'static str) -> std::io::Result<()> {
    match decide(site, None) {
        None => Ok(()),
        Some(action) => perform(site, action),
    }
}

/// Worker-scoped failpoint for multi-threaded sites: rules carrying a
/// `worker=<id>` modifier match only their worker, so hit counts stay
/// deterministic regardless of thread interleaving.
#[inline]
pub fn hit_worker(site: &'static str, worker: usize) -> std::io::Result<()> {
    match decide(site, Some(worker)) {
        None => Ok(()),
        Some(action) => perform(site, action),
    }
}

/// Delay-only failpoint for sites that cannot express an error and must
/// not panic (barriers, `()`-returning dispatch). Parse-time validation
/// restricts [`sites::DELAY_ONLY`] rules to `delay:<ms>` actions.
#[inline]
pub fn maybe_delay(site: &'static str) {
    if let Some(Action::Delay(ms)) = decide(site, None) {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Total fires recorded at `site` since arming (the chaos tests'
/// reconciliation ledger against the `fault.injected` counters).
pub fn fired(site: &str) -> u64 {
    lock()
        .as_ref()
        .map(|reg| reg.rules.iter().filter(|r| r.site == site).map(|r| r.fired).sum())
        .unwrap_or(0)
}

/// Total fires across every armed rule since arming.
pub fn injected_total() -> u64 {
    lock()
        .as_ref()
        .map(|reg| reg.rules.iter().map(|r| r.fired).sum())
        .unwrap_or(0)
}

/// True when an error message names an injected fault or a transient IO
/// condition worth retrying (the vendored `anyhow` carries flat message
/// chains, so classification is textual by design).
pub fn is_transient_error_msg(msg: &str) -> bool {
    let lower = msg.to_ascii_lowercase();
    lower.contains("injected fault")
        || lower.contains("timed out")
        || lower.contains("interrupted system call")
        || lower.contains("resource temporarily unavailable")
}

#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    // The registry is process-global; in-crate tests that arm it (only
    // ever on `sites::TEST_PROBE` — never a real site, so concurrent
    // tests exercising real paths stay fault-free) serialize here.
    static L: Mutex<()> = Mutex::new(());
    L.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Arm-for-the-duration guard so a failing assertion can't leave the
    /// process-global registry armed for later tests.
    struct Armed;
    impl Drop for Armed {
        fn drop(&mut self) {
            disarm();
        }
    }

    #[test]
    fn unarmed_sites_are_no_ops() {
        let _g = test_lock();
        disarm();
        assert!(!armed());
        assert!(hit_io(sites::TEST_PROBE).is_ok());
        assert_eq!(fired(sites::TEST_PROBE), 0);
    }

    #[test]
    fn err_rule_fires_and_counts() {
        let _g = test_lock();
        let _armed = Armed;
        assert_eq!(arm_spec("seed=7;test.probe=err,times=2").unwrap(), 1);
        let e = hit_io(sites::TEST_PROBE).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::Interrupted);
        assert!(e.to_string().contains("injected fault at test.probe"));
        assert!(hit_io(sites::TEST_PROBE).is_err());
        // `times=2` exhausted: further hits pass through.
        assert!(hit_io(sites::TEST_PROBE).is_ok());
        assert_eq!(fired(sites::TEST_PROBE), 2);
        assert_eq!(injected_total(), 2);
    }

    #[test]
    fn after_skips_leading_hits() {
        let _g = test_lock();
        let _armed = Armed;
        arm_spec("test.probe=err,after=2,times=1").unwrap();
        assert!(hit_io(sites::TEST_PROBE).is_ok());
        assert!(hit_io(sites::TEST_PROBE).is_ok());
        assert!(hit_io(sites::TEST_PROBE).is_err());
        assert!(hit_io(sites::TEST_PROBE).is_ok());
        assert_eq!(fired(sites::TEST_PROBE), 1);
    }

    #[test]
    fn worker_scope_matches_only_its_worker() {
        let _g = test_lock();
        let _armed = Armed;
        arm_spec("test.probe=err,worker=1").unwrap();
        assert!(hit_io(sites::TEST_PROBE).is_ok(), "unscoped hit never matches");
        assert!(hit_worker(sites::TEST_PROBE, 0).is_ok());
        assert!(hit_worker(sites::TEST_PROBE, 1).is_err());
        assert_eq!(fired(sites::TEST_PROBE), 1);
    }

    #[test]
    fn probability_is_seed_deterministic() {
        let _g = test_lock();
        let _armed = Armed;
        let run = |seed: u64| -> Vec<bool> {
            arm_spec(&format!("seed={seed};test.probe=err,p=0.5")).unwrap();
            (0..32).map(|_| hit_io(sites::TEST_PROBE).is_err()).collect()
        };
        let a = run(3);
        let b = run(3);
        assert_eq!(a, b, "same seed, same fire schedule");
        assert!(a.iter().any(|&f| f) && !a.iter().all(|&f| f), "p=0.5 mixes outcomes");
        let c = run(4);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn delay_rule_sleeps_then_proceeds() {
        let _g = test_lock();
        let _armed = Armed;
        arm_spec("test.probe=delay:5,times=1").unwrap();
        let t0 = std::time::Instant::now();
        assert!(hit_io(sites::TEST_PROBE).is_ok());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5));
        maybe_delay(sites::TEST_PROBE); // exhausted: no further sleep
        assert_eq!(fired(sites::TEST_PROBE), 1);
    }

    #[test]
    fn panic_rule_panics_with_site_name() {
        let _g = test_lock();
        let _armed = Armed;
        arm_spec("test.probe=panic,times=1").unwrap();
        let caught = std::panic::catch_unwind(|| hit_io(sites::TEST_PROBE));
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("injected panic at test.probe"), "{msg}");
    }

    #[test]
    fn spec_errors_are_descriptive() {
        let _g = test_lock();
        disarm();
        let cases = [
            ("nonsite=err", "unknown fault site"),
            ("test.probe=explode", "unknown fault action"),
            ("test.probe=delay", "delay needs an argument"),
            ("test.probe=err,p=1.5", "outside [0, 1]"),
            ("test.probe=err,bogus=1", "unknown fault modifier"),
            ("test.probe", "not site=action"),
            ("seed=x;test.probe=err", "bad fault seed"),
            ("kernel.dispatch=panic", "only delay"),
            ("engine.sync=err", "only delay"),
        ];
        for (spec, want) in cases {
            let err = arm_spec(spec).unwrap_err();
            assert!(err.contains(want), "{spec:?}: {err}");
        }
        assert!(!armed(), "failed arming leaves the layer disarmed");
    }

    #[test]
    fn arm_from_doc_reads_fault_table() {
        let _g = test_lock();
        let _armed = Armed;
        let src = "[fault]\nseed = 9\nrules = [\"test.probe=err,times=1\"]\n";
        let doc = Doc::parse(src).unwrap();
        assert_eq!(arm_from_doc(&doc).unwrap(), 1);
        assert!(hit_io(sites::TEST_PROBE).is_err());
        assert!(hit_io(sites::TEST_PROBE).is_ok());
        // No [fault] table: no-op, leaves arming untouched.
        let empty = Doc::parse("[run]\nepochs = 1\n").unwrap();
        assert_eq!(arm_from_doc(&empty).unwrap(), 0);
        // Bad entries are rejected.
        let bad = Doc::parse("[fault]\nrules = [3]\n").unwrap();
        assert!(arm_from_doc(&bad).unwrap_err().contains("strings"));
    }

    #[test]
    fn transient_classification_is_textual() {
        assert!(is_transient_error_msg("run: injected fault at checkpoint.save"));
        assert!(is_transient_error_msg("read: Connection Timed Out"));
        assert!(!is_transient_error_msg("header claims 12 params (truncated checkpoint)"));
        assert!(!is_transient_error_msg("sampler kept nothing at epoch 3"));
    }
}
