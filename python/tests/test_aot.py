"""AOT emission smoke tests: HLO text artifacts parse-ably emitted,
manifest is consistent with the model registry, and the HLO interchange
constraints (text format, tuple root, parameter arity) hold.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_plan_covers_registry():
    """Every plan references a registered model and vice versa."""
    assert set(aot.PLANS) == set(M.DEFAULT_OPTS)
    for name in aot.PLANS:
        M.make_model(name)  # must not raise


def test_to_hlo_text_shape():
    """Emitted text is real HLO: module header + tuple-rooted ENTRY."""
    fn = lambda x: (x * 2 + 1,)
    text = aot.to_hlo_text(fn, jax.ShapeDtypeStruct((4,), jnp.float32))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True => root is a tuple (what rust's to_tuple expects)
    assert "tuple(" in text


def test_train_step_arity():
    """train_step lowers with exactly 8 parameters (rust contract)."""
    model = M.make_model("mlp_cifar10")
    fns = M.build_fns(model, M.DEFAULT_OPTS["mlp_cifar10"])
    pc = fns["param_count"]
    f32, i32 = jnp.float32, jnp.int32
    specs = [
        jax.ShapeDtypeStruct((pc,), f32),
        jax.ShapeDtypeStruct((pc,), f32),
        jax.ShapeDtypeStruct((pc,), f32),
        jax.ShapeDtypeStruct((8, 3072), f32),
        jax.ShapeDtypeStruct((8,), i32),
        jax.ShapeDtypeStruct((8,), f32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((), f32),
    ]
    text = aot.to_hlo_text(fns["train_step"], *specs)
    for i in range(8):
        assert f"parameter({i})" in text
    assert "parameter(8)" not in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestEmittedManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_files_exist(self, manifest):
        for name, entry in manifest["models"].items():
            arts = entry["artifacts"]
            files = [arts["init"]]
            for group in ("train_step", "loss_fwd", "eval_step"):
                files.extend(arts[group].values())
            for fname in files:
                path = os.path.join(ART_DIR, fname)
                assert os.path.exists(path), f"{name}: missing {fname}"
                with open(path) as f:
                    head = f.read(64)
                assert head.startswith("HloModule"), f"{name}: {fname} not HLO text"

    def test_param_counts_match_registry(self, manifest):
        for name, entry in manifest["models"].items():
            model = M.make_model(name)
            fns = M.build_fns(model, M.DEFAULT_OPTS[name])
            assert entry["param_count"] == fns["param_count"]

    def test_es_update_kernel_present(self, manifest):
        ks = manifest["kernels"]["es_update"]
        assert str(aot.ES_UPDATE_BLOCK) in ks
        assert os.path.exists(os.path.join(ART_DIR, ks[str(aot.ES_UPDATE_BLOCK)]))

    def test_flops_estimates_positive(self, manifest):
        for name, entry in manifest["models"].items():
            assert entry["flops_per_sample_fwd"] > 0, name
