//! Frequency-tuning perf sweep: scoring-FP cost amortized over
//! k ∈ {1, 2, 4, 8} steps (`run.score_every`) for ES on the CIFAR-dims
//! MLP — the paper's "flexible frequency tuning" wall-clock lever — plus
//! the `score_every × scoring_precision` cross-sweep (DESIGN.md §9):
//! bf16 ranked scoring at k ∈ {1, 4}, showing the cadence stride and the
//! precision ladder compose on the measured scoring wall-clock.
//!
//! Emits machine-readable `BENCH_frequency.json` (per-k fp_samples,
//! fp_passes, measured scoring seconds, accuracy) so the amortization is
//! tracked across PRs — and exits non-zero unless `fp_samples` strictly
//! decreases across the whole k sweep, so CI catches the stride silently
//! regressing to per-step scoring at any cadence.

use std::time::Instant;

use evosample::coordinator::train_with_sampler;
use evosample::prelude::*;
use evosample::runtime::native::NativeRuntime;
use evosample::util::bench::smoke_mode;
use evosample::util::json::{num, obj, s, Json};

fn main() {
    let (n, epochs, hidden) = if smoke_mode() { (2048, 4, 48) } else { (8192, 10, 96) };
    let ks = [1usize, 2, 4, 8];

    // CIFAR-dims MLP: 3072-wide inputs, 10 classes; ES with anneal 0 so
    // every step is scoring-eligible and the k-fold saving is exact.
    let mut cfg = RunConfig::new(
        "perf_frequency",
        "native",
        DatasetConfig::SynthCifar { n, classes: 10, label_noise: 0.05, hard_frac: 0.2 },
    );
    cfg.epochs = epochs;
    cfg.meta_batch = 128;
    cfg.mini_batch = 32;
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
    cfg.test_n = 256;
    cfg.sampler = SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.0 };
    let split = data::build(&cfg.dataset, cfg.test_n, 42);

    println!(
        "== frequency tuning (n={n}, B={}, b={}, hidden={hidden}, {} epochs) ==",
        cfg.meta_batch, cfg.mini_batch, epochs
    );
    println!(
        "{:>2} {:>12} {:>10} {:>12} {:>12} {:>8}",
        "k", "fp_samples", "fp_passes", "scoring_ms", "train_wall_s", "acc%"
    );

    let mut per_k = Vec::new();
    for &k in &ks {
        cfg.score_every = k;
        let mut rt = NativeRuntime::new(split.train.x_len(), hidden, 10);
        let sampler =
            evosample::sampler::build(&cfg.sampler, split.train.n, cfg.epochs).expect(&cfg.name);
        let t0 = Instant::now();
        let r = train_with_sampler(&cfg, &mut rt, &split, sampler).expect(&cfg.name);
        let wall = t0.elapsed().as_secs_f64() - r.cost.eval_s;
        println!(
            "{k:>2} {:>12} {:>10} {:>12.2} {:>12.2} {:>8.2}",
            r.cost.fp_samples,
            r.cost.fp_passes,
            r.cost.scoring_s * 1e3,
            wall,
            r.accuracy_pct()
        );
        per_k.push((k, r));
    }

    // ---- precision × cadence cross-sweep (DESIGN.md §9) -----------------
    // bf16 ranked scoring at k ∈ {1, 4}: the precision ladder divides the
    // per-pass scoring cost while the cadence stride divides the number
    // of passes, so the two savings compose multiplicatively on the
    // measured scoring wall-clock.
    println!("\n{:>2} {:>6} {:>12} {:>12} {:>8}", "k", "prec", "fp_samples", "scoring_ms", "acc%");
    let mut per_kp = Vec::new();
    for &k in &[1usize, 4] {
        cfg.score_every = k;
        cfg.scoring_precision = ScoringPrecision::Bf16;
        let mut rt = NativeRuntime::new(split.train.x_len(), hidden, 10);
        let sampler =
            evosample::sampler::build(&cfg.sampler, split.train.n, cfg.epochs).expect(&cfg.name);
        let r = train_with_sampler(&cfg, &mut rt, &split, sampler).expect(&cfg.name);
        println!(
            "{k:>2} {:>6} {:>12} {:>12.2} {:>8.2}",
            "bf16",
            r.cost.fp_samples,
            r.cost.scoring_s * 1e3,
            r.accuracy_pct()
        );
        per_kp.push((k, r));
    }
    cfg.scoring_precision = ScoringPrecision::Exact;

    let find = |k: usize| &per_k.iter().find(|(kk, _)| *kk == k).unwrap().1;
    let k1 = find(1);
    let k4 = find(4);
    let scoring_saving = if k1.cost.scoring_s > 0.0 {
        100.0 * (1.0 - k4.cost.scoring_s / k1.cost.scoring_s)
    } else {
        0.0
    };
    println!(
        "\nk=4 vs k=1: fp_samples {} -> {} ({}x), measured scoring time saved {scoring_saving:.1}%",
        k1.cost.fp_samples,
        k4.cost.fp_samples,
        if k4.cost.fp_samples > 0 { k1.cost.fp_samples / k4.cost.fp_samples } else { 0 },
    );
    let bf16_k4 = &per_kp.iter().find(|(kk, _)| *kk == 4).unwrap().1;
    let composed_saving = if k1.cost.scoring_s > 0.0 {
        100.0 * (1.0 - bf16_k4.cost.scoring_s / k1.cost.scoring_s)
    } else {
        0.0
    };
    println!(
        "bf16 @ k=4 vs exact @ k=1: measured scoring time saved {composed_saving:.1}% \
         (cadence x precision, composed)"
    );

    let rows: Vec<Json> = per_k
        .iter()
        .map(|(k, r)| {
            obj(vec![
                ("k", num(*k as f64)),
                ("fp_samples", num(r.cost.fp_samples as f64)),
                ("fp_passes", num(r.cost.fp_passes as f64)),
                ("bp_samples", num(r.cost.bp_samples as f64)),
                ("scoring_s", num(r.cost.scoring_s)),
                ("train_wall_s", num(r.cost.train_wall_s())),
                ("acc_pct", num(r.accuracy_pct())),
            ])
        })
        .collect();
    let out = obj(vec![
        ("bench", s("perf_frequency")),
        ("backend", s("native")),
        ("mode", s(if smoke_mode() { "smoke" } else { "full" })),
        (
            "shape",
            obj(vec![
                ("n", num(n as f64)),
                ("epochs", num(epochs as f64)),
                ("hidden", num(hidden as f64)),
                ("meta_batch", num(cfg.meta_batch as f64)),
                ("mini_batch", num(cfg.mini_batch as f64)),
            ]),
        ),
        ("sweep", Json::Arr(rows)),
        (
            "precision_sweep",
            Json::Arr(
                per_k
                    .iter()
                    .filter(|(k, _)| *k == 1 || *k == 4)
                    .map(|(k, r)| (*k, "exact", r))
                    .chain(per_kp.iter().map(|(k, r)| (*k, "bf16", r)))
                    .map(|(k, prec, r)| {
                        obj(vec![
                            ("k", num(k as f64)),
                            ("precision", s(prec)),
                            ("fp_samples", num(r.cost.fp_samples as f64)),
                            ("scoring_s", num(r.cost.scoring_s)),
                            ("acc_pct", num(r.accuracy_pct())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("scoring_time_saved_pct_k4", num(scoring_saving)),
        ("scoring_time_saved_pct_bf16_k4_vs_exact_k1", num(composed_saving)),
    ]);
    let payload = out.to_string_compact() + "\n";
    std::fs::write("BENCH_frequency.json", payload).expect("write BENCH_frequency.json");
    println!("wrote BENCH_frequency.json");

    // CI guard: the whole point of the knob is the k-fold scoring-FP
    // saving; if it ever stops materializing, fail the bench loudly.
    // fp_samples must strictly decrease across the whole k sweep (which
    // subsumes the headline k=4 < k=1 criterion).
    for pair in per_k.windows(2) {
        let (ka, ra) = &pair[0];
        let (kb, rb) = &pair[1];
        if rb.cost.fp_samples >= ra.cost.fp_samples {
            eprintln!(
                "FAIL: fp_samples not strictly decreasing in k: k={ka} -> {} vs k={kb} -> {} \
                 — frequency tuning is not amortizing the scoring FP",
                ra.cost.fp_samples, rb.cost.fp_samples
            );
            std::process::exit(1);
        }
    }
}
