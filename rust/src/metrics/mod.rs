//! Result recording: JSONL writers under `results/` + summary helpers.
//!
//! Every bench/example writes one JSON object per training run so paper
//! tables can be regenerated or re-aggregated without re-running.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::coordinator::TrainResult;
use crate::util::json::{num, obj, s, Json};

/// Serialize a TrainResult to a flat JSON record.
pub fn result_to_json(r: &TrainResult) -> Json {
    obj(vec![
        ("name", s(r.name.clone())),
        ("sampler", s(r.sampler.clone())),
        ("seed", num(r.seed as f64)),
        ("epochs", num(r.epochs as f64)),
        ("steps", num(r.steps as f64)),
        ("accuracy_pct", num(r.accuracy_pct())),
        ("eval_loss", num(r.final_eval.loss)),
        ("train_wall_s", num(r.cost.train_wall_s())),
        ("scoring_s", num(r.cost.scoring_s)),
        ("train_s", num(r.cost.train_s)),
        ("select_s", num(r.cost.select_s)),
        ("sync_s", num(r.cost.sync_s)),
        ("fp_samples", num(r.cost.fp_samples as f64)),
        ("bp_samples", num(r.cost.bp_samples as f64)),
        ("bp_passes", num(r.cost.bp_passes as f64)),
        ("total_flops", num(r.cost.total_flops() as f64)),
        (
            "loss_curve",
            Json::Arr(r.loss_curve.iter().map(|&l| num(l)).collect()),
        ),
        (
            "eval_curve",
            Json::Arr(
                r.eval_curve
                    .iter()
                    .map(|&(e, l, a)| {
                        Json::Arr(vec![num(e as f64), num(l), num(a)])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Append-only JSONL recorder.
pub struct Recorder {
    path: PathBuf,
}

impl Recorder {
    /// Records under `results/<name>.jsonl` (dir created on demand).
    pub fn new(name: &str) -> std::io::Result<Recorder> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        Ok(Recorder { path: dir.join(format!("{name}.jsonl")) })
    }

    pub fn in_dir(dir: &Path, name: &str) -> std::io::Result<Recorder> {
        std::fs::create_dir_all(dir)?;
        Ok(Recorder { path: dir.join(format!("{name}.jsonl")) })
    }

    pub fn record(&self, j: &Json) -> std::io::Result<()> {
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&self.path)?;
        writeln!(f, "{}", j.to_string_compact())
    }

    pub fn record_result(&self, r: &TrainResult) -> std::io::Result<()> {
        self.record(&result_to_json(r))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CostSummary, EvalStats};
    use crate::util::timer::PhaseTimers;

    fn dummy() -> TrainResult {
        TrainResult {
            name: "t".into(),
            sampler: "es".into(),
            seed: 1,
            epochs: 2,
            steps: 10,
            loss_curve: vec![1.0, 0.5],
            eval_curve: vec![(1, 0.4, 0.9)],
            final_eval: EvalStats { loss: 0.4, accuracy: 0.9 },
            timers: PhaseTimers::new(),
            cost: CostSummary::default(),
            class_bp_counts: vec![],
            bp_at_eval: vec![100],
        }
    }

    #[test]
    fn result_roundtrips_through_json() {
        let j = result_to_json(&dummy());
        let txt = j.to_string_compact();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("sampler").unwrap().as_str(), Some("es"));
        assert_eq!(back.get("accuracy_pct").unwrap().as_f64(), Some(90.0));
        assert_eq!(back.get("loss_curve").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn recorder_appends_lines() {
        let dir = std::env::temp_dir().join("evosample_test_rec");
        let rec = Recorder::in_dir(&dir, "unit").unwrap();
        // unique content per test run; just check append semantics
        rec.record(&result_to_json(&dummy())).unwrap();
        rec.record(&result_to_json(&dummy())).unwrap();
        let text = std::fs::read_to_string(rec.path()).unwrap();
        assert!(text.lines().count() >= 2);
        let _ = std::fs::remove_file(rec.path());
    }
}
