//! UCB dynamic data pruning (Raju et al. 2021): treat sample selection as
//! a multi-armed bandit. Each sample keeps an exponentially-decayed loss
//! estimate; the epoch keeps the top (1−r)·n samples by upper confidence
//! bound  ucb_i = ema_i + c·sqrt(ln(t) / n_i), where n_i counts how often
//! the sample was trained on — unseen/rarely-seen samples get wide bounds
//! and are explored.

use super::{Sampler, Selection, ShardLog, ShardObservations};
use crate::util::math;
use crate::util::Pcg64;

pub struct Ucb {
    prune_ratio: f64,
    decay: f32,
    c: f32,
    ema: Vec<f32>,
    seen: Vec<u32>,
    t: u64,
    /// Applied-observation buffer for worker-replica mode (§D.5 sync).
    shard_log: ShardLog,
}

impl Ucb {
    pub fn new(n: usize, prune_ratio: f64, decay: f32, c: f32) -> Self {
        assert!((0.0..1.0).contains(&prune_ratio));
        Ucb {
            prune_ratio,
            decay,
            c,
            ema: vec![0.0; n],
            seen: vec![0; n],
            t: 1,
            shard_log: ShardLog::default(),
        }
    }

    /// The EMA/visit-count update shared by local observation and the
    /// §D.5 merge path.
    fn apply(&mut self, indices: &[u32], losses: &[f32]) {
        for (&i, &l) in indices.iter().zip(losses) {
            let i = i as usize;
            self.ema[i] = if self.seen[i] == 0 {
                l
            } else {
                math::ema(self.ema[i], l, self.decay)
            };
            self.seen[i] += 1;
        }
        self.t += indices.len() as u64;
    }

    fn ucb_score(&self, i: usize) -> f32 {
        let n_i = self.seen[i].max(1) as f32;
        let bonus = self.c * ((self.t as f32).ln().max(0.0) / n_i).sqrt();
        // Unseen samples get the maximum exploration bonus on top of a
        // neutral estimate.
        let base = if self.seen[i] == 0 { f32::MAX / 4.0 } else { self.ema[i] };
        base + bonus
    }
}

impl Sampler for Ucb {
    fn name(&self) -> &'static str {
        "ucb"
    }

    fn n(&self) -> usize {
        self.ema.len()
    }

    fn on_epoch_start(&mut self, epoch: usize, _rng: &mut Pcg64) -> Vec<u32> {
        let n = self.n();
        if epoch == 0 {
            return (0..n as u32).collect();
        }
        let keep = ((1.0 - self.prune_ratio) * n as f64).ceil() as usize;
        let scores: Vec<f32> = (0..n).map(|i| self.ucb_score(i)).collect();
        let mut kept = math::top_k_indices(&scores, keep.max(1));
        kept.sort_unstable();
        kept
    }

    fn observe_train(&mut self, indices: &[u32], losses: &[f32], _epoch: usize) {
        self.shard_log.record(indices, losses);
        self.apply(indices, losses);
    }

    fn select(&mut self, meta: &[u32], _mini: usize, _epoch: usize, _rng: &mut Pcg64) -> Selection {
        Selection::unweighted(meta.to_vec())
    }

    fn begin_shard(&mut self, _shard: &[u32]) {
        self.shard_log.begin();
    }

    fn export_observations(&mut self) -> ShardObservations {
        self.shard_log.export()
    }

    fn merge_observations(&mut self, obs: &[(Vec<u32>, Vec<f32>)], _epoch: usize) {
        // Apply directly so merged peer state is not re-exported.
        for (indices, losses) in obs {
            self.apply(indices, losses);
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_ratio() {
        let mut u = Ucb::new(100, 0.3, 0.8, 1.0);
        let idx: Vec<u32> = (0..100).collect();
        u.observe_train(&idx, &vec![1.0; 100], 0);
        let kept = u.on_epoch_start(1, &mut Pcg64::new(0));
        assert_eq!(kept.len(), 70);
    }

    #[test]
    fn high_loss_samples_survive() {
        let mut u = Ucb::new(10, 0.5, 0.8, 0.01);
        let idx: Vec<u32> = (0..10).collect();
        let losses: Vec<f32> = (0..10).map(|i| if i < 5 { 10.0 } else { 0.01 }).collect();
        for _ in 0..3 {
            u.observe_train(&idx, &losses, 0);
        }
        let kept = u.on_epoch_start(1, &mut Pcg64::new(0));
        for i in 0..5u32 {
            assert!(kept.contains(&i), "{i} has high loss, must be kept");
        }
    }

    #[test]
    fn unseen_samples_are_explored() {
        let mut u = Ucb::new(10, 0.5, 0.8, 1.0);
        // Only samples 0..5 observed, with high loss.
        let idx: Vec<u32> = (0..5).collect();
        u.observe_train(&idx, &vec![5.0; 5], 0);
        let kept = u.on_epoch_start(1, &mut Pcg64::new(0));
        // The 5 unseen samples have max exploration score: all kept.
        for i in 5..10u32 {
            assert!(kept.contains(&i), "unseen {i} must be explored");
        }
    }

    #[test]
    fn confidence_bonus_shrinks_with_visits() {
        let mut u = Ucb::new(2, 0.5, 0.8, 1.0);
        u.observe_train(&[0], &[1.0], 0);
        for _ in 0..50 {
            u.observe_train(&[1], &[1.0], 0);
        }
        assert!(u.ucb_score(0) > u.ucb_score(1), "fewer visits => wider bound");
    }

    #[test]
    fn ema_decays_toward_recent() {
        let mut u = Ucb::new(1, 0.3, 0.8, 1.0);
        u.observe_train(&[0], &[10.0], 0);
        for _ in 0..30 {
            u.observe_train(&[0], &[0.0], 0);
        }
        assert!(u.ema[0] < 0.1, "ema={}", u.ema[0]);
    }
}
