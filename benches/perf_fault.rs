//! Disarmed-failpoint overhead bench (DESIGN.md §12): the zero-cost-
//! when-off contract. Each `fault::hit_io` / `maybe_delay` at a hot site
//! must reduce to one relaxed atomic load when no faults are armed —
//! same budget as `obs::counters_on()`.
//!
//! Report-only (the chaos tests enforce behavior; this tracks cost):
//! prints ns/op for a batch of disarmed hits against an empty-loop
//! baseline, then the armed-but-non-matching case (rules present, site
//! not targeted), which pays the registry lock and is expected to be
//! slower — it only runs while chaos experiments are armed.

use evosample::fault::{self, sites};
use evosample::util::bench::Bencher;

/// Hits per bench iteration: one `hit_io` is sub-ns, far below timer
/// resolution, so measure batches and report the per-iteration figure.
const BATCH: u32 = 10_000;

fn main() {
    println!("== disarmed failpoint overhead (batch = {BATCH} hits/iter) ==");
    let b = Bencher::default();

    let base = b.run("baseline: counter loop", || {
        let mut acc = 0u32;
        for i in 0..BATCH {
            acc = acc.wrapping_add(std::hint::black_box(i));
        }
        acc
    });

    fault::disarm();
    let off = b.run("disarmed hit_io(kernel.dispatch)", || {
        let mut ok = 0u32;
        for _ in 0..BATCH {
            if fault::hit_io(sites::KERNEL_DISPATCH).is_ok() {
                ok += 1;
            }
        }
        ok
    });
    let off_delay = b.run("disarmed maybe_delay(engine.sync)", || {
        for _ in 0..BATCH {
            fault::maybe_delay(sites::ENGINE_SYNC);
        }
        BATCH
    });

    // Armed-but-elsewhere: a rule exists for a different site, so every
    // hit takes the registry lock and scans rules. Not on the hot path
    // in production — armed registries exist only during chaos runs.
    fault::arm_spec("seed=1;checkpoint.save=err,times=1").expect("arm");
    let armed = b.run("armed elsewhere hit_io(kernel.dispatch)", || {
        let mut ok = 0u32;
        for _ in 0..BATCH {
            if fault::hit_io(sites::KERNEL_DISPATCH).is_ok() {
                ok += 1;
            }
        }
        ok
    });
    fault::disarm();

    let per_hit_ns =
        |r: &evosample::util::bench::BenchResult| r.median.as_secs_f64() * 1e9 / BATCH as f64;
    println!(
        "per-hit: baseline {:.2} ns, disarmed hit_io {:.2} ns, disarmed maybe_delay {:.2} ns, \
         armed-elsewhere {:.2} ns",
        per_hit_ns(&base),
        per_hit_ns(&off),
        per_hit_ns(&off_delay),
        per_hit_ns(&armed),
    );
}
