//! END-TO-END DRIVER (DESIGN.md "End-to-end validation"): pre-train a real
//! transformer LM through the full three-layer stack — session API →
//! engine → PJRT-compiled AOT artifacts (JAX L2 + Pallas L1 kernels) — on
//! the synthetic corpus, for several hundred optimizer steps, with ES/ESWP
//! against the baseline. Streams typed engine events into
//! results/e2e_pretrain_events.jsonl, logs loss curves
//! (results/e2e_pretrain.jsonl), and prints the summary recorded in
//! EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example end_to_end_pretrain
//!
//! EVOSAMPLE_E2E_STEPS overrides the target step count (default ~300).

use evosample::config::presets::e2e_pretrain;
use evosample::prelude::*;
use evosample::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    let mut runs = e2e_pretrain(Scale::from_env());
    // Target a few hundred steps: steps = epochs * n / B.
    let target_steps: usize = std::env::var("EVOSAMPLE_E2E_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    for cfg in &mut runs {
        let per_epoch = cfg.dataset.n().div_ceil(cfg.meta_batch);
        cfg.epochs = (target_steps / per_epoch).max(3);
        cfg.eval_every = 1;
    }

    let rec = Recorder::new("e2e_pretrain")?;
    // One session hosts all three methods: shared runtime + data split,
    // per-method name/sampler swaps, events streamed to JSONL.
    let mut session = SessionBuilder::from_config(runs[0].clone())
        .sink(Box::new(EventLog::new("e2e_pretrain_events")?))
        .sink(Box::new(ProgressSink::new()))
        .build()?;
    println!(
        "e2e: pre-training txf_lm for ~{} steps per method on {} sequences",
        target_steps,
        session.data().train.n
    );

    let mut base: Option<RunResult> = None;
    for cfg in &runs {
        session.set_name(&cfg.name);
        session.set_sampler(cfg.sampler.clone());
        let t0 = std::time::Instant::now();
        let r = session.run()?;
        rec.record_result(&r)?;
        rec.record(&obj(vec![
            ("fig", s("e2e_loss_curve")),
            ("method", s(r.sampler.clone())),
            ("steps", num(r.steps as f64)),
            (
                "curve",
                Json::Arr(r.loss_curve.iter().map(|&l| num(l)).collect()),
            ),
        ]))?;
        println!("\n== {} ==", r.sampler);
        println!(
            "  steps {} | final train loss {:.4} | eval loss {:.4} | token acc {:.1}%",
            r.steps,
            r.loss_curve.last().unwrap(),
            r.final_eval.loss,
            r.accuracy_pct()
        );
        println!(
            "  wall {:.1}s (total incl eval {:.1}s) | bp samples {} | fp samples {}",
            r.cost.train_wall_s(),
            t0.elapsed().as_secs_f64(),
            r.cost.bp_samples,
            r.cost.fp_samples
        );
        print!("  loss curve: ");
        for (e, l) in r.loss_curve.iter().enumerate() {
            print!("e{e}:{l:.3} ");
        }
        println!();
        match &base {
            None => base = Some(r),
            Some(b) => println!(
                "  vs baseline: saved {:.1}% wall ({:.1}% flops-pred), Δeval loss {:+.4}",
                saved_time_pct(&b.cost, &r.cost),
                predicted_saved_time_pct(&b.cost, &r.cost),
                r.final_eval.loss - b.final_eval.loss
            ),
        }
    }
    println!("\n(curves in results/e2e_pretrain.jsonl — summarized in EXPERIMENTS.md)");
    Ok(())
}
