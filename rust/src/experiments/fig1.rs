//! Fig. 1 + Fig. 8: the weight-signal illustration. Generates the decaying
//! noisy loss signal and the Loss-vs-ES weight traces for several β1
//! (Fig. 8 sweeps β1 ∈ {0.1, 0.5, 0.8} at β2 = 0.9), reports the
//! total-variation smoothing factor and the Thm. 3.2 transfer-function
//! magnitudes, and writes the traces for plotting.

use crate::metrics::Recorder;
use crate::sampler::analysis::{fig1_traces, total_variation, transfer_magnitude};
use crate::util::bench::table_header;
use crate::util::json::{num, obj, s, Json};
use crate::util::Pcg64;

pub fn run(steps: usize) -> anyhow::Result<()> {
    let rec = Recorder::new("fig1_weights")?;
    table_header(
        "Fig. 1/8 — weight signals (total variation vs raw losses)",
        &["beta1", "beta2", "TV(loss)", "TV(ES)", "smoothing", "|H(i·inf)|"],
    );
    for &(b1, b2) in &[(0.5f32, 0.9f32), (0.1, 0.9), (0.8, 0.9)] {
        let mut rng = Pcg64::new(1234);
        let (loss, w_loss, w_es) = fig1_traces(steps, b1, b2, &mut rng);
        let tv_l = total_variation(&w_loss);
        let tv_e = total_variation(&w_es);
        let hinf = transfer_magnitude(b1 as f64, b2 as f64, 1e9);
        println!(
            "{b1:5.2} | {b2:5.2} | {tv_l:8.2} | {tv_e:8.2} | {:5.2}x | {hinf:.3}",
            tv_l / tv_e
        );
        rec.record(&obj(vec![
            ("fig", s("fig1_trace")),
            ("beta1", num(b1 as f64)),
            ("beta2", num(b2 as f64)),
            ("loss", Json::Arr(loss.iter().map(|&x| num(x as f64)).collect())),
            ("w_es", Json::Arr(w_es.iter().map(|&x| num(x as f64)).collect())),
        ]))?;
    }
    println!("(traces in results/fig1_weights.jsonl; |H| matches |beta2-beta1| per Thm 3.2)");
    Ok(())
}
