//! Serve-protocol walkthrough (DESIGN.md §10): boot the multi-tenant
//! selection service in-process, then speak the JSONL-over-TCP protocol
//! to it exactly as an external client would — submit two jobs, follow
//! one job's event stream, poll status, and shut the server down.
//!
//!     cargo run --release --example serve_client
//!
//! Against a separately launched server (`evosample serve --port P`),
//! the same lines work over `evosample submit --addr 127.0.0.1:P ...`.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use evosample::config::ServeConfig;
use evosample::serve::Server;
use evosample::util::json::{obj, s, Json};

const JOB_TOML: &str = "\
[run]
model = \"native\"
epochs = 4
meta_batch = 32
mini_batch = 8
test_n = 64
eval_every = 1

[dataset]
kind = \"synth_cifar\"
n = 256
classes = 4

[sampler]
kind = \"es\"
";

/// One request line, one response line, on a fresh connection.
fn request(addr: SocketAddr, req: &Json) -> anyhow::Result<Json> {
    let mut conn = TcpStream::connect(addr)?;
    conn.write_all(req.to_string_compact().as_bytes())?;
    conn.write_all(b"\n")?;
    let mut line = String::new();
    BufReader::new(conn).read_line(&mut line)?;
    Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

fn main() -> anyhow::Result<()> {
    // A throwaway state dir; a long-lived deployment would point this at
    // durable storage so killed servers resume their in-flight jobs.
    let state_dir = std::env::temp_dir().join(format!("serve_client_{}", std::process::id()));
    let server = Server::start(ServeConfig {
        port: 0, // ephemeral — the handle reports the bound address
        max_concurrent: 2,
        max_queue: 8,
        kernel_budget: 2,
        state_dir: state_dir.to_string_lossy().into_owned(),
        checkpoint_every: 1,
        ..ServeConfig::default()
    })?;
    let addr = server.addr();

    // ---- submit: config TOML rides the wire verbatim -------------------
    for (id, sampler) in [("demo_es", "es"), ("demo_base", "baseline")] {
        let resp = request(
            addr,
            &obj(vec![
                ("cmd", s("submit")),
                ("config", s(JOB_TOML)),
                ("sampler", s(sampler)), // registry-name override
                ("job_id", s(id)),
            ]),
        )?;
        println!("submit {id}: {}", resp.to_string_compact());
    }

    // ---- events: backlog replay, then live until the job finishes ------
    let mut conn = TcpStream::connect(addr)?;
    let req = obj(vec![("cmd", s("events")), ("job", s("demo_es"))]);
    conn.write_all(req.to_string_compact().as_bytes())?;
    conn.write_all(b"\n")?;
    for line in BufReader::new(conn).lines() {
        let line = line?;
        let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
        match j.get("event").and_then(Json::as_str) {
            Some("result") => {
                let pct = j.get("accuracy_pct").and_then(Json::as_f64).unwrap_or(f64::NAN);
                println!("demo_es result: accuracy {pct:.2}%");
            }
            Some(ev) => println!("demo_es event: {ev}"),
            // The final non-event line closes the stream.
            None => break,
        }
    }

    // ---- status: queue/latency/cost accounting per job -----------------
    let status = request(addr, &obj(vec![("cmd", s("status"))]))?;
    println!("status: {}", status.to_string_compact());

    // ---- metrics: live telemetry scrape (DESIGN.md §11) ----------------
    // One response carries the process `obs::` registry snapshot plus
    // queue depth / kernel-lane occupancy under "global", and per-job
    // selection health (keep rate, fp passes, wall seconds) under
    // "jobs". `evosample top --addr ...` polls exactly this verb.
    let metrics = request(addr, &obj(vec![("cmd", s("metrics"))]))?;
    if let Some(global) = metrics.get("global") {
        println!("metrics global: {}", global.to_string_compact());
    }
    for job in metrics.get("jobs").and_then(Json::as_arr).into_iter().flatten() {
        let id = job.get("job").and_then(Json::as_str).unwrap_or("?");
        let keep = job
            .get("keep_rate_pct")
            .and_then(Json::as_f64)
            .map(|k| format!("{k:.1}%"))
            .unwrap_or_else(|| "-".to_string());
        println!("metrics job {id}: keep_rate {keep}");
    }

    // ---- shutdown: drain finishes queued jobs, then exits --------------
    let resp = request(addr, &obj(vec![("cmd", s("shutdown"))]))?;
    println!("shutdown: {}", resp.to_string_compact());
    server.wait();
    let _ = std::fs::remove_dir_all(&state_dir);
    Ok(())
}
