//! Per-job shared state: lifecycle, accounting, event backlog, live
//! subscribers, and the cooperative interrupt flag.
//!
//! A [`JobShared`] is the one object both sides touch while a job runs:
//! the scheduler's worker thread (event sink + epoch hook) writes into
//! it, connection threads read status and subscribe to the event
//! stream. Everything mutable sits behind one small mutex; the
//! interrupt flag is a lock-free atomic so the epoch hook can poll it
//! without contending with event pushes.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{num, obj, s, Json};

/// Events kept per job for late subscribers; older events drop off (the
/// drop count is reported in `status`, so truncation is never silent).
/// Live subscriber channels are bounded to the same cap: a subscriber
/// that falls a full backlog behind is disconnected rather than
/// queueing events without bound.
pub const EVENT_BACKLOG_CAP: usize = 4096;

/// Cooperative-interrupt flag values (checked at epoch boundaries).
pub const INTERRUPT_NONE: u8 = 0;
/// Client `cancel`: the job ends as `Cancelled`.
pub const INTERRUPT_CANCEL: u8 = 1;
/// Server `shutdown abort`: the job ends as `Interrupted` with its
/// checkpoint retained, so the next server start resumes it.
pub const INTERRUPT_SHUTDOWN: u8 = 2;

/// Job lifecycle. `Queued → Running → {Done, Failed, Cancelled}`;
/// `Interrupted` is the resumable parking state a `shutdown abort` (or
/// a killed server) leaves behind — a restart re-enqueues it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
    Interrupted,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Interrupted => "interrupted",
        }
    }

    pub fn parse(text: &str) -> Option<JobState> {
        Some(match text {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            "interrupted" => JobState::Interrupted,
            _ => return None,
        })
    }

    /// Terminal states never run again; `Interrupted` is NOT terminal.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

struct JobMeta {
    name: String,
    sampler: String,
    epochs_total: usize,
    state: JobState,
    submitted: Instant,
    started: Option<Instant>,
    queue_s: f64,
    /// Wall seconds from previous server lives (resumed jobs).
    prior_wall_s: f64,
    final_wall_s: Option<f64>,
    epochs_done: usize,
    fp_passes: u64,
    bp_samples: u64,
    /// Keep rate (%) of the job's most recent epoch (selection health).
    keep_rate_pct: Option<f64>,
    accuracy: Option<f64>,
    error: Option<String>,
    events: VecDeque<Json>,
    events_dropped: u64,
    subscribers: Vec<SyncSender<Json>>,
}

/// Shared handle for one job; lives in the queue's job table and is
/// cloned (via `Arc`) into the worker running it.
pub struct JobShared {
    id: String,
    interrupt: AtomicU8,
    /// The interrupt the epoch hook actually *acted on* when it aborted
    /// the run (set just before the hook bails). The scheduler
    /// classifies a session error by this, not by [`JobShared::interrupt_kind`]:
    /// a genuine failure that merely races a cancel/shutdown request
    /// never sets it, so the job correctly ends `Failed` instead of
    /// masquerading as a cooperative stop.
    interrupt_fired: AtomicU8,
    meta: Mutex<JobMeta>,
}

impl JobShared {
    pub fn new(id: &str, name: &str, sampler: &str, epochs_total: usize) -> JobShared {
        JobShared {
            id: id.to_string(),
            interrupt: AtomicU8::new(INTERRUPT_NONE),
            interrupt_fired: AtomicU8::new(INTERRUPT_NONE),
            meta: Mutex::new(JobMeta {
                name: name.to_string(),
                sampler: sampler.to_string(),
                epochs_total,
                state: JobState::Queued,
                submitted: Instant::now(),
                started: None,
                queue_s: 0.0,
                prior_wall_s: 0.0,
                final_wall_s: None,
                epochs_done: 0,
                fp_passes: 0,
                bp_samples: 0,
                keep_rate_pct: None,
                accuracy: None,
                error: None,
                events: VecDeque::new(),
                events_dropped: 0,
                subscribers: Vec::new(),
            }),
        }
    }

    /// Seed accounting carried over from a previous server life.
    pub fn with_prior(self, wall_s: f64, epochs_done: usize) -> JobShared {
        {
            let mut m = self.lock();
            m.prior_wall_s = wall_s;
            m.epochs_done = epochs_done;
        }
        self
    }

    /// Restore the full durable accounting of a rescanned record —
    /// timing, counters, and outcome — so a terminal job reports its
    /// original wall/queue numbers after a server restart instead of
    /// zeros. (Contract: [`JobShared::record_json`] persists every field
    /// this reads.)
    pub fn with_record(self, rec: &JobRecord) -> JobShared {
        {
            let mut m = self.lock();
            m.prior_wall_s = rec.wall_s;
            m.epochs_done = rec.epochs_done;
            m.queue_s = rec.queue_s;
            m.fp_passes = rec.fp_passes;
            m.bp_samples = rec.bp_samples;
            m.accuracy = rec.accuracy;
            m.error = rec.error.clone();
            if rec.state.is_terminal() {
                // `started` stays None on a restored terminal job, so pin
                // the final wall clock to the recorded value explicitly.
                m.final_wall_s = Some(rec.wall_s);
            }
        }
        self
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, JobMeta> {
        self.meta.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn state(&self) -> JobState {
        self.lock().state
    }

    pub fn interrupt_kind(&self) -> u8 {
        self.interrupt.load(Ordering::Relaxed)
    }

    /// Request cooperative interruption (`INTERRUPT_CANCEL` /
    /// `INTERRUPT_SHUTDOWN`); the epoch hook observes it at the next
    /// epoch boundary.
    pub fn request_interrupt(&self, kind: u8) {
        self.interrupt.store(kind, Ordering::Relaxed);
    }

    /// Record that the epoch hook is aborting the run *because of* this
    /// interrupt (called immediately before the hook bails).
    pub fn acknowledge_interrupt(&self, kind: u8) {
        self.interrupt_fired.store(kind, Ordering::Relaxed);
    }

    /// The interrupt the epoch hook aborted the run for, or
    /// [`INTERRUPT_NONE`] when the run failed on its own (even if an
    /// interrupt request happened to be pending).
    pub fn fired_interrupt(&self) -> u8 {
        self.interrupt_fired.load(Ordering::Relaxed)
    }

    /// Append an event to the backlog (capped) and fan it out to live
    /// subscribers. The `"job"` key is stamped here so every consumer
    /// sees tagged lines.
    pub fn push_event(&self, mut ev: Json) {
        if let Json::Obj(map) = &mut ev {
            map.insert("job".to_string(), Json::Str(self.id.clone()));
        }
        let mut m = self.lock();
        if m.events.len() >= EVENT_BACKLOG_CAP {
            m.events.pop_front();
            m.events_dropped += 1;
        }
        m.events.push_back(ev.clone());
        // Bounded fan-out: a subscriber whose channel is full has fallen
        // a whole backlog behind — drop it like a disconnected one (its
        // stream ends early) instead of growing its queue without bound.
        m.subscribers.retain(|tx| tx.try_send(ev.clone()).is_ok());
    }

    /// Subscribe to the event stream: the full backlog replays into the
    /// channel immediately; live events follow until the job finishes
    /// (senders are dropped at terminal states, ending the stream) or
    /// the subscriber falls more than [`EVENT_BACKLOG_CAP`] events
    /// behind (it is disconnected, ending the stream early). A
    /// subscription to an already-finished job yields the backlog and
    /// ends.
    pub fn subscribe(&self) -> Receiver<Json> {
        let (tx, rx) = sync_channel(EVENT_BACKLOG_CAP);
        let mut m = self.lock();
        for ev in &m.events {
            // The backlog never exceeds the channel bound, so the replay
            // always fits.
            let _ = tx.try_send(ev.clone());
        }
        if !m.state.is_terminal() && m.state != JobState::Interrupted {
            m.subscribers.push(tx);
        }
        rx
    }

    /// Queued → Running: freeze the queue latency, start the wall clock,
    /// and announce admission on the event stream.
    pub fn mark_running(&self) {
        let queue_s;
        {
            let mut m = self.lock();
            m.state = JobState::Running;
            m.queue_s = m.submitted.elapsed().as_secs_f64();
            m.started = Some(Instant::now());
            queue_s = m.queue_s;
        }
        if crate::obs::counters_on() {
            crate::obs::registry().histogram("serve.queue_wait_s").record(queue_s);
        }
        self.push_event(obj(vec![("event", s("admitted")), ("queue_s", num(queue_s))]));
    }

    /// Selection-health note from the job's event stream: keep rate of
    /// the epoch now starting (surfaced in `status` and `metrics`).
    pub fn note_selection(&self, kept: usize, dataset_n: usize) {
        self.lock().keep_rate_pct = Some(kept as f64 / dataset_n.max(1) as f64 * 100.0);
    }

    /// Restore a terminal state from a rescanned record without the
    /// side effects of [`JobShared::finish`] (no events, no wall-clock
    /// mutation — the record already carries the final accounting).
    pub fn restore_terminal(&self, state: JobState) {
        self.lock().state = state;
    }

    /// Live accounting update from the epoch hook.
    pub fn progress(&self, epochs_done: usize, fp_passes: u64, bp_samples: u64) {
        let mut m = self.lock();
        m.epochs_done = epochs_done;
        m.fp_passes = fp_passes;
        m.bp_samples = bp_samples;
    }

    /// Move to a final (or parked) state: stop the wall clock, record
    /// the outcome, emit an optional final event plus a `state` marker,
    /// and disconnect all subscribers (their streams end).
    pub fn finish(
        &self,
        state: JobState,
        accuracy: Option<f64>,
        error: Option<String>,
        final_event: Option<Json>,
    ) {
        {
            let mut m = self.lock();
            m.state = state;
            if let Some(st) = m.started.take() {
                m.final_wall_s = Some(m.prior_wall_s + st.elapsed().as_secs_f64());
            }
            if accuracy.is_some() {
                m.accuracy = accuracy;
            }
            m.error = error;
        }
        if let Some(ev) = final_event {
            self.push_event(ev);
        }
        self.push_event(obj(vec![("event", s("state")), ("state", s(state.as_str()))]));
        self.lock().subscribers.clear();
    }

    fn wall_s(m: &JobMeta) -> f64 {
        m.final_wall_s.unwrap_or_else(|| {
            m.prior_wall_s + m.started.map(|st| st.elapsed().as_secs_f64()).unwrap_or(0.0)
        })
    }

    /// The per-job record `status` responses carry.
    pub fn status_json(&self) -> Json {
        let m = self.lock();
        let mut fields = vec![
            ("job", s(self.id.clone())),
            ("name", s(m.name.clone())),
            ("sampler", s(m.sampler.clone())),
            ("state", s(m.state.as_str())),
            ("epochs_done", num(m.epochs_done as f64)),
            ("epochs_total", num(m.epochs_total as f64)),
            ("queue_s", num(m.queue_s)),
            ("wall_s", num(Self::wall_s(&m))),
            ("fp_passes", num(m.fp_passes as f64)),
            ("bp_samples", num(m.bp_samples as f64)),
            ("events_dropped", num(m.events_dropped as f64)),
        ];
        if let Some(kr) = m.keep_rate_pct {
            fields.push(("keep_rate_pct", num(kr)));
        }
        if let Some(acc) = m.accuracy {
            fields.push(("accuracy", num(acc)));
        }
        if let Some(err) = &m.error {
            fields.push(("error", s(err.clone())));
        }
        obj(fields)
    }

    /// Durable `<id>.job.json` record (the startup rescan's source of
    /// truth). Carries the config TOML verbatim so a restarted server
    /// can rebuild the run config without the original client.
    pub fn record_json(&self, config_toml: &str) -> Json {
        let m = self.lock();
        let mut fields = vec![
            ("job", s(self.id.clone())),
            ("name", s(m.name.clone())),
            ("sampler", s(m.sampler.clone())),
            ("state", s(m.state.as_str())),
            ("config_toml", s(config_toml)),
            ("epochs_done", num(m.epochs_done as f64)),
            ("epochs_total", num(m.epochs_total as f64)),
            ("queue_s", num(m.queue_s)),
            ("wall_s", num(Self::wall_s(&m))),
            ("fp_passes", num(m.fp_passes as f64)),
            ("bp_samples", num(m.bp_samples as f64)),
        ];
        if let Some(acc) = m.accuracy {
            fields.push(("accuracy", num(acc)));
        }
        if let Some(err) = &m.error {
            fields.push(("error", s(err.clone())));
        }
        obj(fields)
    }
}

/// Write the durable job record (best-effort callers decide what to do
/// with the error). Goes through [`crate::fault::write_atomic`], so a
/// crash mid-write leaves the previous record parseable — a rescan never
/// sees a torn `.job.json`.
pub fn write_record(dir: &Path, shared: &JobShared, config_toml: &str) -> std::io::Result<()> {
    crate::fault::hit_io(crate::fault::sites::SERVE_RECORD_WRITE)?;
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{}.job.json", shared.id()));
    crate::fault::write_atomic(
        &path,
        shared.record_json(config_toml).to_string_compact().as_bytes(),
    )
}

/// One parsed `<id>.job.json` from a startup rescan.
pub struct JobRecord {
    pub id: String,
    pub name: String,
    pub sampler: String,
    pub state: JobState,
    pub config_toml: String,
    pub epochs_done: usize,
    pub queue_s: f64,
    pub wall_s: f64,
    pub fp_passes: u64,
    pub bp_samples: u64,
    pub accuracy: Option<f64>,
    pub error: Option<String>,
}

/// Scan `dir` for `*.job.json` records (unreadable/corrupt files are
/// skipped — a rescan must never prevent the server from starting).
pub fn scan_records(dir: &Path) -> Vec<JobRecord> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return out,
    };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(".job.json"))
        })
        .collect();
    paths.sort();
    for path in paths {
        let Ok(src) = std::fs::read_to_string(&path) else { continue };
        let Ok(j) = Json::parse(&src) else { continue };
        let get = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        let (Some(id), Some(state)) = (get("job"), get("state")) else { continue };
        let Some(state) = JobState::parse(&state) else { continue };
        out.push(JobRecord {
            id,
            name: get("name").unwrap_or_default(),
            sampler: get("sampler").unwrap_or_default(),
            state,
            config_toml: get("config_toml").unwrap_or_default(),
            epochs_done: j.get("epochs_done").and_then(Json::as_usize).unwrap_or(0),
            queue_s: j.get("queue_s").and_then(Json::as_f64).unwrap_or(0.0),
            wall_s: j.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0),
            fp_passes: j.get("fp_passes").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            bp_samples: j.get("bp_samples").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            accuracy: j.get("accuracy").and_then(Json::as_f64),
            error: get("error"),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backlog_caps_and_counts_drops() {
        let j = JobShared::new("j1", "n", "es", 4);
        for i in 0..(EVENT_BACKLOG_CAP + 10) {
            j.push_event(obj(vec![("event", s("tick")), ("i", num(i as f64))]));
        }
        let m = j.lock();
        assert_eq!(m.events.len(), EVENT_BACKLOG_CAP);
        assert_eq!(m.events_dropped, 10);
        // Oldest dropped: first surviving event is i = 10.
        assert_eq!(m.events.front().unwrap().get("i").and_then(Json::as_f64), Some(10.0));
    }

    #[test]
    fn subscribe_replays_backlog_then_streams_live() {
        let j = JobShared::new("j1", "n", "es", 4);
        j.push_event(obj(vec![("event", s("queued"))]));
        let rx = j.subscribe();
        j.push_event(obj(vec![("event", s("admitted"))]));
        j.finish(JobState::Done, Some(0.9), None, None);
        let got: Vec<String> = rx
            .iter()
            .map(|e| e.get("event").and_then(Json::as_str).unwrap().to_string())
            .collect();
        assert_eq!(got, vec!["queued", "admitted", "state"]);
        // Every line is job-tagged for multiplexed client streams.
        let late = j.subscribe();
        let first = late.iter().next().unwrap();
        assert_eq!(first.get("job").and_then(Json::as_str), Some("j1"));
        // Late subscription on a finished job ends after the backlog.
        assert!(late.iter().count() < EVENT_BACKLOG_CAP);
    }

    #[test]
    fn slow_subscriber_is_disconnected_not_buffered_unboundedly() {
        let j = JobShared::new("j1", "n", "es", 4);
        let rx = j.subscribe();
        // A subscriber that never reads saturates its bounded channel…
        for i in 0..(EVENT_BACKLOG_CAP + 5) {
            j.push_event(obj(vec![("event", s("tick")), ("i", num(i as f64))]));
        }
        // …and is dropped from the fan-out list at the first overflow.
        assert!(j.lock().subscribers.is_empty(), "overflowing subscriber must be disconnected");
        // The receiver drains exactly the channel bound, then the stream
        // ends (sender dropped) instead of blocking or growing.
        assert_eq!(rx.iter().count(), EVENT_BACKLOG_CAP);
    }

    #[test]
    fn status_tracks_lifecycle_and_accounting() {
        let j = JobShared::new("j2", "runA", "eswp", 8);
        assert_eq!(j.state(), JobState::Queued);
        j.mark_running();
        j.progress(3, 120, 4096);
        let st = j.status_json();
        assert_eq!(st.get("state").and_then(Json::as_str), Some("running"));
        assert_eq!(st.get("epochs_done").and_then(Json::as_f64), Some(3.0));
        assert_eq!(st.get("fp_passes").and_then(Json::as_f64), Some(120.0));
        assert_eq!(st.get("bp_samples").and_then(Json::as_f64), Some(4096.0));
        j.finish(JobState::Done, Some(0.75), None, None);
        let st = j.status_json();
        assert_eq!(st.get("state").and_then(Json::as_str), Some("done"));
        assert_eq!(st.get("accuracy").and_then(Json::as_f64), Some(0.75));
        assert!(st.get("wall_s").and_then(Json::as_f64).unwrap() >= 0.0);
    }

    #[test]
    fn records_roundtrip_through_scan() {
        let dir = std::env::temp_dir()
            .join(format!("evosample_jobrec_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let toml = "[run]\nmodel = \"mlp\"\n";
        let j = JobShared::new("j3", "runB", "es", 2).with_prior(1.5, 1);
        write_record(&dir, &j, toml).unwrap();
        let recs = scan_records(&dir);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].id, "j3");
        assert_eq!(recs[0].state, JobState::Queued);
        assert_eq!(recs[0].config_toml, toml);
        assert_eq!(recs[0].epochs_done, 1);
        assert!(recs[0].wall_s >= 1.5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn terminal_record_restores_timing_and_outcome() {
        let dir = std::env::temp_dir()
            .join(format!("evosample_jobrec_term_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let toml = "[run]\nmodel = \"mlp\"\n";
        let j = JobShared::new("j9", "runC", "es", 4);
        j.mark_running();
        j.progress(4, 64, 2048);
        j.finish(JobState::Done, Some(0.81), None, None);
        write_record(&dir, &j, toml).unwrap();
        let wall_before = j.status_json().get("wall_s").and_then(Json::as_f64).unwrap();
        let queue_before = j.status_json().get("queue_s").and_then(Json::as_f64).unwrap();

        // A fresh server life rescans the record: the restored job must
        // report the original wall/queue accounting, not zeros.
        let recs = scan_records(&dir);
        assert_eq!(recs.len(), 1);
        let rec = &recs[0];
        assert_eq!(rec.state, JobState::Done);
        let restored = JobShared::new(&rec.id, &rec.name, &rec.sampler, 4).with_record(rec);
        restored.restore_terminal(rec.state);
        let st = restored.status_json();
        assert_eq!(st.get("state").and_then(Json::as_str), Some("done"));
        assert_eq!(st.get("wall_s").and_then(Json::as_f64), Some(wall_before));
        assert_eq!(st.get("queue_s").and_then(Json::as_f64), Some(queue_before));
        assert_eq!(st.get("fp_passes").and_then(Json::as_f64), Some(64.0));
        assert_eq!(st.get("bp_samples").and_then(Json::as_f64), Some(2048.0));
        assert_eq!(st.get("accuracy").and_then(Json::as_f64), Some(0.81));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_record_restores_error() {
        let j = JobShared::new("j10", "runD", "es", 2);
        j.mark_running();
        j.finish(JobState::Failed, None, Some("boom".into()), None);
        let rec_json = j.record_json("");
        assert_eq!(rec_json.get("error").and_then(Json::as_str), Some("boom"));
        let dir = std::env::temp_dir()
            .join(format!("evosample_jobrec_fail_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_record(&dir, &j, "").unwrap();
        let recs = scan_records(&dir);
        assert_eq!(recs[0].error.as_deref(), Some("boom"));
        let restored =
            JobShared::new(&recs[0].id, "", "", 2).with_record(&recs[0]);
        restored.restore_terminal(recs[0].state);
        let st = restored.status_json();
        assert_eq!(st.get("error").and_then(Json::as_str), Some("boom"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keep_rate_surfaces_in_status() {
        let j = JobShared::new("j11", "n", "es", 2);
        assert!(j.status_json().get("keep_rate_pct").is_none());
        j.note_selection(384, 512);
        assert_eq!(
            j.status_json().get("keep_rate_pct").and_then(Json::as_f64),
            Some(75.0)
        );
    }

    #[test]
    fn interrupt_flag_is_observable() {
        let j = JobShared::new("j4", "n", "es", 2);
        assert_eq!(j.interrupt_kind(), INTERRUPT_NONE);
        j.request_interrupt(INTERRUPT_SHUTDOWN);
        assert_eq!(j.interrupt_kind(), INTERRUPT_SHUTDOWN);
        // A pending request alone is not an acknowledgement: only the
        // hook acting on it marks the run as cooperatively stopped.
        assert_eq!(j.fired_interrupt(), INTERRUPT_NONE);
        j.acknowledge_interrupt(INTERRUPT_SHUTDOWN);
        assert_eq!(j.fired_interrupt(), INTERRUPT_SHUTDOWN);
    }

    #[test]
    fn state_parse_roundtrips() {
        for st in [
            JobState::Queued,
            JobState::Running,
            JobState::Done,
            JobState::Failed,
            JobState::Cancelled,
            JobState::Interrupted,
        ] {
            assert_eq!(JobState::parse(st.as_str()), Some(st));
        }
        assert_eq!(JobState::parse("nope"), None);
        assert!(JobState::Done.is_terminal());
        assert!(!JobState::Interrupted.is_terminal(), "interrupted must be resumable");
    }
}
