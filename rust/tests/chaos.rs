//! Seeded fault-scenario matrix (DESIGN.md §12): the chaos invariants
//! the fault layer + durability hardening must hold under injection.
//!
//! * No job is lost or double-run across failures and restarts.
//! * Durable files (`.ckpt`, `.job.json`, `.result.json`) always parse —
//!   a crash window leaves the previous version, never a torn file.
//! * Kill/corrupt-then-restart resumes (or cleanly restarts) the job.
//! * Telemetry accounts for every injection: the `fault.injected` /
//!   `retry.attempts` / `worker.lost` counters reconcile against the
//!   registry's own fired ledger.
//!
//! The scenario seed comes from `EVOSAMPLE_CHAOS_SEED` (CI runs two
//! fixed seeds); every invariant here must hold for *any* seed. This
//! test binary is its own process, so arming real sites is safe — but
//! the registry is still process-global, so scenarios serialize on a
//! mutex and disarm via drop guard even when an assertion fails.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use evosample::config::{Doc, ServeConfig};
use evosample::fault::{self, sites};
use evosample::prelude::*;
use evosample::serve::{Server, ServerHandle};
use evosample::util::json::{obj, s as jstr, Json};

static CHAOS: Mutex<()> = Mutex::new(());

fn chaos_guard() -> std::sync::MutexGuard<'static, ()> {
    CHAOS.lock().unwrap_or_else(|e| e.into_inner())
}

/// Disarm-on-drop so a failing assertion can't leave faults armed for
/// the next scenario.
struct Armed;
impl Drop for Armed {
    fn drop(&mut self) {
        fault::disarm();
    }
}

fn chaos_seed() -> u64 {
    std::env::var("EVOSAMPLE_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(7)
}

fn counter(name: &str) -> u64 {
    evosample::obs::registry().counter(name).get()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("evosample_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn start_server(dir: &Path, checkpoint_every: usize, retry_max: usize) -> ServerHandle {
    Server::start(ServeConfig {
        port: 0,
        max_concurrent: 1,
        max_queue: 8,
        kernel_budget: 2,
        state_dir: dir.to_string_lossy().into_owned(),
        checkpoint_every,
        retry_max,
        retry_backoff_ms: 1, // keep chaos scenarios fast
        ..ServeConfig::default()
    })
    .unwrap()
}

fn job_toml(name: &str, seed: u64, epochs: usize) -> String {
    format!(
        "[run]\nmodel = \"native\"\nname = \"{name}\"\nepochs = {epochs}\n\
         meta_batch = 32\nmini_batch = 8\ntest_n = 64\nseed = {seed}\neval_every = 1\n\n\
         [dataset]\nkind = \"synth_cifar\"\nn = 192\nclasses = 4\n\n\
         [sampler]\nkind = \"es\"\n\n\
         [lr]\nschedule = \"const\"\nlr = 0.02\n"
    )
}

fn request(addr: SocketAddr, req: &Json) -> Json {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(req.to_string_compact().as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

fn submit(addr: SocketAddr, toml: &str, job_id: &str) -> Json {
    request(
        addr,
        &obj(vec![
            ("cmd", jstr("submit")),
            ("config", jstr(toml)),
            ("job_id", jstr(job_id)),
        ]),
    )
}

/// Stream a job's events until the final `ok` line (terminal/parked).
fn stream_events(addr: SocketAddr, job: &str) -> Vec<Json> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let req = obj(vec![("cmd", jstr("events")), ("job", jstr(job))]);
    conn.write_all(req.to_string_compact().as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let reader = BufReader::new(conn);
    let mut out = Vec::new();
    for line in reader.lines() {
        let j = Json::parse(line.unwrap().trim()).unwrap();
        let done = j.get("ok").is_some();
        out.push(j);
        if done {
            break;
        }
    }
    out
}

fn event_names(events: &[Json]) -> Vec<String> {
    events
        .iter()
        .filter_map(|e| e.get("event").and_then(Json::as_str).map(str::to_string))
        .collect()
}

fn record_json(dir: &Path, id: &str) -> Json {
    let src = std::fs::read_to_string(dir.join(format!("{id}.job.json"))).unwrap();
    Json::parse(&src).unwrap()
}

fn standalone(toml: &str) -> RunResult {
    let cfg = RunConfig::from_doc(&Doc::parse(toml).unwrap()).unwrap();
    let rt = evosample::runtime::make_runtime(&cfg).unwrap();
    SessionBuilder::from_config(cfg).runtime(rt).build().unwrap().run().unwrap()
}

fn assert_matches_standalone(result: &Json, reference: &RunResult, tag: &str) {
    let f = |k: &str| result.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    assert_eq!(f("accuracy_pct"), reference.accuracy_pct(), "{tag}: accuracy");
    assert_eq!(f("steps") as u64, reference.steps, "{tag}: steps");
    let served_curve: Vec<f64> = result
        .get("loss_curve")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_f64)
        .collect();
    assert_eq!(served_curve, reference.loss_curve, "{tag}: loss curve");
}

/// Satellite regression: a crash in `write_atomic`'s commit window (after
/// the tmp fsync, before the rename) leaves the PREVIOUS file intact and
/// parseable; the orphaned `.tmp` sibling is invisible to record scans
/// and consumed by the next successful write.
#[test]
fn torn_write_crash_window_preserves_previous_file() {
    let _g = chaos_guard();
    let dir = fresh_dir("torn");
    let path = dir.join("victim.job.json");
    fault::write_atomic(&path, b"{\"v\":1}").unwrap();

    let _armed = Armed;
    fault::arm_spec(&format!("seed={};atomic.commit=err,times=1", chaos_seed())).unwrap();
    let err = fault::write_atomic(&path, b"{\"v\":2}").unwrap_err();
    assert!(err.to_string().contains("injected fault at atomic.commit"), "{err}");
    // The previous version survives the simulated crash…
    let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(back.get("v").and_then(Json::as_f64), Some(1.0));
    // …the orphaned tmp is on disk but never scanned as a record…
    let tmp = dir.join("victim.job.json.tmp");
    assert!(tmp.exists(), "tmp sibling left by the aborted commit");
    assert!(
        evosample::serve::job::scan_records(&dir).is_empty(),
        "a .tmp sibling must never surface in the record scan"
    );
    // …and the retried write both lands and consumes the tmp.
    fault::write_atomic(&path, b"{\"v\":2}").unwrap();
    let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(back.get("v").and_then(Json::as_f64), Some(2.0));
    assert!(!tmp.exists(), "successful commit consumes the tmp file");
    assert_eq!(fault::fired(sites::ATOMIC_COMMIT), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// An injected checkpoint-write failure is transient: the job announces
/// `retrying`, re-runs, completes with the standalone result, and every
/// injection is accounted for in the telemetry counters.
#[test]
fn injected_checkpoint_failure_retries_to_completion() {
    let _g = chaos_guard();
    evosample::obs::raise_level(evosample::obs::COUNTERS);
    let dir = fresh_dir("ckpt_retry");
    let toml = job_toml("ckpt_retry", 41, 3);
    let reference = standalone(&toml);

    let injected0 = counter("fault.injected");
    let retries0 = counter("retry.attempts");
    let _armed = Armed;
    fault::arm_spec(&format!("seed={};checkpoint.save=err,times=1", chaos_seed())).unwrap();

    let handle = start_server(&dir, 1, 2);
    let addr = handle.addr();
    assert_eq!(submit(addr, &toml, "cr").get("ok"), Some(&Json::Bool(true)));
    let events = stream_events(addr, "cr");
    let names = event_names(&events);
    assert!(names.contains(&"retrying".to_string()), "{names:?}");
    assert!(names.contains(&"run_end".to_string()), "{names:?}");
    // Exactly one result event: the failed attempt never double-reports.
    let results: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("event").and_then(Json::as_str) == Some("result"))
        .collect();
    assert_eq!(results.len(), 1, "{names:?}");
    // The retried run restarts deterministically: standalone-identical.
    assert_matches_standalone(results[0], &reference, "retried");
    handle.shutdown(false);
    handle.wait();

    // Durables parse and agree.
    assert_eq!(record_json(&dir, "cr").get("state").and_then(Json::as_str), Some("done"));
    let result_file =
        Json::parse(&std::fs::read_to_string(dir.join("cr.result.json")).unwrap()).unwrap();
    assert_eq!(
        result_file.get("accuracy_pct").and_then(Json::as_f64),
        Some(reference.accuracy_pct())
    );

    // Counter reconciliation: every injection and retry is accounted.
    assert_eq!(fault::fired(sites::CHECKPOINT_SAVE), 1);
    assert_eq!(counter("fault.injected") - injected0, fault::injected_total());
    assert_eq!(counter("retry.attempts") - retries0, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A persistently-failing transient site spends the whole retry budget,
/// then fails the job with an explicit `retries_exhausted` reason — and
/// the durable record still parses.
#[test]
fn persistent_transient_failure_exhausts_retries_cleanly() {
    let _g = chaos_guard();
    evosample::obs::raise_level(evosample::obs::COUNTERS);
    let dir = fresh_dir("exhaust");

    let _armed = Armed;
    fault::arm_spec(&format!("seed={};serve.job_claim=err", chaos_seed())).unwrap();

    let handle = start_server(&dir, 0, 1);
    let addr = handle.addr();
    assert_eq!(submit(addr, &job_toml("exhaust", 43, 2), "ex").get("ok"), Some(&Json::Bool(true)));
    let events = stream_events(addr, "ex");
    let names = event_names(&events);
    assert!(names.contains(&"retrying".to_string()), "{names:?}");
    handle.shutdown(false);
    handle.wait();

    // retry_max=1: the initial attempt plus one retry, both injected.
    assert_eq!(fault::fired(sites::SERVE_JOB_CLAIM), 2);
    let rec = record_json(&dir, "ex");
    assert_eq!(rec.get("state").and_then(Json::as_str), Some("failed"), "{rec:?}");
    let error = rec.get("error").and_then(Json::as_str).unwrap();
    assert!(error.starts_with("retries_exhausted: "), "{error}");
    assert!(error.contains("injected fault at serve.job_claim"), "{error}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill-then-restart with a CORRUPTED checkpoint: the next life restarts
/// the job from scratch (surfacing the reason), finishes it exactly
/// once, and matches the uninterrupted run.
#[test]
fn corrupt_checkpoint_after_kill_restarts_without_losing_the_job() {
    let _g = chaos_guard();
    let dir = fresh_dir("kill_restart");
    let toml = job_toml("kill_restart", 45, 40);
    let reference = standalone(&toml);

    // Life 1: interrupt mid-run with a checkpoint on disk.
    let life1 = start_server(&dir, 1, 0);
    let addr = life1.addr();
    assert_eq!(submit(addr, &toml, "kr").get("ok"), Some(&Json::Bool(true)));
    let mut conn = TcpStream::connect(addr).unwrap();
    let req = obj(vec![("cmd", jstr("events")), ("job", jstr("kr"))]);
    conn.write_all(req.to_string_compact().as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(conn);
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stream ended before epoch 1");
        let j = Json::parse(line.trim()).unwrap();
        if j.get("event").and_then(Json::as_str) == Some("epoch_end")
            && j.get("epoch").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0
        {
            break;
        }
    }
    let resp = request(addr, &obj(vec![("cmd", jstr("shutdown")), ("mode", jstr("abort"))]));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    life1.wait();
    let ckpt = dir.join("kr.ckpt");
    assert!(ckpt.exists(), "abort parks the job with its checkpoint");

    // The "kill corrupted the disk" scenario: truncate below the header.
    std::fs::write(&ckpt, b"EVOS").unwrap();

    // Life 2: rescan requeues, the corrupt checkpoint demotes to a clean
    // restart, and the job completes exactly once.
    let life2 = start_server(&dir, 1, 0);
    let events = stream_events(life2.addr(), "kr");
    let names = event_names(&events);
    assert!(names.contains(&"requeued".to_string()), "{names:?}");
    let restarted = events
        .iter()
        .find(|e| e.get("event").and_then(Json::as_str) == Some("restarted"))
        .unwrap_or_else(|| panic!("no restarted event: {names:?}"));
    let reason = restarted.get("reason").and_then(Json::as_str).unwrap();
    assert!(reason.contains("unreadable checkpoint"), "{reason}");
    assert!(!names.contains(&"resumed".to_string()), "corrupt checkpoint must not resume");
    let results: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("event").and_then(Json::as_str) == Some("result"))
        .collect();
    assert_eq!(results.len(), 1, "job must complete exactly once: {names:?}");
    assert_matches_standalone(results[0], &reference, "restarted");
    life2.shutdown(false);
    life2.wait();
    assert_eq!(record_json(&dir, "kr").get("state").and_then(Json::as_str), Some("done"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Degraded-mode acceptance: a threaded run surviving one injected
/// worker panic finishes with W−1 workers, emits exactly one
/// `WorkerLost`, bumps `worker.lost`, and stays deterministic — two runs
/// under the same armed schedule produce identical results.
#[test]
fn threaded_worker_panic_degrades_deterministically() {
    let _g = chaos_guard();
    evosample::obs::raise_level(evosample::obs::COUNTERS);

    let run_armed = || {
        fault::arm_spec(&format!(
            "seed={};engine.worker_step=panic,worker=1,after=3,times=1",
            chaos_seed()
        ))
        .unwrap();
        let mut cfg = RunConfig::new(
            "chaos_threaded",
            "native",
            DatasetConfig::SynthCifar { n: 192, classes: 4, label_noise: 0.05, hard_frac: 0.2 },
        );
        cfg.epochs = 4;
        cfg.meta_batch = 32;
        cfg.mini_batch = 8;
        cfg.lr = LrSchedule::Const { lr: 0.02 };
        cfg.test_n = 64;
        cfg.eval_every = 2;
        cfg.seed = 17;
        cfg.sampler = SamplerConfig::es_default();
        cfg.workers = 3;
        cfg.threaded_workers = true;
        let events: std::sync::Arc<Mutex<Vec<Event>>> =
            std::sync::Arc::new(Mutex::new(Vec::new()));
        let sink = std::sync::Arc::clone(&events);
        let rt = evosample::runtime::make_runtime(&cfg).unwrap();
        let result = SessionBuilder::from_config(cfg)
            .runtime(rt)
            .on_event(move |ev: &Event| sink.lock().unwrap().push(ev.clone()))
            .build()
            .unwrap()
            .run()
            .unwrap();
        let fired = fault::fired(sites::ENGINE_WORKER_STEP);
        fault::disarm();
        let events = std::sync::Arc::try_unwrap(events).unwrap().into_inner().unwrap();
        (result, events, fired)
    };

    let _armed = Armed;
    let lost0 = counter("worker.lost");
    let (r1, ev1, fired1) = run_armed();
    assert_eq!(fired1, 1, "the panic rule fires exactly once");
    assert_eq!(counter("worker.lost") - lost0, 1);

    // Exactly one quarantine, of the targeted worker slot.
    let lost: Vec<(usize, usize, String)> = ev1
        .iter()
        .filter_map(|e| match e {
            Event::WorkerLost { epoch, worker, error } => {
                Some((*epoch, *worker, error.clone()))
            }
            _ => None,
        })
        .collect();
    assert_eq!(lost.len(), 1, "{lost:?}");
    assert_eq!(lost[0].1, 1, "the worker=1 scope quarantines slot 1");
    assert!(lost[0].2.contains("panicked"), "{lost:?}");
    let lost_epoch = lost[0].0;

    // Epochs after the loss sync W−1 survivors; epochs before sync W.
    for ev in &ev1 {
        if let Event::SyncRound { epoch, workers } = ev {
            let expect = if *epoch >= lost_epoch { 2 } else { 3 };
            assert_eq!(*workers, expect, "epoch {epoch}");
        }
    }
    assert_eq!(r1.loss_curve.len(), 4, "the run finishes all epochs degraded");

    // Determinism: same seed + same fault schedule → identical run.
    let (r2, ev2, _) = run_armed();
    assert_eq!(r1.loss_curve, r2.loss_curve, "degraded loss curve is deterministic");
    assert_eq!(r1.accuracy_pct(), r2.accuracy_pct());
    assert_eq!(r1.steps, r2.steps);
    let lost2: Vec<&Event> =
        ev2.iter().filter(|e| matches!(e, Event::WorkerLost { .. })).collect();
    assert_eq!(lost2.len(), 1);
    assert!(
        matches!(lost2[0], Event::WorkerLost { epoch, worker: 1, .. } if *epoch == lost_epoch),
        "loss lands on the same epoch both runs: {lost2:?}"
    );
}
