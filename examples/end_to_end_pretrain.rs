//! END-TO-END DRIVER (DESIGN.md "End-to-end validation"): pre-train a real
//! transformer LM through the full three-layer stack — rust coordinator →
//! PJRT-compiled AOT artifacts (JAX L2 + Pallas L1 kernels) — on the
//! synthetic corpus, for several hundred optimizer steps, with ES/ESWP
//! against the baseline. Logs the loss curves (results/e2e_pretrain.jsonl)
//! and prints the summary recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example end_to_end_pretrain
//!
//! EVOSAMPLE_E2E_STEPS overrides the target step count (default ~300).

use evosample::config::presets::{e2e_pretrain, Scale};
use evosample::coordinator::{predicted_saved_time_pct, saved_time_pct, train};
use evosample::data;
use evosample::experiments::make_runtime;
use evosample::metrics::Recorder;
use evosample::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    let mut runs = e2e_pretrain(Scale::from_env());
    // Target a few hundred steps: steps = epochs * n / B.
    let target_steps: usize = std::env::var("EVOSAMPLE_E2E_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    for cfg in &mut runs {
        let per_epoch = cfg.dataset.n().div_ceil(cfg.meta_batch);
        cfg.epochs = (target_steps / per_epoch).max(3);
        cfg.eval_every = 1;
    }

    let rec = Recorder::new("e2e_pretrain")?;
    let split = data::build(&runs[0].dataset, runs[0].test_n, 1234);
    let mut rt = make_runtime(&runs[0])?;
    println!(
        "e2e: pre-training txf_lm ({} params) for ~{} steps per method on {} sequences",
        rt.param_count(),
        target_steps,
        split.train.n
    );

    let mut base = None;
    for cfg in &runs {
        let t0 = std::time::Instant::now();
        let r = train(cfg, rt.as_mut(), &split)?;
        rec.record_result(&r)?;
        rec.record(&obj(vec![
            ("fig", s("e2e_loss_curve")),
            ("method", s(r.sampler.clone())),
            ("steps", num(r.steps as f64)),
            (
                "curve",
                Json::Arr(r.loss_curve.iter().map(|&l| num(l)).collect()),
            ),
        ]))?;
        println!("\n== {} ==", r.sampler);
        println!(
            "  steps {} | final train loss {:.4} | eval loss {:.4} | token acc {:.1}%",
            r.steps,
            r.loss_curve.last().unwrap(),
            r.final_eval.loss,
            r.accuracy_pct()
        );
        println!(
            "  wall {:.1}s (total incl eval {:.1}s) | bp samples {} | fp samples {}",
            r.cost.train_wall_s(),
            t0.elapsed().as_secs_f64(),
            r.cost.bp_samples,
            r.cost.fp_samples
        );
        print!("  loss curve: ");
        for (e, l) in r.loss_curve.iter().enumerate() {
            print!("e{e}:{l:.3} ");
        }
        println!();
        match &base {
            None => base = Some(r),
            Some(b) => println!(
                "  vs baseline: saved {:.1}% wall ({:.1}% flops-pred), Δeval loss {:+.4}",
                saved_time_pct(&b.cost, &r.cost),
                predicted_saved_time_pct(&b.cost, &r.cost),
                r.final_eval.loss - b.final_eval.loss
            ),
        }
    }
    println!("\n(curves in results/e2e_pretrain.jsonl — summarized in EXPERIMENTS.md)");
    Ok(())
}
