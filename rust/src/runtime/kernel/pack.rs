//! Packed parameter layout for the kernel layer.
//!
//! Canonical (interchange) layout — what `get_params`/`set_params`,
//! checkpoints, §D.5 parameter averaging, and the scalar reference all
//! speak — is the historical flat vector:
//!
//! ```text
//! [ W1 (d·h, row-major [d][h]) | b1 (h) | W2 (h·c, row-major [h][c]) | b2 (c) ]
//! ```
//!
//! Packed (kernel) layout transposes `W1` so the forward dots and the
//! backward outer products are unit-stride, and keeps everything else
//! in canonical orientation (already unit-stride for the kernels):
//!
//! ```text
//! [ W1ᵀ (h·d, row-major [h][d]) | b1 (h) | W2 (h·c) | b2 (c) ]
//! ```
//!
//! Packing is a pure permutation — `pack_from` followed by
//! `unpack_into` is the identity, bit for bit — so moving between the
//! two layouts never perturbs training state. The contract: pack on
//! `init`/`set_params` (cold), unpack on `get_params`/`read_params_into`
//! (cold), and run every hot-path kernel on the packed form. Optimizer
//! state (`velocity`) and gradients live in packed space too, so the
//! SGD update is a straight elementwise sweep.

/// Model dimensions plus offset arithmetic for both layouts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout {
    /// Input features per sample.
    pub d: usize,
    /// Hidden units.
    pub h: usize,
    /// Classes.
    pub c: usize,
}

impl Layout {
    pub fn new(d: usize, h: usize, c: usize) -> Layout {
        Layout { d, h, c }
    }

    pub fn param_count(&self) -> usize {
        self.d * self.h + self.h + self.h * self.c + self.c
    }

    // Canonical offsets.
    pub fn w1_off(&self) -> usize {
        0
    }
    pub fn b1_off(&self) -> usize {
        self.d * self.h
    }
    pub fn w2_off(&self) -> usize {
        self.b1_off() + self.h
    }
    pub fn b2_off(&self) -> usize {
        self.w2_off() + self.h * self.c
    }

    // Packed offsets ([W1ᵀ | b1 | W2 | b2]).
    pub fn pb1_off(&self) -> usize {
        self.h * self.d
    }
    pub fn pw2_off(&self) -> usize {
        self.pb1_off() + self.h
    }
    pub fn pb2_off(&self) -> usize {
        self.pw2_off() + self.h * self.c
    }
}

/// Split a flat packed buffer into its four mutable segments
/// `(w1t, b1, w2, b2)`.
pub fn split_packed_mut(
    buf: &mut [f32],
    l: Layout,
) -> (&mut [f32], &mut [f32], &mut [f32], &mut [f32]) {
    debug_assert_eq!(buf.len(), l.param_count());
    let (w1t, rest) = buf.split_at_mut(l.pb1_off());
    let (b1, rest) = rest.split_at_mut(l.h);
    let (w2, b2) = rest.split_at_mut(l.h * l.c);
    (w1t, b1, w2, b2)
}

/// A parameter-space buffer held in PACKED order.
#[derive(Clone, Debug)]
pub struct PackedBuf {
    l: Layout,
    buf: Vec<f32>,
}

impl PackedBuf {
    pub fn zeros(l: Layout) -> PackedBuf {
        PackedBuf { l, buf: vec![0.0; l.param_count()] }
    }

    pub fn layout(&self) -> Layout {
        self.l
    }

    pub fn flat(&self) -> &[f32] {
        &self.buf
    }

    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }

    pub fn fill(&mut self, v: f32) {
        self.buf.fill(v);
    }

    /// `W1ᵀ` segment, row-major `[h][d]`.
    pub fn w1t(&self) -> &[f32] {
        &self.buf[..self.l.pb1_off()]
    }

    pub fn b1(&self) -> &[f32] {
        &self.buf[self.l.pb1_off()..self.l.pw2_off()]
    }

    /// `W2` segment, row-major `[h][c]`.
    pub fn w2(&self) -> &[f32] {
        &self.buf[self.l.pw2_off()..self.l.pb2_off()]
    }

    pub fn b2(&self) -> &[f32] {
        &self.buf[self.l.pb2_off()..]
    }

    /// Install a canonical flat parameter vector (transposing `W1`).
    pub fn pack_from(&mut self, flat: &[f32]) {
        let l = self.l;
        debug_assert_eq!(flat.len(), l.param_count());
        // W1 canonical [d][h] -> packed [h][d].
        for q in 0..l.d {
            let src = &flat[q * l.h..(q + 1) * l.h];
            for (j, &v) in src.iter().enumerate() {
                self.buf[j * l.d + q] = v;
            }
        }
        self.buf[l.pb1_off()..l.pw2_off()].copy_from_slice(&flat[l.b1_off()..l.w2_off()]);
        self.buf[l.pw2_off()..l.pb2_off()].copy_from_slice(&flat[l.w2_off()..l.b2_off()]);
        self.buf[l.pb2_off()..].copy_from_slice(&flat[l.b2_off()..]);
    }

    /// Export to a canonical flat parameter vector (transposing `W1`).
    pub fn unpack_into(&self, flat: &mut [f32]) {
        let l = self.l;
        debug_assert_eq!(flat.len(), l.param_count());
        for j in 0..l.h {
            let src = &self.buf[j * l.d..(j + 1) * l.d];
            for (q, &v) in src.iter().enumerate() {
                flat[q * l.h + j] = v;
            }
        }
        flat[l.b1_off()..l.w2_off()].copy_from_slice(&self.buf[l.pb1_off()..l.pw2_off()]);
        flat[l.w2_off()..l.b2_off()].copy_from_slice(&self.buf[l.pw2_off()..l.pb2_off()]);
        flat[l.b2_off()..].copy_from_slice(&self.buf[l.pb2_off()..]);
    }
}

/// f32 → bf16 with round-to-nearest-even (the standard truncate-plus-
/// carry trick on the raw bits). NaN payloads are preserved quiet.
#[inline(always)]
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    (bits.wrapping_add(round) >> 16) as u16
}

/// bf16 → f32: exact (bf16 is the top half of the f32 bit pattern).
#[inline(always)]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// A bf16 shadow of a [`PackedBuf`] — same packed segment layout, u16
/// storage — for the reduced-precision forward-only scoring path.
///
/// Refresh contract (DESIGN.md §9): the shadow is a *derived* copy, only
/// ever written by quantizing the exact packed parameters. The runtime
/// marks it stale whenever the exact parameters change (`init`,
/// `set_params`, after each train step) and re-quantizes lazily at the
/// next `loss_fwd_ranked` call, so runs that never score in bf16 never
/// pay for the mirror.
#[derive(Clone, Debug)]
pub struct PackedBf16 {
    l: Layout,
    buf: Vec<u16>,
}

impl PackedBf16 {
    pub fn zeros(l: Layout) -> PackedBf16 {
        PackedBf16 { l, buf: vec![0; l.param_count()] }
    }

    pub fn layout(&self) -> Layout {
        self.l
    }

    /// Re-quantize every segment from the exact packed parameters.
    pub fn refresh_from(&mut self, packed: &PackedBuf) {
        debug_assert_eq!(self.l, packed.layout());
        for (o, &v) in self.buf.iter_mut().zip(packed.flat()) {
            *o = f32_to_bf16(v);
        }
    }

    /// `W1ᵀ` segment, row-major `[h][d]`.
    pub fn w1t(&self) -> &[u16] {
        &self.buf[..self.l.pb1_off()]
    }

    pub fn b1(&self) -> &[u16] {
        &self.buf[self.l.pb1_off()..self.l.pw2_off()]
    }

    /// `W2` segment, row-major `[h][c]`.
    pub fn w2(&self) -> &[u16] {
        &self.buf[self.l.pw2_off()..self.l.pb2_off()]
    }

    pub fn b2(&self) -> &[u16] {
        &self.buf[self.l.pb2_off()..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_offsets_are_consistent() {
        let l = Layout::new(5, 3, 2);
        assert_eq!(l.param_count(), 5 * 3 + 3 + 3 * 2 + 2);
        assert_eq!(l.b1_off(), 15);
        assert_eq!(l.w2_off(), 18);
        assert_eq!(l.b2_off(), 24);
        assert_eq!(l.pb1_off(), 15);
        assert_eq!(l.pw2_off(), 18);
        assert_eq!(l.pb2_off(), 24);
    }

    #[test]
    fn pack_unpack_roundtrips_bit_for_bit() {
        let l = Layout::new(7, 4, 3);
        let flat: Vec<f32> = (0..l.param_count()).map(|i| (i as f32).sin()).collect();
        let mut packed = PackedBuf::zeros(l);
        packed.pack_from(&flat);
        let mut back = vec![0.0f32; l.param_count()];
        packed.unpack_into(&mut back);
        assert_eq!(flat, back);
    }

    #[test]
    fn pack_transposes_w1() {
        // d=2, h=3: canonical W1[q][j] = 10*q + j.
        let l = Layout::new(2, 3, 1);
        let mut flat = vec![0.0f32; l.param_count()];
        for q in 0..2 {
            for j in 0..3 {
                flat[q * 3 + j] = (10 * q + j) as f32;
            }
        }
        let mut packed = PackedBuf::zeros(l);
        packed.pack_from(&flat);
        // Packed row j must hold W1[:, j] = [j, 10 + j].
        for j in 0..3 {
            assert_eq!(packed.w1t()[j * 2], j as f32);
            assert_eq!(packed.w1t()[j * 2 + 1], (10 + j) as f32);
        }
    }

    #[test]
    fn bf16_roundtrip_is_exact_for_bf16_representable_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5, -3.25, 1e-38, 3.0e38] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v, "v={v}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-9 sits exactly between bf16(1.0) and the next bf16
        // value; nearest-even resolves to 1.0 (even mantissa).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16_to_f32(f32_to_bf16(halfway)), 1.0);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(bf16_to_f32(f32_to_bf16(above)), f32::from_bits(0x3F81_0000));
        // Relative error is bounded by 2^-8 for normal values.
        for i in 1..200u32 {
            let v = (i as f32 * 0.37).exp() * if i % 2 == 0 { 1.0 } else { -1.0 };
            let back = bf16_to_f32(f32_to_bf16(v));
            assert!(((back - v) / v).abs() <= 1.0 / 256.0, "v={v} back={back}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn packed_bf16_mirrors_segment_offsets() {
        let l = Layout::new(7, 4, 3);
        let flat: Vec<f32> = (0..l.param_count()).map(|i| (i as f32).cos()).collect();
        let mut packed = PackedBuf::zeros(l);
        packed.pack_from(&flat);
        let mut shadow = PackedBf16::zeros(l);
        shadow.refresh_from(&packed);
        assert_eq!(shadow.w1t().len(), packed.w1t().len());
        assert_eq!(shadow.b1().len(), packed.b1().len());
        assert_eq!(shadow.w2().len(), packed.w2().len());
        assert_eq!(shadow.b2().len(), packed.b2().len());
        for (&q, &v) in shadow.w2().iter().zip(packed.w2()) {
            assert_eq!(q, f32_to_bf16(v));
        }
    }

    #[test]
    fn split_packed_mut_segments_have_expected_lengths() {
        let l = Layout::new(3, 4, 2);
        let mut buf = vec![0.0f32; l.param_count()];
        let (w1t, b1, w2, b2) = split_packed_mut(&mut buf, l);
        assert_eq!(w1t.len(), 12);
        assert_eq!(b1.len(), 4);
        assert_eq!(w2.len(), 8);
        assert_eq!(b2.len(), 2);
    }
}
