//! Minimal JSON parser + writer (serde is not available offline).
//!
//! The parser reads `artifacts/manifest.json` (written by python's json
//! module, so the input is always spec-compliant); the writer emits the
//! JSONL result records under `results/`. Full JSON spec: objects, arrays,
//! strings (with escapes), numbers, bool, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly (stable key order — Obj is a BTreeMap).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for result records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: impl Into<String>) -> Json {
    Json::Str(x.into())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            // Surrogate pairs are not used by our writers;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| format!("utf8: {e}"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"x",true,null],"m":{"n":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{
          "models": {"mlp": {"param_count": 820874,
                              "artifacts": {"train_step": {"32": "f.hlo.txt"}}}},
          "version": 1
        }"#;
        let j = Json::parse(src).unwrap();
        let pc = j
            .get("models").unwrap()
            .get("mlp").unwrap()
            .get("param_count").unwrap()
            .as_usize().unwrap();
        assert_eq!(pc, 820874);
    }

    #[test]
    fn writer_escapes_controls() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string_compact(), r#""a\"b\\c\nd""#);
    }
}
