//! evolint self-check (DESIGN.md §13).
//!
//! Two halves, both load-bearing:
//!
//! * the crate's own sources must lint clean — the determinism,
//!   durability, and panic-safety contracts are machine-checked, not
//!   conventions; and
//! * every rule must FIRE on a negative fixture — a lint that always
//!   passes is indistinguishable from a lint that checks nothing.

use evosample::analysis::{self, catalog::Catalogs, rules};

/// Registry catalogs extracted from the real tree (fixtures lint
/// against the same name lists the crate does).
fn cats() -> Catalogs {
    let root = analysis::default_src_root();
    Catalogs::from_sources(|rel| std::fs::read_to_string(root.join(rel)).ok())
        .expect("catalogs extract from the real tree")
}

/// Rule ids that fire on `src` placed at `rel` (relative to rust/src).
fn fired(rel: &str, src: &str) -> Vec<&'static str> {
    analysis::lint_source(rel, src, &cats()).iter().map(|f| f.rule).collect()
}

#[test]
fn crate_is_violation_free() {
    let report = analysis::lint_crate(&analysis::default_src_root())
        .expect("lint run over rust/src");
    assert!(report.files_scanned > 40, "scanned {} files", report.files_scanned);
    assert!(
        report.is_clean(),
        "the crate must lint clean:\n{}",
        report.to_text()
    );
}

#[test]
fn fires_on_unordered_iteration_in_scoped_paths() {
    let src = "use std::collections::HashMap;\nfn f(m: HashMap<u32, u32>) { for _ in &m {} }\n";
    assert!(
        fired("coordinator/fixture.rs", src).contains(&rules::UNORDERED),
        "HashMap in coordinator/ must fire"
    );
    assert!(
        fired("sampler/fixture.rs", "fn f() { let s = std::collections::HashSet::new(); }")
            .contains(&rules::UNORDERED),
        "HashSet in sampler/ must fire"
    );
    // api/ is outside the determinism scope.
    assert!(fired("api/fixture.rs", src).is_empty());
}

#[test]
fn fires_on_wallclock_outside_blessed_layers() {
    let src = "fn f() { let _t = std::time::Instant::now(); }";
    assert!(fired("coordinator/engine/fixture.rs", src).contains(&rules::WALLCLOCK));
    assert!(
        fired("data/fixture.rs", "fn f() { let _t = std::time::SystemTime::now(); }")
            .contains(&rules::WALLCLOCK),
        "SystemTime fires too"
    );
    // The blessed layers may read the clock.
    assert!(fired("serve/fixture.rs", src).is_empty());
    assert!(fired("obs/fixture.rs", src).is_empty());
    assert!(fired("fault/fixture.rs", src).is_empty());
    assert!(fired("util/timer.rs", src).is_empty());
    // …but util/ broadly may not (bench.rs times through Stopwatch).
    assert!(fired("util/bench.rs", src).contains(&rules::WALLCLOCK));
}

#[test]
fn fires_on_raw_write_primitives() {
    for src in [
        r#"fn f() { let _ = std::fs::write("p", b"x"); }"#,
        r#"fn f() -> std::io::Result<std::fs::File> { std::fs::File::create("p") }"#,
        r#"fn f() { let _ = std::fs::rename("a", "b"); }"#,
    ] {
        assert!(
            fired("coordinator/fixture.rs", src).contains(&rules::ATOMIC),
            "must fire on: {src}"
        );
    }
    // The atomic-commit implementation itself is the one allowed home.
    assert!(fired(
        "fault/atomic_io.rs",
        r#"fn f() { let _ = std::fs::rename("a", "b"); }"#
    )
    .is_empty());
}

#[test]
fn fires_on_panics_in_serve_and_fault() {
    let unwrap_src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
    let expect_src = r#"fn f(x: Option<u32>) -> u32 { x.expect("present") }"#;
    let panic_src = r#"fn f() { panic!("boom"); }"#;
    for src in [unwrap_src, expect_src, panic_src] {
        assert!(fired("serve/fixture.rs", src).contains(&rules::PANIC), "serve: {src}");
        assert!(fired("fault/fixture.rs", src).contains(&rules::PANIC), "fault: {src}");
    }
    // Out of scope: the engine may unwrap (its panics are caught by the
    // threaded engine's quarantine, not a server teardown).
    assert!(fired("coordinator/fixture.rs", unwrap_src).is_empty());
    // The poisoned-lock house pattern must NOT be flagged: identifier
    // tokenization distinguishes unwrap from unwrap_or_else.
    let house = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap_or_else(|e| e.into_inner()) }";
    assert!(fired("serve/fixture.rs", house).is_empty(), "unwrap_or_else is fine");
    // A string literal CONTAINING unwrap() is content, not code.
    let string_only = r#"fn f() -> &'static str { "please .unwrap() later" }"#;
    assert!(fired("serve/fixture.rs", string_only).is_empty());
}

#[test]
fn fires_on_unknown_failpoint_site() {
    let bad = r#"fn f() { let _ = crate::fault::hit_io("bogus.site"); }"#;
    assert!(fired("serve/fixture.rs", bad).contains(&rules::FAILPOINT));
    let good = r#"fn f() { let _ = crate::fault::hit_io("checkpoint.save"); }"#;
    assert!(
        fired("coordinator/fixture.rs", good).is_empty(),
        "sites in fault::sites::ALL pass"
    );
}

#[test]
fn fires_on_uncataloged_metric_name() {
    let bad = r#"fn f() { crate::obs::registry().counter("bogus.metric").add(1); }"#;
    assert!(fired("serve/fixture.rs", bad).contains(&rules::METRIC));
    let good = r#"fn f() { crate::obs::registry().counter("engine.steps").add(1); }"#;
    assert!(fired("serve/fixture.rs", good).is_empty());
    // Dynamic names (format!) are out of literal-check scope.
    let dynamic = r#"fn f(site: &str) { crate::obs::registry().counter(&format!("fault.injected.{site}")).add(1); }"#;
    assert!(fired("fault/fixture.rs", dynamic).is_empty());
}

#[test]
fn fires_on_unknown_event_name() {
    let bad = r#"fn f() -> (&'static str, Json) { ("event", s("not_an_event")) }"#;
    assert!(fired("serve/fixture.rs", bad).contains(&rules::EVENT));
    for good_name in ["run_start", "eval_done", "queued", "retrying"] {
        let good = format!(r#"fn f() -> (&'static str, Json) {{ ("event", s("{good_name}")) }}"#);
        assert!(
            fired("serve/fixture.rs", &good).is_empty(),
            "{good_name} is a known event"
        );
    }
}

#[test]
fn allow_directive_suppresses_and_unused_allow_fires() {
    let suppressed = "\
fn f() {
    // lint:allow(robustness/no-panic-in-serve): fixture demonstrates suppression
    panic!(\"boom\");
}
";
    assert!(
        fired("serve/fixture.rs", suppressed).is_empty(),
        "a justified allow suppresses the finding without an unused-allow"
    );
    // Same directive with nothing to suppress → lint/unused-allow.
    let unused = "// lint:allow(robustness/no-panic-in-serve): stale reason\nfn f() {}\n";
    assert_eq!(fired("serve/fixture.rs", unused), vec![rules::UNUSED_ALLOW]);
    // Unknown rule id → flagged rather than silently inert.
    let unknown = "// lint:allow(no/such-rule): whatever\nfn f() {}\n";
    assert_eq!(fired("serve/fixture.rs", unknown), vec![rules::UNUSED_ALLOW]);
    // Missing reason → malformed → flagged.
    let malformed = "// lint:allow(robustness/no-panic-in-serve)\nfn f() { panic!(\"x\"); }\n";
    let got = fired("serve/fixture.rs", malformed);
    assert!(got.contains(&rules::UNUSED_ALLOW), "malformed directive is reported");
    assert!(got.contains(&rules::PANIC), "and it suppresses nothing");
}

#[test]
fn test_code_is_exempt_from_every_rule() {
    let src = "\
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _t = std::time::Instant::now();
        let _ = std::fs::write(\"p\", b\"x\");
        let m: std::collections::HashMap<u32, u32> = Default::default();
        assert!(m.is_empty());
        Some(1).unwrap();
        crate::obs::registry().counter(\"test.only.name\").add(1);
    }
}
";
    for rel in ["serve/fixture.rs", "coordinator/fixture.rs", "data/fixture.rs"] {
        assert!(fired(rel, src).is_empty(), "test spans exempt everything in {rel}");
    }
}

/// Satellite check: the serve connection/record paths the fault layer
/// hardened stay panic-free — per file, not just via the whole-crate
/// sweep, so a regression names the file that broke.
#[test]
fn serve_connection_paths_stay_panic_free() {
    let root = analysis::default_src_root();
    let catalogs = cats();
    for rel in ["serve/server.rs", "serve/scheduler.rs", "serve/job.rs", "serve/queue.rs"] {
        let src = std::fs::read_to_string(root.join(rel)).expect(rel);
        let panics: Vec<String> = analysis::lint_source(rel, &src, &catalogs)
            .into_iter()
            .filter(|f| f.rule == rules::PANIC)
            .map(|f| format!("{}:{}", f.file, f.line))
            .collect();
        assert!(panics.is_empty(), "{rel} has panic paths: {panics:?}");
    }
}

/// Every rule in the registry has at least one firing fixture above;
/// keep the list and the registry in sync.
#[test]
fn every_rule_has_a_fixture() {
    let exercised = [
        rules::UNORDERED,
        rules::WALLCLOCK,
        rules::ATOMIC,
        rules::PANIC,
        rules::FAILPOINT,
        rules::METRIC,
        rules::EVENT,
        rules::UNUSED_ALLOW,
    ];
    for rule in rules::ALL_RULES {
        assert!(exercised.contains(rule), "rule {rule} lacks a negative fixture");
    }
}
