//! Integration tests: the full coordinator stack on the pure-rust
//! NativeRuntime (no artifacts needed). These pin the paper-level
//! *behavioral* claims at miniature scale: ES reduces BP samples without
//! hurting accuracy, ESWP prunes, samplers find hard samples, gradient
//! accumulation counts BP passes correctly, and runs are deterministic.

// Exercises the deprecated `coordinator::train` shim on purpose: its
// behavior must stay pinned for as long as it exists.
#![allow(deprecated)]

use evosample::config::{DatasetConfig, LrSchedule, RunConfig, SamplerConfig};
use evosample::coordinator::{predicted_saved_time_pct, train};
use evosample::data;
use evosample::runtime::native::NativeRuntime;
use evosample::runtime::ModelRuntime;

/// A small, learnable float-feature task + matching native runtime.
fn setup(n: usize, classes: usize) -> (RunConfig, data::SplitDataset, NativeRuntime) {
    let cfg_ds = DatasetConfig::SynthCifar {
        n,
        classes,
        label_noise: 0.05,
        hard_frac: 0.2,
    };
    let split = data::build(&cfg_ds, 256, 42);
    let rt = NativeRuntime::new(split.train.x_len(), 32, classes);
    let mut cfg = RunConfig::new("itest", "native", cfg_ds);
    cfg.epochs = 6;
    cfg.meta_batch = 64;
    cfg.mini_batch = 16;
    cfg.lr = LrSchedule::OneCycle { max_lr: 0.02, warmup_frac: 0.3 };
    cfg.test_n = 256;
    (cfg, split, rt)
}

#[test]
fn baseline_learns_the_synthetic_task() {
    let (mut cfg, split, mut rt) = setup(512, 4);
    cfg.sampler = SamplerConfig::Uniform;
    let r = train(&cfg, &mut rt, &split).unwrap();
    assert!(
        r.final_eval.accuracy > 0.5,
        "baseline acc {} should beat 4-class chance",
        r.final_eval.accuracy
    );
    assert!(r.loss_curve.first().unwrap() > r.loss_curve.last().unwrap());
}

#[test]
fn es_reduces_bp_samples_with_comparable_accuracy() {
    let (mut cfg, split, mut rt) = setup(1024, 4);
    cfg.sampler = SamplerConfig::Uniform;
    let base = train(&cfg, &mut rt, &split).unwrap();

    cfg.sampler = SamplerConfig::es_default();
    let es = train(&cfg, &mut rt, &split).unwrap();

    // Paper Tab. 1: ES uses b/B of the baseline's BP samples (modulo
    // annealing epochs that run full batches).
    assert!(
        (es.cost.bp_samples as f64) < 0.6 * base.cost.bp_samples as f64,
        "es bp={} base bp={}",
        es.cost.bp_samples,
        base.cost.bp_samples
    );
    // Scoring FPs appear only for ES.
    assert_eq!(base.cost.fp_samples, 0);
    assert!(es.cost.fp_samples > 0);
    // Lossless-ish at miniature scale: within 12 points absolute.
    assert!(
        es.final_eval.accuracy > base.final_eval.accuracy - 0.12,
        "es acc {} vs base {}",
        es.final_eval.accuracy,
        base.final_eval.accuracy
    );
    // The analytic model predicts meaningful savings at b/B=25%.
    assert!(predicted_saved_time_pct(&base.cost, &es.cost) > 25.0);
}

#[test]
fn eswp_prunes_and_saves_more_flops_than_es() {
    let (mut cfg, split, mut rt) = setup(1024, 4);
    cfg.sampler = SamplerConfig::es_default();
    let es = train(&cfg, &mut rt, &split).unwrap();
    cfg.sampler = SamplerConfig::eswp_default();
    let eswp = train(&cfg, &mut rt, &split).unwrap();
    assert!(
        eswp.cost.total_flops() < es.cost.total_flops(),
        "eswp {} !< es {}",
        eswp.cost.total_flops(),
        es.cost.total_flops()
    );
    assert!(eswp.steps < es.steps, "pruning must shorten epochs");
}

#[test]
fn every_sampler_trains_end_to_end() {
    let (mut cfg, split, mut rt) = setup(512, 4);
    for sampler in [
        SamplerConfig::Uniform,
        SamplerConfig::Loss,
        SamplerConfig::Ordered,
        SamplerConfig::es_default(),
        SamplerConfig::eswp_default(),
        SamplerConfig::infobatch_default(),
        SamplerConfig::kakurenbo_default(),
        SamplerConfig::ucb_default(),
        SamplerConfig::RandomPrune { prune_ratio: 0.2 },
    ] {
        cfg.sampler = sampler;
        let r = train(&cfg, &mut rt, &split)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.sampler.name()));
        assert!(r.final_eval.accuracy > 0.3, "{} collapsed", r.sampler);
        assert!(r.steps > 0);
    }
}

#[test]
fn training_is_deterministic_per_seed() {
    let (mut cfg, split, mut rt) = setup(256, 4);
    cfg.sampler = SamplerConfig::es_default();
    let a = train(&cfg, &mut rt, &split).unwrap();
    let b = train(&cfg, &mut rt, &split).unwrap();
    assert_eq!(a.final_eval.accuracy, b.final_eval.accuracy);
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.cost.bp_samples, b.cost.bp_samples);

    cfg.seed = 99;
    let c = train(&cfg, &mut rt, &split).unwrap();
    assert_ne!(a.loss_curve, c.loss_curve, "different seed, different run");
}

#[test]
fn grad_accum_counts_bp_passes() {
    let (mut cfg, split, mut rt) = setup(256, 4);
    cfg.sampler = SamplerConfig::Uniform;
    cfg.meta_batch = 32;
    cfg.mini_batch = 32;
    cfg.micro_batch = 8;
    let r = train(&cfg, &mut rt, &split).unwrap();
    // Every step: 32 samples / 8 micro = 4 BP passes.
    assert_eq!(r.cost.bp_passes, r.steps * 4);

    // ESWP in the same low-resource setting: b=8 => 1 BP pass per step.
    cfg.mini_batch = 8;
    cfg.micro_batch = 8;
    cfg.sampler = SamplerConfig::eswp_default();
    let r2 = train(&cfg, &mut rt, &split).unwrap();
    let active_passes = r2.cost.bp_passes;
    // Annealed epochs still run 4 passes; active ones run 1. So strictly
    // fewer than baseline's uniform 4/step.
    assert!(active_passes < r2.steps * 4, "{active_passes} vs {}", r2.steps * 4);
}

#[test]
fn distributed_simulation_matches_single_worker_statistically() {
    let (mut cfg, split, mut rt) = setup(512, 4);
    cfg.sampler = SamplerConfig::eswp_default();
    cfg.workers = 4;
    let r = train(&cfg, &mut rt, &split).unwrap();
    assert!(r.final_eval.accuracy > 0.4, "dist acc {}", r.final_eval.accuracy);
    // All kept samples still flow through training.
    assert!(r.cost.bp_samples > 0);
}

#[test]
fn es_concentrates_bp_on_hard_and_noisy_samples() {
    // The mechanism test: after training, samples that ES selected most
    // should skew toward the generator's high-difficulty tail.
    let cfg_ds = DatasetConfig::SynthCifar {
        n: 512,
        classes: 4,
        label_noise: 0.1,
        hard_frac: 0.2,
    };
    let split = data::build(&cfg_ds, 128, 7);
    let mut rt = NativeRuntime::new(split.train.x_len(), 32, 4);
    let mut cfg = RunConfig::new("mech", "native", cfg_ds);
    cfg.epochs = 8;
    cfg.meta_batch = 64;
    cfg.mini_batch = 16;
    cfg.sampler = SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.0 };
    cfg.lr = LrSchedule::Const { lr: 0.02 };
    cfg.test_n = 128;

    // Track selection counts via the class_bp-like route: instead use the
    // sampler's weights after training — high-difficulty samples should
    // have higher weights. We train and then re-derive by difficulty split.
    let r = train(&cfg, &mut rt, &split).unwrap();
    assert!(r.cost.bp_samples > 0);

    // Use an explicit Evolved sampler fed by a real loss oracle to assert
    // the weight ordering (trainer API does not expose sampler state).
    use evosample::runtime::BatchBuf;
    use evosample::sampler::evolved::Evolved;
    use evosample::sampler::Sampler;
    let mut es = Evolved::new(split.train.n, 8, 0.2, 0.9, 0.0, 0.0);
    let mut buf = BatchBuf::new();
    let all: Vec<u32> = (0..split.train.n as u32).collect();
    for chunk in all.chunks(64) {
        buf.fill(&split.train, chunk);
        let losses = rt.loss_fwd(buf.x(&split.train), &buf.y, chunk.len()).unwrap();
        es.observe_meta(chunk, &losses, 1);
    }
    let w = es.weights_table();
    let hard_mean: f32 = all
        .iter()
        .filter(|&&i| split.train.difficulty[i as usize] >= 0.6)
        .map(|&i| w[i as usize])
        .sum::<f32>()
        / all.iter().filter(|&&i| split.train.difficulty[i as usize] >= 0.6).count() as f32;
    let easy_mean: f32 = all
        .iter()
        .filter(|&&i| split.train.difficulty[i as usize] < 0.4)
        .map(|&i| w[i as usize])
        .sum::<f32>()
        / all.iter().filter(|&&i| split.train.difficulty[i as usize] < 0.4).count() as f32;
    assert!(
        hard_mean > 1.5 * easy_mean,
        "hard weight {hard_mean} vs easy {easy_mean}: selection should find hard samples"
    );
}

#[test]
fn annealing_window_disables_selection_at_edges() {
    let (mut cfg, split, mut rt) = setup(256, 4);
    cfg.epochs = 10;
    cfg.sampler = SamplerConfig::Es { beta1: 0.2, beta2: 0.9, anneal_frac: 0.1 };
    let r = train(&cfg, &mut rt, &split).unwrap();
    // 1 annealed epoch at each side: those run BP on full meta-batches.
    // steps/epoch = 256/64 = 4; annealed epochs contribute 64*4 BP samples,
    // active ones 16*4.
    let expected = 2 * 4 * 64 + 8 * 4 * 16;
    assert_eq!(r.cost.bp_samples, expected as u64);
}

#[test]
fn eval_handles_ragged_test_sets() {
    let cfg_ds = DatasetConfig::SynthCifar { n: 256, classes: 4, label_noise: 0.0, hard_frac: 0.2 };
    let split = data::build(&cfg_ds, 100, 3); // 100 not divisible by chunk
    let mut rt = NativeRuntime::new(split.train.x_len(), 16, 4);
    rt.init(0).unwrap();
    let stats = evosample::coordinator::evaluate(&mut rt, &split).unwrap();
    assert!(stats.loss.is_finite());
    assert!((0.0..=1.0).contains(&stats.accuracy));
}
