//! Analytical tools for the paper's theory: the explicit Eq. 3.2 weight
//! expansion (Prop. 3.1), the Thm. 3.2 transfer function, and the Fig. 1/8
//! synthetic weight-signal traces.

/// Explicit form of the ES weight after observing `losses[0..T]` at steps
/// 1..=T (paper Eq. 3.2, *without* truncating the O(β2^t) boundary terms,
/// so it matches the recursion exactly):
///
///   w(T) = (1-β2)·Σ_{k=1..T} β2^{T-k} ℓ(k)
///        + (β2-β1)·Σ_{k=1..T-1} β2^{T-1-k} (ℓ(k+1)-ℓ(k))
///        + boundary(s0, ℓ(1))
pub fn explicit_weight(losses: &[f32], beta1: f32, beta2: f32, s0: f32) -> f32 {
    let t_max = losses.len();
    if t_max == 0 {
        return s0;
    }
    let (b1, b2) = (beta1 as f64, beta2 as f64);
    // s(T) expansion: s(T) = β2^T s0 + (1-β2) Σ β2^{T-k} ℓ(k).
    let mut s_t = b2.powi(t_max as i32) * s0 as f64;
    for (k, &l) in losses.iter().enumerate() {
        // losses[k] is ℓ(k+1)
        s_t += (1.0 - b2) * b2.powi((t_max - 1 - k) as i32) * l as f64;
    }
    // w(T) = s(T) + (β2-β1)/(1-β2) · (s(T) - s(T-1))  [Eq. B.18]
    // with s(T)-s(T-1) expanded per Eq. B.20 including boundary terms.
    let mut diff = -(1.0 - b2) * b2.powi(t_max as i32 - 1) * s0 as f64;
    diff += (1.0 - b2) * b2.powi(t_max as i32 - 1) * losses[0] as f64;
    for k in 1..t_max {
        diff += (1.0 - b2)
            * b2.powi((t_max - 1 - k) as i32)
            * (losses[k] - losses[k - 1]) as f64;
    }
    let w = if (1.0 - b2).abs() < 1e-12 {
        // β2 = 1: s never moves, w = β1 s0 + (1-β1) ℓ(T).
        b1 * s0 as f64 + (1.0 - b1) * *losses.last().unwrap() as f64
    } else {
        s_t + (b2 - b1) / (1.0 - b2) * diff
    };
    w as f32
}

/// |H(iω)| for the Thm. 3.2 transfer function
/// H(ω) = ((β2-β1)ω + (1-β2)) / (ω + (1-β2)).
pub fn transfer_magnitude(beta1: f64, beta2: f64, omega: f64) -> f64 {
    let a = beta2 - beta1;
    let b = 1.0 - beta2;
    (((a * omega).powi(2) + b * b) / (omega * omega + b * b)).sqrt()
}

/// One step of the coupled recursion for a single scalar signal; returns
/// (w, s'). Used by the Fig. 1/8 signal traces.
pub fn scalar_step(s: f32, loss: f32, beta1: f32, beta2: f32) -> (f32, f32) {
    let w = beta1 * s + (1.0 - beta1) * loss;
    let s2 = beta2 * s + (1.0 - beta2) * loss;
    (w, s2)
}

/// Generate the Fig. 1 / Fig. 8 illustration: a decaying loss signal with
/// random perturbations, plus the weight signals of Loss (Eq. 2.3) and ES
/// (Eq. 3.1) for the given betas. Returns (loss, w_loss, w_es) traces.
pub fn fig1_traces(
    steps: usize,
    beta1: f32,
    beta2: f32,
    rng: &mut crate::util::Pcg64,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut loss = Vec::with_capacity(steps);
    let mut w_loss = Vec::with_capacity(steps);
    let mut w_es = Vec::with_capacity(steps);
    let mut s = 1.0f32 / 8.0;
    for t in 0..steps {
        // Decaying trend with oscillatory noise — "typical behaviors of
        // loss curves in general machine learning" (Fig. 1 caption).
        let trend = 2.5 * (-(t as f32) / (steps as f32 * 0.35)).exp() + 0.3;
        let noise = 0.35 * rng.normal() * (1.0 + 0.5 * (t as f32 * 0.9).sin());
        let l = (trend + noise).max(0.02);
        let (w, s2) = scalar_step(s, l, beta1, beta2);
        s = s2;
        loss.push(l);
        w_loss.push(l); // Eq. 2.3: weight == current loss
        w_es.push(w);
    }
    (loss, w_loss, w_es)
}

/// Discrete total variation of a signal — the quantitative "oscillation"
/// measure used to verify the smoothing claim of Thm. 3.2 numerically.
pub fn total_variation(xs: &[f32]) -> f64 {
    xs.windows(2).map(|w| (w[1] - w[0]).abs() as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::Pcg64;

    #[test]
    fn transfer_magnitude_bounded_by_one() {
        // Thm. 3.2 (i): |H(iω)| <= 1 for all frequencies, β ∈ (0,1).
        check("|H| <= 1", 300, |g| {
            let b1 = g.f64_in(0.001, 0.999);
            let b2 = g.f64_in(0.001, 0.999);
            let omega = 10f64.powf(g.f64_in(-4.0, 4.0));
            let h = transfer_magnitude(b1, b2, omega);
            prop_assert!(h <= 1.0 + 1e-12, "|H|={h} at b1={b1} b2={b2} w={omega}");
            Ok(())
        });
    }

    #[test]
    fn transfer_high_freq_limit_is_beta_gap() {
        // Thm. 3.2 (ii): |H(iω)| → |β2-β1| as ω → ∞.
        for (b1, b2) in [(0.2, 0.9), (0.5, 0.9), (0.8, 0.9), (0.9, 0.2)] {
            let h = transfer_magnitude(b1, b2, 1e8);
            assert!((h - ((b2 - b1) as f64).abs()).abs() < 1e-6, "b1={b1} b2={b2}: {h}");
        }
    }

    #[test]
    fn transfer_dc_gain_is_one() {
        // ω → 0: |H| → 1 (the overall trend passes through unattenuated).
        let h = transfer_magnitude(0.2, 0.9, 1e-9);
        assert!((h - 1.0).abs() < 1e-6);
    }

    #[test]
    fn explicit_weight_beta2_one_special_case() {
        let w = explicit_weight(&[1.0, 2.0, 3.0], 0.5, 1.0, 0.125);
        assert!((w - (0.5 * 0.125 + 0.5 * 3.0)).abs() < 1e-6);
    }

    #[test]
    fn explicit_matches_scalar_recursion_exactly() {
        check("explicit == recursion", 100, |g| {
            let t = g.usize_in(1, 50);
            let b1 = g.f32_in(0.0, 1.0);
            let b2 = g.f32_in(0.0, 0.99);
            let losses = g.vec_f32(t, 0.0, 5.0);
            let s0 = 0.125f32;
            let mut s = s0;
            let mut w = s0;
            for &l in &losses {
                let (w2, s2) = scalar_step(s, l, b1, b2);
                w = w2;
                s = s2;
            }
            let we = explicit_weight(&losses, b1, b2, s0);
            prop_assert!(
                (w - we).abs() < 1e-3 * (1.0 + w.abs()),
                "rec={w} explicit={we} (b1={b1} b2={b2} t={t})"
            );
            Ok(())
        });
    }

    #[test]
    fn es_weights_smoother_than_loss_weights() {
        // The Fig. 1 claim, checked numerically: total variation of the ES
        // weight signal is strictly below the raw loss signal's for the
        // paper's default betas.
        let mut rng = Pcg64::new(42);
        let (_, w_loss, w_es) = fig1_traces(400, 0.5, 0.9, &mut rng);
        let tv_loss = total_variation(&w_loss);
        let tv_es = total_variation(&w_es);
        assert!(
            tv_es < 0.8 * tv_loss,
            "tv_es={tv_es} not < 0.8 * tv_loss={tv_loss}"
        );
    }

    #[test]
    fn es_weights_track_the_trend() {
        // Smoothing must not destroy the signal: the ES weights still
        // correlate strongly with the loss trend.
        let mut rng = Pcg64::new(7);
        let (loss, _, w_es) = fig1_traces(400, 0.5, 0.9, &mut rng);
        // Pearson correlation.
        let n = loss.len() as f64;
        let mx = loss.iter().map(|&x| x as f64).sum::<f64>() / n;
        let my = w_es.iter().map(|&x| x as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let mut dx = 0.0;
        let mut dy = 0.0;
        for (&x, &y) in loss.iter().zip(&w_es) {
            num += (x as f64 - mx) * (y as f64 - my);
            dx += (x as f64 - mx).powi(2);
            dy += (y as f64 - my).powi(2);
        }
        let r = num / (dx.sqrt() * dy.sqrt());
        assert!(r > 0.7, "correlation {r}");
    }
}
