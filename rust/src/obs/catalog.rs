//! The authoritative metric-name catalog (DESIGN.md §11, §13).
//!
//! Every static metric name any instrumentation site passes to
//! [`Registry::counter`](super::Registry::counter) /
//! [`gauge`](super::Registry::gauge) /
//! [`histogram`](super::Registry::histogram) is declared here, and
//! evolint's `registry/metric-names` rule machine-checks the match: a
//! typo'd name at a call site (silently splitting a metric in two) or a
//! name added without cataloging it fails `evosample lint`.
//!
//! Dynamically-suffixed families are out of literal-check scope and
//! documented here instead: `fault.injected.<site>` (per-site fire
//! counts), `serve.shed.<reason>` (per-reason admission sheds), and the
//! `job.<id>.…` names minted by [`Registry::scope`](super::Registry::scope).

/// `data/loader.rs`: prefetched meta-batches handed to the engine.
pub const DATA_PREFETCH_BATCHES: &str = "data.prefetch_batches";
/// `data/loader.rs`: seconds the engine blocked on the prefetch channel.
pub const DATA_PREFETCH_STALL_S: &str = "data.prefetch_stall_s";
/// `coordinator/engine`: completed epochs.
pub const ENGINE_EPOCHS: &str = "engine.epochs";
/// `coordinator/engine`: completed optimizer steps.
pub const ENGINE_STEPS: &str = "engine.steps";
/// `coordinator/engine/threaded.rs`: §D.5 sync rounds completed.
pub const ENGINE_SYNC_ROUNDS: &str = "engine.sync_rounds";
/// `fault/mod.rs`: total injected faults (per-site under
/// `fault.injected.<site>`).
pub const FAULT_INJECTED: &str = "fault.injected";
/// `runtime/kernel/pool.rs`: kernel dispatches through the pool.
pub const KERNEL_DISPATCHES: &str = "kernel.dispatches";
/// `runtime/kernel/pool.rs`: lanes actually granted.
pub const KERNEL_LANES_GRANTED: &str = "kernel.lanes_granted";
/// `runtime/kernel/pool.rs`: lanes currently held.
pub const KERNEL_LANES_IN_USE: &str = "kernel.lanes_in_use";
/// `runtime/kernel/pool.rs`: lanes requested.
pub const KERNEL_LANES_REQUESTED: &str = "kernel.lanes_requested";
/// `serve/scheduler.rs`: job retry attempts after worker errors.
pub const RETRY_ATTEMPTS: &str = "retry.attempts";
/// `runtime/native.rs`: bf16 weight-shadow refreshes (DESIGN.md §9).
pub const RUNTIME_BF16_SHADOW_REFRESH: &str = "runtime.bf16_shadow_refresh";
/// `coordinator/engine/pipeline.rs`: cadence steps that reused cached
/// weights instead of scoring (DESIGN.md §8).
pub const SELECT_CADENCE_SKIPS: &str = "select.cadence_skips";
/// `coordinator/engine`: share of the dataset kept this epoch.
pub const SELECT_KEEP_RATE_PCT: &str = "select.keep_rate_pct";
/// `coordinator/engine/pipeline.rs`: meta-loss distribution summary.
pub const SELECT_META_LOSS: &str = "select.meta_loss";
/// `coordinator/engine`: samples pruned from the epoch's active set.
pub const SELECT_PRUNED_SIZE: &str = "select.pruned_size";
/// `coordinator/engine/pipeline.rs`: scoring forward passes run.
pub const SELECT_SCORING_PASSES: &str = "select.scoring_passes";
/// `serve/queue.rs`: queued jobs.
pub const SERVE_QUEUE_DEPTH: &str = "serve.queue_depth";
/// `serve/job.rs`: seconds a job waited between submission and start.
pub const SERVE_QUEUE_WAIT_S: &str = "serve.queue_wait_s";
/// `serve/queue.rs`: running jobs.
pub const SERVE_RUNNING: &str = "serve.running";
/// `serve/queue.rs`: admission-control sheds (per-reason under
/// `serve.shed.<reason>`).
pub const SERVE_SHED: &str = "serve.shed";
/// `serve/queue.rs`: jobs accepted into the queue.
pub const SERVE_SUBMITTED: &str = "serve.submitted";
/// `coordinator/engine/pipeline.rs`: data-gather stage duration.
pub const STAGE_DATA_GATHER: &str = "stage.data_gather";
/// `coordinator/engine/pipeline.rs`: observe stage duration.
pub const STAGE_OBSERVE: &str = "stage.observe";
/// `coordinator/engine/pipeline.rs`: scoring-FP stage duration.
pub const STAGE_SCORING_FP: &str = "stage.scoring_fp";
/// `coordinator/engine/pipeline.rs`: select stage duration.
pub const STAGE_SELECT: &str = "stage.select";
/// `coordinator/engine/threaded.rs`: §D.5 sync-round duration.
pub const STAGE_SYNC: &str = "stage.sync";
/// `coordinator/engine/pipeline.rs`: train-BP stage duration.
pub const STAGE_TRAIN_BP: &str = "stage.train_bp";
/// `coordinator/engine/threaded.rs`: workers lost to panics/step errors.
pub const WORKER_LOST: &str = "worker.lost";

/// Every cataloged static metric name, sorted.
pub const ALL: &[&str] = &[
    DATA_PREFETCH_BATCHES,
    DATA_PREFETCH_STALL_S,
    ENGINE_EPOCHS,
    ENGINE_STEPS,
    ENGINE_SYNC_ROUNDS,
    FAULT_INJECTED,
    KERNEL_DISPATCHES,
    KERNEL_LANES_GRANTED,
    KERNEL_LANES_IN_USE,
    KERNEL_LANES_REQUESTED,
    RETRY_ATTEMPTS,
    RUNTIME_BF16_SHADOW_REFRESH,
    SELECT_CADENCE_SKIPS,
    SELECT_KEEP_RATE_PCT,
    SELECT_META_LOSS,
    SELECT_PRUNED_SIZE,
    SELECT_SCORING_PASSES,
    SERVE_QUEUE_DEPTH,
    SERVE_QUEUE_WAIT_S,
    SERVE_RUNNING,
    SERVE_SHED,
    SERVE_SUBMITTED,
    STAGE_DATA_GATHER,
    STAGE_OBSERVE,
    STAGE_SCORING_FP,
    STAGE_SELECT,
    STAGE_SYNC,
    STAGE_TRAIN_BP,
    WORKER_LOST,
];

#[cfg(test)]
mod tests {
    use super::ALL;

    #[test]
    fn catalog_is_sorted_and_unique() {
        for w in ALL.windows(2) {
            assert!(w[0] < w[1], "catalog must stay sorted/deduped: {} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn names_use_the_dotted_lowercase_convention() {
        for name in ALL {
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase()
                    || c.is_ascii_digit()
                    || c == '.'
                    || c == '_'),
                "bad metric name {name:?}"
            );
            assert!(name.contains('.'), "names are <subsystem>.<metric>: {name:?}");
        }
    }
}
