//! Tab. 3: large-model full fine-tuning (ViT-L/ImageNet substitute:
//! txf_cls on a 16-class token task). Paper shape: batch-level methods'
//! savings now DOMINATE (BP of a big model ≫ scoring FP), ES best among
//! batch-level, ESWP best overall.

use crate::config::presets::{table3, Scale};
use crate::metrics::Recorder;
use crate::util::bench::table_header;

use super::{fmt_acc, fmt_saved, make_runtime, mean_acc, run_config, total_cost, trials};

pub fn run(scale: Scale) -> anyhow::Result<()> {
    let runs = table3(scale);
    let rec = Recorder::new("table3_vit_ft")?;
    let n_trials = trials(scale);
    table_header(
        "Table 3 — full fine-tune (ViT-L substitute txf_cls)",
        &["method", "acc% (Δ)", "time saved (flops-pred)"],
    );
    let mut rt = make_runtime(&runs[0])?;
    let mut base_acc = 0.0;
    let mut base_cost = None;
    for cfg in &runs {
        let rs = run_config(cfg, rt.as_mut(), n_trials)?;
        for r in &rs {
            rec.record_result(r)?;
        }
        let acc = mean_acc(&rs);
        let cost = total_cost(&rs);
        if cfg.sampler.name() == "baseline" {
            base_acc = acc;
            base_cost = Some(cost);
            println!("{:<12} | {acc:5.1}       | —", "baseline");
        } else {
            println!(
                "{:<12} | {} | {}",
                cfg.sampler.name(),
                fmt_acc(acc, base_acc),
                fmt_saved(base_cost.as_ref().unwrap(), &cost)
            );
        }
    }
    Ok(())
}
