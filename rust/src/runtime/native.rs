//! NativeRuntime: a pure-rust one-hidden-layer MLP classifier with
//! blocked, multi-threaded forward/backward kernels and SGD-momentum.
//!
//! Purpose (DESIGN.md §3): (a) lets the entire coordinator stack be
//! tested and benchmarked without AOT artifacts, (b) provides an
//! independent second implementation of weighted-batch training to
//! cross-check the XLA path, and (c) isolates L3 overhead in the perf
//! benches (selection cost vs BP cost with a known-cost backend).
//!
//! Model: x[in_dim] → relu(W1 x + b1)[hidden] → W2 h + b2 → softmax CE.
//! Per-sample losses, weighted gradient (Σ w_i ∇ℓ_i / Σ w_i) — the same
//! objective the L2 train_step lowers.
//!
//! Compute runs on the [`super::kernel`] layer (DESIGN.md §7):
//! parameters live in the packed layout (`W1` transposed so every inner
//! loop is unit-stride), a persistent [`KernelPool`] spreads forward
//! work by batch-row ranges and backward work by the fixed gradient
//! shards, and the softmax-CE pass is fused (one max/exp sweep yields
//! both per-sample loss and `dlogits`). Results are bit-identical
//! across kernel thread counts: forward rows are independent, and
//! gradients always reduce over the same [`GRAD_SHARDS`] row shards in
//! ascending order. `loss_fwd` takes a forward-only scoring fast path
//! that streams per-row activations through lane scratch instead of
//! retaining them. Every exact kernel call site routes through one
//! [`KernelDispatch`] (scalar-blocked or explicit simd, DESIGN.md §9),
//! and `loss_fwd_ranked` offers a reduced-precision ranking forward
//! over a lazily refreshed bf16 shadow of the packed weights —
//! scoring-only, never used for the BP batch or eval.
//!
//! The step hot path (`train_step_into`/`loss_fwd_into`) is
//! allocation-free in steady state: every buffer is runtime-owned
//! scratch that is reused across steps.

use std::sync::Arc;

use super::kernel::pack::{split_packed_mut, Layout, PackedBf16, PackedBuf};
use super::kernel::pool::{KernelBudget, KernelPool, SharedRows, SharedSlots};
use super::kernel::{
    default_dispatch, default_threads, simd, split_range, KernelDispatch, GRAD_SHARDS,
};
use super::{BatchX, ModelRuntime, StepOutput};
use crate::util::Pcg64;

/// Below this many inner-loop mults a step runs single-lane — pool
/// dispatch overhead would dominate. Lane count never changes numerics,
/// so the cutover is purely a performance knob.
const PAR_MIN_FLOPS: usize = 1 << 16;

/// The single lane-cutover policy shared by every kernel call site:
/// 1 lane below the dispatch-overhead threshold, all pool lanes above.
fn lanes_for(work: usize, pool: &KernelPool) -> usize {
    if work < PAR_MIN_FLOPS || pool.threads() == 1 {
        1
    } else {
        pool.threads()
    }
}

/// Per-batch kernel work estimate (inner-loop mults) for `n` rows.
fn batch_work(n: usize, l: Layout) -> usize {
    n * (l.d + l.c) * l.h
}

/// One fixed gradient shard: a packed-layout gradient accumulator plus
/// its `dh` backprop scratch.
struct GradShard {
    grads: Vec<f32>,
    dh: Vec<f32>,
}

/// Per-lane scratch for the forward-only scoring fast path.
struct RowScratch {
    hidden: Vec<f32>,
    logits: Vec<f32>,
}

pub struct NativeRuntime {
    layout: Layout,
    momentum: f32,
    weight_decay: f32,
    /// Parameters, optimizer state, and reduced gradients — all in the
    /// packed kernel layout (canonical only at the get/set boundary).
    params: PackedBuf,
    velocity: PackedBuf,
    grads: PackedBuf,
    /// bf16 shadow of `params` for `loss_fwd_ranked`. Allocated on
    /// first use, re-quantized lazily whenever `shadow_dirty` — runs
    /// that score exactly never pay for it.
    shadow_bf16: Option<PackedBf16>,
    shadow_dirty: bool,
    /// Supported batch sizes are unconstrained for the native path, but
    /// we report the configured ones so trainer validation still runs.
    fwd_size: usize,
    eval_size: usize,
    /// Configured kernel lanes (0 = auto). Resolved lazily.
    threads_cfg: usize,
    /// Which exact kernel implementation every hot path runs on
    /// (DESIGN.md §9): one variant per runtime, never mixed.
    dispatch: KernelDispatch,
    /// Shared cap on spawned kernel lanes across runtimes (serve mode);
    /// `None` = unconstrained, the historical behavior.
    budget: Option<Arc<KernelBudget>>,
    pool: Option<KernelPool>,
    // Runtime-owned step scratch (reused, never reallocated in steady
    // state).
    h_buf: Vec<f32>,
    logits_buf: Vec<f32>,
    dlogits_buf: Vec<f32>,
    loss_buf: Vec<f32>,
    shard_grads: Vec<GradShard>,
    fwd_scratch: Vec<RowScratch>,
}

impl NativeRuntime {
    pub fn new(in_dim: usize, hidden: usize, classes: usize) -> Self {
        let layout = Layout::new(in_dim, hidden, classes);
        NativeRuntime {
            layout,
            momentum: 0.9,
            weight_decay: 0.0,
            params: PackedBuf::zeros(layout),
            velocity: PackedBuf::zeros(layout),
            grads: PackedBuf::zeros(layout),
            shadow_bf16: None,
            shadow_dirty: true,
            fwd_size: 0,
            eval_size: 0,
            threads_cfg: 0,
            dispatch: default_dispatch(),
            budget: None,
            pool: None,
            h_buf: Vec::new(),
            logits_buf: Vec::new(),
            dlogits_buf: Vec::new(),
            loss_buf: Vec::new(),
            shard_grads: Vec::new(),
            fwd_scratch: Vec::new(),
        }
    }

    /// Fix the kernel lane count (0 = auto: `EVOSAMPLE_KERNEL_THREADS`
    /// or `available_parallelism`). Clamped to [`GRAD_SHARDS`] — beyond
    /// that the fixed-shard reduction has no parallelism left to give.
    /// Thread count never changes results (DESIGN.md §7).
    pub fn with_kernel_threads(mut self, threads: usize) -> Self {
        self.threads_cfg = threads;
        self.pool = None;
        self
    }

    /// Pin the exact kernel implementation (default: [`default_dispatch`],
    /// i.e. simd unless `EVOSAMPLE_KERNEL_DISPATCH` says otherwise).
    /// Like the lane count, dispatch never changes bits across thread
    /// counts — but the two variants are only tolerance-equal to each
    /// other, so a run sticks with one.
    pub fn with_dispatch(mut self, dispatch: KernelDispatch) -> Self {
        self.dispatch = dispatch;
        self
    }

    /// The kernel implementation this runtime's hot paths run on.
    pub fn kernel_dispatch(&self) -> KernelDispatch {
        self.dispatch
    }

    /// Charge this runtime's spawned kernel lanes against a shared
    /// [`KernelBudget`] (serve mode). When the budget is tight the pool
    /// spawns fewer lanes — results are unchanged (DESIGN.md §7), only
    /// parallelism degrades.
    pub fn with_kernel_budget(mut self, budget: Arc<KernelBudget>) -> Self {
        self.budget = Some(budget);
        self.pool = None;
        self
    }

    /// The resolved kernel lane count this runtime will use.
    pub fn kernel_threads(&self) -> usize {
        if self.threads_cfg > 0 {
            self.threads_cfg.min(GRAD_SHARDS)
        } else {
            default_threads()
        }
    }

    /// Canonical-layout snapshot of the last step's reduced gradient
    /// (tests, diagnostics).
    pub fn grads_canonical(&self) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.layout.param_count()];
        self.grads.unpack_into(&mut flat);
        flat
    }

    /// Spawn the worker pool on first use (so constructing runtimes in
    /// tests/config code stays free).
    fn ensure_pool(&mut self) {
        if self.pool.is_none() {
            self.pool = Some(match &self.budget {
                Some(budget) => {
                    KernelPool::with_budget(self.kernel_threads(), Arc::clone(budget))
                }
                None => KernelPool::new(self.kernel_threads()),
            });
        }
    }

    fn expect_f32<'a>(x: BatchX<'a>) -> anyhow::Result<&'a [f32]> {
        match x {
            BatchX::F32(v) => Ok(v),
            BatchX::I32(_) => anyhow::bail!("NativeRuntime supports float features only"),
        }
    }

    /// Forward-only scoring (the sampler FP): streams each row's hidden
    /// and logits through lane scratch — no activation retention — and
    /// appends `n` CE losses to `out`.
    fn loss_fwd_core(
        &mut self,
        x: &[f32],
        y: &[i32],
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let l = self.layout;
        anyhow::ensure!(x.len() == n * l.d && y.len() == n, "batch shape mismatch");
        for &yi in y {
            anyhow::ensure!((yi as usize) < l.c, "label {yi} out of range");
        }
        self.ensure_pool();
        let pool = self.pool.as_ref().expect("kernel pool");
        let lanes = lanes_for(batch_work(n, l), pool);
        while self.fwd_scratch.len() < lanes {
            self.fwd_scratch
                .push(RowScratch { hidden: vec![0.0; l.h], logits: vec![0.0; l.c] });
        }
        let start = out.len();
        out.resize(start + n, 0.0);
        let dispatch = self.dispatch;
        if lanes == 1 {
            let rs = &mut self.fwd_scratch[0];
            let dst = &mut out[start..];
            for (i, di) in dst.iter_mut().enumerate() {
                scoring_row(
                    dispatch,
                    &self.params,
                    &x[i * l.d..(i + 1) * l.d],
                    y[i] as usize,
                    rs,
                    di,
                );
            }
        } else {
            let out_rows = SharedRows::new(&mut out[start..]);
            let scratch = SharedSlots::new(&mut self.fwd_scratch[..lanes]);
            let params = &self.params;
            pool.run(&|t| {
                let (r0, r1) = split_range(n, lanes, t);
                if r0 == r1 {
                    return;
                }
                // SAFETY: one lane per scratch slot / output range.
                let rs = unsafe { scratch.get_mut(t) };
                let dst = unsafe { out_rows.range(r0, r1) };
                for (k, di) in dst.iter_mut().enumerate() {
                    let i = r0 + k;
                    scoring_row(dispatch, params, &x[i * l.d..(i + 1) * l.d], y[i] as usize, rs, di);
                }
            });
        }
        Ok(())
    }

    /// Reduced-precision ranking forward (`loss_fwd_ranked`): the same
    /// row-streaming structure as `loss_fwd_core`, reading weights from
    /// the bf16 shadow pack. Deterministic (fixed per-row op sequence,
    /// row partitioning never changes bits) but NOT tolerance-coupled to
    /// the exact path — it exists to rank, not to measure.
    fn loss_fwd_ranked_core(
        &mut self,
        x: &[f32],
        y: &[i32],
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let l = self.layout;
        anyhow::ensure!(x.len() == n * l.d && y.len() == n, "batch shape mismatch");
        for &yi in y {
            anyhow::ensure!((yi as usize) < l.c, "label {yi} out of range");
        }
        if self.shadow_dirty || self.shadow_bf16.is_none() {
            let shadow = self.shadow_bf16.get_or_insert_with(|| PackedBf16::zeros(l));
            shadow.refresh_from(&self.params);
            self.shadow_dirty = false;
            if crate::obs::counters_on() {
                crate::obs::registry().counter("runtime.bf16_shadow_refresh").add(1);
            }
        }
        self.ensure_pool();
        let pool = self.pool.as_ref().expect("kernel pool");
        let lanes = lanes_for(batch_work(n, l), pool);
        while self.fwd_scratch.len() < lanes {
            self.fwd_scratch
                .push(RowScratch { hidden: vec![0.0; l.h], logits: vec![0.0; l.c] });
        }
        let start = out.len();
        out.resize(start + n, 0.0);
        let shadow = self.shadow_bf16.as_ref().expect("bf16 shadow");
        if lanes == 1 {
            let rs = &mut self.fwd_scratch[0];
            let dst = &mut out[start..];
            for (i, di) in dst.iter_mut().enumerate() {
                scoring_row_bf16(shadow, &x[i * l.d..(i + 1) * l.d], y[i] as usize, rs, di);
            }
        } else {
            let out_rows = SharedRows::new(&mut out[start..]);
            let scratch = SharedSlots::new(&mut self.fwd_scratch[..lanes]);
            pool.run(&|t| {
                let (r0, r1) = split_range(n, lanes, t);
                if r0 == r1 {
                    return;
                }
                // SAFETY: one lane per scratch slot / output range.
                let rs = unsafe { scratch.get_mut(t) };
                let dst = unsafe { out_rows.range(r0, r1) };
                for (k, di) in dst.iter_mut().enumerate() {
                    let i = r0 + k;
                    scoring_row_bf16(shadow, &x[i * l.d..(i + 1) * l.d], y[i] as usize, rs, di);
                }
            });
        }
        Ok(())
    }

    /// One weighted SGD-momentum step. Fills `self.loss_buf` with the
    /// per-sample losses and returns the weighted mean loss. The whole
    /// path reuses runtime-owned scratch — zero steady-state
    /// allocations — and is bit-identical across lane counts (fixed
    /// shard partition, ascending-order reduction, main-thread CE).
    fn train_step_core(
        &mut self,
        x: &[f32],
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
    ) -> anyhow::Result<f32> {
        let l = self.layout;
        anyhow::ensure!(n > 0, "empty batch");
        anyhow::ensure!(x.len() == n * l.d, "x shape");
        anyhow::ensure!(y.len() == n && weights.len() == n, "y/weights shape");
        self.ensure_pool();
        self.h_buf.resize(n * l.h, 0.0);
        self.logits_buf.resize(n * l.c, 0.0);
        self.dlogits_buf.resize(n * l.c, 0.0);
        self.loss_buf.clear();
        self.loss_buf.resize(n, 0.0);
        let pool = self.pool.as_ref().expect("kernel pool");
        let dispatch = self.dispatch;

        // ---- forward (row-parallel, retained activations) --------------
        forward_rows(pool, dispatch, &self.params, x, n, &mut self.h_buf, &mut self.logits_buf);

        // ---- fused softmax-CE: loss + scaled dlogits in one sweep ------
        // Main thread, fixed row order: part of the determinism contract.
        let wsum: f32 = weights.iter().sum::<f32>().max(1e-12);
        let mut sum_lw = 0.0f32;
        for i in 0..n {
            let yi = y[i] as usize;
            anyhow::ensure!(yi < l.c, "label {yi} out of range");
            let w = weights[i];
            let scale = w / wsum;
            let li = &self.logits_buf[i * l.c..(i + 1) * l.c];
            let dl = &mut self.dlogits_buf[i * l.c..(i + 1) * l.c];
            let loss = if scale == 0.0 {
                // Zero-scale rows contribute a loss but no gradient —
                // and may carry garbage features, so skip their grad
                // math entirely (matches the historical behavior). Zero
                // the reused dlogits row so stale values can never leak.
                dl.fill(0.0);
                dispatch.ce_loss_row(li, yi)
            } else {
                dispatch.ce_loss_grad_row(li, yi, scale, dl)
            };
            self.loss_buf[i] = loss;
            sum_lw += loss * w;
        }
        let mean_loss = sum_lw / wsum;

        // ---- backward into fixed gradient shards -----------------------
        // Shard boundaries depend only on n (never on the lane count);
        // each shard accumulates its rows in ascending order.
        let shards = GRAD_SHARDS.min(n);
        let pc = l.param_count();
        while self.shard_grads.len() < shards {
            self.shard_grads.push(GradShard { grads: vec![0.0; pc], dh: vec![0.0; l.h] });
        }
        let lanes = lanes_for(batch_work(n, l), pool);
        {
            let shard_slots = SharedSlots::new(&mut self.shard_grads[..shards]);
            let h_buf = &self.h_buf;
            let dlogits = &self.dlogits_buf;
            let params = &self.params;
            let task = |t: usize| {
                let mut s = t;
                while s < shards {
                    // SAFETY: shard s is owned by exactly one lane
                    // (s ≡ t mod lanes).
                    let sg = unsafe { shard_slots.get_mut(s) };
                    let GradShard { grads, dh } = sg;
                    grads.fill(0.0);
                    let (gw1t, gb1, gw2, gb2) = split_packed_mut(grads, l);
                    let (r0, r1) = split_range(n, shards, s);
                    for i in r0..r1 {
                        // Same predicate as the fused CE loop (scale can
                        // underflow to 0 for tiny positive weights —
                        // those rows have no dlogits and must be
                        // skipped, exactly like the scalar reference).
                        if weights[i] / wsum == 0.0 {
                            continue;
                        }
                        dispatch.backward_row(
                            &x[i * l.d..(i + 1) * l.d],
                            &h_buf[i * l.h..(i + 1) * l.h],
                            &dlogits[i * l.c..(i + 1) * l.c],
                            params.w2(),
                            l.d,
                            l.c,
                            gw1t,
                            gb1,
                            gw2,
                            gb2,
                            dh,
                        );
                    }
                    s += lanes;
                }
            };
            if lanes == 1 {
                task(0);
            } else {
                pool.run(&task);
            }
        }

        // ---- deterministic reduction: ascending shard order ------------
        {
            let gflat = self.grads.flat_mut();
            gflat.copy_from_slice(&self.shard_grads[0].grads);
            for sg in &self.shard_grads[1..shards] {
                for (a, &b) in gflat.iter_mut().zip(&sg.grads) {
                    *a += b;
                }
            }
        }

        // ---- SGD momentum + weight decay (elementwise in packed space,
        // a pure permutation of the canonical update) --------------------
        let momentum = self.momentum;
        let wd = self.weight_decay;
        for ((pi, vi), &gi) in self
            .params
            .flat_mut()
            .iter_mut()
            .zip(self.velocity.flat_mut().iter_mut())
            .zip(self.grads.flat().iter())
        {
            let g = gi + wd * *pi;
            *vi = momentum * *vi + g;
            *pi -= lr * *vi;
        }
        self.shadow_dirty = true;
        Ok(mean_loss)
    }
}

impl Clone for NativeRuntime {
    /// Deep copy of the training state (params, velocity, config). The
    /// worker pool is NOT shared — the clone spawns its own lazily — and
    /// scratch starts empty.
    fn clone(&self) -> NativeRuntime {
        NativeRuntime {
            layout: self.layout,
            momentum: self.momentum,
            weight_decay: self.weight_decay,
            params: self.params.clone(),
            velocity: self.velocity.clone(),
            grads: PackedBuf::zeros(self.layout),
            shadow_bf16: None,
            shadow_dirty: true,
            fwd_size: self.fwd_size,
            eval_size: self.eval_size,
            threads_cfg: self.threads_cfg,
            dispatch: self.dispatch,
            budget: self.budget.clone(),
            pool: None,
            h_buf: Vec::new(),
            logits_buf: Vec::new(),
            dlogits_buf: Vec::new(),
            loss_buf: Vec::new(),
            shard_grads: Vec::new(),
            fwd_scratch: Vec::new(),
        }
    }
}

/// Retained forward over all rows: fills `h_buf` (`n·h`) and
/// `logits_buf` (`n·c`), parallelized by disjoint row ranges.
fn forward_rows(
    pool: &KernelPool,
    dispatch: KernelDispatch,
    params: &PackedBuf,
    x: &[f32],
    n: usize,
    h_buf: &mut [f32],
    logits_buf: &mut [f32],
) {
    let l = params.layout();
    let lanes = lanes_for(batch_work(n, l), pool);
    if lanes == 1 {
        dispatch.hidden_fwd(x, params.w1t(), params.b1(), l.d, l.h, h_buf);
        dispatch.logits_fwd(h_buf, params.w2(), params.b2(), l.h, l.c, logits_buf);
        return;
    }
    let h_rows = SharedRows::new(h_buf);
    let lg_rows = SharedRows::new(logits_buf);
    pool.run(&|t| {
        let (r0, r1) = split_range(n, lanes, t);
        if r0 == r1 {
            return;
        }
        // SAFETY: lanes write disjoint row ranges.
        let hr = unsafe { h_rows.range(r0 * l.h, r1 * l.h) };
        let lg = unsafe { lg_rows.range(r0 * l.c, r1 * l.c) };
        dispatch.hidden_fwd(&x[r0 * l.d..r1 * l.d], params.w1t(), params.b1(), l.d, l.h, hr);
        dispatch.logits_fwd(hr, params.w2(), params.b2(), l.h, l.c, lg);
    });
}

/// Forward-only scoring for one row through lane scratch.
fn scoring_row(
    dispatch: KernelDispatch,
    params: &PackedBuf,
    xi: &[f32],
    yi: usize,
    rs: &mut RowScratch,
    out: &mut f32,
) {
    let l = params.layout();
    dispatch.hidden_fwd(xi, params.w1t(), params.b1(), l.d, l.h, &mut rs.hidden);
    dispatch.logits_fwd(&rs.hidden, params.w2(), params.b2(), l.h, l.c, &mut rs.logits);
    *out = dispatch.ce_loss_row(&rs.logits, yi);
}

/// bf16 scoring for one row: dequantize-on-load weights, f32
/// activations, exact CE on the resulting logits. Always uses the simd
/// kernels — the reduced-precision path has no scalar twin (dispatch
/// selects among the *exact* implementations only).
fn scoring_row_bf16(shadow: &PackedBf16, xi: &[f32], yi: usize, rs: &mut RowScratch, out: &mut f32) {
    let l = shadow.layout();
    simd::hidden_fwd_bf16(xi, shadow.w1t(), shadow.b1(), l.d, l.h, &mut rs.hidden);
    simd::logits_fwd_bf16(&rs.hidden, shadow.w2(), shadow.b2(), l.h, l.c, &mut rs.logits);
    *out = simd::ce_loss_row(&rs.logits, yi);
}

impl ModelRuntime for NativeRuntime {
    fn param_count(&self) -> usize {
        self.layout.param_count()
    }

    fn init(&mut self, seed: i32) -> anyhow::Result<()> {
        // Identical RNG consumption to the historical scalar init: the
        // canonical flat vector is generated first, then packed (a pure
        // permutation).
        let l = self.layout;
        let mut rng = Pcg64::new(seed as u64 ^ 0xab5e1);
        let (b1, w2, b2) = (l.b1_off(), l.w2_off(), l.b2_off());
        let std1 = (2.0 / l.d as f32).sqrt();
        let std2 = (2.0 / l.h as f32).sqrt();
        let mut flat = vec![0.0f32; l.param_count()];
        for (i, p) in flat.iter_mut().enumerate() {
            *p = if i < b1 {
                std1 * rng.normal()
            } else if i < w2 {
                0.0
            } else if i < b2 {
                std2 * rng.normal()
            } else {
                0.0
            };
        }
        self.params.pack_from(&flat);
        self.velocity.fill(0.0);
        self.shadow_dirty = true;
        Ok(())
    }

    fn loss_fwd_into(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let x = Self::expect_f32(x)?;
        self.loss_fwd_core(x, y, n, out)
    }

    fn loss_fwd_ranked(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let x = Self::expect_f32(x)?;
        self.loss_fwd_ranked_core(x, y, n, out)
    }

    fn train_step(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
    ) -> anyhow::Result<StepOutput> {
        let x = Self::expect_f32(x)?;
        let mean_loss = self.train_step_core(x, y, weights, lr, n)?;
        Ok(StepOutput { losses: self.loss_buf.clone(), mean_loss })
    }

    fn train_step_into(
        &mut self,
        x: BatchX<'_>,
        y: &[i32],
        weights: &[f32],
        lr: f32,
        n: usize,
        losses: &mut Vec<f32>,
    ) -> anyhow::Result<f32> {
        let x = Self::expect_f32(x)?;
        let mean_loss = self.train_step_core(x, y, weights, lr, n)?;
        losses.extend_from_slice(&self.loss_buf);
        Ok(mean_loss)
    }

    fn eval(&mut self, x: BatchX<'_>, y: &[i32], n: usize) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let xs = Self::expect_f32(x)?;
        let l = self.layout;
        anyhow::ensure!(xs.len() == n * l.d && y.len() == n, "batch shape mismatch");
        self.ensure_pool();
        self.h_buf.resize(n * l.h, 0.0);
        self.logits_buf.resize(n * l.c, 0.0);
        let pool = self.pool.as_ref().expect("kernel pool");
        forward_rows(pool, self.dispatch, &self.params, xs, n, &mut self.h_buf, &mut self.logits_buf);
        let mut losses = Vec::with_capacity(n);
        let mut correct = Vec::with_capacity(n);
        for i in 0..n {
            let yi = y[i] as usize;
            anyhow::ensure!(yi < l.c, "label {yi} out of range");
            let li = &self.logits_buf[i * l.c..(i + 1) * l.c];
            losses.push(self.dispatch.ce_loss_row(li, yi));
            let argmax = li
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            correct.push((argmax == yi) as u8 as f32);
        }
        Ok((losses, correct))
    }

    fn train_sizes(&self) -> Vec<usize> {
        Vec::new() // native path accepts any batch size
    }

    fn fwd_size(&self) -> usize {
        self.fwd_size
    }

    fn eval_size(&self) -> usize {
        self.eval_size
    }

    fn get_params(&mut self) -> anyhow::Result<Vec<f32>> {
        let mut flat = vec![0.0f32; self.layout.param_count()];
        self.params.unpack_into(&mut flat);
        Ok(flat)
    }

    fn read_params_into(&mut self, out: &mut [f32]) -> anyhow::Result<()> {
        anyhow::ensure!(out.len() == self.layout.param_count(), "param count mismatch");
        self.params.unpack_into(out);
        Ok(())
    }

    fn set_params(&mut self, params: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(params.len() == self.layout.param_count(), "param count mismatch");
        self.params.pack_from(params);
        self.shadow_dirty = true;
        Ok(())
    }

    fn get_opt_state(&mut self) -> anyhow::Result<Vec<f32>> {
        // SGD-momentum: the velocity buffer, in canonical layout (the
        // same pure permutation get_params uses).
        let mut flat = vec![0.0f32; self.layout.param_count()];
        self.velocity.unpack_into(&mut flat);
        Ok(flat)
    }

    fn set_opt_state(&mut self, state: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(state.len() == self.layout.param_count(), "opt state count mismatch");
        self.velocity.pack_from(state);
        Ok(())
    }

    fn flops_per_sample_fwd(&self) -> u64 {
        (2 * self.layout.d * self.layout.h + 2 * self.layout.h * self.layout.c) as u64
    }

    fn spawn_replica(&self) -> anyhow::Result<Box<dyn ModelRuntime + Send>> {
        // Pure host state: a replica is a deep copy (params, velocity)
        // sharing nothing with the parent. Replicas default to a single
        // kernel lane so W engine replicas don't oversubscribe the box
        // (W × lanes threads); lane count never changes numerics.
        let mut replica = self.clone();
        replica.threads_cfg = 1;
        Ok(Box::new(replica))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(n: usize, d: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        // Linearly separable blobs: class c centered at unit vector e_c.
        let mut rng = Pcg64::new(seed);
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0i32; n];
        for i in 0..n {
            let c = i % classes;
            y[i] = c as i32;
            for j in 0..d {
                x[i * d + j] = if j == c { 2.0 } else { 0.0 } + 0.3 * rng.normal();
            }
        }
        (x, y)
    }

    #[test]
    fn overfits_separable_blobs() {
        let mut rt = NativeRuntime::new(8, 16, 4);
        rt.init(0).unwrap();
        let (x, y) = toy_batch(32, 8, 4, 1);
        let w = vec![1.0; 32];
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.1, 32).unwrap();
            first.get_or_insert(out.mean_loss);
            last = out.mean_loss;
        }
        assert!(last < 0.2 * first.unwrap(), "{} -> {last}", first.unwrap());
        let (_, correct) = rt.eval(BatchX::F32(&x), &y, 32).unwrap();
        let acc: f32 = correct.iter().sum::<f32>() / 32.0;
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn losses_match_loss_fwd() {
        let mut rt = NativeRuntime::new(8, 16, 4);
        rt.init(3).unwrap();
        let (x, y) = toy_batch(16, 8, 4, 2);
        let fwd = rt.loss_fwd(BatchX::F32(&x), &y, 16).unwrap();
        let w = vec![1.0; 16];
        // train_step computes losses at the SAME params before updating.
        let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.01, 16).unwrap();
        for (a, b) in fwd.iter().zip(&out.losses) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_weight_samples_do_not_affect_update() {
        let (x, y) = toy_batch(8, 8, 4, 3);
        let mut rt1 = NativeRuntime::new(8, 8, 4);
        rt1.init(7).unwrap();
        let mut rt2 = NativeRuntime::new(8, 8, 4);
        rt2.init(7).unwrap();
        let mut w = vec![1.0f32; 8];
        w[4..].iter_mut().for_each(|v| *v = 0.0);
        // rt2 sees garbage in the zero-weighted rows.
        let mut x2 = x.clone();
        for v in &mut x2[4 * 8..] {
            *v = 99.0;
        }
        rt1.train_step(BatchX::F32(&x), &y, &w, 0.1, 8).unwrap();
        rt2.train_step(BatchX::F32(&x2), &y, &w, 0.1, 8).unwrap();
        let p1 = rt1.get_params().unwrap();
        let p2 = rt2.get_params().unwrap();
        for (a, b) in p1.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gradcheck_against_finite_differences() {
        // Weighted-CE gradient vs central differences on a tiny model.
        let mut rt = NativeRuntime::new(3, 4, 3);
        rt.init(11).unwrap();
        let (x, y) = toy_batch(4, 3, 3, 5);
        let w = vec![0.7f32, 1.3, 0.0, 2.0];

        let loss_at = |rt: &mut NativeRuntime, params: &[f32]| -> f32 {
            rt.set_params(params).unwrap();
            let l = rt.loss_fwd(BatchX::F32(&x), &y, 4).unwrap();
            let ws: f32 = w.iter().sum();
            l.iter().zip(&w).map(|(&l, &wi)| l * wi).sum::<f32>() / ws
        };

        let p0 = rt.get_params().unwrap();
        // Analytic grads: run one step with lr = 0 so the params don't
        // move, then read the reduced gradient in canonical layout.
        rt.set_params(&p0).unwrap();
        rt.train_step(BatchX::F32(&x), &y, &w, 0.0, 4).unwrap();
        let analytic = rt.grads_canonical();

        let eps = 1e-3f32;
        let mut checked = 0;
        for idx in (0..p0.len()).step_by(p0.len() / 13 + 1) {
            let mut pp = p0.clone();
            pp[idx] += eps;
            let lp = loss_at(&mut rt, &pp);
            pp[idx] -= 2.0 * eps;
            let lm = loss_at(&mut rt, &pp);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - analytic[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {idx}: fd={fd} analytic={}",
                analytic[idx]
            );
            checked += 1;
        }
        assert!(checked >= 10);
    }

    #[test]
    fn init_resets_state_deterministically() {
        let mut rt = NativeRuntime::new(4, 4, 2);
        rt.init(5).unwrap();
        let a = rt.get_params().unwrap();
        let (x, y) = toy_batch(4, 4, 2, 6);
        rt.train_step(BatchX::F32(&x), &y, &[1.0; 4], 0.1, 4).unwrap();
        rt.init(5).unwrap();
        assert_eq!(rt.get_params().unwrap(), a);
    }

    #[test]
    fn rejects_token_batches() {
        let mut rt = NativeRuntime::new(4, 4, 2);
        rt.init(0).unwrap();
        assert!(rt.loss_fwd(BatchX::I32(&[1, 2]), &[0], 1).is_err());
    }

    #[test]
    fn set_get_params_roundtrips_through_packing() {
        let mut rt = NativeRuntime::new(5, 3, 2);
        rt.init(9).unwrap();
        let p = rt.get_params().unwrap();
        rt.set_params(&p).unwrap();
        assert_eq!(rt.get_params().unwrap(), p, "pack/unpack must be lossless");
        let mut buf = vec![0.0f32; p.len()];
        rt.read_params_into(&mut buf).unwrap();
        assert_eq!(buf, p);
        assert!(rt.read_params_into(&mut [0.0f32; 3]).is_err(), "length mismatch must error");
    }

    #[test]
    fn opt_state_restore_resumes_momentum_exactly() {
        // Train 3 steps, snapshot (params + velocity), train 2 more; a
        // fresh runtime restored from the snapshot must reproduce the
        // last 2 steps bit-for-bit — params alone would not (momentum).
        let (x, y) = toy_batch(16, 8, 4, 51);
        let w = vec![1.0f32; 16];
        let mut rt = NativeRuntime::new(8, 8, 4);
        rt.init(5).unwrap();
        for _ in 0..3 {
            rt.train_step(BatchX::F32(&x), &y, &w, 0.1, 16).unwrap();
        }
        let p = rt.get_params().unwrap();
        let v = rt.get_opt_state().unwrap();
        assert!(v.iter().any(|&vi| vi != 0.0), "momentum must be live mid-run");
        for _ in 0..2 {
            rt.train_step(BatchX::F32(&x), &y, &w, 0.1, 16).unwrap();
        }
        let expected = rt.get_params().unwrap();

        let mut resumed = NativeRuntime::new(8, 8, 4);
        resumed.init(5).unwrap();
        resumed.set_params(&p).unwrap();
        resumed.set_opt_state(&v).unwrap();
        for _ in 0..2 {
            resumed.train_step(BatchX::F32(&x), &y, &w, 0.1, 16).unwrap();
        }
        assert_eq!(resumed.get_params().unwrap(), expected);
    }

    #[test]
    fn thread_count_does_not_change_the_bits() {
        // Big enough (n·(d+c)·h ≥ PAR_MIN_FLOPS) that the multi-lane
        // runtime actually dispatches to the pool.
        let (d, h, c, n) = (128usize, 32usize, 4usize, 16usize);
        let (x, y) = toy_batch(n, d, c, 21);
        let mut w = vec![1.0f32; n];
        w[3] = 0.0;
        w[7] = 2.5;
        let run = |threads: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let mut rt = NativeRuntime::new(d, h, c).with_kernel_threads(threads);
            rt.init(13).unwrap();
            let mut all = Vec::new();
            for _ in 0..3 {
                let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.05, n).unwrap();
                all.extend_from_slice(&out.losses);
            }
            let fwd = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
            (all, fwd, rt.get_params().unwrap())
        };
        let (l1, f1, p1) = run(1);
        for threads in [2usize, 4, 8] {
            let (lt, ft, pt) = run(threads);
            assert_eq!(l1, lt, "losses diverged at {threads} threads");
            assert_eq!(f1, ft, "scoring diverged at {threads} threads");
            assert_eq!(p1, pt, "params diverged at {threads} threads");
        }
    }

    #[test]
    fn shared_budget_never_changes_the_bits() {
        // Two runtimes on one tight budget: the second gets fewer (or
        // zero) worker lanes, yet both must match the unbudgeted run
        // exactly (DESIGN.md §7: lane count never changes numerics).
        let (d, h, c, n) = (128usize, 32usize, 4usize, 16usize);
        let (x, y) = toy_batch(n, d, c, 37);
        let w = vec![1.0f32; n];
        let step = |rt: &mut NativeRuntime| -> (Vec<f32>, Vec<f32>) {
            rt.init(41).unwrap();
            let out = rt.train_step(BatchX::F32(&x), &y, &w, 0.05, n).unwrap();
            (out.losses, rt.get_params().unwrap())
        };
        let mut free = NativeRuntime::new(d, h, c).with_kernel_threads(4);
        let reference = step(&mut free);
        let budget = KernelBudget::new(3);
        let mut a = NativeRuntime::new(d, h, c)
            .with_kernel_threads(4)
            .with_kernel_budget(Arc::clone(&budget));
        let ra = step(&mut a);
        assert_eq!(budget.in_use(), 3, "first runtime takes the whole budget");
        let mut b = NativeRuntime::new(d, h, c)
            .with_kernel_threads(4)
            .with_kernel_budget(Arc::clone(&budget));
        let rb = step(&mut b);
        assert_eq!(ra, reference);
        assert_eq!(rb, reference);
        drop(a);
        drop(b);
        assert_eq!(budget.in_use(), 0, "dropped runtimes return their lanes");
    }

    #[test]
    fn scalar_and_simd_dispatch_agree_within_tolerance() {
        let (d, h, c, n) = (67usize, 13usize, 5usize, 9usize);
        let (x, y) = toy_batch(n, d, c, 31);
        let w = vec![1.0f32; n];
        let run = |dispatch: KernelDispatch| -> (Vec<f32>, Vec<f32>) {
            let mut rt = NativeRuntime::new(d, h, c).with_dispatch(dispatch);
            rt.init(17).unwrap();
            let fwd = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
            for _ in 0..3 {
                rt.train_step(BatchX::F32(&x), &y, &w, 0.05, n).unwrap();
            }
            (fwd, rt.get_params().unwrap())
        };
        let (f_sc, p_sc) = run(KernelDispatch::Scalar);
        let (f_sd, p_sd) = run(KernelDispatch::Simd);
        for (a, b) in f_sc.iter().zip(&f_sd) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + a.abs()), "fwd: scalar={a} simd={b}");
        }
        for (a, b) in p_sc.iter().zip(&p_sd) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs()), "params: scalar={a} simd={b}");
        }
    }

    #[test]
    fn ranked_losses_track_exact_and_follow_param_updates() {
        let (d, h, c, n) = (48usize, 12usize, 4usize, 16usize);
        let mut rt = NativeRuntime::new(d, h, c);
        rt.init(23).unwrap();
        let (x, y) = toy_batch(n, d, c, 8);

        let exact = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
        let mut ranked = Vec::new();
        rt.loss_fwd_ranked(BatchX::F32(&x), &y, n, &mut ranked).unwrap();
        assert_eq!(ranked.len(), n);
        for (i, (&a, &b)) in ranked.iter().zip(&exact).enumerate() {
            assert!((a - b).abs() <= 5e-2 * (1.0 + b.abs()), "[{i}] bf16={a} exact={b}");
        }

        // Same params → identical bf16 bits (the path is deterministic).
        let mut again = Vec::new();
        rt.loss_fwd_ranked(BatchX::F32(&x), &y, n, &mut again).unwrap();
        assert_eq!(ranked, again);

        // A train step must invalidate the shadow: the next ranked pass
        // sees the NEW parameters, tracking the new exact losses.
        let ones = vec![1.0f32; n];
        rt.train_step(BatchX::F32(&x), &y, &ones, 0.2, n).unwrap();
        let exact2 = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
        let mut ranked2 = Vec::new();
        rt.loss_fwd_ranked(BatchX::F32(&x), &y, n, &mut ranked2).unwrap();
        assert_ne!(ranked, ranked2, "shadow must refresh after a step");
        for (&a, &b) in ranked2.iter().zip(&exact2) {
            assert!((a - b).abs() <= 5e-2 * (1.0 + b.abs()), "post-step bf16={a} exact={b}");
        }

        // set_params invalidates it too.
        let p = rt.get_params().unwrap();
        let perturbed: Vec<f32> = p.iter().map(|v| v * 1.5 + 0.01).collect();
        rt.set_params(&perturbed).unwrap();
        let exact3 = rt.loss_fwd(BatchX::F32(&x), &y, n).unwrap();
        let mut ranked3 = Vec::new();
        rt.loss_fwd_ranked(BatchX::F32(&x), &y, n, &mut ranked3).unwrap();
        for (&a, &b) in ranked3.iter().zip(&exact3) {
            assert!((a - b).abs() <= 5e-2 * (1.0 + b.abs()), "post-set bf16={a} exact={b}");
        }
    }

    #[test]
    fn ranked_path_is_bit_stable_across_thread_counts() {
        let (d, h, c, n) = (128usize, 32usize, 4usize, 16usize);
        let (x, y) = toy_batch(n, d, c, 19);
        let run = |threads: usize| -> Vec<f32> {
            let mut rt = NativeRuntime::new(d, h, c).with_kernel_threads(threads);
            rt.init(29).unwrap();
            let mut out = Vec::new();
            rt.loss_fwd_ranked(BatchX::F32(&x), &y, n, &mut out).unwrap();
            out
        };
        let r1 = run(1);
        for t in [2usize, 4, 8] {
            assert_eq!(r1, run(t), "ranked losses diverged at {t} threads");
        }
    }

    #[test]
    fn clone_and_replica_preserve_dispatch() {
        let rt = NativeRuntime::new(8, 8, 4).with_dispatch(KernelDispatch::Scalar);
        assert_eq!(rt.clone().kernel_dispatch(), KernelDispatch::Scalar);
        let rt = NativeRuntime::new(8, 8, 4).with_dispatch(KernelDispatch::Simd);
        assert_eq!(rt.clone().kernel_dispatch(), KernelDispatch::Simd);
    }

    #[test]
    fn replica_starts_equal_then_diverges_independently() {
        let mut rt = NativeRuntime::new(8, 8, 4);
        rt.init(2).unwrap();
        let mut replica = rt.spawn_replica().unwrap();
        assert_eq!(rt.get_params().unwrap(), replica.get_params().unwrap());

        let (x, y) = toy_batch(8, 8, 4, 4);
        replica.train_step(BatchX::F32(&x), &y, &[1.0; 8], 0.1, 8).unwrap();
        assert_ne!(
            rt.get_params().unwrap(),
            replica.get_params().unwrap(),
            "replica steps must not touch the parent"
        );

        // Param-averaging round brings them back together.
        let p = replica.get_params().unwrap();
        rt.set_params(&p).unwrap();
        assert_eq!(rt.get_params().unwrap(), p);
    }
}
