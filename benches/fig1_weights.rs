//! Regenerates paper Fig. 1 / Fig. 8 (weight-signal illustration).
fn main() {
    evosample::experiments::fig1::run(400).expect("fig1");
}
