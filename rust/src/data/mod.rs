//! Dataset substrates: in-memory tensor datasets + procedural generators.
//!
//! The paper evaluates on CIFAR-10/100, ImageNet-1K, GLUE and NuminaMath.
//! None are available offline, so each workload has a procedural synthetic
//! substitute that preserves the *statistical structure data selection
//! exploits* (DESIGN.md §3): a spread of per-sample difficulty, a tail of
//! hard/slow-to-learn samples, label noise, and class structure. Every
//! generator also records the ground-truth per-sample difficulty so tests
//! and Fig. 9/10-style analyses can check that samplers actually find the
//! hard samples.

pub mod corpus;
pub mod loader;
pub mod nlu;
pub mod synth_cifar;

use crate::config::DatasetConfig;
use crate::util::Pcg64;

/// Input modality: flat float features (images) or token sequences (text).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Modality {
    Float { dim: usize },
    Tokens { seq: usize },
}

impl Modality {
    pub fn x_len(&self) -> usize {
        match *self {
            Modality::Float { dim } => dim,
            Modality::Tokens { seq } => seq,
        }
    }
}

/// An in-memory dataset with per-sample metadata.
///
/// Exactly one of `x_f32`/`x_i32` is populated depending on `modality`.
/// Labels are always i32: one per sample for classification (`y_dim == 1`)
/// or one per token for LM targets (`y_dim == seq`).
#[derive(Clone, Debug)]
pub struct TensorDataset {
    pub modality: Modality,
    pub n: usize,
    pub classes: usize, // 0 for unlabeled (MAE) / LM
    pub x_f32: Vec<f32>,
    pub x_i32: Vec<i32>,
    pub y: Vec<i32>,
    pub y_dim: usize,
    /// Ground-truth difficulty in [0, 1] (generator-side; analysis only).
    pub difficulty: Vec<f32>,
    /// True class before label noise (analysis only; == y when no noise).
    pub clean_class: Vec<i32>,
}

impl TensorDataset {
    pub fn x_len(&self) -> usize {
        self.modality.x_len()
    }

    pub fn class_of(&self, i: usize) -> i32 {
        debug_assert!(self.y_dim == 1);
        self.y[i]
    }

    /// Gather float features for `indices` into a contiguous batch buffer.
    pub fn gather_x_f32(&self, indices: &[u32], out: &mut Vec<f32>) {
        let d = self.x_len();
        out.clear();
        out.reserve(indices.len() * d);
        for &i in indices {
            let i = i as usize;
            out.extend_from_slice(&self.x_f32[i * d..(i + 1) * d]);
        }
    }

    /// Gather token features for `indices`.
    pub fn gather_x_i32(&self, indices: &[u32], out: &mut Vec<i32>) {
        let d = self.x_len();
        out.clear();
        out.reserve(indices.len() * d);
        for &i in indices {
            let i = i as usize;
            out.extend_from_slice(&self.x_i32[i * d..(i + 1) * d]);
        }
    }

    /// Gather labels for `indices`.
    pub fn gather_y(&self, indices: &[u32], out: &mut Vec<i32>) {
        out.clear();
        out.reserve(indices.len() * self.y_dim);
        for &i in indices {
            let i = i as usize;
            out.extend_from_slice(&self.y[i * self.y_dim..(i + 1) * self.y_dim]);
        }
    }

    /// Structural invariants; generators assert this before returning.
    pub fn validate(&self) -> Result<(), String> {
        let d = self.x_len();
        match self.modality {
            Modality::Float { .. } => {
                if self.x_f32.len() != self.n * d {
                    return Err(format!("x_f32 len {} != n*d {}", self.x_f32.len(), self.n * d));
                }
                if !self.x_i32.is_empty() {
                    return Err("x_i32 must be empty for Float modality".into());
                }
            }
            Modality::Tokens { .. } => {
                if self.x_i32.len() != self.n * d {
                    return Err(format!("x_i32 len {} != n*seq {}", self.x_i32.len(), self.n * d));
                }
                if !self.x_f32.is_empty() {
                    return Err("x_f32 must be empty for Tokens modality".into());
                }
            }
        }
        if self.y.len() != self.n * self.y_dim {
            return Err(format!("y len {} != n*y_dim {}", self.y.len(), self.n * self.y_dim));
        }
        if self.difficulty.len() != self.n || self.clean_class.len() != self.n {
            return Err("metadata length mismatch".into());
        }
        if self.classes > 0 && self.y_dim == 1 {
            if let Some(&bad) = self.y.iter().find(|&&c| c < 0 || c as usize >= self.classes) {
                return Err(format!("label {bad} out of [0,{})", self.classes));
            }
        }
        Ok(())
    }
}

/// A train/test pair produced by every generator.
#[derive(Clone, Debug)]
pub struct SplitDataset {
    pub train: TensorDataset,
    pub test: TensorDataset,
}

/// Build the dataset a `RunConfig` asks for. `test_n` is the held-out size.
pub fn build(cfg: &DatasetConfig, test_n: usize, seed: u64) -> SplitDataset {
    let mut rng = Pcg64::with_stream(seed, 0xda7a);
    match cfg {
        DatasetConfig::SynthCifar { n, classes, label_noise, hard_frac } => {
            synth_cifar::generate(*n, test_n, *classes, *label_noise, *hard_frac, &mut rng)
        }
        DatasetConfig::LmCorpus { n, vocab, seq } => {
            corpus::generate(*n, test_n, *vocab, *seq, &mut rng)
        }
        DatasetConfig::Nlu { task, n, vocab, seq, classes } => {
            nlu::generate(task, *n, test_n, *vocab, *seq, *classes, &mut rng)
        }
        DatasetConfig::MaeImages { n, dim } => synth_cifar::generate_unlabeled(*n, test_n, *dim, &mut rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TensorDataset {
        TensorDataset {
            modality: Modality::Float { dim: 2 },
            n: 3,
            classes: 2,
            x_f32: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            x_i32: vec![],
            y: vec![0, 1, 0],
            y_dim: 1,
            difficulty: vec![0.1, 0.5, 0.9],
            clean_class: vec![0, 1, 0],
        }
    }

    #[test]
    fn gather_picks_rows() {
        let ds = tiny();
        let mut x = Vec::new();
        ds.gather_x_f32(&[2, 0], &mut x);
        assert_eq!(x, vec![4.0, 5.0, 0.0, 1.0]);
        let mut y = Vec::new();
        ds.gather_y(&[1, 1], &mut y);
        assert_eq!(y, vec![1, 1]);
    }

    #[test]
    fn validate_catches_mismatch() {
        let mut ds = tiny();
        ds.validate().unwrap();
        ds.y[1] = 5; // out of class range
        assert!(ds.validate().is_err());
        let mut ds = tiny();
        ds.x_f32.pop();
        assert!(ds.validate().is_err());
    }

    #[test]
    fn build_dispatches_all_kinds() {
        for cfg in [
            DatasetConfig::SynthCifar { n: 64, classes: 4, label_noise: 0.1, hard_frac: 0.2 },
            DatasetConfig::LmCorpus { n: 32, vocab: 64, seq: 16 },
            DatasetConfig::Nlu { task: "sst2".into(), n: 32, vocab: 64, seq: 12, classes: 2 },
            DatasetConfig::MaeImages { n: 32, dim: 48 },
        ] {
            let split = build(&cfg, 16, 7);
            split.train.validate().unwrap();
            split.test.validate().unwrap();
            assert_eq!(split.train.n, cfg.n());
            assert_eq!(split.test.n, 16);
        }
    }

    #[test]
    fn build_is_deterministic_in_seed() {
        let cfg = DatasetConfig::SynthCifar { n: 32, classes: 4, label_noise: 0.1, hard_frac: 0.2 };
        let a = build(&cfg, 8, 3);
        let b = build(&cfg, 8, 3);
        let c = build(&cfg, 8, 4);
        assert_eq!(a.train.x_f32, b.train.x_f32);
        assert_eq!(a.train.y, b.train.y);
        assert_ne!(a.train.x_f32, c.train.x_f32);
    }
}
