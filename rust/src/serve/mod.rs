//! The multi-tenant selection service: many concurrent training jobs as
//! queued [`api::Session`](crate::api::Session) runs behind one
//! line-oriented JSONL-over-TCP protocol (localhost only).
//!
//! Pieces (DESIGN.md §10):
//!
//! * [`protocol`] — the wire format: one JSON object per line, commands
//!   `submit` / `status` / `events` / `cancel` / `shutdown`.
//! * [`job`] — per-job shared state: lifecycle, accounting (queue
//!   latency, wall time, `fp_passes`/`bp_samples`), the capped event
//!   backlog, and live subscriber fan-out.
//! * [`queue`] — the job table + pending queue with admission control:
//!   submissions past `serve.max_queue` are shed with an explicit
//!   `rejected{reason: "queue_full"}` instead of unbounded buffering.
//! * [`scheduler`] — `serve.max_concurrent` worker threads draining the
//!   queue. All concurrent jobs share one
//!   [`KernelBudget`](crate::runtime::kernel::pool::KernelBudget), so
//!   the aggregate spawned kernel lanes stay capped no matter how many
//!   jobs run; budget pressure degrades lane counts, never numerics
//!   (DESIGN.md §7), so served jobs are bit-identical to standalone
//!   runs. Running jobs checkpoint at epoch boundaries through the
//!   engine's [`EpochHook`](crate::coordinator::engine::EpochHook).
//! * [`server`] — the TCP front door + startup rescan: jobs found in a
//!   non-terminal state in `serve.state_dir` are re-enqueued and resume
//!   from their last checkpoint.

pub mod job;
pub mod protocol;
pub mod queue;
pub mod scheduler;
pub mod server;

pub use server::{Server, ServerHandle};
