//! Regenerates paper Fig. 4 / Tab. 9 (low-resource SFT w/ grad accum).
fn main() {
    evosample::experiments::fig4::run(evosample::config::presets::Scale::from_env())
        .expect("fig4");
}
