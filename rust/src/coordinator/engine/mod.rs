//! The pipelined execution engine behind the training coordinator.
//!
//! [`Engine`] runs the paper's Alg. 1 loop by driving a [`StepPipeline`]
//! (explicit data-gather → scoring-FP → select → BP → observe stages) in
//! one of three modes:
//!
//! * **Single worker** (`workers == 1`): the pre-engine trainer loop,
//!   bit-for-bit — same RNG schedule, same arithmetic, same results —
//!   with meta-batch index assembly moved onto the double-buffered
//!   [`Prefetcher`] so it overlaps compute.
//! * **Sequential simulation** (`workers > 1`, `threaded_workers` off):
//!   W simulated workers share the runtime and sampler, take turns
//!   stepping round-robin over disjoint shards, and defer loss
//!   observations to an epoch-end sync — the historical Table 4 mode.
//! * **Threaded replicas** (`workers > 1`, `threaded_workers` on): W real
//!   `std::thread` workers, each owning a runtime replica
//!   ([`ModelRuntime::spawn_replica`]) and a sampler replica. Parameters
//!   average at sync rounds via `get_params`/`set_params`; sampler tables
//!   synchronize by all-gathering shard observation logs — the paper's
//!   §D.5 "additional round of synchronization". See DESIGN.md §2.

pub mod pipeline;
mod threaded;

pub use pipeline::{ObservationRoute, Stage, StageObserver, StepCtx, StepPipeline, StepStats};

use crate::api::events::{emit_into, Event, EventBus};
use crate::config::RunConfig;
use crate::data::loader::{EpochLoader, Prefetcher};
use crate::data::SplitDataset;
use crate::runtime::ModelRuntime;
use crate::sampler::Sampler;
use crate::util::json::Json;
use crate::util::timer::{phase, PhaseTimers};
use crate::util::Pcg64;

use super::accounting::CostSummary;
use super::trainer::{evaluate, EvalStats, TrainResult};

/// Epoch-boundary view of a run's full mutable state, handed to an
/// [`EpochHook`] after every completed epoch (post `EpochEnd` emission).
/// Everything a checkpoint needs to continue the run *exactly* is here:
/// parameters + optimizer state, the main RNG position, the sampler's
/// tables, step counters, the scoring-cadence ticks, and the curves.
pub struct RunSnapshot<'a> {
    /// The epoch that just completed (0-based).
    pub epoch: usize,
    pub step_idx: usize,
    /// Canonical flat parameters after this epoch.
    pub params: &'a [f32],
    /// Optimizer state ([`ModelRuntime::get_opt_state`]; may be empty).
    pub opt_state: &'a [f32],
    /// Main-RNG `(state, inc)` — captured here it is exactly the state
    /// the next epoch's `on_epoch_start` will consume.
    pub rng_state: (u128, u128),
    pub sampler: &'a dyn Sampler,
    pub stats: &'a StepStats,
    pub score_ticks: &'a [u64],
    pub loss_curve: &'a [f64],
    pub eval_curve: &'a [(usize, f64, f64)],
    pub bp_at_eval: &'a [u64],
    pub timers: &'a PhaseTimers,
}

/// Per-epoch callback on the sequential engine paths (single-worker and
/// the data-parallel simulation). Returning `Err` aborts the run — the
/// serve scheduler uses that for cooperative cancellation; the error
/// propagates out of [`Engine::run`].
pub trait EpochHook: Send {
    fn on_epoch_end(&mut self, snap: &RunSnapshot<'_>) -> anyhow::Result<()>;
}

impl<F> EpochHook for F
where
    F: FnMut(&RunSnapshot<'_>) -> anyhow::Result<()> + Send,
{
    fn on_epoch_end(&mut self, snap: &RunSnapshot<'_>) -> anyhow::Result<()> {
        self(snap)
    }
}

/// Mid-run state captured from a [`RunSnapshot`] (plus the sampler's
/// [`Sampler::state_json`]), sufficient to continue a sequential run
/// bit-for-bit from the next epoch. Threaded mode does not support
/// resume (replica-local RNG/pipeline state is not captured).
#[derive(Clone, Debug)]
pub struct EngineResume {
    /// First epoch the resumed run executes (`snapshot.epoch + 1`).
    pub next_epoch: usize,
    pub step_idx: usize,
    pub params: Vec<f32>,
    pub opt_state: Vec<f32>,
    pub rng_state: (u128, u128),
    /// `None` = the sampler does not support state capture; the caller
    /// must not have produced such a resume point (build-time check).
    pub sampler_state: Option<Json>,
    pub stats: StepStats,
    pub score_ticks: Vec<u64>,
    pub loss_curve: Vec<f64>,
    pub eval_curve: Vec<(usize, f64, f64)>,
    pub bp_at_eval: Vec<u64>,
    /// Phase-ledger seconds `(label, secs)` accumulated before the
    /// checkpoint, re-seeded into the resumed run's timers.
    pub timer_secs: Vec<(String, f64)>,
}

impl EngineResume {
    /// Capture a resume point from an epoch-boundary snapshot; the
    /// continued run starts at `snap.epoch + 1`.
    pub fn from_snapshot(snap: &RunSnapshot<'_>) -> EngineResume {
        EngineResume {
            next_epoch: snap.epoch + 1,
            step_idx: snap.step_idx,
            params: snap.params.to_vec(),
            opt_state: snap.opt_state.to_vec(),
            rng_state: snap.rng_state,
            sampler_state: snap.sampler.state_json(),
            stats: snap.stats.clone(),
            score_ticks: snap.score_ticks.to_vec(),
            loss_curve: snap.loss_curve.to_vec(),
            eval_curve: snap.eval_curve.to_vec(),
            bp_at_eval: snap.bp_at_eval.to_vec(),
            timer_secs: snap
                .timers
                .phases()
                .map(|(label, d)| (label.to_string(), d.as_secs_f64()))
                .collect(),
        }
    }
}

/// One training run: configuration + runtime + data + sampler.
pub struct Engine<'a> {
    cfg: &'a RunConfig,
    rt: &'a mut dyn ModelRuntime,
    data: &'a SplitDataset,
    sampler: Box<dyn Sampler>,
    observer: Option<Box<dyn StageObserver>>,
    events: Option<&'a mut EventBus>,
    hook: Option<Box<dyn EpochHook>>,
    resume: Option<EngineResume>,
}

impl<'a> Engine<'a> {
    pub fn new(
        cfg: &'a RunConfig,
        rt: &'a mut dyn ModelRuntime,
        data: &'a SplitDataset,
        sampler: Box<dyn Sampler>,
    ) -> Engine<'a> {
        Engine { cfg, rt, data, sampler, observer: None, events: None, hook: None, resume: None }
    }

    /// Install a per-stage accounting hook (single-worker and simulation
    /// modes; threaded workers run without one — their stage wall-clock
    /// still lands in the merged phase ledger).
    pub fn with_observer(mut self, observer: Box<dyn StageObserver>) -> Engine<'a> {
        self.observer = Some(observer);
        self
    }

    /// Attach the typed event stream: every sink on `bus` observes this
    /// run per the DESIGN.md §6 ordering contract. Purely additive — the
    /// RNG schedule and arithmetic are untouched.
    pub fn with_event_bus(mut self, bus: &'a mut EventBus) -> Engine<'a> {
        self.events = Some(bus);
        self
    }

    /// Install an epoch-boundary hook (sequential modes only; the
    /// threaded path has no single serializable state to snapshot).
    pub fn with_epoch_hook(mut self, hook: Box<dyn EpochHook>) -> Engine<'a> {
        self.hook = Some(hook);
        self
    }

    /// Continue a previous run from an epoch-boundary [`EngineResume`]
    /// instead of starting fresh. Sequential modes only.
    pub fn resume_from(mut self, resume: EngineResume) -> Engine<'a> {
        self.resume = Some(resume);
        self
    }

    /// Post-run sampler inspection (tests, table analyses).
    pub fn sampler(&self) -> &dyn Sampler {
        self.sampler.as_ref()
    }

    pub fn into_sampler(self) -> Box<dyn Sampler> {
        self.sampler
    }

    /// Execute the full run.
    pub fn run(&mut self) -> anyhow::Result<TrainResult> {
        if self.cfg.threaded_workers && self.cfg.workers > 1 {
            anyhow::ensure!(
                self.resume.is_none(),
                "resume is not supported in threaded-worker mode \
                 (replica-local state is not captured)"
            );
            anyhow::ensure!(
                self.hook.is_none(),
                "epoch hooks are not supported in threaded-worker mode"
            );
            threaded::run(
                self.cfg,
                self.rt,
                self.data,
                self.sampler.as_mut(),
                self.events.as_deref_mut(),
            )
        } else {
            self.run_sequential()
        }
    }

    /// Single-worker path and the sequential data-parallel simulation.
    fn run_sequential(&mut self) -> anyhow::Result<TrainResult> {
        let cfg = self.cfg;
        // Fresh-start state first; a resume point overrides every piece
        // below. init always runs so backends reset cleanly before the
        // restored params/optimizer state land on top.
        self.rt.init(cfg.seed as i32)?;

        let mut timers = PhaseTimers::new();
        let train_ds = &self.data.train;
        let n = train_ds.n;
        let mut pipeline = StepPipeline::new(train_ds.classes);

        // LR horizon: full-data steps so every method sees the same
        // schedule (pruning shortens the run, not the schedule — matches
        // InfoBatch).
        let total_steps = cfg.epochs * n.div_ceil(cfg.meta_batch);
        let mut step_idx = 0usize;

        let mut loss_curve = Vec::with_capacity(cfg.epochs);
        let mut eval_curve = Vec::new();
        let mut bp_at_eval = Vec::new();

        let mut rng = Pcg64::new(cfg.seed);
        let mut start_epoch = 0usize;
        if let Some(r) = self.resume.take() {
            anyhow::ensure!(
                r.next_epoch <= cfg.epochs,
                "resume epoch {} beyond configured epochs {}",
                r.next_epoch,
                cfg.epochs
            );
            self.rt.set_params(&r.params)?;
            self.rt.set_opt_state(&r.opt_state)?;
            if let Some(state) = &r.sampler_state {
                self.sampler.restore_state(state)?;
            } else {
                anyhow::bail!(
                    "resume point has no sampler state (sampler {:?} does not \
                     support capture)",
                    self.sampler.name()
                );
            }
            rng = Pcg64::from_state(r.rng_state.0, r.rng_state.1);
            pipeline.stats = r.stats;
            pipeline.set_score_ticks(r.score_ticks);
            step_idx = r.step_idx;
            loss_curve = r.loss_curve;
            eval_curve = r.eval_curve;
            bp_at_eval = r.bp_at_eval;
            for (label, secs) in &r.timer_secs {
                timers.add(label, std::time::Duration::from_secs_f64(*secs));
            }
            start_epoch = r.next_epoch;
        }

        let workers = cfg.workers.max(1);

        emit_into(
            &mut self.events,
            Event::RunStart {
                name: cfg.name.clone(),
                sampler: self.sampler.name().to_string(),
                epochs: cfg.epochs,
            },
        );

        for epoch in start_epoch..cfg.epochs {
            // ---- set-level selection -----------------------------------
            let kept =
                timers.time(phase::PRUNE, || self.sampler.on_epoch_start(epoch, &mut rng));
            anyhow::ensure!(!kept.is_empty(), "sampler kept nothing at epoch {epoch}");
            // Floor the kept set at one meta-batch: smaller sets would make
            // the loader's wraparound pad emit duplicate indices inside a
            // single meta-batch (DESIGN.md §8.4). Identity unless a
            // high-prune config actually under-keeps.
            let kept = crate::sampler::enforce_min_keep(kept, cfg.meta_batch, n);
            note_epoch_obs(kept.len(), n);
            emit_into(
                &mut self.events,
                Event::EpochStart { epoch, kept: kept.len(), dataset_n: n },
            );

            let mut epoch_loss_sum = 0.0f64;
            let mut epoch_loss_cnt = 0u64;

            if workers == 1 {
                // The loader is shuffled on this thread (consuming the
                // main RNG exactly as direct iteration would), then
                // streamed through the double-buffered prefetcher so
                // index assembly overlaps the step.
                let loader = EpochLoader::new(&kept, cfg.meta_batch, &mut rng);
                let mut pf = Prefetcher::from_loader(loader, 2);
                while let Some(meta) = pf.next() {
                    let ctx = StepCtx {
                        cfg,
                        train_ds,
                        epoch,
                        lr: cfg.lr.lr_at(step_idx, total_steps) as f32,
                        stream: 0,
                    };
                    let mut route = ObservationRoute::Immediate;
                    let step_mean = pipeline.run_step(
                        &ctx,
                        self.rt,
                        self.sampler.as_mut(),
                        &meta,
                        &mut rng,
                        &mut timers,
                        self.observer.as_deref_mut(),
                        &mut route,
                        self.events.as_deref_mut(),
                    )?;
                    epoch_loss_sum += step_mean;
                    epoch_loss_cnt += 1;
                    step_idx += 1;
                    pf.recycle(meta);
                }
            } else {
                // ---- sequential data-parallel simulation ---------------
                // Shard round-robin; every worker sees a disjoint subset.
                // The effective worker count is floored at kept/B so each
                // shard carries at least one full meta-batch — a shorter
                // shard would wrap around inside a single meta-batch and
                // emit duplicate indices (DESIGN.md §8.4). Identity (same
                // shards, same RNG forks) whenever shards were already
                // ≥ B, so the bit-for-bit pin against the pre-refactor
                // loop holds for every non-degenerate config.
                let eff = workers.min((kept.len() / cfg.meta_batch).max(1));
                let mut loaders: Vec<EpochLoader> = (0..eff)
                    .map(|w| {
                        let shard: Vec<u32> =
                            kept.iter().copied().skip(w).step_by(eff).collect();
                        let mut wrng = rng.fork(0xd15c0 + w as u64);
                        EpochLoader::new(&shard, cfg.meta_batch, &mut wrng)
                    })
                    .collect();
                // Deferred sampler observations (the simulated §D.5 sync).
                let mut sync_buf: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();
                let mut meta_scratch: Vec<u32> = Vec::new();

                'rounds: loop {
                    let mut progressed = false;
                    for (w, loader) in loaders.iter_mut().enumerate() {
                        if !loader.next_batch_into(&mut meta_scratch) {
                            continue;
                        }
                        progressed = true;
                        let ctx = StepCtx {
                            cfg,
                            train_ds,
                            epoch,
                            lr: cfg.lr.lr_at(step_idx, total_steps) as f32,
                            // Per-worker cadence stream: each simulated
                            // worker re-scores every k-th of its own
                            // steps rather than whichever worker the
                            // global stride lands on. (Stream *ownership*
                            // matches the threaded mode; the tick
                            // lifetimes still differ — DESIGN.md §8.2.)
                            stream: w,
                        };
                        let mut route = ObservationRoute::Deferred(&mut sync_buf);
                        let step_mean = pipeline.run_step(
                            &ctx,
                            self.rt,
                            self.sampler.as_mut(),
                            &meta_scratch,
                            &mut rng,
                            &mut timers,
                            self.observer.as_deref_mut(),
                            &mut route,
                            self.events.as_deref_mut(),
                        )?;
                        epoch_loss_sum += step_mean;
                        epoch_loss_cnt += 1;
                        step_idx += 1;
                    }
                    if !progressed {
                        break 'rounds;
                    }
                }

                // ---- simulated score synchronization -------------------
                if !sync_buf.is_empty() {
                    timers.time(phase::SELECT, || {
                        for (idx, losses) in sync_buf.drain(..) {
                            self.sampler.observe_train(&idx, &losses, epoch);
                        }
                    });
                }
                emit_into(&mut self.events, Event::SyncRound { epoch, workers: eff });
            }

            let epoch_mean = if epoch_loss_cnt > 0 {
                epoch_loss_sum / epoch_loss_cnt as f64
            } else {
                f64::NAN
            };
            loss_curve.push(epoch_mean);

            // ---- eval --------------------------------------------------
            let at_eval_point = cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0;
            if at_eval_point || epoch + 1 == cfg.epochs {
                let stats = timers.time(phase::EVAL, || evaluate(self.rt, self.data))?;
                eval_curve.push((epoch, stats.loss, stats.accuracy));
                bp_at_eval.push(pipeline.stats.bp_samples);
                emit_into(
                    &mut self.events,
                    Event::EvalDone {
                        epoch,
                        loss: stats.loss,
                        accuracy: stats.accuracy,
                        bp_samples: pipeline.stats.bp_samples,
                    },
                );
            }
            emit_into(
                &mut self.events,
                Event::EpochEnd { epoch, mean_train_loss: epoch_mean },
            );
            if self.hook.is_some() {
                // Snapshot cost is only paid when a hook is installed, so
                // the plain path stays byte-identical in work and bits.
                let params = self.rt.get_params()?;
                let opt_state = self.rt.get_opt_state()?;
                let snap = RunSnapshot {
                    epoch,
                    step_idx,
                    params: &params,
                    opt_state: &opt_state,
                    rng_state: rng.state(),
                    sampler: self.sampler.as_ref(),
                    stats: &pipeline.stats,
                    score_ticks: pipeline.score_ticks(),
                    loss_curve: &loss_curve,
                    eval_curve: &eval_curve,
                    bp_at_eval: &bp_at_eval,
                    timers: &timers,
                };
                self.hook.as_mut().unwrap().on_epoch_end(&snap)?;
            }
        }

        emit_into(
            &mut self.events,
            Event::RunEnd {
                steps: pipeline.stats.steps,
                accuracy: eval_curve.last().map(|&(_, _, a)| a).unwrap_or(f64::NAN),
            },
        );

        Ok(assemble_result(
            cfg,
            self.sampler.name(),
            self.rt,
            &timers,
            &pipeline.stats,
            loss_curve,
            eval_curve,
            bp_at_eval,
            pipeline.class_bp_counts.clone(),
        ))
    }
}

/// Selection-health gauges at an epoch boundary (DESIGN.md §11): the
/// keep rate and pruned-set size of the epoch now starting, plus a
/// completed-epoch counter. Shared by the sequential and threaded paths.
pub(super) fn note_epoch_obs(kept: usize, dataset_n: usize) {
    if crate::obs::counters_on() {
        let reg = crate::obs::registry();
        reg.counter("engine.epochs").add(1);
        let pct = kept as f64 / dataset_n.max(1) as f64 * 100.0;
        reg.gauge("select.keep_rate_pct").set(pct.round() as i64);
        reg.gauge("select.pruned_size").set(dataset_n.saturating_sub(kept) as i64);
    }
}

/// Shared result assembly across engine modes.
#[allow(clippy::too_many_arguments)]
pub(super) fn assemble_result(
    cfg: &RunConfig,
    sampler_name: &str,
    rt: &mut dyn ModelRuntime,
    timers: &PhaseTimers,
    stats: &StepStats,
    loss_curve: Vec<f64>,
    eval_curve: Vec<(usize, f64, f64)>,
    bp_at_eval: Vec<u64>,
    class_bp_counts: Vec<u64>,
) -> TrainResult {
    let final_eval = eval_curve
        .last()
        .map(|&(_, l, a)| EvalStats { loss: l, accuracy: a })
        .unwrap_or_default();
    let cost = CostSummary::from_run(
        timers,
        stats.fp_samples,
        stats.bp_samples,
        stats.bp_passes,
        rt.flops_per_sample_fwd(),
    )
    .with_fp_passes(stats.fp_passes);
    TrainResult {
        name: cfg.name.clone(),
        sampler: sampler_name.to_string(),
        seed: cfg.seed,
        epochs: cfg.epochs,
        steps: stats.steps,
        loss_curve,
        eval_curve,
        final_eval,
        timers: timers.clone(),
        cost,
        class_bp_counts,
        bp_at_eval,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, LrSchedule, SamplerConfig};
    use crate::runtime::native::NativeRuntime;
    use crate::{data, sampler};
    use std::sync::{Arc, Mutex};

    fn small_cfg(sampler: SamplerConfig) -> RunConfig {
        let mut cfg = RunConfig::new(
            "engine_unit",
            "native",
            DatasetConfig::SynthCifar { n: 128, classes: 4, label_noise: 0.0, hard_frac: 0.2 },
        );
        // 4 epochs so the 5% annealing window leaves active epochs and
        // the scoring-FP stage actually runs.
        cfg.epochs = 4;
        cfg.meta_batch = 32;
        cfg.mini_batch = 8;
        cfg.lr = LrSchedule::Const { lr: 0.02 };
        cfg.test_n = 64;
        cfg.sampler = sampler;
        cfg
    }

    struct Recorder(Arc<Mutex<Vec<Stage>>>);

    impl StageObserver for Recorder {
        fn on_stage(&mut self, stage: Stage, _elapsed: std::time::Duration) {
            self.0.lock().unwrap().push(stage);
        }
    }

    #[test]
    fn observer_sees_all_five_stages() {
        let cfg = small_cfg(SamplerConfig::es_default());
        let split = data::build(&cfg.dataset, cfg.test_n, 1);
        let mut rt = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut engine = Engine::new(&cfg, &mut rt, &split, s)
            .with_observer(Box::new(Recorder(seen.clone())));
        engine.run().unwrap();
        let seen = seen.lock().unwrap();
        for stage in
            [Stage::DataGather, Stage::ScoringFp, Stage::Select, Stage::TrainBp, Stage::Observe]
        {
            assert!(seen.contains(&stage), "stage {stage:?} never observed");
        }
    }

    #[test]
    fn engine_exposes_sampler_after_run() {
        let cfg = small_cfg(SamplerConfig::es_default());
        let split = data::build(&cfg.dataset, cfg.test_n, 2);
        let mut rt = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let mut engine = Engine::new(&cfg, &mut rt, &split, s);
        engine.run().unwrap();
        let es = engine
            .sampler()
            .as_any()
            .downcast_ref::<crate::sampler::evolved::Evolved>()
            .expect("es sampler");
        // Tables moved off the uniform init during training.
        let init = 1.0 / split.train.n as f32;
        assert!(es.weights_table().iter().any(|&w| (w - init).abs() > 1e-6));
    }

    #[test]
    fn hook_capture_then_resume_matches_uninterrupted_run() {
        let cfg = small_cfg(SamplerConfig::es_default());
        let split = data::build(&cfg.dataset, cfg.test_n, 3);

        // Uninterrupted baseline.
        let mut rt_a = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let base = Engine::new(&cfg, &mut rt_a, &split, s).run().unwrap();
        let base_params = rt_a.get_params().unwrap();

        // Same run with a hook capturing resume points mid-run and at the
        // final epoch boundary.
        let captured: Arc<Mutex<Vec<EngineResume>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = captured.clone();
        let last_epoch = cfg.epochs - 1;
        let mut rt_b = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let hooked = Engine::new(&cfg, &mut rt_b, &split, s)
            .with_epoch_hook(Box::new(move |snap: &RunSnapshot<'_>| {
                if snap.epoch == 1 || snap.epoch == last_epoch {
                    sink.lock().unwrap().push(EngineResume::from_snapshot(snap));
                }
                Ok(())
            }))
            .run()
            .unwrap();
        // Snapshotting must not perturb the run itself.
        assert_eq!(base.loss_curve, hooked.loss_curve);
        assert_eq!(base_params, rt_b.get_params().unwrap());

        let mut captured = captured.lock().unwrap();
        assert_eq!(captured.len(), 2);
        let final_point = captured.pop().unwrap();
        let mid_point = captured.pop().unwrap();
        assert_eq!(mid_point.next_epoch, 2);

        // Resuming from epoch 2 must land on the uninterrupted trajectory
        // exactly: curves, counters, and parameters bit-for-bit.
        let mut rt_c = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let resumed =
            Engine::new(&cfg, &mut rt_c, &split, s).resume_from(mid_point).run().unwrap();
        assert_eq!(base.loss_curve, resumed.loss_curve);
        assert_eq!(base.eval_curve, resumed.eval_curve);
        assert_eq!(base.steps, resumed.steps);
        assert_eq!(base.cost.fp_passes, resumed.cost.fp_passes);
        assert_eq!(base.cost.bp_samples, resumed.cost.bp_samples);
        assert_eq!(base_params, rt_c.get_params().unwrap());

        // A resume point at the final epoch boundary replays nothing and
        // still reports the completed run's result.
        assert_eq!(final_point.next_epoch, cfg.epochs);
        let mut rt_d = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let replay =
            Engine::new(&cfg, &mut rt_d, &split, s).resume_from(final_point).run().unwrap();
        assert_eq!(base.loss_curve, replay.loss_curve);
        assert_eq!(base.steps, replay.steps);
        assert_eq!(base_params, rt_d.get_params().unwrap());
    }

    #[test]
    fn resume_without_sampler_state_is_rejected() {
        let cfg = small_cfg(SamplerConfig::es_default());
        let split = data::build(&cfg.dataset, cfg.test_n, 4);
        let captured: Arc<Mutex<Option<EngineResume>>> = Arc::new(Mutex::new(None));
        let sink = captured.clone();
        let mut rt = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        Engine::new(&cfg, &mut rt, &split, s)
            .with_epoch_hook(Box::new(move |snap: &RunSnapshot<'_>| {
                if snap.epoch == 0 {
                    *sink.lock().unwrap() = Some(EngineResume::from_snapshot(snap));
                }
                Ok(())
            }))
            .run()
            .unwrap();
        let mut point = captured.lock().unwrap().take().unwrap();
        point.sampler_state = None;

        let mut rt2 = NativeRuntime::new(split.train.x_len(), 16, 4);
        let s = sampler::build(&cfg.sampler, split.train.n, cfg.epochs).unwrap();
        let err = Engine::new(&cfg, &mut rt2, &split, s)
            .resume_from(point)
            .run()
            .expect_err("resume without sampler state must fail");
        assert!(err.to_string().contains("sampler state"), "unexpected error: {err}");
    }
}
