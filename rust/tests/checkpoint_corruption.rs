//! Corruption corpus for `Checkpoint::load` / `Checkpoint::load_extra`
//! (DESIGN.md §12): every mangled artifact a crash or bad disk can leave
//! behind must surface as a clean `InvalidData` (or plain IO) error —
//! never a panic, and never an allocation sized from a corrupt header.
//!
//! The corpus sweeps:
//! * truncation at EVERY byte boundary of the 16-byte header and at
//!   every word boundary of the payload,
//! * a bit-flip in every header byte (magic / version / param count),
//! * trailing garbage after a valid payload,
//! * structural corruption of the JSON metadata sidecar.

use std::io::ErrorKind;
use std::path::PathBuf;

use evosample::coordinator::checkpoint::Checkpoint;
use evosample::util::json::{num, obj, s, Json};

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("evosample_corrupt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn reference() -> Checkpoint {
    Checkpoint {
        model: "mlp".into(),
        step: 321,
        seed: 9,
        params: (0..24).map(|i| (i as f32) * 0.75 - 4.0).collect(),
    }
}

/// Save the reference checkpoint (with an extra sidecar section, like
/// serve resume does) and return the raw `.ckpt` bytes.
fn saved_bytes(dir: &PathBuf) -> Vec<u8> {
    let extra = obj(vec![("epoch", num(3.0)), ("rng", s("abc123"))]);
    let path = reference().save_with_extra(dir, "ref", &extra).unwrap();
    std::fs::read(path).unwrap()
}

/// Every load of a corrupt artifact must return `Err` — specifically
/// `InvalidData` once the file is readable — and must not panic. The
/// caller passes the mangled bytes; this writes + loads them.
fn assert_invalid(dir: &PathBuf, bytes: &[u8], what: &str) {
    std::fs::write(dir.join("ref.ckpt"), bytes).unwrap();
    match Checkpoint::load(dir, "ref") {
        Ok(_) => panic!("{what}: corrupt checkpoint loaded successfully"),
        Err(e) => assert_eq!(e.kind(), ErrorKind::InvalidData, "{what}: {e}"),
    }
}

#[test]
fn truncation_at_every_header_and_word_boundary_is_invalid_data() {
    let dir = fresh_dir("trunc");
    let good = saved_bytes(&dir);
    assert_eq!(good.len(), 16 + 24 * 4);
    // Every header byte boundary, then payload cuts in stride 4, then
    // a mid-word cut. None may panic; all must be InvalidData.
    let mut cuts: Vec<usize> = (0..=16).collect();
    cuts.extend((17..good.len()).step_by(4));
    cuts.push(good.len() - 2);
    for cut in cuts {
        assert_invalid(&dir, &good[..cut], &format!("truncated to {cut} bytes"));
    }
    // Sanity: the untouched image still loads.
    std::fs::write(dir.join("ref.ckpt"), &good).unwrap();
    assert_eq!(Checkpoint::load(&dir, "ref").unwrap(), reference());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bit_flip_in_every_header_byte_is_invalid_data() {
    let dir = fresh_dir("flip");
    let good = saved_bytes(&dir);
    for byte in 0..16 {
        for bit in 0..8 {
            let mut bad = good.clone();
            bad[byte] ^= 1 << bit;
            // Flipping magic corrupts the tag; flipping version makes an
            // unsupported version; flipping the count mismatches the
            // payload — including high bits that claim exabyte payloads,
            // which must be rejected before any allocation.
            assert_invalid(&dir, &bad, &format!("bit {bit} of header byte {byte} flipped"));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trailing_garbage_is_invalid_data() {
    let dir = fresh_dir("tail");
    let good = saved_bytes(&dir);
    for extra in [1usize, 3, 4, 4096] {
        let mut bad = good.clone();
        bad.resize(good.len() + extra, 0xA5);
        assert_invalid(&dir, &bad, &format!("{extra} trailing bytes"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn empty_and_sub_header_files_are_invalid_data() {
    let dir = fresh_dir("stub");
    let _ = saved_bytes(&dir);
    assert_invalid(&dir, b"", "empty file");
    assert_invalid(&dir, b"EVOS", "magic only");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupting the metadata sidecar must never panic: structural damage
/// errors cleanly out of `load_extra`, while `load` (whose sidecar
/// fields are best-effort) still recovers the binary payload.
#[test]
fn sidecar_corruption_never_panics() {
    let dir = fresh_dir("sidecar");
    let _ = saved_bytes(&dir);
    let sidecar = dir.join("ref.json");
    let good_meta = std::fs::read_to_string(&sidecar).unwrap();

    for (what, text) in [
        ("truncated json", &good_meta[..good_meta.len() / 2]),
        ("not json at all", "]]]]{{{{"),
        ("empty file", ""),
    ] {
        std::fs::write(&sidecar, text).unwrap();
        let err = Checkpoint::load_extra(&dir, "ref").unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData, "{what}: {err}");
        // The binary side is intact: load still returns the params and
        // falls back to defaulted metadata fields.
        let back = Checkpoint::load(&dir, "ref").unwrap();
        assert_eq!(back.params, reference().params, "{what}");
    }

    // Valid JSON of the wrong shape parses; the extra section is simply
    // absent, and the typed fields default rather than panic.
    std::fs::write(&sidecar, "[1,2,3]").unwrap();
    assert_eq!(Checkpoint::load_extra(&dir, "ref").unwrap(), Json::Null);
    let back = Checkpoint::load(&dir, "ref").unwrap();
    assert_eq!(back.model, "");
    assert_eq!(back.step, 0);
    assert_eq!(back.params, reference().params);

    // A missing sidecar is not fatal to load either.
    std::fs::remove_file(&sidecar).unwrap();
    assert_eq!(Checkpoint::load(&dir, "ref").unwrap().params, reference().params);
    let _ = std::fs::remove_dir_all(&dir);
}
