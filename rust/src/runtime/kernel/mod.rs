//! Blocked, multi-threaded CPU kernel layer behind [`super::native`].
//!
//! The naive `NativeRuntime` walked `W1` with stride `hidden` in its
//! inner loops, so the FP/BP cost ratios the perf benches report were
//! dominated by cache misses rather than the algorithmic costs the
//! paper's §3.3 accounting models. This module makes the hot path fast
//! while keeping results **bit-identical across kernel thread counts**:
//!
//! * [`pack`] — the packed parameter layout. `W1` is stored transposed
//!   (`[hidden][in_dim]`) so both the forward dot products and the
//!   backward outer-product accumulation run unit-stride; `b1`, `W2`
//!   (`[hidden][classes]`) and `b2` keep their canonical orientation,
//!   which is already unit-stride for every kernel that touches them.
//!   Packing happens on `set_params`/`init`, unpacking on `get_params` —
//!   the canonical flat layout remains the only interchange format
//!   (checkpoints, §D.5 parameter averaging, the XLA cross-check).
//! * [`gemm`] — cache-blocked micro-kernels: multi-accumulator
//!   unit-stride dots, axpy updates, relu-gated backward rows, and the
//!   fused softmax-CE pass that produces per-sample loss and `dlogits`
//!   from a single max/exp sweep.
//! * [`pool`] — a persistent `std::thread` worker pool, spawned once per
//!   runtime and reused for every step. Work is distributed by batch-row
//!   ranges (forward) and by fixed gradient shards (backward).
//! * [`reference`] — the pre-kernel scalar implementation, kept verbatim
//!   as an executable specification for the equivalence test-suite and
//!   as the baseline the perf benches measure speedups against.
//!
//! # Determinism contract
//!
//! Per-sample forward work is embarrassingly parallel: each row's result
//! is computed by a fixed single-row op sequence, so any row partition
//! yields identical bits. Gradients are accumulated into
//! [`GRAD_SHARDS`] *fixed* row shards — the shard boundaries depend only
//! on the batch size, never on the thread count — and reduced into the
//! final gradient in ascending shard order on one thread. A 1-thread run
//! therefore produces exactly the same bits as an 8-thread run (tested
//! in `tests/kernel_equivalence.rs`).

pub mod gemm;
pub mod pack;
pub mod pool;
pub mod reference;

/// Fixed number of gradient shards. This is the determinism anchor (the
/// reduction tree never changes shape with the thread count) and the
/// useful upper bound on backward parallelism, so auto-detected thread
/// counts are clamped to it.
pub const GRAD_SHARDS: usize = 8;

/// Resolve the default kernel worker count: the
/// `EVOSAMPLE_KERNEL_THREADS` env var when set to a positive integer,
/// otherwise `available_parallelism`, both clamped to [`GRAD_SHARDS`].
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("EVOSAMPLE_KERNEL_THREADS") {
        if let Ok(t) = v.trim().parse::<usize>() {
            if t >= 1 {
                return t.min(GRAD_SHARDS);
            }
        }
    }
    std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(GRAD_SHARDS)
}

/// Contiguous even split of `n` items into `parts`: returns the
/// half-open range assigned to `part`. Ranges are disjoint, cover
/// `0..n`, and extra parts (when `parts > n`) come out empty.
pub fn split_range(n: usize, parts: usize, part: usize) -> (usize, usize) {
    debug_assert!(part < parts.max(1));
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let start = part * base + part.min(rem);
    let len = base + usize::from(part < rem);
    (start, start + len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_range_covers_and_is_disjoint() {
        for n in [0usize, 1, 3, 7, 8, 9, 64, 65] {
            for parts in 1..=9usize {
                let mut covered = 0usize;
                let mut next = 0usize;
                for p in 0..parts {
                    let (a, b) = split_range(n, parts, p);
                    assert_eq!(a, next, "n={n} parts={parts} p={p}");
                    assert!(b >= a);
                    next = b;
                    covered += b - a;
                }
                assert_eq!(next, n);
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn split_range_is_balanced() {
        let sizes: Vec<usize> =
            (0..4).map(|p| { let (a, b) = split_range(10, 4, p); b - a }).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn default_threads_is_positive_and_clamped() {
        let t = default_threads();
        assert!(t >= 1);
        assert!(t <= GRAD_SHARDS);
    }
}
