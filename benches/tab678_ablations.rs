//! Regenerates paper Tables 6, 7 and 8 (ablations).
fn main() {
    let scale = evosample::config::presets::Scale::from_env();
    evosample::experiments::ablations::run_tab6(scale).expect("tab6");
    evosample::experiments::ablations::run_tab7(scale).expect("tab7");
    evosample::experiments::ablations::run_tab8(scale).expect("tab8");
}
